"""Spiking SegNet (the paper's segmentation workload) trained end-to-end
on the synthetic lane dataset — exercising direct coding (OPT1), EConv
(OPT2) economics, and per-pixel spike decoding.

Run: PYTHONPATH=src python examples/segmentation.py [--steps 60]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.data.synthetic import seg_batch
from repro.models import cnn
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--img", type=int, default=32)
    args = ap.parse_args()

    cfg = CNNConfig(name="segnet", layers=cnn.SEGNET_LAYERS, img=args.img,
                    n_classes=2)
    params = cnn.segnet_init(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt = adamw.init(params, opt_cfg)

    def loss_fn(p, imgs, masks):
        logits = cnn.segnet_apply(cfg, p, imgs)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(masks, 2)
        # lane pixels are rare: weight them up
        w = jnp.where(masks == 1, 4.0, 1.0)
        return -jnp.mean(w * jnp.sum(onehot * logp, axis=-1))

    @jax.jit
    def step(p, o, imgs, masks):
        loss, g = jax.value_and_grad(loss_fn)(p, imgs, masks)
        p, o = adamw.update(g, o, p, opt_cfg)
        return p, o, loss

    def iou(p, imgs, masks):
        pred = jnp.argmax(cnn.segnet_apply(cfg, p, imgs), axis=-1)
        inter = jnp.sum((pred == 1) & (masks == 1))
        union = jnp.sum((pred == 1) | (masks == 1))
        return float(inter) / max(float(union), 1.0)

    val = seg_batch(99, 0, 0, 16, img=args.img)
    vi, vm = jnp.asarray(val["image"]), jnp.asarray(val["mask"])
    print(f"initial lane IoU: {iou(params, vi, vm):.3f}")
    for s in range(args.steps):
        b = seg_batch(0, 0, s, args.batch, img=args.img)
        params, opt, loss = step(params, opt, jnp.asarray(b["image"]),
                                 jnp.asarray(b["mask"]))
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:3d} loss {float(loss):.4f}")
    final = iou(params, vi, vm)
    print(f"final lane IoU: {final:.3f}")

    # Event economics on the trained model (Fig. 2 style)
    _, stats = cnn.segnet_apply(cfg, params, vi, collect_stats=True)
    for i, s in enumerate(stats):
        print(f"  layer {i}: sparsity {1 - float(jnp.mean(s)):.2%} "
              f"-> econv does {float(jnp.mean(s)):.2%} of tconv work")


if __name__ == "__main__":
    main()
