"""Long-context decode with SDSA: the paper's Attention Core at 500k tokens.

The assigned `long_500k` shape decodes one token against a 524,288-token
context. With softmax attention that means a multi-GB KV cache per
sequence; with the paper's spike-driven attention the whole cross-token
state is the O(d) status vector, so this demo decodes at position 500k on
a laptop-class CPU — state size independent of context length.

Run: PYTHONPATH=src python examples/long_context.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, SpikingConfig
from repro.launch import steps as steps_mod
from repro.models import lm

CFG = LMConfig(name="long-demo", family="dense", n_layers=4, d_model=256,
               n_heads=8, n_kv_heads=4, d_ff=512, vocab=4096,
               spiking=SpikingConfig(t_steps=2), remat="none",
               loss_chunk=32)

CTX = 524_288


def main():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    sz = lambda st: sum(x.size * x.dtype.itemsize
                        for x in jax.tree.leaves(st))

    # SDSA state: O(d) per layer, independent of the 500k context.
    state = lm.init_decode_state(CFG, b=1, s=CTX, spiking=True)
    print(f"SDSA decode state @ {CTX:,} ctx: {sz(state)/1e3:.1f} KB")
    kv = lm.init_decode_state(CFG, b=1, s=CTX, spiking=False)
    print(f"dense KV cache   @ {CTX:,} ctx: {sz(kv)/1e6:,.0f} MB "
          f"({sz(kv)/sz(state):,.0f}x larger)")

    step = jax.jit(steps_mod.make_serve_step(CFG, spiking=True))
    tok = jnp.array([1], jnp.int32)
    # warm the state with a few "recent" tokens, then decode at pos ~500k
    for i in range(4):
        logits, state = step(params, state, tok, jnp.int32(CTX - 8 + i))
    t0 = time.time()
    n = 32
    for i in range(n):
        logits, state = step(params, state, tok,
                             jnp.int32(CTX - 4 + i % 4))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decoded {n} tokens at ~{CTX:,}-token positions: "
          f"{n/dt:.1f} tok/s on CPU — per-token cost is context-free "
          f"(the OR-status update of Sec. III-C)")


if __name__ == "__main__":
    main()
