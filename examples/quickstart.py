"""Quickstart: the ExSpike stack in 60 lines.

  1. build a spiking LM (LIF + SDSA, binary activations everywhere),
  2. run one forward/backward step,
  3. inspect event sparsity + APEC compression on a real spike tensor,
  4. compare SDSA's O(d) decode state against a dense KV cache.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, SpikingConfig
from repro.core import apec
from repro.core.lif import LIFConfig
from repro.models import lm
from repro.models.layers import lif_fire

cfg = LMConfig(name="quickstart", family="dense", n_layers=4, d_model=128,
               n_heads=8, n_kv_heads=4, d_ff=256, vocab=512,
               spiking=SpikingConfig(t_steps=2), remat="none", loss_chunk=32)

params = lm.init_params(cfg, jax.random.PRNGKey(0))
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"model: {cfg.name}, {n_params/1e6:.2f}M params, T={cfg.spiking.t_steps}")

# --- 1. spiking forward + loss + grads -----------------------------------
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
batch = {"tokens": tokens, "labels": tokens}
loss, grads = jax.value_and_grad(
    lambda p: lm.loss_fn(cfg, p, batch, spiking=True))(params)
print(f"spiking loss {float(loss):.4f}  "
      f"grad norm {float(sum(jnp.sum(g.astype(jnp.float32)**2) for g in jax.tree.leaves(grads)))**0.5:.3f}")

# --- 2. event statistics on a real spike tensor --------------------------
drive = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 64, 128))
spikes = lif_fire(drive, LIFConfig())
sparsity = 1.0 - float(jnp.mean(spikes))
print(f"LIF spikes: binary={bool(jnp.all((spikes==0)|(spikes==1)))}, "
      f"sparsity={sparsity:.2%}")

# --- 3. APEC: compress adjacent-position events (Eq. 1-3) ----------------
flat = spikes.reshape(-1, 128)
st = apec.apec_stats(flat, g=2)
print(f"APEC-2: events {float(st.events_before):.0f} -> "
      f"{float(st.events_after):.0f} "
      f"({float(st.reduction_ratio):.2f}x reduction, exact by linearity)")
w = jax.random.normal(jax.random.PRNGKey(3), (128, 64))
err = jnp.max(jnp.abs(apec.apec_matmul(flat, w, 2) - flat @ w))
print(f"APEC matmul max error vs dense: {float(err):.2e}")

# --- 4. O(d) SDSA decode state vs dense KV cache --------------------------
sz = lambda st_: sum(x.size for x in jax.tree.leaves(st_))
sdsa_state = lm.init_decode_state(cfg, b=1, s=32768, spiking=True)
kv_state = lm.init_decode_state(cfg, b=1, s=32768, spiking=False)
print(f"decode state @32k ctx: SDSA={sz(sdsa_state)/1e3:.1f}K elems, "
      f"dense KV cache={sz(kv_state)/1e6:.1f}M elems "
      f"({sz(kv_state)/sz(sdsa_state):.0f}x larger)")
