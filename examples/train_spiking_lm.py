"""End-to-end driver: train a ~100M-param spiking LM for a few hundred
steps on the synthetic Markov corpus, with rolling checkpoints, straggler
monitoring, and a mid-run restart to demonstrate fault-tolerant resume.

Run: PYTHONPATH=src python examples/train_spiking_lm.py [--steps 300]
(≈100M params is slow on 1 CPU core; --small trains a 12M variant.)
"""
import argparse
import os
import shutil

from repro.configs.base import LMConfig, SpikingConfig
from repro.launch.train import train_loop

LM_100M = LMConfig(
    name="spiking-lm-100m", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=1536, vocab=32000,
    spiking=SpikingConfig(t_steps=2), remat="none", loss_chunk=64)

LM_SMALL = LM_100M.replace(name="spiking-lm-12m", n_layers=6, d_model=256,
                           d_ff=768, vocab=8000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/exspike_lm_ckpt")
    args = ap.parse_args()

    cfg = LM_SMALL if args.small else LM_100M
    import jax
    from repro.models import lm
    n = lm.param_count(cfg)
    print(f"=== training {cfg.name}: {n/1e6:.0f}M params, spiking "
          f"(LIF tau=0.5, SDSA attention, T={cfg.spiking.t_steps}) ===")
    # No EXSPIKE_BACKEND pin: every registry backend is differentiable
    # (surrogate-gradient VJPs), so training resolves kernels per platform
    # exactly like serving does.
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # Phase 1: train to 60% of budget, checkpointing every 25 steps.
    split = int(args.steps * 0.6)
    out1 = train_loop(cfg, steps=split, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, save_every=25, lr=1e-3,
                      log_every=25)
    print(f"--- phase 1 done at loss {out1['final_loss']:.4f}; simulating "
          f"a node failure + restart ---")

    # Phase 2: fresh process state, auto-resume from the latest checkpoint.
    out2 = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, save_every=25, resume=True,
                      lr=1e-3, log_every=25)
    first = out1["losses"][0]
    last = out2["final_loss"]
    print(f"=== done: loss {first:.4f} -> {last:.4f} over {args.steps} "
          f"steps (resumed across restart) ===")
    assert last < first, "loss should decrease end-to-end"


if __name__ == "__main__":
    main()
