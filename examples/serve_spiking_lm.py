"""Batched serving demo: continuous-batching decode over slot state.

Compares the two serving modes the dry-run exercises:
  * spiking (SDSA) — O(d) per-slot state, constant-memory long contexts;
  * dense baseline — real KV cache, the decode_32k regime.

Run: PYTHONPATH=src python examples/serve_spiking_lm.py
"""
import time

import numpy as np

from repro.configs.base import LMConfig, SpikingConfig
from repro.launch.serve import Request, Server

CFG = LMConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
               n_heads=8, n_kv_heads=4, d_ff=512, vocab=4096,
               spiking=SpikingConfig(t_steps=2), remat="none",
               loss_chunk=32)


def drive(spiking: bool, label: str):
    server = Server(CFG, n_slots=4, max_seq=128, spiking=spiking)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, CFG.vocab, 12)),
                    max_new=24) for i in range(10)]
    t0 = time.time()
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in reqs)
    import jax
    state_elems = sum(x.size for x in jax.tree.leaves(server.state))
    print(f"[{label}] {len(reqs)} reqs x 24 new tokens: {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s), decode state "
          f"{state_elems/1e6:.2f}M elems")
    return reqs


if __name__ == "__main__":
    a = drive(spiking=True, label="spiking SDSA (O(d) state)")
    b = drive(spiking=False, label="dense GQA  (KV cache)  ")
    print("sample generations (spiking):",
          [r.generated[:6] for r in a[:2]])
