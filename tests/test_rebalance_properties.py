"""Property tests for occupancy-weighted shard rebalancing + DMA ledger.

`rebalance_shard_plan` decides which payload tile rows each shard
computes; a wrong permutation silently computes the wrong rows or loses
some entirely, so the invariants are pinned as properties over random
maps (with deterministic fallbacks per `hypothesis_compat`):

  * the plan's `perm` is a permutation — every tile row (hence every
    occupied tile) lands on exactly one shard, none dropped;
  * pre/post per-shard counts conserve the total occupied-tile count,
    and the rebalanced max never exceeds the static max;
  * the plan is deterministic for a fixed map (split points are a pure
    function of the carried occupancy);
  * plan-aware `shard_occupancy_to_csr` still hands every shard a work
    list satisfying the full TileCSR invariants against its ASSIGNED
    rows, under ONE shared `pow2_step_cap`;
  * the all-empty map degenerates to identity (nothing to move) and
    dummy-step-only per-shard grids.

The DMA-overlap ledger (`costmodel.dma_overlap_ledger`) is the cost
model the pipelined kernels' benchmark columns are read against, so its
accounting identities are pinned here too.
"""
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, st  # noqa: E402
from test_csr_properties import check_csr_invariants  # noqa: E402

from repro.core.costmodel import dma_overlap_ledger
from repro.core.spikes import (pow2_step_cap, rebalance_shard_plan,
                               shard_occupancy_to_csr)


def _random_map(shards: int, rows: int, kt: int, seed: int,
                density: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return ((rng.random((shards * rows, kt)) < density)
            * rng.integers(1, 9, (shards * rows, kt))).astype(np.int32)


# ------------------------------------------------------- hypothesis side
@given(st.integers(1, 6), st.integers(1, 5), st.integers(1, 4),
       st.integers(0, 2 ** 30), st.floats(0.0, 1.0))
def test_plan_is_permutation_and_conserves_tiles(shards, rows, kt, seed,
                                                 density):
    occ_np = _random_map(shards, rows, kt, seed, density)
    plan = rebalance_shard_plan(jnp.asarray(occ_np), shards)
    mt = shards * rows
    # every tile row on exactly one shard
    assert sorted(plan.perm.tolist()) == list(range(mt))
    np.testing.assert_array_equal(plan.perm[plan.inverse()], np.arange(mt))
    # occupied tiles conserved and never made worse
    total = int((occ_np > 0).sum())
    assert sum(plan.pre_per_shard) == sum(plan.post_per_shard) == total
    assert max(plan.post_per_shard) <= max(plan.pre_per_shard)
    # per-shard slices keep global row order (ascending members)
    for i in range(shards):
        sl = plan.perm[i * rows:(i + 1) * rows]
        assert np.all(np.diff(sl) > 0)
        # post counts actually describe the assignment
        assert plan.post_per_shard[i] == int((occ_np[sl] > 0).sum())


@given(st.integers(1, 5), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 2 ** 30), st.floats(0.0, 1.0))
def test_plan_deterministic_split_points(shards, rows, kt, seed, density):
    occ_np = _random_map(shards, rows, kt, seed, density)
    a = rebalance_shard_plan(jnp.asarray(occ_np), shards)
    b = rebalance_shard_plan(jnp.asarray(occ_np.copy()), shards)
    np.testing.assert_array_equal(a.perm, b.perm)
    assert a.pre_per_shard == b.pre_per_shard
    assert a.post_per_shard == b.post_per_shard


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2 ** 30))
def test_plan_aware_shard_csr_shares_cap_and_holds_invariants(shards, rows,
                                                              seed):
    kt = 3
    occ_np = _random_map(shards, rows, kt, seed, 0.4)
    plan = rebalance_shard_plan(jnp.asarray(occ_np), shards)
    per = shard_occupancy_to_csr(jnp.asarray(occ_np), shards, plan=plan)
    assert len(per) == shards
    caps = {c.n_steps for c in per}
    assert len(caps) == 1, "shards must share one cap"
    cap = caps.pop()
    assert cap <= rows * kt
    assert cap == pow2_step_cap(cap, rows * kt)    # pow2 or dense-bounded
    for i, csr in enumerate(per):
        local = occ_np[plan.perm[i * rows:(i + 1) * rows]]
        check_csr_invariants(local, csr, cap=cap)


# ----------------------------------------------- deterministic fallbacks
def test_empty_map_identity_plan_and_dummy_grids():
    occ_np = np.zeros((8, 3), np.int32)
    plan = rebalance_shard_plan(jnp.asarray(occ_np), 4)
    assert plan.identity and not plan.improves
    assert plan.pre_per_shard == plan.post_per_shard == (0, 0, 0, 0)
    per = shard_occupancy_to_csr(jnp.asarray(occ_np), 4, plan=plan)
    for csr in per:
        # one dummy visit per all-empty tile row, nothing else
        check_csr_invariants(occ_np[:2], csr)
        assert int(np.asarray(csr.valid).sum()) == 2
        assert int(np.asarray(csr.occ).sum()) == 0


def test_hotspot_band_improves_and_default_split_unchanged():
    # all load on the first two tile rows: static split gives (5, 2, 0, 0)
    occ_np = np.array([[1, 1, 1], [1, 1, 0], [0, 1, 0], [0, 1, 0],
                       [0, 0, 0], [0, 0, 0], [0, 0, 0], [0, 0, 0]],
                      np.int32)
    plan = rebalance_shard_plan(jnp.asarray(occ_np), 4)
    assert plan.pre_per_shard == (5, 2, 0, 0)
    assert max(plan.post_per_shard) < 5 and plan.improves
    # plan=None keeps the historical static row-contiguous behavior
    static = shard_occupancy_to_csr(jnp.asarray(occ_np), 4)
    for i, csr in enumerate(static):
        check_csr_invariants(occ_np[2 * i:2 * i + 2], csr,
                             cap=csr.n_steps)


def test_one_row_per_shard_cannot_improve():
    # rps == 1: permuting tile rows only relabels shards
    occ_np = np.array([[3, 3], [0, 0], [0, 0], [0, 0]], np.int32)
    plan = rebalance_shard_plan(jnp.asarray(occ_np), 4)
    assert not plan.improves
    assert max(plan.post_per_shard) == max(plan.pre_per_shard) == 2


def test_plan_rejects_tracers_uneven_rows_and_mismatched_use():
    import jax
    with pytest.raises(ValueError, match="divisible"):
        rebalance_shard_plan(jnp.zeros((3, 2), jnp.int32), 2)
    with pytest.raises(ValueError, match="eager|tracing"):
        jax.jit(lambda o: rebalance_shard_plan(o, 2))(
            jnp.zeros((4, 2), jnp.int32))
    plan = rebalance_shard_plan(jnp.zeros((4, 2), jnp.int32), 2)
    with pytest.raises(ValueError, match="plan covers"):
        shard_occupancy_to_csr(jnp.zeros((8, 2), jnp.int32), 2, plan=plan)


# ------------------------------------------------------ DMA-overlap ledger
@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 2 ** 30),
       st.floats(0.0, 1.0), st.integers(1, 512))
def test_dma_ledger_accounting_identities(mt, kt, seed, density, n):
    occ_np = ((np.random.default_rng(seed).random((mt, kt)) < density)
              .astype(np.int32))
    for backend in ("pallas-csr", "packed-csr"):
        ser = dma_overlap_ledger(occ_np, n, backend=backend)
        pipe = dma_overlap_ledger(occ_np, n, backend=backend,
                                  pipelined=True)
        # split always sums to the total; serial hides nothing
        assert ser.bytes_prefetched == 0.0
        assert ser.bytes_prefetched + ser.bytes_stalled == ser.bytes_total
        assert pipe.bytes_prefetched + pipe.bytes_stalled \
            == pipe.bytes_total
        # pipelining never fetches more, never exposes more
        assert pipe.bytes_total <= ser.bytes_total
        assert pipe.bytes_stalled <= ser.bytes_stalled
        assert 0.0 <= pipe.overlap_fraction <= 1.0


def test_dma_ledger_deterministic_points():
    occ = np.zeros((4, 4), np.int32)
    occ[0, :2] = 1
    occ[2, 1] = 3
    # 3 occupied tiles + 2 all-empty rows, N=256 -> 2 N-tiles
    ser = dma_overlap_ledger(occ, 256)
    pipe = dma_overlap_ledger(occ, 256, pipelined=True)
    tile = 128 * 128 * 4
    assert ser.bytes_total == ser.bytes_stalled == 10 * tile
    assert pipe.bytes_total == 6 * tile
    assert pipe.bytes_stalled == 2 * tile        # one warm-up per N-tile
    assert pipe.bytes_prefetched == 4 * tile
    # empty map: pipelined grid is dummy-only, so it fetches NOTHING
    empty = dma_overlap_ledger(np.zeros((4, 4), np.int32), 256,
                               pipelined=True)
    assert empty.bytes_total == empty.overlap_fraction == 0.0
    with pytest.raises(ValueError, match="csr family"):
        dma_overlap_ledger(occ, 256, backend="pallas", pipelined=True)
    with pytest.raises(ValueError, match="unknown"):
        dma_overlap_ledger(occ, 256, backend="nope")


def test_have_hypothesis_flag_is_bool():
    assert isinstance(HAVE_HYPOTHESIS, bool)


# ------------------------------------------------- sharded composition
def test_rebalanced_pipe_sharded_parity(multidevice_run):
    """Pipelined CSR kernel + rebalanced shard split composed on an
    8-device mesh: attribution, pre/post imbalance drop, fwd and both
    grads at 1e-5 (shared subprocess; see conftest.multidevice_run)."""
    multidevice_run.check("REBALANCE_PIPE")
