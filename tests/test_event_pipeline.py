"""Full-event forward pipeline: EventTensor carrier invariants, consumer
pass-throughs, and the jaxpr-level proof that the fused model forwards run
ZERO standalone dense occupancy reductions between spiking layers.

The jaxpr detector looks for the `tile_occupancy` signature — a reduce_sum
eliminating a whole (tile_m x tile_k) block of a spike-sized tensor
(reduced-size product >= 4096; the fused LIF emission's count-map
aggregation reduces 16-element chunks and every norm/head reduction in
these models is far smaller, so the signature is unambiguous).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spikes as spikes_mod
from repro.core.events import (EventTensor, conv_patch_occupancy,
                               max_pool_events)
from repro.kernels import dispatch, ops

ATOL = 1e-5


def _clustered(key, m, k, density=0.05):
    return (jax.random.uniform(key, (m, k)) < density).astype(jnp.float32)


# ------------------------------------------------------ carrier invariants
def test_event_tensor_pytree_roundtrip_and_jit():
    s = _clustered(jax.random.PRNGKey(0), 256, 128)
    et = EventTensor.from_spikes(s)
    leaves, treedef = jax.tree.flatten(et)
    et2 = jax.tree.unflatten(treedef, leaves)
    assert isinstance(et2, EventTensor) and et2.tiling == (128, 128)

    @jax.jit
    def through(e):
        return e.reshape(2, 128, 128)

    out = through(et)
    assert isinstance(out, EventTensor)
    assert out.occupancy is not None          # trailing axis preserved
    np.testing.assert_array_equal(np.asarray(out.spikes),
                                  np.asarray(s.reshape(2, 128, 128)))


def test_reshape_rule_preserves_or_drops_map():
    et = EventTensor.from_spikes(_clustered(jax.random.PRNGKey(1), 256, 128))
    assert et.reshape(4, 64, 128).occupancy is not None   # last axis kept
    assert et.reshape(256 * 128).occupancy is None        # flattened: drop
    assert et.reshape(256, 2, 64).occupancy is None       # axis split: drop


def test_wrong_tiling_rejected_loudly():
    et = EventTensor.from_spikes(_clustered(jax.random.PRNGKey(2), 256, 128))
    with pytest.raises(ValueError, match="tiling"):
        et.occupancy_for(64, 64)
    with pytest.raises(ValueError, match="does not cover"):
        EventTensor(et.spikes, jnp.zeros((7, 7), jnp.int32))
    # a map whose grid mismatches the consumer's padded tiling must raise
    with pytest.raises(ValueError, match="does not match"):
        ops.spike_matmul_csr(et.spikes[:128], et.spikes.reshape(-1, 128).T
                             [:128, :64], occupancy=et.occupancy)


def test_fused_emission_matches_rederived_map():
    """The producer's map (lif_scan_occ, any backend) must equal the
    consumer's re-derivation exactly — counts, not just support."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, 200)) * 2.0
    for be in ("ref", "pallas-interpret"):
        s, occ, chunks = dispatch.call_backend("lif_scan_occ", be, x)
        np.testing.assert_array_equal(np.asarray(occ),
                                      np.asarray(ops.padded_occupancy(s)))
        np.testing.assert_array_equal(
            np.asarray(occ),
            np.asarray(chunks).reshape(-1, 16, occ.shape[1]).sum(axis=1))


# ------------------------------------------------- consumer pass-throughs
def test_spike_matmul_csr_accepts_occupancy_without_csr():
    """Satellite: a caller holding the map but no work list must not pay a
    second dense pre-pass — the compaction runs on the tiny map alone."""
    s = _clustered(jax.random.PRNGKey(4), 256, 256)
    w = jax.random.normal(jax.random.PRNGKey(5), (256, 64))
    occ = ops.padded_occupancy(s)
    with spikes_mod.watch_occupancy_prepasses() as rec:
        out = ops.spike_matmul_csr(s, w, occupancy=occ)
    assert rec["calls"] == 0, rec
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)
    with spikes_mod.watch_occupancy_prepasses() as rec2:
        out2 = ops.apec_matmul_csr(s, w, g=2, occupancy=occ)
    assert rec2["calls"] == 0, rec2
    np.testing.assert_allclose(np.asarray(out2), np.asarray(s @ w),
                               atol=ATOL)


def test_apec_matmul_accepts_decomposed_operands_and_maps():
    """Satellite: the predicated path aligns with the CSR path — a caller
    that already decomposed passes (residual, overlap) + occupancies and
    no fresh per-operand pre-pass runs."""
    s = _clustered(jax.random.PRNGKey(6), 256, 128, density=0.2)
    w = jax.random.normal(jax.random.PRNGKey(7), (128, 64))
    ov, res = ops.apec_decompose(s, 2)
    occ_res = ops.padded_occupancy(res)
    occ_ov = ops.padded_occupancy(ov)
    with spikes_mod.watch_occupancy_prepasses() as rec:
        out = ops.apec_matmul(s, w, g=2, decomposed=(res, ov),
                              occ_res=occ_res, occ_ov=occ_ov)
    assert rec["calls"] == 0, rec
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=1e-4)
    # carried map of the undecomposed spikes serves both operands too
    et = EventTensor.from_spikes(s)
    with spikes_mod.watch_occupancy_prepasses() as rec2:
        out2 = ops.apec_matmul(et, w, g=2)
    assert rec2["calls"] == 0, rec2
    np.testing.assert_allclose(np.asarray(out2), np.asarray(s @ w),
                               atol=1e-4)


@pytest.mark.parametrize("h,w,k,stride,padding", [
    (7, 7, 3, 2, "SAME"),        # non-divisible H/W: ho = ceil(7/2) = 4
    (9, 9, 3, 2, "SAME"),
    (15, 15, 3, 2, "SAME"),
    (7, 7, 2, 2, "VALID"),       # pooling analog
])
def test_window_occupancy_edge_parity_nondivisible(h, w, k, stride, padding):
    """Boundary dilation with stride > 1 on non-divisible H/W: the numpy
    fast path and the traced path must agree exactly, and neither may
    mark an out-of-image chunk occupied when the straddling window's
    in-image half is empty (the old symmetric halo over-dilated backward
    past the image start)."""
    c = 32
    key = jax.random.PRNGKey(h * 31 + stride)
    sp = (jax.random.uniform(key, (2, h, w, c)) < 0.05).astype(jnp.float32)
    # image 0 fully empty; image 1 events only in the top-left quadrant,
    # so every bottom/right edge window straddles into empty territory
    sp = sp.at[0].set(0.0).at[1, h // 2:].set(0.0).at[1, :, w // 2:].set(0.0)
    et = EventTensor.from_spikes(sp)
    occ_np = conv_patch_occupancy(et, (k, k, c, c), stride, padding)
    occ_tr = jax.jit(lambda e: conv_patch_occupancy(
        e, (k, k, c, c), stride, padding))(et)
    np.testing.assert_array_equal(np.asarray(occ_np), np.asarray(occ_tr))
    patches = jax.lax.conv_general_dilated_patches(
        sp, (k, k), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    true_occ = np.asarray(ops.padded_occupancy(
        patches.reshape(-1, patches.shape[-1])))
    # conservative: never marks a truly occupied tile empty
    assert bool(np.all((true_occ == 0) | (np.asarray(occ_np) > 0)))


def test_window_occupancy_empty_image_stays_empty_under_stride():
    """Chunk-aligned geometry (8x8 images: 64 input rows per image divide
    the 8-row chunks exactly): an all-empty image must contribute ZERO
    occupied output chunks under strided windows, even with a fully dense
    neighbor image — the edge clamp must not bleed across the boundary."""
    from repro.core.events import window_occupancy
    n, h, w, c = 2, 8, 8, 128
    sp = jnp.zeros((n, h, w, c), jnp.float32).at[1].set(1.0)
    et = EventTensor.from_spikes(sp)
    occ, chunks = window_occupancy(et, (2, 2), 2, (4, 4), c)
    ch = np.asarray(chunks)
    # image 0 owns output rows 0..15 = chunks 0..1: all empty
    assert int(ch[:2].sum()) == 0, ch[:, 0]
    assert int(ch[2:4].sum()) > 0        # image 1's chunks are live
    sp = (jax.random.uniform(jax.random.PRNGKey(8), (2, 16, 16, 32)) < 0.02
          ).astype(jnp.float32).at[0].set(0.0)
    et = EventTensor.from_spikes(sp)
    w = jax.random.normal(jax.random.PRNGKey(9), (3, 3, 32, 8))
    occ_p = conv_patch_occupancy(et, w.shape, 1, "SAME")
    patches = jax.lax.conv_general_dilated_patches(
        sp, (3, 3), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    true_occ = np.asarray(ops.padded_occupancy(
        patches.reshape(2 * 16 * 16, -1)))
    assert occ_p.shape == true_occ.shape
    # conservative: never marks an occupied tile empty
    assert bool(np.all((true_occ == 0) | (np.asarray(occ_p) > 0)))
    # useful: the empty image's tiles stay empty in the propagated map
    assert int((np.asarray(occ_p) == 0).sum()) > 0
    pooled = max_pool_events(et, 2)
    true_pool = np.asarray(ops.padded_occupancy(
        pooled.spikes.reshape(-1, 32)))
    assert bool(np.all((true_pool == 0) | (np.asarray(pooled.occupancy) > 0)))


# ------------------------------------------- jaxpr: zero dense pre-passes
def _dense_occ_reductions(jaxpr, min_reduced=4096):
    """Count reduce_sum eqns eliminating >= `min_reduced` elements — the
    dense `tile_occupancy` signature — recursively through sub-jaxprs."""
    found = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "reduce_sum":
                axes = eqn.params.get("axes", ())
                shape = eqn.invars[0].aval.shape
                red = int(np.prod([shape[a] for a in axes])) if axes else 1
                if red >= min_reduced:
                    found.append((shape, axes))
            for v in eqn.params.values():
                for sub in jax.tree.leaves(
                        v, is_leaf=lambda x: isinstance(
                            x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        walk(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        walk(sub)
    walk(jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr)
    return found


def test_detector_flags_the_rederive_path():
    """Positive control: the standalone pre-pass IS the signature."""
    s = _clustered(jax.random.PRNGKey(10), 256, 128)
    w = jax.random.normal(jax.random.PRNGKey(11), (128, 64))
    jx = jax.make_jaxpr(lambda sv: ops.spike_matmul(sv, w))(s)
    assert len(_dense_occ_reductions(jx)) >= 1


def _fused_overrides():
    return (dispatch.use_backend("pallas-interpret", op="lif_scan_occ"),
            dispatch.use_backend("pallas-csr-interpret", op="spike_matmul"),
            dispatch.use_backend("pallas-csr-interpret", op="econv"))


def test_fused_spikingformer_forward_has_zero_dense_occ_reductions():
    """The tentpole's proof: with the event backends live, a whole-network
    spikingformer trace re-derives occupancy from a dense activation
    exactly zero times — every consumer runs off carried/propagated maps
    emitted by the fused LIF. Asserted at BOTH levels from one trace:
    the jaxpr contains no dense-reduction signature, and the trace-time
    watcher recorded zero `tile_occupancy` calls."""
    from repro.configs.base import SpikingConfig
    from repro.models import spikingformer
    params = spikingformer.spikingformer_init(jax.random.PRNGKey(0),
                                              depth=1, dim=32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 32, 32, 3))
    o1, o2, o3 = _fused_overrides()
    with warnings.catch_warnings(), o1, o2, o3:
        warnings.simplefilter("ignore", RuntimeWarning)
        with spikes_mod.watch_occupancy_prepasses() as rec:
            jx = jax.make_jaxpr(lambda xx: spikingformer.spikingformer_apply(
                params, xx, n_heads=4,
                spiking_cfg=SpikingConfig(t_steps=2)))(x)
    flagged = _dense_occ_reductions(jx)
    assert flagged == [], flagged
    assert rec["calls"] == 0, rec


def test_fused_vgg11_forward_rederives_only_at_the_coded_input():
    """CNN family: every spike-fed conv consumes a carried/propagated map.
    The single allowed re-derivation is the direct-coded INPUT conv
    (OPT1): its drive is multi-bit, produced by no spiking layer — i.e.
    zero standalone reductions BETWEEN spiking layers."""
    from repro.configs.base import CNNConfig, SpikingConfig
    from repro.models import cnn
    cfg = CNNConfig(name="vgg11", layers=cnn.VGG11_LAYERS,
                    spiking=SpikingConfig(t_steps=1))
    p = cnn.vgg11_init(cfg, jax.random.PRNGKey(0))
    # batch 2: every layer's B*H*W fills 8-row chunks (down to the 2x2
    # tail convs), so the fused emission holds end to end — at batch 1
    # the tail layers' producers fall back to ref emission, the
    # documented lif_scan_occ degrade.
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    o1, o2, o3 = _fused_overrides()
    with warnings.catch_warnings(), o1, o2, o3:
        warnings.simplefilter("ignore", RuntimeWarning)
        jx = jax.make_jaxpr(
            lambda xx: cnn.vgg11_apply(cfg, p, xx))(x)
    flagged = _dense_occ_reductions(jx)
    assert len(flagged) <= 1, flagged


# ----------------------------------------------------- sharded EventTensor
def test_event_tensor_sharded_parity(multidevice_run):
    """8-way shard_map parity vs single device at 1e-5, carried-occupancy
    routing asserted — runs in the shared multi-device subprocess."""
    multidevice_run.check("EVENT_TENSOR")
