"""Optional-hypothesis shim: property tests skip (not error) offline.

Usage in test modules:

    from hypothesis_compat import HAVE_HYPOTHESIS, given, st

When `hypothesis` is installed this re-exports the real `given` /
`strategies`. When it's absent (the offline CI image), `given` replaces
the test with a zero-arg function that calls `pytest.skip`, so collection
succeeds and the deterministic tests in the same module still run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for `hypothesis.strategies`: every strategy factory
        returns a placeholder; values are never drawn because the test
        body is replaced with a skip."""

        def __getattr__(self, name):
            def factory(*args, **kwargs):
                return f"<unavailable strategy {name}>"
            return factory

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco
