"""APEC properties (Sec. III-A2): exactness for any spike tensor, Eq. 1-4."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, st

from repro.core import apec


def _spike_tensor(seed, p_positions, channels, density):
    key = jax.random.PRNGKey(seed)
    return (jax.random.uniform(key, (p_positions, channels))
            < density).astype(jnp.float32)


@given(seed=st.integers(0, 2**16), g=st.sampled_from([2, 4, 8]),
       density=st.floats(0.05, 0.95))
def test_apec_matmul_exact(seed, g, density):
    """Eq. 1 decomposition preserves the accumulation exactly — the paper's
    central correctness claim ('APEC preserves numerical equivalence')."""
    s = _spike_tensor(seed, 16, 24, density)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (24, 12))
    np.testing.assert_allclose(apec.apec_matmul(s, w, g), s @ w,
                               atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 2**16), g=st.sampled_from([2, 4]))
def test_apec_decompose_disjoint_and_reconstructs(seed, g):
    s = _spike_tensor(seed, 8, 32, 0.4)
    overlap, residual = apec.apec_decompose(s, g)
    # overlap AND residual_i == 0 (disjointness, Fig. 5)
    assert float(jnp.sum(overlap[..., None, :] * residual)) == 0.0
    np.testing.assert_array_equal(apec.apec_reconstruct(overlap, residual), s)


@given(seed=st.integers(0, 2**16))
def test_apec_eliminated_events_eq2(seed):
    """dN = (g-1)|O_G| and events_after + dN == events_before."""
    g = 2
    s = _spike_tensor(seed, 32, 16, 0.5)
    stats = apec.apec_stats(s, g)
    assert float(stats.events_before) == float(
        stats.events_after + stats.eliminated)
    overlap, _ = apec.apec_decompose(s, g)
    assert float(stats.eliminated) == (g - 1) * float(jnp.sum(overlap))


def test_apec_eq3_accumulation_savings():
    # Paper's concrete Fig. 5 example: 14 -> 8 events, 3x3 conv, 64 channels
    # eliminates 6*64*9 = 3456 accumulations.
    s1 = jnp.zeros((2, 16)).at[0, :10].set(1.0).at[1, 2:12].set(1.0)
    stats = apec.apec_stats(s1, 2)
    assert float(stats.events_before) == 20.0
    overlap = float(jnp.sum(jnp.min(s1.reshape(1, 2, 16), axis=1)))
    assert float(stats.eliminated) == overlap
    savings = stats.accum_savings(co=64, k=3)
    assert float(savings) == overlap * 64 * 9


def test_apec_overlap_decays_with_group_size():
    """|O_G| shrinks with g (the paper's inset observation) for smooth maps."""
    key = jax.random.PRNGKey(0)
    base = (jax.random.uniform(key, (128, 1, 64)) < 0.5)
    # spatially correlated spikes: adjacent positions share base pattern
    s = jnp.repeat(base, 8, axis=1).reshape(1024, 64).astype(jnp.float32)
    noise = (jax.random.uniform(jax.random.PRNGKey(1), s.shape) < 0.1)
    s = jnp.clip(s + noise, 0, 1)
    o2 = float(apec.apec_stats(s, 2).overlap_mean)
    o4 = float(apec.apec_stats(s, 4).overlap_mean)
    o8 = float(apec.apec_stats(s, 8).overlap_mean)
    assert o2 >= o4 >= o8


def test_apec_overhead_eq4():
    assert apec.apec_overhead_bits(64, 3, 16) == 64 * 9 * 16


def test_apec_spatial_grouping():
    s = (jax.random.uniform(jax.random.PRNGKey(2), (2, 4, 8, 16))
         < 0.3).astype(jnp.float32)
    overlap, residual = apec.apec_spatial(s, 2)
    assert overlap.shape == (2, 4, 4, 16)
    assert residual.shape == (2, 4, 4, 2, 16)


# ---------------------------------------------------------------------------
# Deterministic edge cases: keep the APEC invariants covered even when the
# hypothesis property tests above skip (offline image).
# ---------------------------------------------------------------------------
import pytest  # noqa: E402


@pytest.mark.parametrize("g", [2, 4, 8])
def test_apec_all_zeros(g):
    s = jnp.zeros((16, 24), jnp.float32)
    overlap, residual = apec.apec_decompose(s, g)
    assert float(jnp.sum(overlap)) == 0.0 and float(jnp.sum(residual)) == 0.0
    w = jnp.ones((24, 4))
    np.testing.assert_array_equal(apec.apec_matmul_jnp(s, w, g),
                                  jnp.zeros((16, 4)))
    stats = apec.apec_stats(s, g)
    assert float(stats.events_before) == 0.0
    assert float(stats.eliminated) == 0.0
    assert float(stats.groups_with_overlap) == 0.0


@pytest.mark.parametrize("g", [2, 4])
def test_apec_all_ones_maximal_overlap(g):
    p, c = 16, 8
    s = jnp.ones((p, c), jnp.float32)
    overlap, residual = apec.apec_decompose(s, g)
    np.testing.assert_array_equal(overlap, jnp.ones((p // g, c)))
    np.testing.assert_array_equal(residual, jnp.zeros((p // g, g, c)))
    stats = apec.apec_stats(s, g)
    # Eq. 2 at saturation: every group eliminates (g-1)*C accumulations
    assert float(stats.eliminated) == (g - 1) * (p // g) * c
    np.testing.assert_array_equal(apec.apec_reconstruct(overlap, residual), s)


@pytest.mark.parametrize("fn", ["decompose", "matmul", "group"])
def test_apec_indivisible_group_raises(fn):
    s = jnp.ones((10, 8), jnp.float32)   # 10 positions, g=3 does not divide
    with pytest.raises(ValueError, match="not divisible"):
        if fn == "decompose":
            apec.apec_decompose(s, 3)
        elif fn == "matmul":
            apec.apec_matmul_jnp(s, jnp.ones((8, 4)), 3)
        else:
            apec.group_adjacent(s, 3)


def test_apec_spatial_indivisible_width_raises():
    with pytest.raises(ValueError, match="not divisible"):
        apec.apec_spatial(jnp.ones((1, 4, 6, 8)), 4)


@pytest.mark.parametrize("g", [2, 4, 8])
def test_apec_matmul_exact_deterministic(g):
    """Exactness vs s @ w on a fixed worst-ish pattern (mixed overlap:
    full groups, empty groups, partial residuals)."""
    s = (jax.random.uniform(jax.random.PRNGKey(11), (32, 48)) < 0.5
         ).astype(jnp.float32)
    s = s.at[:8].set(1.0).at[8:16].set(0.0)     # saturated + empty groups
    w = jax.random.normal(jax.random.PRNGKey(12), (48, 20))
    np.testing.assert_allclose(np.asarray(apec.apec_matmul_jnp(s, w, g)),
                               np.asarray(s @ w), atol=1e-4, rtol=1e-4)
    # dispatch-routed public entry agrees too (whatever backend resolves)
    np.testing.assert_allclose(np.asarray(apec.apec_matmul(s, w, g)),
                               np.asarray(s @ w), atol=1e-4, rtol=1e-4)


def test_apec_decompose_reconstruct_roundtrip_deterministic():
    s = (jax.random.uniform(jax.random.PRNGKey(13), (24, 16)) < 0.35
         ).astype(jnp.float32)
    for g in (2, 4):
        overlap, residual = apec.apec_decompose(s, g)
        assert float(jnp.sum(overlap[..., None, :] * residual)) == 0.0
        np.testing.assert_array_equal(
            apec.apec_reconstruct(overlap, residual), s)
