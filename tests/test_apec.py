"""APEC properties (Sec. III-A2): exactness for any spike tensor, Eq. 1-4."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import apec


def _spike_tensor(seed, p_positions, channels, density):
    key = jax.random.PRNGKey(seed)
    return (jax.random.uniform(key, (p_positions, channels))
            < density).astype(jnp.float32)


@given(seed=st.integers(0, 2**16), g=st.sampled_from([2, 4, 8]),
       density=st.floats(0.05, 0.95))
def test_apec_matmul_exact(seed, g, density):
    """Eq. 1 decomposition preserves the accumulation exactly — the paper's
    central correctness claim ('APEC preserves numerical equivalence')."""
    s = _spike_tensor(seed, 16, 24, density)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (24, 12))
    np.testing.assert_allclose(apec.apec_matmul(s, w, g), s @ w,
                               atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 2**16), g=st.sampled_from([2, 4]))
def test_apec_decompose_disjoint_and_reconstructs(seed, g):
    s = _spike_tensor(seed, 8, 32, 0.4)
    overlap, residual = apec.apec_decompose(s, g)
    # overlap AND residual_i == 0 (disjointness, Fig. 5)
    assert float(jnp.sum(overlap[..., None, :] * residual)) == 0.0
    np.testing.assert_array_equal(apec.apec_reconstruct(overlap, residual), s)


@given(seed=st.integers(0, 2**16))
def test_apec_eliminated_events_eq2(seed):
    """dN = (g-1)|O_G| and events_after + dN == events_before."""
    g = 2
    s = _spike_tensor(seed, 32, 16, 0.5)
    stats = apec.apec_stats(s, g)
    assert float(stats.events_before) == float(
        stats.events_after + stats.eliminated)
    overlap, _ = apec.apec_decompose(s, g)
    assert float(stats.eliminated) == (g - 1) * float(jnp.sum(overlap))


def test_apec_eq3_accumulation_savings():
    # Paper's concrete Fig. 5 example: 14 -> 8 events, 3x3 conv, 64 channels
    # eliminates 6*64*9 = 3456 accumulations.
    s1 = jnp.zeros((2, 16)).at[0, :10].set(1.0).at[1, 2:12].set(1.0)
    stats = apec.apec_stats(s1, 2)
    assert float(stats.events_before) == 20.0
    overlap = float(jnp.sum(jnp.min(s1.reshape(1, 2, 16), axis=1)))
    assert float(stats.eliminated) == overlap
    savings = stats.accum_savings(co=64, k=3)
    assert float(savings) == overlap * 64 * 9


def test_apec_overlap_decays_with_group_size():
    """|O_G| shrinks with g (the paper's inset observation) for smooth maps."""
    key = jax.random.PRNGKey(0)
    base = (jax.random.uniform(key, (128, 1, 64)) < 0.5)
    # spatially correlated spikes: adjacent positions share base pattern
    s = jnp.repeat(base, 8, axis=1).reshape(1024, 64).astype(jnp.float32)
    noise = (jax.random.uniform(jax.random.PRNGKey(1), s.shape) < 0.1)
    s = jnp.clip(s + noise, 0, 1)
    o2 = float(apec.apec_stats(s, 2).overlap_mean)
    o4 = float(apec.apec_stats(s, 4).overlap_mean)
    o8 = float(apec.apec_stats(s, 8).overlap_mean)
    assert o2 >= o4 >= o8


def test_apec_overhead_eq4():
    assert apec.apec_overhead_bits(64, 3, 16) == 64 * 9 * 16


def test_apec_spatial_grouping():
    s = (jax.random.uniform(jax.random.PRNGKey(2), (2, 4, 8, 16))
         < 0.3).astype(jnp.float32)
    overlap, residual = apec.apec_spatial(s, 2)
    assert overlap.shape == (2, 4, 4, 16)
    assert residual.shape == (2, 4, 4, 2, 16)
