"""Density-adaptive hybrid dispatch: calibration provenance, bucketing,
route selection (concrete + traced), attribution, and grad parity.

The hybrid resolver picks between the predicated-dense and event-compacted
(CSR) kernels per call from the carried occupancy map's occupied-tile
count, bucketed into pow2 bands so jit sees a bounded route set. These
tests pin the three layers separately: the calibrated cost model (fit
against the committed BENCH_PR3 crossover, not a hardcoded percentile),
the bucket scheme (concrete/traced parity, monotone route table), and the
dispatch integration (attribution strings, the single-trace lax.cond
route flip, and 1e-5 forward/grad parity on every differentiable pair).
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel
from repro.kernels import dispatch, ops

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _fresh_dispatch_state(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    dispatch.reset_fallback_warnings()


def _spikes_with_tiles(key, m, k, n_live, block=128):
    """(m, k) binary spikes occupying exactly `n_live` (block, block)
    tiles (row-major from the top-left), half-dense inside live tiles."""
    mt, kt = m // block, k // block
    assert n_live <= mt * kt
    s = np.zeros((m, k), np.float32)
    live = (np.asarray(jax.random.uniform(key, (block, block))) < 0.5
            ).astype(np.float32)
    for t in range(n_live):
        i, j = t // kt, t % kt
        s[i * block:(i + 1) * block, j * block:(j + 1) * block] = live
    return jnp.asarray(s)


# -------------------------------------------------- calibration provenance
@pytest.mark.parametrize("op", ["spike_matmul", "apec_matmul"])
def test_calibration_points_match_committed_bench(op):
    """The embedded calibration table IS the committed BENCH_PR3 crossover
    data — re-derived from the artifact, not a hardcoded percentile. If
    the bench is re-measured, this pins the table to follow it."""
    points = costmodel.crossover_points_from_bench(
        str(REPO / "BENCH_PR3.json"), op)
    assert tuple(points) == costmodel.ROUTE_CALIBRATION_POINTS[op]


@pytest.mark.parametrize("op", ["spike_matmul", "apec_matmul", "econv"])
def test_calibrated_predicate_reproduces_bench_crossover(op):
    """On the calibration geometry (4x4 tile grid) the fitted predicate
    must agree with what the bench measured: event wins in the sparse
    band, dense wins near-full."""
    assert costmodel.event_route_wins(op, 1, 4, 4)       # 97% sparse
    assert costmodel.event_route_wins(op, 3, 4, 4)
    assert not costmodel.event_route_wins(op, 16, 4, 4)  # full grid
    r, h = costmodel.calibrated_route_params(op)
    assert r > 0 and h > 0


# ---------------------------------------------------------------- buckets
def test_pow2_bucket_concrete_and_traced_agree():
    total = 64
    max_bits = total.bit_length()
    for c in list(range(0, 20)) + [31, 32, 33, 63, 64]:
        traced = int(jax.jit(
            lambda x: costmodel.pow2_bucket_traced(x, max_bits)
        )(jnp.int32(c)))
        assert traced == costmodel.pow2_bucket(c), c


def test_bucket_representatives_cover_every_bucket():
    total = 16
    for b in range(costmodel.num_buckets(total)):
        rep = costmodel.bucket_representative(b, total)
        assert 0 <= rep <= total
        if rep > 0:
            assert costmodel.pow2_bucket(rep) == min(
                b, costmodel.pow2_bucket(total))


def test_route_table_is_monotone_and_threshold_matches():
    """Sparser never flips back to dense: the per-bucket route table is a
    True-prefix (event) followed by False (dense), and the threshold is
    exactly the prefix edge — what the traced cond branches on."""
    for op in dispatch.HYBRID_OPS:
        for mt, kt in [(4, 4), (2, 3), (8, 4), (2, 2)]:
            table = costmodel.hybrid_route_table(op, mt, kt)
            thresh = costmodel.hybrid_event_bucket_threshold(op, mt, kt)
            # monotone: once dense, stays dense
            first_false = next((i for i, v in enumerate(table) if not v),
                               len(table))
            assert all(not v for v in table[first_false:]), (op, mt, kt)
            assert thresh == first_false - 1, (op, mt, kt)


# ----------------------------------------------------- concrete routing
def test_concrete_hybrid_picks_event_when_sparse_dense_when_full():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    with dispatch.use_hybrid("spike_matmul"):
        for n_live, family in [(1, "pallas-csr-interpret"),
                               (16, "pallas-interpret")]:
            s = _spikes_with_tiles(key, 512, 512, n_live)
            occ = ops.padded_occupancy(s)
            be, attr = dispatch.resolve_with_attribution(
                "spike_matmul", s, w, occupancy=occ)
            bucket = costmodel.pow2_bucket(n_live)
            assert be.name == family
            assert attr == f"{family}<-{dispatch.HYBRID}[b{bucket}]"
            out = be.fn(s, w, occupancy=occ)
            np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                                       atol=1e-4)


def test_hybrid_disengages_without_a_map():
    """No carried occupancy -> auto selection, tagged `<-hybrid` so the
    attribution shows hybrid was asked for but had nothing to route on."""
    s = _spikes_with_tiles(jax.random.PRNGKey(2), 256, 256, 2)
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_hybrid("spike_matmul"):
        _, attr = dispatch.resolve_with_attribution("spike_matmul", s, w)
    assert attr == f"{dispatch.REF}<-{dispatch.HYBRID}"


def test_hybrid_scopes_to_named_op_only():
    s = _spikes_with_tiles(jax.random.PRNGKey(3), 256, 256, 2)
    w = jnp.zeros((256, 64), jnp.float32)
    occ = ops.padded_occupancy(s)
    with dispatch.use_hybrid("apec_matmul"):
        _, attr = dispatch.resolve_with_attribution(
            "spike_matmul", s, w, occupancy=occ)
    assert dispatch.HYBRID not in attr


def test_resolved_backends_surfaces_hybrid_attribution():
    with dispatch.use_hybrid():
        rb = dispatch.resolved_backends()
    # example inputs carry no occupancy map -> every hybrid op shows the
    # disengage tag; non-hybrid ops stay untagged
    for op in dispatch.HYBRID_OPS:
        assert rb[op].endswith(f"<-{dispatch.HYBRID}"), rb[op]
    assert dispatch.HYBRID not in rb["lif_scan"]


def test_dispatch_table_names_hybrid_pairs():
    text = dispatch.table()
    assert "hybrid:" in text
    assert "calibrated r=" in text


# ------------------------------------------------------- traced routing
def test_traced_hybrid_single_trace_flips_route_at_bucket_boundary():
    """ONE jit trace, two occupancies straddling the route threshold: the
    lax.cond picks event for the sparse call and dense for the full call
    without retracing — recompiles are bounded by map shape, not by
    occupancy values. (Satellite 4's bucket-boundary case.)"""
    w = jax.random.normal(jax.random.PRNGKey(4), (512, 256))
    thresh = costmodel.hybrid_event_bucket_threshold("spike_matmul", 4, 4)
    assert 0 <= thresh < costmodel.num_buckets(16) - 1
    # counts landing in the last event bucket and the first dense bucket
    c_event = (1 << thresh) - 1 if thresh > 0 else 1
    c_dense = 1 << thresh
    assert costmodel.pow2_bucket(c_event) <= thresh \
        < costmodel.pow2_bucket(c_dense)

    calls = []

    def f(s, occ):
        with dispatch.use_hybrid("spike_matmul"):
            be, attr = dispatch.resolve_with_attribution(
                "spike_matmul", s, w, occupancy=occ)
        calls.append(attr)
        return be.fn(s, w, occupancy=occ)

    jf = jax.jit(f)
    for n_live in (c_event, c_dense):
        s = _spikes_with_tiles(jax.random.PRNGKey(5), 512, 512, n_live)
        out = jf(s, ops.padded_occupancy(s))
        np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                                   atol=1e-4)
    assert jf._cache_size() == 1
    # the synthetic backend name carries both routes + the threshold
    assert all(a.startswith(f"{dispatch.HYBRID}[") for a in calls)
    assert f"@b{thresh}]" in calls[0]


# ----------------------------------------------- satellite 4: grad parity
def _hybrid_case(op):
    """(args, kwargs, occupancy) exercising op's hybrid pair."""
    if op == "econv":
        sp = (jax.random.uniform(jax.random.PRNGKey(6),
                                 (2, 8, 8, 128)) < 0.1).astype(jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(7), (3, 3, 128, 32)) * 0.1
        from repro.core.events import EventTensor, conv_patch_occupancy
        occ = conv_patch_occupancy(EventTensor.from_spikes(sp), w.shape,
                                   1, "SAME")
        return (sp, w), {"stride": 1, "padding": "SAME"}, occ
    s = _spikes_with_tiles(jax.random.PRNGKey(8), 512, 512, 5)
    w = jax.random.normal(jax.random.PRNGKey(9), (512, 256)) * 0.1
    kw = {"g": 2} if op == "apec_matmul" else {}
    return (s, w), kw, ops.padded_occupancy(s)


@pytest.mark.parametrize("op", dispatch.HYBRID_OPS)
def test_hybrid_route_grad_parity_across_buckets(op):
    """Every differentiable pair hybrid can choose between: forward and
    jax.grad (wrt weights) match ref at 1e-5 whichever route the bucket
    lands on, including the traced cond (both branches differentiated)."""
    spec_pair = dispatch._hybrid_route_pair(dispatch._REGISTRY[op])
    if spec_pair is None:
        pytest.skip(f"no hybrid pair for {op} on this platform")
    if not (spec_pair[0].differentiable and spec_pair[1].differentiable):
        pytest.skip(f"hybrid pair for {op} not differentiable")
    (a0, w), kwargs, occ = _hybrid_case(op)

    def loss_ref(wv):
        return jnp.mean(dispatch.call_backend(op, dispatch.REF, a0, wv,
                                              **kwargs) ** 2)

    ref_out = dispatch.call_backend(op, dispatch.REF, a0, w, **kwargs)
    ref_grad = jax.grad(loss_ref)(w)

    attrs = []

    def run(occupancy):
        with dispatch.use_hybrid(op):
            be, attr = dispatch.resolve_with_attribution(
                op, a0, w, occupancy=occupancy, **kwargs)
        attrs.append(attr)

        def loss(wv):
            return jnp.mean(be.fn(a0, wv, occupancy=occupancy,
                                  **kwargs) ** 2)
        return be.fn(a0, w, occupancy=occupancy, **kwargs), \
            jax.grad(loss)(w)

    # concrete map: whichever route the bucket picks
    out, grad = run(occ)
    assert dispatch.HYBRID in attrs[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(ref_grad),
                               atol=1e-5, rtol=1e-5)
    # traced map: grads flow through the lax.cond (both branches)
    out_t, grad_t = jax.jit(run)(occ)
    assert attrs[-1].startswith(f"{dispatch.HYBRID}[")
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(grad_t), np.asarray(ref_grad),
                               atol=1e-5, rtol=1e-5)


def test_hybrid_grad_parity_both_sides_of_boundary():
    """Grad parity on BOTH routes explicitly: one occupancy per side of
    the spike_matmul route threshold, same jit trace (satellite 4's
    flipping case), gradients wrt weights match ref at 1e-5."""
    w = jax.random.normal(jax.random.PRNGKey(10), (512, 256)) * 0.1
    thresh = costmodel.hybrid_event_bucket_threshold("spike_matmul", 4, 4)
    c_event = (1 << thresh) - 1 if thresh > 0 else 1
    c_dense = min(16, 1 << thresh)

    def grad_fn(s, occ):
        with dispatch.use_hybrid("spike_matmul"):
            be, _ = dispatch.resolve_with_attribution(
                "spike_matmul", s, w, occupancy=occ)

        def loss(wv):
            return jnp.mean(be.fn(s, wv, occupancy=occ) ** 2)
        return jax.grad(loss)(w)

    jg = jax.jit(grad_fn)
    for n_live in (c_event, c_dense):
        s = _spikes_with_tiles(jax.random.PRNGKey(11), 512, 512, n_live)

        def loss_ref(wv):
            return jnp.mean((s @ wv) ** 2)
        np.testing.assert_allclose(
            np.asarray(jg(s, ops.padded_occupancy(s))),
            np.asarray(jax.grad(loss_ref)(w)), atol=1e-5, rtol=1e-5)
    assert jg._cache_size() == 1
