"""Property tests for the TileCSR pre-pass (+ deterministic fallbacks).

`occupancy_to_csr` feeds tile indices straight into Pallas block index
maps, where a wrong entry reads the wrong tile *silently* — so the
invariants are pinned as properties over random shapes/tilings/occupancy
rather than a handful of examples:

  * `row_ptr` is monotone non-decreasing, starts at 0, and ends at the
    real (valid) step count;
  * every occupied tile appears exactly once among the compute steps,
    in row-major order, inside its row's `row_ptr` span;
  * dummy steps (valid, occ==0) appear exactly for all-empty rows, at
    k-tile 0;
  * clamp-padding steps (valid==0) repeat the last real step's indices
    (no new DMA) and never count events;
  * the per-shard pre-pass (`shard_occupancy_to_csr`) compacts each
    shard identically to compacting its rows alone, under ONE shared
    power-of-two cap.

When hypothesis is absent (offline CI image), the `@given` tests skip
and the parametrized deterministic cases below exercise the same checker
on hand-picked edge maps.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, st  # noqa: E402

from repro.core.spikes import (TileCSR, occupancy_to_csr, pow2_step_cap,
                               shard_occupancy_to_csr, stack_shard_csrs)


def check_csr_invariants(occ_np: np.ndarray, csr: TileCSR,
                         cap: int | None = None) -> None:
    """Assert every TileCSR structural invariant against its source map."""
    mt, kt = occ_np.shape
    mask = occ_np > 0
    row_occupied = mask.sum(axis=1)
    expect_steps = int(np.where(row_occupied > 0, row_occupied, 1).sum())

    row_ptr = np.asarray(csr.row_ptr)
    tm = np.asarray(csr.tile_m_idx)
    tk = np.asarray(csr.tile_k_idx)
    occ_steps = np.asarray(csr.occ)
    valid = np.asarray(csr.valid)

    # row_ptr: canonical CSR over m-tile rows
    assert row_ptr.shape == (mt + 1,)
    assert row_ptr[0] == 0
    assert np.all(np.diff(row_ptr) >= 0), "row_ptr not monotone"
    assert row_ptr[-1] == expect_steps == int(valid.sum())

    # cap covers the real steps; default cap is exactly trimmed
    assert csr.n_steps >= expect_steps
    if cap is None:
        assert csr.n_steps == expect_steps

    # every occupied tile appears exactly once, row-major, in its span
    flat_steps = tm[:expect_steps] * kt + tk[:expect_steps]
    mask2 = mask.copy()
    mask2[:, 0] |= row_occupied == 0          # dummy visit per empty row
    np.testing.assert_array_equal(flat_steps,
                                  np.nonzero(mask2.ravel())[0])
    for i in range(mt):
        span = slice(int(row_ptr[i]), int(row_ptr[i + 1]))
        assert np.all(tm[span] == i)

    # dummy steps: valid, occ==0, k-tile 0, exactly the all-empty rows
    dummy = (valid == 1) & (occ_steps == 0) & \
        (np.arange(csr.n_steps) < expect_steps) & \
        ~mask[tm, tk]
    assert np.all(tk[dummy] == 0)
    np.testing.assert_array_equal(np.sort(tm[dummy]),
                                  np.nonzero(row_occupied == 0)[0])
    # compute steps carry the exact event counts
    real = (valid == 1) & ~dummy
    np.testing.assert_array_equal(occ_steps[real], occ_np[tm[real], tk[real]])

    # clamp padding: repeats the last real step, contributes nothing
    if csr.n_steps > expect_steps:
        assert np.all(valid[expect_steps:] == 0)
        assert np.all(occ_steps[expect_steps:] == 0)
        assert np.all(tm[expect_steps:] == tm[expect_steps - 1])
        assert np.all(tk[expect_steps:] == tk[expect_steps - 1])


# ------------------------------------------------------- hypothesis side
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 2 ** 30),
       st.floats(0.0, 1.0))
def test_csr_invariants_random_maps(mt, kt, seed, density):
    occ_np = (np.random.default_rng(seed).random((mt, kt)) < density
              ).astype(np.int32) * np.random.default_rng(seed + 1).integers(
                  1, 9, (mt, kt))
    check_csr_invariants(occ_np, occupancy_to_csr(jnp.asarray(occ_np)))


@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 2 ** 30),
       st.integers(0, 40))
def test_csr_invariants_hold_under_any_covering_cap(mt, kt, seed, extra):
    occ_np = np.random.default_rng(seed).integers(0, 3, (mt, kt))
    exact = occupancy_to_csr(jnp.asarray(occ_np)).n_steps
    cap = exact + extra
    csr = occupancy_to_csr(jnp.asarray(occ_np), cap=cap)
    assert csr.n_steps == cap
    check_csr_invariants(occ_np, csr, cap=cap)


@given(st.integers(1, 16384), st.integers(1, 16384))
def test_pow2_cap_is_pow2_covering_and_dense_bounded(n, dense):
    n = min(n, dense)                      # usage invariant: n <= dense
    cap = pow2_step_cap(n, dense)
    assert n <= cap <= dense
    assert cap == dense or (cap & (cap - 1)) == 0


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 2 ** 30))
def test_shard_csr_matches_per_shard_compaction(shards, rows, seed):
    kt = 3
    occ_np = np.random.default_rng(seed).integers(0, 2, (shards * rows, kt))
    per = shard_occupancy_to_csr(jnp.asarray(occ_np), shards)
    assert len(per) == shards
    caps = {c.n_steps for c in per}
    assert len(caps) == 1, "shards must share one cap"
    cap = caps.pop()
    assert cap == pow2_step_cap(
        max(occupancy_to_csr(jnp.asarray(occ_np[i * rows:(i + 1) * rows])
                             ).n_steps for i in range(shards)), rows * kt)
    for i, csr in enumerate(per):
        check_csr_invariants(occ_np[i * rows:(i + 1) * rows], csr, cap=cap)


# ----------------------------------------------- deterministic fallbacks
EDGE_MAPS = [
    np.zeros((1, 1), np.int32),                      # single empty tile
    np.ones((1, 1), np.int32),                       # single full tile
    np.zeros((4, 3), np.int32),                      # all rows empty
    np.full((3, 4), 7, np.int32),                    # fully occupied
    np.eye(4, 5, dtype=np.int32) * 3,                # diagonal
    np.array([[0, 2, 0], [0, 0, 0], [1, 0, 4]]),    # mixed + empty row
    np.array([[0, 0, 0, 5]]),                        # single trailing tile
]


@pytest.mark.parametrize("occ_np", EDGE_MAPS,
                         ids=[f"map{i}" for i in range(len(EDGE_MAPS))])
def test_csr_invariants_edge_maps(occ_np):
    check_csr_invariants(occ_np, occupancy_to_csr(jnp.asarray(occ_np)))
    capped = occupancy_to_csr(jnp.asarray(occ_np), cap=25)
    check_csr_invariants(occ_np, capped, cap=25)


def test_pow2_cap_deterministic_points():
    assert pow2_step_cap(1, 64) == 1
    assert pow2_step_cap(3, 64) == 4
    assert pow2_step_cap(4, 64) == 4
    assert pow2_step_cap(33, 64) == 64     # dense-bounded
    assert pow2_step_cap(5, 6) == 6        # dense smaller than next pow2
    assert pow2_step_cap(0, 16) == 1       # degenerate guard


def test_shard_csr_deterministic_and_stacks():
    occ_np = np.array([[0, 0], [3, 0], [0, 0], [0, 0],
                       [1, 1], [0, 2], [4, 4], [0, 0]])
    per = shard_occupancy_to_csr(jnp.asarray(occ_np), 4)
    # most occupied shard (rows 4:6 -> 3 tiles) sets the shared pow2 cap
    assert {c.n_steps for c in per} == {4}
    for i, csr in enumerate(per):
        check_csr_invariants(occ_np[2 * i:2 * i + 2], csr, cap=4)
    stacked = stack_shard_csrs(per)
    assert stacked.row_ptr.shape == (4, 3)
    assert stacked.tile_k_idx.shape == (4, 4)
    for i, csr in enumerate(per):
        np.testing.assert_array_equal(np.asarray(stacked.occ[i]),
                                      np.asarray(csr.occ))


def test_shard_csr_rejects_uneven_rows_and_tracers():
    occ = jnp.zeros((3, 2), jnp.int32)
    with pytest.raises(ValueError, match="divisible"):
        shard_occupancy_to_csr(occ, 2)
    with pytest.raises(ValueError, match="eager"):
        jax.jit(lambda o: shard_occupancy_to_csr(o, 2))(
            jnp.zeros((4, 2), jnp.int32))


def test_stack_shard_csrs_rejects_mixed_caps():
    a = occupancy_to_csr(jnp.asarray(np.ones((2, 2), np.int32)))
    b = occupancy_to_csr(jnp.asarray(np.ones((2, 2), np.int32)), cap=6)
    with pytest.raises(ValueError, match="caps differ"):
        stack_shard_csrs([a, b])


def test_have_hypothesis_flag_is_bool():
    assert isinstance(HAVE_HYPOTHESIS, bool)
