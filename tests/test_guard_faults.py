"""Guarded execution under injected faults (PR8).

The guard's contract, tested as properties:
  * audit flags 100% of injected occupancy UNDERCOUNTS — dense and
    packed payloads, eager (GuardViolationError) and under jit (watcher
    record via debug callback);
  * zero false positives: valid maps and OVERCOUNTED maps (legal upper
    bounds) pass with numerics identical to the unguarded call;
  * repair never returns a silent wrong answer: with a violated map the
    result matches the trusted-payload oracle at 1e-5, eager and jit;
  * stale CSR tags are rejected loudly; wrong map grids raise even
    under jit (shape check is static);
  * the serve loop quarantines NaN logits / raising decode steps with
    bounded retries, and deadlines are terminal on every path.

Property tests use hypothesis when installed and skip (per
hypothesis_compat) offline; the deterministic tests below cover the same
invariants either way.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, st  # noqa: F401
from repro.core import spikes as spk
from repro.kernels import dispatch, ops
from repro.runtime import faults

M, K, N = 256, 256, 64


@pytest.fixture(autouse=True)
def _rearm_warnings():
    dispatch.reset_fallback_warnings()
    yield
    dispatch.reset_fallback_warnings()


def _spikes(seed=0, density=0.05, m=M, k=K):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((m, k)) < density).astype(np.float32))


def _weights(seed=1, k=K, n=N):
    return jax.random.normal(jax.random.PRNGKey(seed), (k, n), jnp.float32)


def _quiet_dispatch(*args, **kwargs):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return dispatch.dispatch(*args, **kwargs)


# Traced-mode callables, traced ONCE under the mode they test (the guard
# binds at resolution = trace time) and reused across examples.
_JITTED = {}


def _jitted(mode):
    if mode not in _JITTED:
        def f(s, occ, w, packed_k=None):
            kw = {} if packed_k is None else {"packed_k": packed_k}
            return _quiet_dispatch("spike_matmul", s, w, occupancy=occ, **kw)
        fn = jax.jit(f, static_argnames=("packed_k",))
        with dispatch.use_guard(mode):
            # trace BOTH signatures now so the mode is captured (packed_k
            # is static -> its own trace; later calls are cache hits and
            # keep the guarded behavior)
            s = _spikes()
            w = _weights()
            fn(s, ops.padded_occupancy(s), w).block_until_ready()
            sp, occp, wordsp = _packed_case()
            fn(jnp.asarray(wordsp), occp, w, packed_k=K).block_until_ready()
        _JITTED[mode] = fn
    return _JITTED[mode]


# --------------------------------------------------------- undercount: dense
@pytest.mark.parametrize("seed,n_tiles", [(0, 1), (1, 2), (2, 4)])
def test_audit_flags_undercount_eager(seed, n_tiles):
    s, w = _spikes(seed), _weights()
    bad, coords = faults.undercount_occupancy(
        ops.padded_occupancy(s), n_tiles=n_tiles, seed=seed)
    assert coords
    with dispatch.use_guard("audit"):
        with pytest.raises(faults.GuardViolationError):
            _quiet_dispatch("spike_matmul", s, w, occupancy=jnp.asarray(bad))


@pytest.mark.parametrize("seed", [0, 3])
def test_audit_flags_undercount_jit(seed):
    """Traced audit can't raise: a violation NaN-poisons the output — a
    loud sentinel for downstream NaN guards, never a plausible wrong
    number."""
    s, w = _spikes(seed), _weights()
    bad, _ = faults.undercount_occupancy(ops.padded_occupancy(s), seed=seed)
    fn = _jitted("audit")
    out = np.asarray(fn(s, jnp.asarray(bad), w))
    assert np.isnan(out).all(), "violation must poison, not pass through"


def test_audit_jit_records_when_watched_at_trace_time():
    """Traces built under an active watcher carry the violation record
    (cond-gated host callback — attached at trace time only, so the hot
    path of unwatched production traces stays effect-free)."""
    s, w = _spikes(11), _weights()
    occ = ops.padded_occupancy(s)
    bad = jnp.asarray(faults.undercount_occupancy(occ, 2, seed=11)[0])
    with dispatch.watch_guard_events() as events:
        fn = jax.jit(lambda o: _quiet_dispatch(
            "spike_matmul", s, w, occupancy=o))
        with dispatch.use_guard("audit"):
            fn(occ).block_until_ready()          # trace (clean): no record
            assert events == []
            fn(bad).block_until_ready()
    assert [e["kind"] for e in events] == ["undercount"], events
    assert events[0]["action"] == "record" and events[0]["traced"]


def test_audit_no_false_positives_eager():
    s, w = _spikes(0), _weights()
    occ = ops.padded_occupancy(s)
    ref = np.asarray(s @ w)
    for m in (occ, jnp.asarray(faults.overcount_occupancy(occ, 2)[0])):
        with dispatch.use_guard("audit"):
            out = _quiet_dispatch("spike_matmul", s, w, occupancy=m)
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


def test_audit_no_false_positives_jit():
    s, w = _spikes(4), _weights()
    occ = ops.padded_occupancy(s)
    over = jnp.asarray(faults.overcount_occupancy(occ, 3)[0])
    fn = _jitted("audit")
    out1, out2 = fn(s, occ, w), fn(s, over, w)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(s @ w),
                               atol=1e-5)       # no NaN poison, exact pass
    np.testing.assert_allclose(np.asarray(out2), np.asarray(s @ w),
                               atol=1e-5)


# -------------------------------------------------------- undercount: packed
def _packed_case(seed=0):
    """Spikes with the upper half of K structurally empty: bit flips
    injected there land in map-empty tiles, which is the detectable
    corruption class (a flip inside an occupied tile is absorbed by the
    upper-bound contract — the documented asymmetry)."""
    s = np.array(_spikes(seed))
    s[:, K // 2:] = 0.0
    s = jnp.asarray(s)
    occ = ops.padded_occupancy(s)
    words = np.asarray(spk.pack_spikes(s))
    return s, occ, words


def test_audit_flags_packed_bitflip_eager():
    s, occ, words = _packed_case(0)
    w = _weights()
    half = words.shape[-1] // 2
    sub, flips = faults.flip_packed_bits(words[:, half:], n_bits=3, seed=0)
    assert flips
    bad = words.copy()
    bad[:, half:] = sub
    with dispatch.use_guard("audit"):
        # clean packed payload: no false positive, parity with dense
        out = _quiet_dispatch("spike_matmul", jnp.asarray(words), w,
                              occupancy=occ, packed_k=K)
        np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                                   atol=1e-4)
        with pytest.raises(faults.GuardViolationError):
            _quiet_dispatch("spike_matmul", jnp.asarray(bad), w,
                            occupancy=occ, packed_k=K)


def test_audit_flags_packed_bitflip_jit():
    s, occ, words = _packed_case(1)
    w = _weights()
    half = words.shape[-1] // 2
    sub, _ = faults.flip_packed_bits(words[:, half:], n_bits=2, seed=1)
    bad = words.copy()
    bad[:, half:] = sub
    fn = _jitted("audit")
    clean = np.asarray(fn(jnp.asarray(words), occ, w, packed_k=K))
    np.testing.assert_allclose(clean, np.asarray(s @ w), atol=1e-4)
    poisoned = np.asarray(fn(jnp.asarray(bad), occ, w, packed_k=K))
    assert np.isnan(poisoned).all()


# ----------------------------------------------------------------- repair
def test_repair_parity_eager():
    s, w = _spikes(5), _weights()
    bad, _ = faults.undercount_occupancy(ops.padded_occupancy(s), 3, seed=5)
    with dispatch.use_guard("repair"):
        with dispatch.watch_guard_events() as events:
            out = _quiet_dispatch("spike_matmul", s, w,
                                  occupancy=jnp.asarray(bad))
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                               atol=1e-5)
    assert events and events[0]["action"] == "repair"
    assert events[0]["attribution"].endswith("+repaired")


def test_repair_parity_jit_and_grad():
    s, w = _spikes(6), _weights()
    bad = jnp.asarray(faults.undercount_occupancy(
        ops.padded_occupancy(s), 2, seed=6)[0])
    fn = _jitted("repair")
    np.testing.assert_allclose(np.asarray(fn(s, bad, w)),
                               np.asarray(s @ w), atol=1e-5)
    # the repair branch (lax.cond) keeps the op differentiable
    with dispatch.use_guard("repair"):
        g = jax.grad(lambda ww: jnp.sum(_quiet_dispatch(
            "spike_matmul", s, ww, occupancy=bad)))(w)
    g_ref = jax.grad(lambda ww: jnp.sum(s @ ww))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)


def test_repair_packed_parity():
    s, occ, words = _packed_case(2)
    w = _weights()
    half = words.shape[-1] // 2
    sub, _ = faults.flip_packed_bits(words[:, half:], n_bits=2, seed=2)
    bad = words.copy()
    bad[:, half:] = sub
    with dispatch.use_guard("repair"):
        out = _quiet_dispatch("spike_matmul", jnp.asarray(bad), w,
                              occupancy=occ, packed_k=K)
    # repair trusts the payload: result = CORRUPTED payload @ w (the map
    # is dropped, nothing silently zeroed) — compare to that oracle.
    s_bad = spk.unpack_spikes(jnp.asarray(bad), dtype=jnp.float32)[:, :K]
    np.testing.assert_allclose(np.asarray(out), np.asarray(s_bad @ w),
                               atol=1e-5)


# ------------------------------------------------- stale metadata (static)
def test_wrong_grid_raises_even_under_jit():
    s, w = _spikes(7), _weights()
    stale = jnp.zeros((1, 1), jnp.int32)      # wrong grid for 256x256
    with dispatch.use_guard("audit"):
        with pytest.raises(faults.GuardViolationError, match="grid"):
            _quiet_dispatch("spike_matmul", s, w, occupancy=stale)
        with pytest.raises(faults.GuardViolationError, match="grid"):
            jax.jit(lambda ss, ww: _quiet_dispatch(
                "spike_matmul", ss, ww, occupancy=stale))(s, w)


def test_stale_csr_rejected_loudly():
    occ = ops.padded_occupancy(_spikes(8))
    csr = spk.occupancy_to_csr(occ, tiling=(128, 128))
    bad = faults.stale_csr(csr, tiling=(64, 64))
    with pytest.raises(ValueError, match="tiling"):
        bad.check_compatible(128, 128, *(int(d) for d in occ.shape))
    wrong_grid = faults.stale_csr(csr, tiling=None, map_shape=(9, 9))
    with pytest.raises(ValueError, match="tile grid"):
        wrong_grid.check_compatible(128, 128, *(int(d) for d in occ.shape))


def test_guard_off_is_exact_passthrough():
    """Default mode adds nothing: same numerics, same attribution."""
    s, w = _spikes(9), _weights()
    occ = ops.padded_occupancy(s)
    base = _quiet_dispatch("spike_matmul", s, w, occupancy=occ)
    assert dispatch.guard_mode() == "off"
    _, attr = dispatch.resolve_with_attribution(
        "spike_matmul", s, w, occupancy=occ)
    with dispatch.use_guard("audit"):
        _, attr_audit = dispatch.resolve_with_attribution(
            "spike_matmul", s, w, occupancy=occ)
        audited = _quiet_dispatch("spike_matmul", s, w, occupancy=occ)
    assert attr_audit == attr                  # guard is policy, not routing
    np.testing.assert_array_equal(np.asarray(base), np.asarray(audited))


def test_guard_mode_env_and_validation(monkeypatch):
    monkeypatch.setenv(dispatch.GUARD_ENV_VAR, "audit")
    assert dispatch.guard_mode() == "audit"
    monkeypatch.setenv(dispatch.GUARD_ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="bogus"):
        dispatch.guard_mode()
    with pytest.raises(ValueError, match="bogus"):
        with dispatch.use_guard("bogus"):
            pass


# ------------------------------------------------------ hypothesis properties
@given(seed=st.integers(0, 10_000), n_tiles=st.integers(1, 6),
       density=st.floats(0.02, 0.3))
def test_property_every_undercount_detected(seed, n_tiles, density):
    s, w = _spikes(seed, density, m=128, k=256), _weights(k=256)
    bad, coords = faults.undercount_occupancy(
        ops.padded_occupancy(s), n_tiles=n_tiles, seed=seed)
    assert coords
    with dispatch.use_guard("audit"):
        with pytest.raises(faults.GuardViolationError):
            _quiet_dispatch("spike_matmul", s, w, occupancy=jnp.asarray(bad))


@given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
       overcount=st.booleans())
def test_property_valid_maps_never_flag(seed, density, overcount):
    s, w = _spikes(seed, density, m=128, k=256), _weights(k=256)
    occ = ops.padded_occupancy(s)
    if overcount:
        occ = jnp.asarray(faults.overcount_occupancy(occ, 2, seed=seed)[0])
    with dispatch.use_guard("audit"):
        out = _quiet_dispatch("spike_matmul", s, w, occupancy=occ)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                               atol=1e-5)


@given(seed=st.integers(0, 10_000))
def test_property_repair_matches_oracle(seed):
    s, w = _spikes(seed, 0.1, m=128, k=256), _weights(k=256)
    bad = jnp.asarray(faults.undercount_occupancy(
        ops.padded_occupancy(s), 2, seed=seed)[0])
    with dispatch.use_guard("repair"):
        out = _quiet_dispatch("spike_matmul", s, w, occupancy=bad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                               atol=1e-5)


# ------------------------------------------------------------- serve loop
from repro.configs.base import LMConfig, SpikingConfig  # noqa: E402
from repro.launch import serve  # noqa: E402

SERVE_CFG = LMConfig(name="guard-serve", family="dense", n_layers=2,
                     d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                     vocab=64, spiking=SpikingConfig(t_steps=1),
                     remat="none", loss_chunk=16)


def test_serve_nan_quarantine_retries_then_succeeds():
    server = serve.Server(SERVE_CFG, n_slots=2, max_seq=32, backoff_s=0.0)
    req = serve.Request(rid=0, prompt=[1, 2, 3], max_new=4)
    server.submit(req)
    server.step()
    server.step()
    assert req.state == "running"
    # poison slot 0's decode state (KV cache / SDSA status NaN'd)
    server.state = faults.nan_decode_state(server.state, slot=0)
    finished = server.run_until_drained(max_steps=200)
    assert req in finished
    assert req.state == "done" and req.done
    assert req.retries >= 1                  # quarantined then recovered
    assert req.failure_cause == "nan_logits"
    assert len(req.generated) == 4           # full regeneration, no
    assert all(s is None for s in server.slot_req)  # poisoned tokens


def test_serve_decode_error_releases_all_slots_and_recovers():
    server = serve.Server(SERVE_CFG, n_slots=2, max_seq=32, backoff_s=0.0)
    # max_new=3: admission prefill emits the first token, so 2-token
    # requests would finish before the fault lands — leave one decode
    # step of runway.
    reqs = [serve.Request(rid=i, prompt=[i + 1], max_new=3)
            for i in range(2)]
    for r in reqs:
        server.submit(r)
    server.step()
    orig = server._step

    def boom(*a, **k):
        raise RuntimeError("kernel fault")
    server._step = boom
    server.step()
    # the batch can't attribute the raise: every active slot quarantines
    assert all(s is None for s in server.slot_req)
    for r in reqs:
        assert r.retries == 1
        assert r.failure_cause == "decode_error:RuntimeError"
    server._step = orig
    server.run_until_drained(max_steps=200)
    assert all(r.state == "done" for r in reqs)


def test_serve_retry_exhaustion_is_terminal_failed():
    server = serve.Server(SERVE_CFG, n_slots=1, max_seq=32, backoff_s=0.0)
    server._step = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("dead kernel"))
    req = serve.Request(rid=0, prompt=[1], max_new=2, max_retries=1)
    server.submit(req)
    finished = server.run_until_drained(max_steps=50)
    assert req in finished
    assert req.state == "failed" and not req.done
    assert req.retries == 1                  # budget spent, then terminal
    assert req.failure_cause == "decode_error:RuntimeError"
    assert server.slot_req[0] is None        # slot released on every path


def test_serve_deadline_terminal_for_active_and_queued():
    t = [0.0]
    server = serve.Server(SERVE_CFG, n_slots=1, max_seq=32,
                          clock=lambda: t[0])
    n_slots, vocab = 1, SERVE_CFG.vocab
    server._step = lambda p, st_, tok, pos: (
        jnp.ones((n_slots, vocab)), st_)     # scheduling-only test
    active = serve.Request(rid=0, prompt=[1, 2], max_new=64, deadline_s=0.5)
    queued = serve.Request(rid=1, prompt=[3], max_new=64, deadline_s=0.5)
    fresh = serve.Request(rid=2, prompt=[4], max_new=2)
    server.submit(active)
    server.submit(queued)
    server.step()                            # active takes the only slot
    assert active.state == "running" and queued.state == "pending"
    t[0] = 1.0                               # both overrun their budget
    server.submit(fresh)
    server.step()
    assert active.state == "failed"
    assert active.failure_cause == "deadline"
    assert queued.state == "failed"          # never admitted, still failed
    assert queued.failure_cause == "deadline"
    server.run_until_drained(max_steps=50)
    assert fresh.state == "done"             # server keeps serving


def test_serve_backoff_gates_readmission():
    t = [0.0]
    server = serve.Server(SERVE_CFG, n_slots=1, max_seq=32,
                          clock=lambda: t[0], backoff_s=10.0)
    server._step = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("flaky"))
    req = serve.Request(rid=0, prompt=[1], max_new=2, max_retries=2)
    server.submit(req)
    server.step()                            # assign + fault -> retry 1
    assert req.retries == 1 and req.not_before == 10.0
    assert not server.step()                 # backing off: nothing active
    assert req.retries == 1                  # NOT readmitted early
    t[0] = 11.0
    server.step()                            # gate open -> retry 2
    assert req.retries == 2 and req.not_before == 11.0 + 20.0
