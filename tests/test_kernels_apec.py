"""APEC Pallas kernel vs oracles + cross-check against core.apec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apec as core_apec
from repro.kernels import ops, ref
from repro.kernels.apec_kernel import apec_decompose_packed


@pytest.mark.parametrize("p,dw,g", [(16, 2, 2), (64, 4, 2), (32, 1, 4),
                                    (64, 8, 8)])
def test_apec_kernel_matches_ref(p, dw, g):
    s = jax.random.bits(jax.random.PRNGKey(0), (p, dw), jnp.uint32)
    ov_k, res_k = apec_decompose_packed(s, g, block_m=max(1, 8 // g),
                                        block_n=min(128, dw),
                                        interpret=True)
    ov_r, res_r = ref.apec_decompose_packed_ref(s, g)
    np.testing.assert_array_equal(ov_k, ov_r)
    np.testing.assert_array_equal(res_k, res_r)


@pytest.mark.parametrize("c", [32, 64, 70])
@pytest.mark.parametrize("g", [2, 4])
def test_apec_kernel_wrapper_matches_core(c, g):
    """Bitwise kernel path == the dense core implementation (Eq. 1/Fig. 5)."""
    s = (jax.random.uniform(jax.random.PRNGKey(1), (32, c)) < 0.4
         ).astype(jnp.float32)
    ov_k, res_k = ops.apec_decompose(s, g)
    ov_c, res_c = core_apec.apec_decompose(s, g)
    np.testing.assert_array_equal(np.asarray(ov_k), np.asarray(ov_c))
    np.testing.assert_array_equal(
        np.asarray(res_k), np.asarray(res_c).reshape(32, c))


def test_apec_kernel_residual_tiles_sparser():
    """The kernel's purpose: residuals are strictly sparser than inputs."""
    s = (jax.random.uniform(jax.random.PRNGKey(2), (64, 64)) < 0.6
         ).astype(jnp.float32)
    _, res = ops.apec_decompose(s, 2)
    assert float(jnp.sum(res)) < float(jnp.sum(s))
