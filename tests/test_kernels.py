"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spikes import pack_spikes, unpack_spikes
from repro.kernels import ops, ref
from repro.kernels.lif_scan import lif_scan_pallas
from repro.kernels.sdsa_kernel import (sdsa_apply_pallas, sdsa_packed,
                                       sdsa_status_pallas)
from repro.kernels.spike_matmul import spike_matmul_pallas


# ---------------------------------------------------------------- lif_scan
@pytest.mark.parametrize("t,m,n", [(1, 8, 128), (4, 16, 256), (8, 8, 384),
                                   (2, 32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lif_scan_kernel_matches_ref(t, m, n, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (t, m, n)) * 2).astype(dtype)
    out = lif_scan_pallas(x, interpret=True)
    expect = ref.lif_scan_ref(x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=0)


@pytest.mark.parametrize("soft_reset", [True, False])
@pytest.mark.parametrize("decay,v_th", [(0.5, 1.0), (0.9, 0.5), (0.0, 1.0)])
def test_lif_scan_kernel_params(decay, v_th, soft_reset):
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 8, 128)) * 2
    out = lif_scan_pallas(x, decay=decay, v_th=v_th, soft_reset=soft_reset,
                          interpret=True)
    expect = ref.lif_scan_ref(x, decay=decay, v_th=v_th,
                              soft_reset=soft_reset)
    np.testing.assert_allclose(out, expect, atol=0)


def test_lif_wrapper_arbitrary_shape():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 7, 11)) * 2
    out = ops.lif(x)
    expect = ref.lif_scan_ref(x)
    np.testing.assert_allclose(out, expect, atol=0)


# -------------------------------------------------------------------- sdsa
@pytest.mark.parametrize("bh,n,dw", [(2, 16, 2), (4, 256, 4), (1, 512, 1),
                                     (8, 64, 8)])
def test_sdsa_status_kernel_sweep(bh, n, dw):
    k = jax.random.bits(jax.random.PRNGKey(0), (bh, n, dw), jnp.uint32)
    v = jax.random.bits(jax.random.PRNGKey(1), (bh, n, dw), jnp.uint32)
    out = sdsa_status_pallas(k, v, block_n=min(256, n), interpret=True)
    np.testing.assert_array_equal(out, ref.sdsa_status_ref(k, v))


@pytest.mark.parametrize("bh,n,dw", [(2, 64, 4), (3, 128, 2)])
def test_sdsa_full_packed_kernel(bh, n, dw):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.bits(kk, (bh, n, dw), jnp.uint32) for kk in ks)
    out = sdsa_packed(q, k, v, block_n=64, interpret=True)
    np.testing.assert_array_equal(out, ref.sdsa_packed_ref(q, k, v))


@pytest.mark.parametrize("d", [32, 64, 70, 128])
def test_sdsa_wrapper_matches_dense_core(d):
    shape = (2, 3, 24, d)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = ((jax.random.uniform(kk, shape) < 0.4).astype(jnp.float32)
               for kk in ks)
    out = ops.sdsa_or(q, k, v)
    np.testing.assert_array_equal(out, ref.sdsa_unpacked_ref(q, k, v))


def test_packed_roundtrip_property():
    s = (jax.random.uniform(jax.random.PRNGKey(4), (5, 96)) < 0.5
         ).astype(jnp.float32)
    np.testing.assert_array_equal(unpack_spikes(pack_spikes(s)), s)


# ------------------------------------------------------------ spike_matmul
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 512, 256)])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_spike_matmul_kernel_sweep(m, k, n, density):
    s = (jax.random.uniform(jax.random.PRNGKey(0), (m, k)) < density
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    out = spike_matmul_pallas(s, w, interpret=True)
    np.testing.assert_allclose(out, ref.spike_matmul_ref(s, w),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spike_matmul_dtypes(dtype):
    s = (jax.random.uniform(jax.random.PRNGKey(2), (128, 256)) < 0.2
         ).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 128)).astype(dtype)
    out = spike_matmul_pallas(s, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.spike_matmul_ref(s, w),
                                                np.float32),
        atol=2e-2, rtol=2e-2)


def test_spike_matmul_skips_empty_tiles_exactly():
    """Zero tiles contribute exactly zero — skipping is lossless."""
    s = jnp.zeros((256, 256), jnp.float32).at[:128, :128].set(
        (jax.random.uniform(jax.random.PRNGKey(4), (128, 128)) < 0.3
         ).astype(jnp.float32))
    w = jax.random.normal(jax.random.PRNGKey(5), (256, 128))
    out = spike_matmul_pallas(s, w, interpret=True)
    np.testing.assert_allclose(out, ref.spike_matmul_ref(s, w), atol=1e-4)


def test_spike_matmul_wrapper_padding():
    s = (jax.random.uniform(jax.random.PRNGKey(6), (100, 200)) < 0.2
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(7), (200, 60))
    out = ops.spike_matmul(s, w)
    np.testing.assert_allclose(out, ref.spike_matmul_ref(s, w), atol=1e-4,
                               rtol=1e-4)
