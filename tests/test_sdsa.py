"""SDSA (Attention Core, Fig. 6) semantics + streaming-decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, st

from repro.core import sdsa


def _qkv(seed, shape=(2, 12, 32), p=0.4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple((jax.random.uniform(k, shape) < p).astype(jnp.float32)
                 for k in ks)


@given(seed=st.integers(0, 2**16))
def test_sdsa_or_output_binary(seed):
    q, k, v = _qkv(seed)
    out = sdsa.sdsa(q, k, v, "or")
    assert bool(jnp.all((out == 0) | (out == 1)))


@given(seed=st.integers(0, 2**16))
def test_status_permutation_invariant(seed):
    _, k, v = _qkv(seed)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 7), k.shape[-2])
    s1 = sdsa.kv_status_or(k, v)
    s2 = sdsa.kv_status_or(k[..., perm, :], v[..., perm, :])
    np.testing.assert_array_equal(s1, s2)


@given(seed=st.integers(0, 2**16))
def test_status_monotone_in_kv(seed):
    """Adding events can only turn status bits on (OR monotonicity)."""
    _, k, v = _qkv(seed)
    extra = (jax.random.uniform(jax.random.PRNGKey(seed + 13), k.shape)
             < 0.2).astype(jnp.float32)
    k2 = jnp.clip(k + extra, 0, 1)
    v2 = jnp.clip(v + extra, 0, 1)
    s1 = sdsa.kv_status_or(k, v)
    s2 = sdsa.kv_status_or(k2, v2)
    assert bool(jnp.all(s2 >= s1))


@given(seed=st.integers(0, 2**16), mode=st.sampled_from(["or", "sum"]))
def test_streaming_decode_equals_prefill(seed, mode):
    """Token-by-token status updates == one-shot reduction (Sec. III-C
    on-the-fly OR during V write-back)."""
    q, k, v = _qkv(seed)
    full = sdsa.sdsa(q, k, v, mode)
    status = jnp.zeros(q.shape[:-2] + q.shape[-1:])
    for t in range(q.shape[-2]):
        status = sdsa.sdsa_decode_update(status, k[..., t, :], v[..., t, :],
                                         mode)
    np.testing.assert_allclose(
        sdsa.sdsa_decode_attend(q[..., -1, :], status), full[..., -1, :],
        atol=1e-5)


@given(seed=st.integers(0, 2**16), mode=st.sampled_from(["or", "sum"]))
def test_causal_sdsa_equals_streaming_decode(seed, mode):
    """The `causal_sdsa` registry op (prefix-OR/sum over tokens of the
    T-pooled kv mask) == folding `sdsa_decode_update` token by token —
    the property that lets serving carry O(d) state."""
    t_steps, b, n, d = 2, 2, 10, 24
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = ((jax.random.uniform(kk, (t_steps, b, n, d)) < 0.4)
               .astype(jnp.float32) for kk in ks)
    full = sdsa.causal_sdsa(q, k, v, mode=mode)
    status = jnp.zeros((b, d))
    for i in range(n):
        if mode == "or":
            phase = jnp.max(k[:, :, i] * v[:, :, i], axis=0)
        else:
            phase = jnp.sum(k[:, :, i] * v[:, :, i], axis=0)
        status = sdsa.sdsa_decode_update(status, phase, jnp.ones_like(phase),
                                         mode)
        np.testing.assert_allclose(
            full[:, :, i], q[:, :, i] * status[None], atol=1e-5)


def test_causal_sdsa_is_causal():
    """Token i's output must not change when later tokens change."""
    t_steps, b, n, d = 2, 1, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = ((jax.random.uniform(kk, (t_steps, b, n, d)) < 0.4)
               .astype(jnp.float32) for kk in ks)
    out = sdsa.causal_sdsa(q, k, v)
    k2 = k.at[:, :, n // 2:].set(1.0)
    v2 = v.at[:, :, n // 2:].set(1.0)
    out2 = sdsa.causal_sdsa(q, k2, v2)
    np.testing.assert_array_equal(out[:, :, :n // 2], out2[:, :, :n // 2])


def test_sdsa_linear_op_count():
    # 3*N*d logic ops vs 2*N^2*d MACs: the Fig. 6 economics.
    assert sdsa.sdsa_ops(1024, 64) == 3 * 1024 * 64
    assert sdsa.softmax_attention_ops(1024, 64) == 2 * 1024 * 1024 * 64
    assert sdsa.sdsa_ops(1 << 19, 64) < sdsa.softmax_attention_ops(1 << 19, 64)


def test_sdsa_cross_matches_self_convention():
    q, k, v = _qkv(0)
    np.testing.assert_array_equal(sdsa.sdsa_cross(q, k, v),
                                  sdsa.sdsa(q, k, v))


def test_sum_mode_counts_events():
    k = jnp.ones((1, 4, 8))
    v = jnp.ones((1, 4, 8))
    assert bool(jnp.all(sdsa.kv_status_sum(k, v) == 4))
