"""Beyond-paper optimization paths (§Perf): spec validity + equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.launch.mesh import abstract_mesh
from repro.models import lm, moe
from repro.runtime import sharding


def _mesh():
    return abstract_mesh((16, 16), ("data", "model"))


def test_tp2d_param_specs_valid():
    cfg = registry.get_config("mistral-large-123b").replace(tp2d=True)
    abs_params = lm.abstract_params(cfg)
    specs = sharding.param_specs(cfg, abs_params, _mesh())
    assert not sharding.validate_specs(abs_params, specs, _mesh())
    # tp2d shards over both axes where divisible (weights resident)
    assert specs["lm_head"] == P(None, ("data", "model"))


def test_pure_fsdp_param_specs_valid():
    cfg = registry.get_config("qwen3-4b").replace(pure_fsdp=True)
    abs_params = lm.abstract_params(cfg)
    specs = sharding.param_specs(cfg, abs_params, _mesh())
    assert not sharding.validate_specs(abs_params, specs, _mesh())
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    # no pure-TP col/row specs remain: at most one sharded dim per leaf
    for s in flat:
        assert sum(ax is not None for ax in s) <= 1


def test_padded_expert_bank_routes_only_real_experts():
    p = moe.moe_init(jax.random.PRNGKey(0), 32, 16, n_experts=6,
                     bank_size=8)
    assert p["w_gate"].shape[0] == 8 and p["router"].shape[-1] == 6
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out = moe.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    # same routing math as an unpadded bank with identical weights
    p6 = {k: (v[:6] if k in ("w_gate", "w_up", "w_down") else v)
          for k, v in p.items()}
    out6 = moe.moe_apply(p6, x, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out6, np.float32), atol=1e-5)


def test_decode_dus_and_masked_update_agree():
    from repro.models import transformer as tfm
    p = tfm.attn_init(jax.random.PRNGKey(0), 64, 4, 2, 16)
    cache = tfm.kv_cache_init(2, 8, 2, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64), jnp.bfloat16)
    kw = dict(n_heads=4, n_kv=2, d_head=16)
    o1, c1 = tfm.attention_dense_decode(p, x, cache, jnp.int32(3),
                                        masked_cache_update=True, **kw)
    o2, c2 = tfm.attention_dense_decode(p, x, cache, jnp.int32(3),
                                        masked_cache_update=False, **kw)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-2)
    np.testing.assert_array_equal(np.asarray(c1.k, np.float32),
                                  np.asarray(c2.k, np.float32))


@pytest.mark.slow
def test_moe_shard_map_equivalence_multidevice(multidevice_run):
    """Manual-EP shard_map MoE == GSPMD moe_apply on a real 2x4 mesh
    (shared 8-host-device subprocess; see conftest.multidevice_run)."""
    multidevice_run.check("SHARD_MAP")


def test_moe_shard_map_falls_back_without_mesh():
    p = moe.moe_init(jax.random.PRNGKey(0), 32, 16, n_experts=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out = moe.moe_apply_shard_map(p, x, top_k=2, capacity_factor=8.0)
    ref = moe.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
