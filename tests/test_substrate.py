"""Substrate: optimizer, schedules, grad compression, data, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, st

from repro.data import pipeline, synthetic
from repro.optim import adamw, grad_compress, schedule
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


# ------------------------------------------------------------------ AdamW
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.init(params, cfg)
    for _ in range(200):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state = adamw.update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_bf16_state_dtype():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    state = adamw.init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    _, state2 = adamw.update({"w": jnp.ones(4)}, state, params, cfg)
    assert state2.mu["w"].dtype == jnp.bfloat16


def test_adamw_clip_norm():
    params = {"w": jnp.zeros(3)}
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    state = adamw.init(params, cfg)
    big = {"w": jnp.full(3, 1e6)}
    new_params, _ = adamw.update(big, state, params, cfg)
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 10.0


def test_schedule_warmup_cosine():
    s = schedule.warmup_cosine(0, warmup_steps=10, total_steps=100)
    assert float(s) == 0.0
    assert float(schedule.warmup_cosine(10, warmup_steps=10,
                                        total_steps=100)) > 0.9
    end = schedule.warmup_cosine(100, warmup_steps=10, total_steps=100,
                                 min_ratio=0.1)
    np.testing.assert_allclose(float(end), 0.1, atol=1e-5)


# --------------------------------------------------------- grad compression
@given(seed=st.integers(0, 2**16))
def test_compress_decompress_bounded_error(seed):
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64,))}
    ef = grad_compress.init(g)
    wire, scales, ef2 = grad_compress.compress(g, ef)
    back = grad_compress.decompress(wire, scales)
    max_err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    scale = float(scales["w"])
    assert max_err <= scale * 0.51 + 1e-6     # half-ulp of int8 grid
    # error feedback holds the residual
    np.testing.assert_allclose(np.asarray(ef2.error["w"], np.float32),
                               np.asarray(g["w"] - back["w"]), atol=2e-2)


def test_error_feedback_preserves_signal_over_steps():
    """A constant tiny gradient below one quantization step must still get
    through within a few iterations thanks to error feedback."""
    g = {"w": jnp.full((8,), 1e-3)}
    big = {"w": jnp.zeros(8).at[0].set(1.0)}   # sets scale = 1/127
    ef = grad_compress.init(g)
    acc = jnp.zeros(8)
    for _ in range(20):
        mixed = {"w": g["w"] + big["w"] * 0}
        # keep scale dominated by a separate large entry
        mixed["w"] = mixed["w"].at[0].set(1.0)
        wire, scales, ef = grad_compress.compress(mixed, ef)
        acc = acc + grad_compress.decompress(wire, scales)["w"]
    # entry 1..7 each delivered ~20*1e-3 total despite quant step ~7.9e-3
    np.testing.assert_allclose(acc[1:], 20e-3, rtol=0.2)


def test_wire_dtype_halves_bytes():
    g = {"w": jnp.zeros((128,), jnp.float32)}
    wire, _, _ = grad_compress.compress(g, grad_compress.init(g))
    assert wire["w"].dtype == jnp.bfloat16    # 2B vs 4B on the wire


# ------------------------------------------------------------------- data
def test_synthetic_determinism():
    a = synthetic.lm_batch(0, 3, 7, 4, 16, 100)
    b = synthetic.lm_batch(0, 3, 7, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic.lm_batch(0, 4, 7, 4, 16, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_markov_structure_learnable():
    batch = synthetic.markov_tokens(0, 0, 0, 8, 256, 64)
    a = 6364136223846793005 % 64
    follows = np.mean(batch[:, 1:] == (a * batch[:, :-1]) % 64)
    assert follows > 0.6                     # 80% greedy transitions


def test_pipeline_prefetch_and_restore():
    mk = lambda shard, step: synthetic.lm_batch(0, shard, step, 2, 8, 50)
    pipe = pipeline.ShardedPipeline(mk, n_shards=2, shard=1).start()
    it = iter(pipe)
    b0, b1 = next(it), next(it)
    state = pipe.state_dict()
    pipe.stop()
    assert state["step"] == 2
    pipe2 = pipeline.ShardedPipeline.restore(mk, state).start()
    b2 = next(iter(pipe2))
    pipe2.stop()
    expect = synthetic.lm_batch(0, 1, 2, 2, 8, 50)
    np.testing.assert_array_equal(b2["tokens"], expect["tokens"])


def test_pipeline_elastic_reshard():
    mk = lambda shard, step: synthetic.lm_batch(0, shard, step, 2, 8, 50)
    pipe = pipeline.ShardedPipeline(mk, n_shards=4, shard=3).start()
    next(iter(pipe))
    state = pipe.state_dict()
    pipe.stop()
    pipe2 = pipeline.ShardedPipeline.restore(mk, state, n_shards=2, shard=1)
    assert pipe2.n_shards == 2 and pipe2.shard == 1 and pipe2.step == 1


# -------------------------------------------------------------- stragglers
def test_straggler_monitor_flags_outliers(monkeypatch):
    """Deterministic: drive the monitor with an injected clock (wall-clock
    sleeps flake under load)."""
    import repro.runtime.straggler as strag
    now = [0.0]
    monkeypatch.setattr(strag.time, "perf_counter", lambda: now[0])
    mon = StragglerMonitor(StragglerConfig(warmup_steps=0, threshold=1.5,
                                           patience=2))
    durations = [0.01, 0.01, 0.01, 0.01, 0.5, 0.5]  # steps 5,6 straggle
    for dt in durations:
        mon.step_start()
        now[0] += dt
        r = mon.step_end()
    assert r["flagged"]
    assert r["exclude_vote"]                  # 2 consecutive -> vote
    assert mon.flagged_steps == [5, 6]
