"""Core dataflow optimizations: OPT1/OPT2/OPT3 + LIF + events (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import direct_coding as dc
from repro.core import eafc, econv, events, lif, spikes


def _spikes(key, shape, p=0.2):
    return (jax.random.uniform(key, shape) < p).astype(jnp.float32)


# ------------------------------------------------------------------- OPT2
@pytest.mark.parametrize("hw,ci,co,k", [(8, 16, 24, 3), (6, 8, 32, 3),
                                        (10, 4, 8, 5)])
def test_econv_scatter_equals_tconv(hw, ci, co, k):
    s = _spikes(jax.random.PRNGKey(0), (2, hw, hw, ci))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, k, ci, co))
    ref = econv.tconv(s, w)
    ev = econv.econv_scatter(s, w)
    np.testing.assert_allclose(ev, ref, atol=1e-5)


def test_econv_event_cost_scales_with_sparsity():
    co, k = 64, 3
    dense = _spikes(jax.random.PRNGKey(0), (1, 16, 16, 32), p=0.9)
    sparse = _spikes(jax.random.PRNGKey(1), (1, 16, 16, 32), p=0.1)
    assert econv.event_ops(sparse, co, k) < econv.event_ops(dense, co, k)
    # TConv cost is sparsity-independent (Fig. 1c)
    assert econv.tconv_ops(16, 16, 32, co, k) == 16 * 16 * 9 * 32 * co


# ------------------------------------------------------------------- OPT3
@pytest.mark.parametrize("pool", [2, 4])
def test_eafc_equals_avgpool_fc(pool):
    s = _spikes(jax.random.PRNGKey(2), (3, 8, 8, 16))
    w = jax.random.normal(jax.random.PRNGKey(3),
                          ((8 // pool) ** 2 * 16, 10))
    np.testing.assert_allclose(eafc.eafc(s, w, pool),
                               eafc.avgpool_fc_ref(s, w, pool),
                               atol=1e-4, rtol=1e-4)


def test_eafc_weight_scaling():
    w = jnp.ones((4, 4))
    np.testing.assert_allclose(eafc.scale_fc_weights(w, 4), w / 16.0)


# ------------------------------------------------------------------- OPT1
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_direct_coding_matmul_exact(bits):
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    ref = dc.reference_quantized_matmul(x, w, bits)
    ev = dc.direct_coded_matmul(x, w, bits)
    np.testing.assert_allclose(ev, ref, atol=1e-4, rtol=1e-4)


def test_direct_coding_conv_exact():
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(7), (3, 3, 3, 8))
    ref = dc.reference_quantized_conv(x, w, 8)
    ev = dc.direct_coded_conv(x, w, 8)
    np.testing.assert_allclose(ev, ref, atol=1e-3, rtol=1e-3)


def test_bit_slice_planes_are_binary():
    q, _ = dc.quantize(jax.random.normal(jax.random.PRNGKey(8), (16,)), 8)
    planes = dc.bit_slice(q, 8)
    assert planes.shape == (8, 16)
    assert bool(jnp.all((planes == 0) | (planes == 1)))


# -------------------------------------------------------------------- LIF
def test_lif_spikes_binary_and_membrane_bounded():
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 4, 32)) * 3
    s = lif.lif_scan(x)
    assert bool(jnp.all((s == 0) | (s == 1)))
    # soft reset with decay<1 keeps membrane geometrically bounded:
    # |v| <= max|x| / (1 - decay) + v_th
    cfg = lif.LIFConfig()
    bound = float(jnp.max(jnp.abs(x))) / (1 - cfg.decay) + cfg.v_th
    v = jnp.zeros((4, 32))
    for t in range(16):
        v, _ = lif.lif_step(v, x[t], cfg)
        assert bool(jnp.all(jnp.abs(v) <= bound))


def test_lif_never_fires_below_threshold():
    x = jnp.full((8, 2, 16), 0.4)   # geometric sum 0.4/(1-0.5) = 0.8 < 1.0
    s = lif.lif_scan(x)
    assert float(jnp.sum(s)) == 0.0


def test_lif_surrogate_gradient_nonzero():
    def f(x):
        return jnp.sum(lif.lif_scan(x))
    g = jax.grad(f)(jnp.full((4, 2, 8), 0.9))
    assert float(jnp.sum(jnp.abs(g))) > 0.0


# ------------------------------------------------------------------ events
def test_fast_event_filter_orders_lowest_first():
    out = events.fast_event_filter(jnp.uint32(0b10110))
    assert list(out[:3]) == [1, 2, 4]
    assert int(out[3]) == -1


def test_event_stream_roundtrip():
    s = _spikes(jax.random.PRNGKey(10), (4, 4, 8), p=0.3)
    stream = events.to_event_stream(s, max_events=int(4 * 4 * 8))
    n = int(jnp.sum(s))
    assert int(jnp.sum(stream.valid)) == n


def test_word_event_counts_match_dense_sum():
    s = _spikes(jax.random.PRNGKey(11), (4, 64), p=0.5)
    assert int(jnp.sum(events.word_event_counts(s))) == int(jnp.sum(s))


# ----------------------------------------------------------------- spikes
def test_tile_occupancy():
    s = jnp.zeros((8, 256))
    s = s.at[0, 0].set(1.0)
    occ = spikes.tile_occupancy(s, 8, 128)
    assert occ.shape == (1, 2)
    assert int(occ[0, 0]) == 1 and int(occ[0, 1]) == 0
    assert float(spikes.occupancy_fraction(s, 8, 128)) == 0.5
