"""Mesh-aware dispatch: resolution semantics (single process) + the
8-device shard_map parity payload (shared multi-device subprocess).

The single-process tests drive `resolve(..., mesh=)` with plain shard
counts — mesh-aware resolution is a pure function of shapes and the
registry, so it needs no devices. The actual 8-way shard_map execution
(forward/backward parity vs the single-device oracle, per-shard CSR work
lists, degrade attribution) runs in conftest's MULTIDEVICE_SCRIPT
`MESH_DISPATCH` section and is asserted here via its marker.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch

CSR = "pallas-csr-interpret"


@pytest.fixture(autouse=True)
def _fresh_dispatch_state(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    dispatch.reset_fallback_warnings()


def _spikes(key, shape, density=0.1):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


# ------------------------------------------------- resolution semantics
def test_mesh_resolution_keeps_csr_when_shards_tile_cleanly():
    s = _spikes(jax.random.PRNGKey(0), (1024, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_backend(CSR, op="spike_matmul"):
        assert dispatch.resolve_name("spike_matmul", s, w, mesh=8) == CSR
        assert dispatch.resolve_attribution("spike_matmul", s, w,
                                            mesh=8) == CSR


def test_mesh_resolution_degrades_csr_on_ragged_shard_grids():
    # 512 rows / 8 shards = 64 < one 128-row tile per shard
    s = _spikes(jax.random.PRNGKey(1), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_backend(CSR, op="spike_matmul"):
        assert dispatch.resolve_name("spike_matmul", s, w) == CSR
        with pytest.warns(RuntimeWarning, match="per-shard rows"):
            assert dispatch.resolve_name("spike_matmul", s, w, mesh=8) \
                == "pallas-interpret"
        assert dispatch.resolve_attribution("spike_matmul", s, w, mesh=8) \
            == f"pallas-interpret<-{CSR}"


def test_use_mesh_context_is_ambient_and_scoped():
    s = _spikes(jax.random.PRNGKey(2), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_backend(CSR, op="spike_matmul"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            with dispatch.use_mesh(8):
                assert dispatch.ambient_mesh() == 8
                assert dispatch.resolve_name("spike_matmul", s, w) \
                    == "pallas-interpret"
        assert dispatch.ambient_mesh() is None
        assert dispatch.resolve_name("spike_matmul", s, w) == CSR


def test_non_mesh_aware_backend_is_refused_under_mesh():
    """econv's serialized event-scatter path never declared `mesh_aware`;
    under a mesh an explicit override must degrade it to ref (it has no
    declared fallback), not run it per shard."""
    args, kwargs = dispatch.example_inputs("econv", jax.random.PRNGKey(3))
    with dispatch.use_backend("jnp", op="econv"):
        assert dispatch.resolve_name("econv", *args, **kwargs) == "jnp"
        with pytest.warns(RuntimeWarning, match="not declared mesh-aware"):
            assert dispatch.resolve_name("econv", *args, mesh=2,
                                         **kwargs) == dispatch.REF


def test_mesh_auto_resolution_records_degrade_attribution():
    """No override: auto selection under a mesh skips gated candidates by
    priority and resolved_backends carries the `<-requested` attribution
    (canonical example shapes never fill a per-shard tile)."""
    with dispatch.use_backend(CSR, op="apec_matmul"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            rb = dispatch.resolved_backends(mesh=8)
    assert rb["apec_matmul"] == f"pallas-interpret<-{CSR}"
    # and without a mesh the same map keeps plain (undegraded) names
    with dispatch.use_backend(CSR, op="apec_matmul"):
        assert dispatch.resolved_backends()["apec_matmul"] == CSR


def test_data_shard_count_reads_batch_axes_only():
    from repro.launch.mesh import abstract_mesh
    assert dispatch.data_shard_count(None) == 1
    assert dispatch.data_shard_count(8) == 8
    m = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    assert dispatch.data_shard_count(m) == 4          # pod*data, not model
    assert dispatch.data_shard_count(
        abstract_mesh((4, 2), ("data", "model"))) == 4


def test_mesh_one_shard_is_plain_resolution():
    from repro.launch.mesh import abstract_mesh
    s = _spikes(jax.random.PRNGKey(4), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_backend(CSR, op="spike_matmul"):
        assert dispatch.resolve_name("spike_matmul", s, w, mesh=1) == CSR
        # a model-only mesh shards features, not event rows: no gate
        assert dispatch.resolve_name(
            "spike_matmul", s, w,
            mesh=abstract_mesh((4,), ("model",))) == CSR


def test_dispatch_entry_accepts_mesh_and_matches_oracle():
    s = _spikes(jax.random.PRNGKey(5), (256, 128))
    w = jax.random.normal(jax.random.PRNGKey(6), (128, 32), jnp.float32)
    out = dispatch.dispatch("spike_matmul", s, w, mesh=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                               atol=1e-5)


def test_steps_factory_traces_under_mesh():
    """launch.steps wraps step fns in use_mesh: resolution inside the jit
    trace must see the ambient mesh. Probed with a fn that records the
    ambient mesh at trace time."""
    from repro.launch import steps as steps_mod
    seen = []

    def probe(x):
        seen.append(dispatch.ambient_mesh())
        return x

    wrapped = steps_mod._under_mesh(probe, 8)
    jax.jit(wrapped)(jnp.zeros((2,)))
    assert seen == [8]
    assert steps_mod._under_mesh(probe, None) is probe


# ------------------------------------------------------ warn-once dedup
def test_degrade_chain_warns_exactly_once_per_op_per_process():
    """The csr->pallas degrade and the pallas->ref surrender each fire ONE
    RuntimeWarning per (op, from, to) per process — resolution happens at
    trace time, and a retrace storm repeating the warning would bury it."""
    s = _spikes(jax.random.PRNGKey(7), (10, 32), 0.5)
    w = jnp.zeros((32, 8), jnp.float32)
    with dispatch.use_backend(CSR, op="apec_matmul"):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for _ in range(3):          # retraces / repeated resolutions
                dispatch.resolve("apec_matmul", s, w, g=3)
        msgs = [str(r.message) for r in rec
                if issubclass(r.category, RuntimeWarning)]
        assert len(msgs) == 2, msgs     # one degrade + one ref surrender
        assert any("degrading to 'pallas-interpret'" in m for m in msgs)
        assert any("falling back to 'ref'" in m for m in msgs)
        # re-armed explicitly -> fires again (fresh-process behavior)
        dispatch.reset_fallback_warnings()
        with warnings.catch_warnings(record=True) as rec2:
            warnings.simplefilter("always")
            dispatch.resolve("apec_matmul", s, w, g=3)
        assert len([r for r in rec2
                    if issubclass(r.category, RuntimeWarning)]) == 2


def test_mesh_degrade_warns_once_and_separately_from_flat_path():
    """The mesh gate's degrade is its own (op, from, to) edge only when it
    lands elsewhere; same-edge degrades share one warning with the flat
    path — per op per process means per resolution edge, not per call."""
    s = _spikes(jax.random.PRNGKey(8), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_backend(CSR, op="spike_matmul"):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for _ in range(4):
                dispatch.resolve("spike_matmul", s, w, mesh=8)
        msgs = [str(r.message) for r in rec
                if issubclass(r.category, RuntimeWarning)]
        assert len(msgs) == 1, msgs
        assert "per-shard rows" in msgs[0]
        # flat path resolves csr fine -> no new warning
        with warnings.catch_warnings(record=True) as rec2:
            warnings.simplefilter("always")
            dispatch.resolve("spike_matmul", s, w)
        assert not rec2


def test_resolved_backends_snapshot_does_not_consume_warn_budget():
    """The serve/train startup log calls resolved_backends() with
    warnings suppressed; that read-only snapshot must not eat the
    once-per-edge budget, or the first REAL degrade on the same edge
    would be silent for the rest of the process."""
    s = _spikes(jax.random.PRNGKey(9), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    with dispatch.use_backend(CSR, op="spike_matmul"):
        rb = dispatch.resolved_backends(mesh=8)   # degrades internally
        assert rb["spike_matmul"] == f"pallas-interpret<-{CSR}"
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            dispatch.resolve("spike_matmul", s, w, mesh=8)
        assert len([r for r in rec
                    if issubclass(r.category, RuntimeWarning)]) == 1


def test_per_shard_occupied_tiles_splits_spike_rows_not_tile_rows():
    """512 uniform rows over 8 shards: every 64-row shard pads to one
    occupied 128-tile. Splitting the global map's 4 TILE rows instead
    would report half the shards empty — the straggler signal must track
    the rows shard_map actually hands each shard."""
    from repro.runtime import sharding as rs
    s = jnp.ones((512, 128), jnp.float32)
    assert rs.per_shard_occupied_tiles(s, 8) == [1] * 8
    # clustered case: only the first shard's rows hold events
    s2 = jnp.zeros((1024, 128), jnp.float32).at[:128].set(1.0)
    per = rs.per_shard_occupied_tiles(s2, 8)
    assert per == [1] + [0] * 7


def test_event_op_sharded_rejects_csr_stack_for_other_ops():
    from repro.core.spikes import shard_occupancy_to_csr, stack_shard_csrs
    from repro.kernels import ops
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding as rs
    s = _spikes(jax.random.PRNGKey(10), (256, 128))
    w = jnp.zeros((128, 64), jnp.float32)
    stack = stack_shard_csrs(shard_occupancy_to_csr(
        ops.padded_occupancy(s), 2, tiling=(128, 128)))
    mesh1 = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="spike_matmul pass-through"):
        rs.event_op_sharded(mesh1, "apec_matmul", s, w, g=2,
                            csr_stack=stack)


# ----------------------------------------- hybrid route-keyed warn-once
def test_hybrid_route_warn_not_suppressed_by_plain_degrade():
    """The plain override degrade and hybrid's event-route refusal share
    the same (op, from, to) edge — csr -> its dense fallback. Warn-once
    state is keyed by route too, so the first HYBRID warning must fire
    even after the plain degrade already consumed the route-less key
    (each names a different decision the user needs to see once)."""
    from repro.kernels import ops
    s = _spikes(jax.random.PRNGKey(30), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    occ = ops.padded_occupancy(s)
    # 1) plain degrade eats the route-less (op, csr, dense) key
    with dispatch.use_backend(CSR, op="spike_matmul"):
        with pytest.warns(RuntimeWarning, match="per-shard rows"):
            dispatch.resolve("spike_matmul", s, w, mesh=8)
    # 2) hybrid's event-route refusal on the same edge still warns once
    with dispatch.use_hybrid("spike_matmul"):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for _ in range(3):
                be, attr = dispatch.resolve_with_attribution(
                    "spike_matmul", s, w, mesh=8, occupancy=occ)
        msgs = [str(r.message) for r in rec
                if issubclass(r.category, RuntimeWarning)]
        assert len(msgs) == 1, msgs
        assert "hybrid event route" in msgs[0]
        assert be.name == "pallas-interpret"
        assert attr == f"pallas-interpret<-{dispatch.HYBRID}"


def test_hybrid_route_warn_rearms_after_reset():
    """reset_fallback_warnings covers the route-keyed entries too: after a
    reset, the hybrid route warning fires again (fresh-process behavior),
    exactly like the plain degrade chain's."""
    from repro.kernels import ops
    s = _spikes(jax.random.PRNGKey(31), (512, 256))
    w = jnp.zeros((256, 64), jnp.float32)
    occ = ops.padded_occupancy(s)
    with dispatch.use_hybrid("spike_matmul"):
        with pytest.warns(RuntimeWarning, match="hybrid event route"):
            dispatch.resolve_with_attribution(
                "spike_matmul", s, w, mesh=8, occupancy=occ)
        dispatch.reset_fallback_warnings()
        with pytest.warns(RuntimeWarning, match="hybrid event route"):
            dispatch.resolve_with_attribution(
                "spike_matmul", s, w, mesh=8, occupancy=occ)


def test_occupancy_imbalance_carries_routes():
    """The straggler report's occ_routes field: per-shard hybrid route
    choices ride alongside the occupied-tile skew (positional, shard
    order) and stay out of the fields string when hybrid is off."""
    from repro.runtime.straggler import occupancy_imbalance
    imb = occupancy_imbalance([4, 0, 1], routes=("dense", "event", "event"))
    assert imb.routes == ("dense", "event", "event")
    assert "occ_routes=dense:event:event" in imb.as_fields()
    assert "occ_routes" not in occupancy_imbalance([4, 0, 1]).as_fields()


def test_event_op_sharded_reports_per_shard_hybrid_routes():
    """A skewed concrete map under hybrid: the with_report occupancy
    imbalance names each shard's route — a sparse shard on the event
    kernel while dense shards run predicated is the feature, and
    `occ_routes` is where it surfaces."""
    from repro.kernels import ops
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding as rs
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    n_dev = 2
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    # shard 0 dense, shard 1 nearly empty
    s = jnp.zeros((256 * n_dev, 256), jnp.float32).at[:256].set(1.0)
    s = s.at[256, 0].set(1.0)
    w = jnp.zeros((256, 64), jnp.float32)
    occ = ops.padded_occupancy(s)
    with dispatch.use_hybrid("spike_matmul"):
        out, rep = rs.event_op_sharded(mesh, "spike_matmul", s, w,
                                       occupancy=occ, with_report=True)
    assert dispatch.HYBRID in rep["attribution"]
    routes = rep["occupancy"].routes
    assert len(routes) == n_dev
    assert routes[0] == "dense" and routes[1] == "event", routes
    assert "occ_routes=dense:event" in rep["occupancy"].as_fields()


# ------------------------------------------------- 8-device subprocess
def test_mesh_dispatch_multidevice_parity(multidevice_run):
    """8-way mesh: spike/apec matmuls resolve to the csr family inside
    shard_map, match single-device outputs within 1e-5 forward AND
    backward, per-shard CSR work lists compose, and the ragged-grid case
    degrades with attribution. (Payload in conftest.MULTIDEVICE_SCRIPT.)
    """
    multidevice_run.check("MESH_DISPATCH")
