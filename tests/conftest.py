import os

# Tests must see the single real CPU device (the dry-run sets its own
# device-count flag in its own process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
