import os
import subprocess
import sys
import textwrap

import pytest

# Tests must see the single real CPU device (the dry-run sets its own
# device-count flag in its own process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Test models are tiny: XLA compile time dominates wall clock, so skip the
# backend optimization pipeline (~30% faster suite; export XLA_FLAGS to
# override).
os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")

try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


# ---------------------------------------------------------------------------
# Shared 8-host-device subprocess: every multi-device test payload runs in
# ONE child process (one interpreter + jax import + compile session instead
# of one per test module). Payloads are independent try/except sections, so
# one failure doesn't mask the others; each test asserts its own marker.
# ---------------------------------------------------------------------------
MULTIDEVICE_SCRIPT = textwrap.dedent("""
    import os
    # 8 *host* (CPU) devices; pin the platform so jax never probes the TPU
    # runtime — on TPU-toolchain images without a TPU attached, that probe
    # blocks for minutes in libtpu initialization timeouts.
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               "--xla_backend_optimization_level=0")
    import tempfile
    import traceback

    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh, use_concrete_mesh

    def section(name, fn):
        try:
            fn()
        except Exception:
            print(name + "_FAIL", flush=True)
            traceback.print_exc()
        else:
            print(name + "_OK", flush=True)

    def ckpt_elastic():
        from repro.checkpoint import checkpointer
        with tempfile.TemporaryDirectory() as d:
            # save on a (4, 2) mesh
            mesh_a = make_mesh((4, 2), ("data", "model"))
            x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
            xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
            checkpointer.save(d, 1, {"x": xa})
            # restore onto a (2, 2) mesh — elastic shrink (data axis halved)
            mesh_b = make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
            sh = {"x": NamedSharding(mesh_b, P("data", "model"))}
            out = checkpointer.restore(d + "/step_000000001", {"x": x}, sh)
            assert out["x"].sharding.mesh.shape["data"] == 2
            np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))

    def elastic_e2e():
        from repro.configs.base import LMConfig, SpikingConfig
        from repro.launch.train import train_loop
        from repro.runtime.elastic import shrunk_mesh
        cfg = LMConfig(name="elastic", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=64, spiking=SpikingConfig(t_steps=1),
                       remat="none", loss_chunk=16)
        with tempfile.TemporaryDirectory() as d:
            mesh_a = make_mesh((4, 2), ("data", "model"))
            out1 = train_loop(cfg, steps=6, batch=8, seq=16, ckpt_dir=d,
                              save_every=3, mesh=mesh_a, log_every=100)
            # 2 of 4 data groups "fail": plan the shrink, rebuild, resume.
            plan = shrunk_mesh((4, 2), ("data", "model"),
                               n_failed_data_groups=2)
            assert plan.mesh_shape == (2, 2) and plan.microbatch_scale == 2
            mesh_b = make_mesh(plan.mesh_shape, plan.axis_names,
                               devices=jax.devices()[:4])
            out2 = train_loop(cfg, steps=10, batch=8, seq=16, ckpt_dir=d,
                              save_every=3, resume=True, mesh=mesh_b,
                              log_every=100)
            assert len(out2["losses"]) == 4            # resumed at step 6
            assert np.isfinite(out2["final_loss"])

    # One jitted train step per (cfg, mesh, spiking): the drill sections
    # replay the same step across healthy/failure/resumed phases, so the
    # jit wrapper must be shared or every phase pays a recompile.
    _DRILL_STEPS = {}

    def _drill_step_fn(cfg, mesh, spiking):
        import functools
        from repro.launch import steps as steps_mod
        from repro.optim import adamw, schedule as sched
        key = (cfg.name, id(mesh), spiking)
        if key not in _DRILL_STEPS:
            schedule_fn = functools.partial(
                sched.warmup_cosine, warmup_steps=2, total_steps=10)
            _DRILL_STEPS[key] = jax.jit(steps_mod.make_train_step(
                cfg, adamw.AdamWConfig(lr=1e-2), schedule_fn,
                spiking=spiking, mesh=mesh))
        return _DRILL_STEPS[key]

    def _drill_loop(cfg, mesh, params, opt_state, batches, start, stop,
                    mgr=None, spiking=False):
        # Feed IDENTICAL global batches regardless of mesh shape (unlike
        # train_loop, which feeds shard 0 local rows — that would give the
        # shrunk mesh different data and no comparable loss trajectory).
        from repro.optim import adamw
        from repro.runtime import sharding
        p_sh = sharding.named(mesh, sharding.param_specs(cfg, params, mesh))
        o_sh = adamw.AdamWState(step=NamedSharding(mesh, P()),
                                mu=p_sh, nu=p_sh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = _drill_step_fn(cfg, mesh, spiking)
        losses = []
        for t in range(start, stop):
            dev = {k: jnp.asarray(v) for k, v in batches[t].items()}
            params, opt_state, metrics = step_fn(params, opt_state, dev)
            losses.append(float(metrics["loss"]))
            if mgr and mgr.should_save(t + 1):
                mgr.save(t + 1, (params, opt_state))
        if mgr:
            mgr.wait()
        return params, opt_state, losses

    def elastic_drill():
        # Recovery drill: mid-training shard loss AND a torn newest
        # checkpoint. restore_latest must walk back to the newest VALID
        # snapshot, reshard_restore must load it onto the shrunk mesh, and
        # the resumed loss trajectory must track the healthy run (same
        # global batches; only fp reduction order differs across meshes).
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs.base import LMConfig, SpikingConfig
        from repro.data import synthetic
        from repro.models import lm
        from repro.optim import adamw
        from repro.runtime import faults
        from repro.runtime.elastic import shrunk_mesh, reshard_restore
        cfg = LMConfig(name="drill", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=64, spiking=SpikingConfig(t_steps=1),
                       remat="none", loss_chunk=16)
        batches = [synthetic.lm_batch(0, 0, t, 8, 16, cfg.vocab)
                   for t in range(10)]
        params0 = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt0 = adamw.init(params0, adamw.AdamWConfig(lr=1e-2))
        mesh_a = make_mesh((4, 2), ("data", "model"))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, save_every=3)
            *_, healthy = _drill_loop(cfg, mesh_a, params0, opt0, batches,
                                      0, 10, mgr=mgr)     # saves 3, 6, 9
            # 2 of 4 data groups die; the newest checkpoint is also torn
            # (writer died with the shard) — recovery must not trust it.
            faults.truncate_checkpoint(os.path.join(d, "step_000000009"))
            plan = shrunk_mesh((4, 2), ("data", "model"),
                               n_failed_data_groups=2)
            assert plan.mesh_shape == (2, 2)
            mesh_b = make_mesh(plan.mesh_shape, plan.axis_names,
                               devices=jax.devices()[:4])
            step, (p, o) = reshard_restore(cfg, mgr, (params0, opt0),
                                           mesh_b)
            assert step == 6, step   # walked back past the torn snapshot
            *_, resumed = _drill_loop(cfg, mesh_b, p, o, batches, 6, 10)
        assert all(np.isfinite(resumed))
        np.testing.assert_allclose(resumed, healthy[6:10],
                                   rtol=0.05, atol=0.05)

    def elastic_packed():
        # The packed-payload config must survive the same elastic
        # roundtrip: checkpoint a SpikingConfig(packed=True) run, restore
        # onto the shrunk mesh, and replay one step — under guard audit —
        # with loss parity vs the pre-failure trajectory.
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs.base import LMConfig, SpikingConfig
        from repro.data import synthetic
        from repro.kernels import dispatch
        from repro.models import lm
        from repro.optim import adamw
        from repro.runtime.elastic import shrunk_mesh, reshard_restore
        cfg = LMConfig(name="drill-packed", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=64,
                       spiking=SpikingConfig(t_steps=1, packed=True),
                       remat="none", loss_chunk=16)
        batches = [synthetic.lm_batch(1, 0, t, 8, 16, cfg.vocab)
                   for t in range(5)]
        params0 = lm.init_params(cfg, jax.random.PRNGKey(1))
        opt0 = adamw.init(params0, adamw.AdamWConfig(lr=1e-2))
        mesh_a = make_mesh((4, 2), ("data", "model"))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, save_every=4)
            *_, pre = _drill_loop(cfg, mesh_a, params0, opt0, batches,
                                  0, 5, mgr=mgr, spiking=True)  # saves @4
            plan = shrunk_mesh((4, 2), ("data", "model"),
                               n_failed_data_groups=2)
            mesh_b = make_mesh(plan.mesh_shape, plan.axis_names,
                               devices=jax.devices()[:4])
            step, (p, o) = reshard_restore(cfg, mgr, (params0, opt0),
                                           mesh_b)
            assert step == 4, step
            with dispatch.use_guard("audit"):   # no false positives under
                *_, replay = _drill_loop(cfg, mesh_b, p, o, batches,  # jit
                                         4, 5, spiking=True)
        np.testing.assert_allclose(replay[0], pre[4], rtol=0.05, atol=0.05)

    def shard_map_moe():
        from repro.models import moe
        mesh = make_mesh((2, 4), ("data", "model"))
        p = moe.moe_init(jax.random.PRNGKey(0), 32, 16, n_experts=8)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32),
                              jnp.float32)
        ref = moe.moe_apply(p, x, top_k=2, capacity_factor=8.0)
        with mesh, use_concrete_mesh(mesh):
            p_sh = jax.device_put(p, {
                "router": NamedSharding(mesh, P(None, None)),
                "w_gate": NamedSharding(mesh, P("model", None, None)),
                "w_up": NamedSharding(mesh, P("model", None, None)),
                "w_down": NamedSharding(mesh, P("model", None, None)),
            })
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            out = jax.jit(lambda pp, xx: moe.moe_apply_shard_map(
                pp, xx, top_k=2, capacity_factor=8.0))(p_sh, xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def mesh_dispatch():
        import warnings
        from repro.core.spikes import (shard_occupancy_to_csr,
                                       stack_shard_csrs)
        from repro.kernels import dispatch, ops
        from repro.runtime import sharding
        mesh8 = make_mesh((8, 1), ("data", "model"))
        # 1024 rows / 8 shards = 128: per-shard tile grids divide cleanly,
        # so mesh-aware resolution must KEEP the csr family per shard.
        s = (jax.random.uniform(jax.random.PRNGKey(0), (1024, 128)) < 0.05
             ).astype(jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 64), jnp.float32)
        ref = np.asarray(jnp.dot(s, w))        # single-device oracle
        g_ref = np.asarray(jax.grad(lambda ww: jnp.sum(s @ ww))(w))
        with dispatch.use_backend("pallas-csr-interpret", op="spike_matmul"):
            out, rep = sharding.event_op_sharded(
                mesh8, "spike_matmul", s, w, with_report=True)
            assert rep["backend"] == "pallas-csr-interpret", rep
            assert rep["attribution"] == "pallas-csr-interpret", rep
            assert rep["n_shards"] == 8 and rep["occupancy"].imbalance >= 1.0
            np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
            g = jax.grad(lambda ww: jnp.sum(sharding.event_op_sharded(
                mesh8, "spike_matmul", s, ww)))(w)
            np.testing.assert_allclose(np.asarray(g), g_ref, atol=1e-5)
            # per-shard eager work lists (no global-occupancy gather),
            # differentiable like the registry backend (custom transpose)
            stack = stack_shard_csrs(shard_occupancy_to_csr(
                ops.padded_occupancy(s), 8, tiling=(128, 128)))
            out2 = sharding.event_op_sharded(mesh8, "spike_matmul", s, w,
                                             csr_stack=stack)
            np.testing.assert_allclose(np.asarray(out2), ref, atol=1e-5)
            g2 = jax.grad(lambda ww: jnp.sum(sharding.event_op_sharded(
                mesh8, "spike_matmul", s, ww, csr_stack=stack)))(w)
            np.testing.assert_allclose(np.asarray(g2), g_ref, atol=1e-5)
        with dispatch.use_backend("pallas-csr-interpret", op="apec_matmul"):
            out3, rep3 = sharding.event_op_sharded(
                mesh8, "apec_matmul", s, w, g=2, with_report=True)
            assert rep3["attribution"] == "pallas-csr-interpret", rep3
            np.testing.assert_allclose(np.asarray(out3), ref, atol=1e-5)
            g3 = jax.grad(lambda ww: jnp.sum(sharding.event_op_sharded(
                mesh8, "apec_matmul", s, ww, g=2)))(w)
            np.testing.assert_allclose(np.asarray(g3), g_ref, atol=1e-5)
        # 512 rows / 8 shards = 64: ragged per-shard tile grid, so the
        # mesh gate must walk the declared chain — and say so in the
        # attribution — while output parity still holds.
        with dispatch.use_backend("pallas-csr-interpret", op="spike_matmul"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                out4, rep4 = sharding.event_op_sharded(
                    mesh8, "spike_matmul", s[:512], w, with_report=True)
                rb = dispatch.resolved_backends(mesh=mesh8)
            assert rep4["attribution"] \
                == "pallas-interpret<-pallas-csr-interpret", rep4
            np.testing.assert_allclose(np.asarray(out4), ref[:512],
                                       atol=1e-5)
            # canonical example shapes never fill a per-shard tile, so
            # the mesh-aware resolved_backends map shows the degrade too
            assert rb["spike_matmul"] \
                == "pallas-interpret<-pallas-csr-interpret", rb

    def event_tensor():
        from repro.core.events import EventTensor
        from repro.kernels import dispatch
        from repro.runtime import sharding
        mesh8 = make_mesh((8, 1), ("data", "model"))
        s = (jax.random.uniform(jax.random.PRNGKey(5), (1024, 128)) < 0.05
             ).astype(jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(6), (128, 64), jnp.float32)
        et = EventTensor.from_spikes(s)
        ref = np.asarray(jnp.dot(s, w))
        g_ref = np.asarray(jax.grad(lambda ww: jnp.sum(s @ ww))(w))
        with dispatch.use_backend("pallas-csr-interpret", op="spike_matmul"):
            # concrete carried map -> per-shard TRIMMED work lists built
            # from the tiny map (occupancy_source must say so: the
            # sharded path reuses the producer's emission, it does not
            # rebuild local lists from resident spikes)
            out, rep = sharding.event_op_sharded(
                mesh8, "spike_matmul", et, w, with_report=True)
            assert rep["occupancy_source"] == "carried", rep
            assert rep["attribution"] == "pallas-csr-interpret", rep
            np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
            # traced carried map: sharded occupancy operand inside the
            # shard_map body, fwd AND bwd parity vs single device
            f = jax.jit(lambda ov, ww: sharding.event_op_sharded(
                mesh8, "spike_matmul", s, ww, occupancy=ov))
            np.testing.assert_allclose(np.asarray(f(et.occupancy, w)), ref,
                                       atol=1e-5)
            g = jax.grad(lambda ww: jnp.sum(sharding.event_op_sharded(
                mesh8, "spike_matmul", et, ww)))(w)
            np.testing.assert_allclose(np.asarray(g), g_ref, atol=1e-5)
            g2 = jax.jit(jax.grad(lambda ww: jnp.sum(f(et.occupancy, ww))))(w)
            np.testing.assert_allclose(np.asarray(g2), g_ref, atol=1e-5)
        with dispatch.use_backend("pallas-csr-interpret", op="apec_matmul"):
            out3, rep3 = sharding.event_op_sharded(
                mesh8, "apec_matmul", et, w, g=2, with_report=True)
            assert rep3["occupancy_source"] == "carried", rep3
            np.testing.assert_allclose(np.asarray(out3), ref, atol=1e-5)

    def rebalance_pipe():
        from repro.core.spikes import rebalance_shard_plan
        from repro.kernels import dispatch, ops
        from repro.runtime import sharding
        mesh8 = make_mesh((8, 1), ("data", "model"))
        # Hotspot band: every event in the first quarter of the rows, so
        # the static row-contiguous split piles all occupied tiles onto
        # two shards while 16 tile rows / 8 shards = 2 leaves the
        # occupancy-weighted plan room to move whole tile rows. K = 128
        # (one k-tile) like the other sections, so the per-tile partial
        # sums keep the dense oracle's reduction order at atol=1e-5.
        s_np = np.zeros((2048, 128), np.float32)
        s_np[:512] = (np.random.default_rng(0).random((512, 128)) < 0.3
                      ).astype(np.float32)
        s = jnp.asarray(s_np)
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 64), jnp.float32)
        occ = np.asarray(ops.padded_occupancy(s))
        plan = rebalance_shard_plan(occ, 8)
        assert sorted(plan.perm.tolist()) == list(range(16)), plan
        assert not plan.identity and plan.improves, plan
        ref = np.asarray(s @ w)
        g_ref = np.asarray(jax.grad(lambda ww: jnp.sum(s @ ww))(w))
        gs_ref = np.asarray(jax.grad(lambda ss: jnp.sum(ss @ w))(s))
        # Pipelined backend + rebalanced split composed: the pipe kernel
        # consumes the occupancy-weighted per-shard work lists, outputs
        # permute back, fwd AND both grads match the dense oracle.
        with dispatch.use_backend("pallas-csr-pipe-interpret",
                                  op="spike_matmul"):
            out, rep = sharding.event_op_sharded(
                mesh8, "spike_matmul", s, w, occupancy=occ,
                with_report=True)
            assert rep["attribution"] == "pallas-csr-pipe-interpret", rep
            imb = rep["occupancy"]
            assert imb.pre_per_shard and \
                imb.imbalance < imb.pre_imbalance, imb
            np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
            out_st = sharding.event_op_sharded(
                mesh8, "spike_matmul", s, w, occupancy=occ,
                rebalance=False)
            np.testing.assert_allclose(np.asarray(out_st), ref, atol=1e-5)
            g = jax.grad(lambda ww: jnp.sum(sharding.event_op_sharded(
                mesh8, "spike_matmul", s, ww, occupancy=occ)))(w)
            np.testing.assert_allclose(np.asarray(g), g_ref, atol=1e-5)
            gs = jax.grad(lambda ss: jnp.sum(sharding.event_op_sharded(
                mesh8, "spike_matmul", ss, w, occupancy=occ)))(s)
            np.testing.assert_allclose(np.asarray(gs), gs_ref, atol=1e-5)

    section("CKPT_ELASTIC", ckpt_elastic)
    section("ELASTIC_E2E", elastic_e2e)
    section("ELASTIC_DRILL", elastic_drill)
    section("ELASTIC_PACKED", elastic_packed)
    section("SHARD_MAP", shard_map_moe)
    section("MESH_DISPATCH", mesh_dispatch)
    section("EVENT_TENSOR", event_tensor)
    section("REBALANCE_PIPE", rebalance_pipe)
""")


class MultideviceRun:
    def __init__(self, stdout: str, stderr: str):
        self.stdout = stdout
        self.stderr = stderr

    def check(self, name: str):
        assert f"{name}_OK" in self.stdout, (
            f"{name} section did not pass in the shared multi-device "
            f"subprocess.\nstdout: {self.stdout[-1000:]}\n"
            f"stderr: {self.stderr[-3000:]}")


_MULTIDEV_PROC = None


def _spawn_multidevice() -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return subprocess.Popen([sys.executable, "-c", MULTIDEVICE_SCRIPT],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True, env=env, cwd=root)


def _uses_multidevice(item) -> bool:
    return "multidevice_run" in getattr(item, "fixturenames", ())


def pytest_collection_modifyitems(session, config, items):
    """Push the multi-device tests to the end of the run so the shared
    subprocess overlaps with the single-process tests ahead of them."""
    items.sort(key=_uses_multidevice)   # stable: only moves consumers last


def pytest_collection_finish(session):
    """Start the shared multi-device subprocess as soon as we know a
    selected test will consume it. Runs after -k/-m deselection, so
    filtered runs don't pay for an unused 8-device child."""
    global _MULTIDEV_PROC
    if _MULTIDEV_PROC is None and any(
            _uses_multidevice(i) for i in session.items):
        _MULTIDEV_PROC = _spawn_multidevice()


@pytest.fixture(scope="session")
def multidevice_run():
    global _MULTIDEV_PROC
    if _MULTIDEV_PROC is None:       # e.g. fixture requested interactively
        _MULTIDEV_PROC = _spawn_multidevice()
    out, err = _MULTIDEV_PROC.communicate(timeout=600)
    return MultideviceRun(out, err)


def pytest_sessionfinish(session, exitstatus):
    """Don't orphan the shared subprocess when a run aborts (-x) before
    any multi-device test consumed the fixture."""
    if _MULTIDEV_PROC is not None and _MULTIDEV_PROC.poll() is None:
        _MULTIDEV_PROC.kill()
