"""HLO collective parser: computation splitting, trip counts, scaling."""
import textwrap

from repro.launch import hlo_analysis as ha

FAKE_HLO = textwrap.dedent("""\
    HloModule jit_step, entry_computation_layout={()->()}

    %region_0.2 (arg_tuple.1: (s32[], f32[256,256])) -> (s32[], f32[256,256]) {
      %p = f32[256,256]{1,0} parameter(0)
      %ar = f32[256,256]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add.1
      %ag = f32[512,256]{1,0} all-gather(%p), dimensions={0}
      ROOT %t = (s32[], f32[256,256]) tuple(%p)
    }

    %region_1.3 (arg_tuple.3: (s32[], f32[256,256])) -> pred[] {
      %gte = s32[] get-tuple-element(%arg_tuple.3), index=0
      %constant.4 = s32[] constant(10)
      ROOT %cmp = pred[] compare(%gte, %constant.4), direction=LT
    }

    %add.1 (a: f32[], b: f32[]) -> f32[] {
      ROOT %r = f32[] add(%a, %b)
    }

    ENTRY %main.4 (x: f32[256,256]) -> f32[256,256] {
      %rs = f32[16,256]{1,0} reduce-scatter(%x), dimensions={0}
      %while.5 = (s32[], f32[256,256]) while(%tuple), condition=%region_1.3, body=%region_0.2
      ROOT %out = f32[256,256]{1,0} get-tuple-element(%while.5), index=1
    }
""")


def test_split_computations():
    comps, entry = ha.split_computations(FAKE_HLO)
    assert entry == "main.4"
    assert set(comps) == {"region_0.2", "region_1.3", "add.1", "main.4"}


def test_trip_count_extraction():
    comps, _ = ha.split_computations(FAKE_HLO)
    assert ha._trip_count(comps["region_1.3"]) == 10


def test_collective_bytes_scaled_by_trips():
    out = ha.collective_bytes(FAKE_HLO)
    ar = 256 * 256 * 4          # f32[256,256] result
    ag = 512 * 256 * 4
    rs = 16 * 256 * 4
    assert out["all-reduce"] == 10 * ar      # inside 10-trip while
    assert out["all-gather"] == 10 * ag
    assert out["reduce-scatter"] == rs       # entry, once
    assert out["total"] == 10 * ar + 10 * ag + rs


def test_unscaled_counts_each_once():
    out = ha.collective_bytes_unscaled(FAKE_HLO)
    assert out["all-reduce"] == 256 * 256 * 4
    assert out["reduce-scatter"] == 16 * 256 * 4


def test_shape_bytes_dtypes():
    assert ha._shape_bytes("bf16[128,4]") == 128 * 4 * 2
    assert ha._shape_bytes("(f32[8], s8[16])") == 8 * 4 + 16
    assert ha._shape_bytes("pred[100]") == 100


def test_real_scan_module_scaling():
    """End-to-end on a real compiled module: scan flops counted once by
    cost_analysis (the documented limitation this parser compensates)."""
    import jax
    import jax.numpy as jnp

    def f(w, x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(w, w).compile()
    comps, entry = ha.split_computations(compiled.as_text())
    assert entry
    conds = [c for c in comps if ha._trip_count(comps[c]) == 10]
    assert conds, "scan trip count not found in any condition computation"
