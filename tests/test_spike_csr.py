"""Event-compacted (CSR-of-tiles) grid: pre-pass + kernel edge cases.

The registry parity harness already enumerates `pallas-csr[-interpret]`
forward and backward against ref on canonical shapes; these tests pin the
pre-pass invariants and the shapes the harness can't see: all-empty /
all-full inputs, padded rows straddling a tile boundary, the traced
(jit) compaction path, and the occupancy/CSR pass-through.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel
from repro.core.spikes import occupancy_to_csr, tile_csr, tile_occupancy
from repro.kernels import ops
from repro.kernels.spike_matmul import spike_matmul_csr_pallas


def _spikes(key, shape, density):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


# ----------------------------------------------------------- CSR pre-pass
def test_csr_matches_numpy_reference():
    occ = jnp.asarray([[0, 3, 0, 1],
                       [0, 0, 0, 0],
                       [2, 0, 0, 0]])
    csr = occupancy_to_csr(occ)
    # occupied tiles row-major + one dummy for the all-empty row 1
    np.testing.assert_array_equal(csr.row_ptr, [0, 2, 3, 4])
    np.testing.assert_array_equal(csr.tile_m_idx, [0, 0, 1, 2])
    np.testing.assert_array_equal(csr.tile_k_idx, [1, 3, 0, 0])
    np.testing.assert_array_equal(csr.occ, [3, 1, 0, 2])  # dummy occ == 0
    np.testing.assert_array_equal(csr.valid, [1, 1, 1, 1])
    assert csr.n_steps == 4 and csr.n_rows == 3


def test_csr_concrete_cap_is_trimmed_and_padding_clamps():
    occ = jnp.asarray([[1, 0], [0, 5]])
    trimmed = occupancy_to_csr(occ)
    assert trimmed.n_steps == 2          # occupied tiles only, zero padding
    padded = occupancy_to_csr(occ, cap=5)
    # padding steps repeat the last real step (same tile -> no new DMA)
    np.testing.assert_array_equal(padded.tile_m_idx, [0, 1, 1, 1, 1])
    np.testing.assert_array_equal(padded.tile_k_idx, [0, 1, 1, 1, 1])
    np.testing.assert_array_equal(padded.occ, [1, 5, 0, 0, 0])
    np.testing.assert_array_equal(padded.valid, [1, 1, 0, 0, 0])
    with pytest.raises(ValueError, match="cap"):
        occupancy_to_csr(occ, cap=1)


def test_csr_traced_matches_concrete():
    occ = tile_occupancy(_spikes(jax.random.PRNGKey(0), (256, 256), 0.02),
                         128, 128)
    eager = occupancy_to_csr(occ, cap=4)
    traced = jax.jit(occupancy_to_csr, static_argnames=("cap",))(occ, cap=4)
    for a, b in zip(eager, traced):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_csr_all_empty_input_keeps_one_step_per_row():
    csr = tile_csr(jnp.zeros((256, 384)), 128, 128)
    assert csr.n_steps == 2              # one dummy per m-tile row, grid >= 1
    np.testing.assert_array_equal(csr.occ, [0, 0])
    np.testing.assert_array_equal(csr.row_ptr, [0, 1, 2])


def test_csr_all_empty_traced_keeps_one_step_per_row():
    """Traced (jit) compaction of an all-empty map: every m-tile row must
    still own >= 1 (dummy) step — a row with no step would leave its
    output block unzeroed (the kernel only writes visited rows)."""
    occ = jnp.zeros((3, 4), jnp.int32)
    csr = jax.jit(occupancy_to_csr)(occ)
    assert csr.n_steps >= 3
    rows = np.asarray(csr.tile_m_idx)[np.asarray(csr.valid) == 1]
    assert set(rows.tolist()) == {0, 1, 2}      # every row visited
    assert int(np.sum(np.asarray(csr.occ))) == 0  # dummies never compute


def test_csr_traced_cap_below_row_count_raises():
    """A caller cap below the m-tile row count cannot place a dummy step
    in every row — rows past the cap would keep garbage output blocks.
    The static lower bound must be enforced loudly at trace time."""
    occ = jnp.zeros((3, 4), jnp.int32)
    for bad_cap in (0, 1, 2):
        with pytest.raises(ValueError, match="m-tile rows"):
            jax.jit(occupancy_to_csr, static_argnames=("cap",))(
                occ, cap=bad_cap)


def test_csr_kernel_all_empty_traced_writes_zeros():
    """All-zero spikes through the jitted wrapper (traced map -> dense
    cap): the dummy grid must zero every output block, matching the
    concrete-path all-empty test above."""
    s = jnp.zeros((256, 384), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(20), (384, 64))
    occ = ops.padded_occupancy(s)
    out = jax.jit(lambda sv, ov: ops.spike_matmul_csr(sv, w, occupancy=ov))(
        s, occ)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_shard_prepass_stays_concrete_under_ambient_trace():
    """A CONCRETE map closed over by a jitted caller must still get the
    trimmed eager pre-pass. Regression: `shard_occupancy_to_csr` used to
    re-wrap its numpy shard slices with `jnp.asarray`, which under an
    ambient jit trace lifts them to tracers — `occupancy_to_csr` then
    silently took its traced path, staging the whole compaction (cumsum/
    scatter per shard) into the program and replacing the trimmed caps
    with dense ones. A jitted `event_op_sharded` over a carried map paid
    ~4x the work list it was promised."""
    from repro.core.spikes import shard_occupancy_to_csr, stack_shard_csrs

    occ_np = np.zeros((8, 4), np.int32)
    occ_np[0, 1] = 3                       # 1 occupied tile in shard 0
    occ = jnp.asarray(occ_np)              # shards 2,3 all-empty
    built = []

    def f(x):
        stack = stack_shard_csrs(
            shard_occupancy_to_csr(occ, 4, tiling=(128, 128)))
        built.append(stack)
        return x + jnp.sum(stack.valid)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros(()))
    # the pre-pass must NOT be staged into the traced program
    assert "cumsum" not in str(jaxpr) and "scatter" not in str(jaxpr)
    # and the cap must stay the trimmed one: busiest shard has 2 rows ->
    # 2 steps (1 occupied + 1 dummy), pow2 bucket 2 — not rows*kt == 8
    # (leading axis of the stacked fields is the 4 shards)
    assert built[0].tile_m_idx.shape == (4, 2)


# ------------------------------------------------------------ kernel edges
def test_csr_kernel_all_empty_writes_zeros():
    s = jnp.zeros((256, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    out = spike_matmul_csr_pallas(s, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    out = ops.apec_matmul_csr(s, w, g=2)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_csr_kernel_all_full_matches_dense_and_pallas():
    s = jnp.ones((256, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 128))
    dense = np.asarray(s @ w)
    np.testing.assert_allclose(
        np.asarray(spike_matmul_csr_pallas(s, w, interpret=True)), dense,
        atol=1e-3, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ops.spike_matmul_csr(s, w)),
                               np.asarray(ops.spike_matmul(s, w)),
                               atol=1e-3, rtol=1e-5)


@pytest.mark.parametrize("m,k,n", [(130, 200, 60), (100, 300, 200)])
def test_csr_wrapper_padding_straddles_tile_boundary(m, k, n):
    """Rows/cols pad up to the next 128 tile; the padded region must never
    mark a tile occupied or corrupt the sliced-back result."""
    s = _spikes(jax.random.PRNGKey(3), (m, k), 0.1)
    w = jax.random.normal(jax.random.PRNGKey(4), (k, n))
    out = ops.spike_matmul_csr(s, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                               atol=1e-4, rtol=1e-4)


def test_padding_never_marks_a_tile_occupied():
    """An (130, 40) input whose rows 128..129 are zero: after padding to
    (256, 128), tile row 1 holds only zeros + padding and must compact to
    a dummy step (occ == 0), with output rows 128.. exactly zero."""
    s = _spikes(jax.random.PRNGKey(5), (130, 40), 0.5).at[128:].set(0.0)
    occ = ops.padded_occupancy(s, 128, 128)
    assert occ.shape == (2, 1)
    assert int(occ[1, 0]) == 0
    csr = occupancy_to_csr(occ)
    np.testing.assert_array_equal(csr.occ, [int(occ[0, 0]), 0])
    w = jax.random.normal(jax.random.PRNGKey(6), (40, 16))
    out = ops.spike_matmul_csr(s, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(out)[128:], 0.0)


def test_csr_wrapper_traced_matches_eager():
    """Under jit the compaction cap falls back to the dense bound; the
    result must match the trimmed eager path bit-for-bit."""
    s = _spikes(jax.random.PRNGKey(7), (2, 100, 96), 0.05)
    w = jax.random.normal(jax.random.PRNGKey(8), (96, 56))
    eager = ops.spike_matmul_csr(s, w)
    jitted = jax.jit(ops.spike_matmul_csr)(s, w)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               atol=1e-5)
    g = 2
    eager = ops.apec_matmul_csr(s, w, g=g)
    jitted = jax.jit(ops.apec_matmul_csr, static_argnames=("g",))(s, w, g=g)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               atol=1e-5)


def test_apec_csr_fused_matches_dense_with_real_overlap():
    """Groups with guaranteed overlap events: the fused in-kernel combine
    (overlap psum broadcast into g member rows) must equal dense s @ w."""
    base = _spikes(jax.random.PRNGKey(9), (64, 1, 96), 0.3)
    member = _spikes(jax.random.PRNGKey(10), (64, 4, 96), 0.2)
    s = jnp.maximum(jnp.broadcast_to(base, member.shape), member)
    s = s.reshape(256, 96)               # g=4 groups share `base` overlap
    w = jax.random.normal(jax.random.PRNGKey(11), (96, 48))
    out = ops.apec_matmul_csr(s, w, g=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=1e-4)


# --------------------------------------------------- pass-through + costs
def test_spike_matmul_occupancy_passthrough_matches():
    s = _spikes(jax.random.PRNGKey(12), (100, 200), 0.1)
    w = jax.random.normal(jax.random.PRNGKey(13), (200, 60))
    occ = ops.padded_occupancy(s, 128, 128)
    np.testing.assert_array_equal(
        np.asarray(ops.spike_matmul(s, w, occupancy=occ)),
        np.asarray(ops.spike_matmul(s, w)))


def test_spike_matmul_rejects_mismatched_occupancy_shape():
    """An occupancy map for another tiling would gate the wrong tiles
    (Pallas clamps out-of-range block indices) — must raise, not skip."""
    s = _spikes(jax.random.PRNGKey(18), (100, 200), 0.1)
    w = jax.random.normal(jax.random.PRNGKey(19), (200, 60))
    occ = ops.padded_occupancy(s, 128, 128)
    with pytest.raises(ValueError, match="occupancy shape"):
        ops.spike_matmul(s, w, block_m=64, block_n=64, block_k=64,
                         occupancy=occ)


def test_spike_matmul_csr_passthrough_matches():
    s = _spikes(jax.random.PRNGKey(14), (100, 200), 0.1)
    w = jax.random.normal(jax.random.PRNGKey(15), (200, 60))
    csr = occupancy_to_csr(ops.padded_occupancy(s, 128, 128))
    np.testing.assert_array_equal(
        np.asarray(ops.spike_matmul_csr(s, w, csr)),
        np.asarray(ops.spike_matmul_csr(s, w)))


def test_spike_matmul_csr_rejects_mismatched_tiling():
    """A work list built for one tiling holds k-tile indices that are
    meaningless under another — the tagged CSR must be refused loudly
    instead of producing a silently wrong product."""
    s = _spikes(jax.random.PRNGKey(16), (256, 256), 0.1)
    w = jax.random.normal(jax.random.PRNGKey(17), (256, 64))
    csr = tile_csr(s, 128, 128)
    assert csr.tiling == (128, 128)
    with pytest.raises(ValueError, match="tiling"):
        ops.spike_matmul_csr(s, w, csr, block_k=64)


def test_spike_matmul_csr_rejects_mismatched_tile_grid():
    """Same tiling, different operand: a CSR compacted from a (2, 2) tile
    grid must be refused for a (2, 4)-grid spike tensor — its k-tile
    indices would gate the wrong tiles silently."""
    s_small = _spikes(jax.random.PRNGKey(24), (256, 256), 0.1)
    s_big = _spikes(jax.random.PRNGKey(25), (256, 512), 0.1)
    w = jax.random.normal(jax.random.PRNGKey(26), (512, 64))
    csr = tile_csr(s_small, 128, 128)
    assert csr.map_shape == (2, 2)
    with pytest.raises(ValueError, match="tile grid"):
        ops.spike_matmul_csr(s_big, w, csr)


def test_csr_wrapper_buckets_grid_sizes_against_recompiles():
    """Concrete inputs with shifting occupancy must reuse a bounded set of
    compiled kernel cores: the wrapper rounds the trimmed step count up to
    a power of two (padding steps are DMA/FLOP-free), so a sweep over
    occupied-tile counts maps to O(log) distinct grid sizes."""
    w = jax.random.normal(jax.random.PRNGKey(27), (512, 64))
    caps = set()
    for n_live in range(1, 9):
        s = jnp.zeros((512, 512), jnp.float32)
        for t in range(n_live):      # occupy k-tiles of row-tile t % 4
            s = s.at[128 * (t % 4), 128 * (t // 4)].set(1.0)
        out = ops.spike_matmul_csr(s, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w),
                                   atol=1e-4)
        caps.add(ops._build_csr(ops.padded_occupancy(s), 128, 128).n_steps)
    assert all((c & (c - 1)) == 0 for c in caps)   # powers of two
    assert len(caps) < 8 // 2 + 2                  # bounded bucket count


def test_costmodel_separates_flops_from_dma():
    occ = np.array([[4, 0, 0, 0],        # 1 occupied + 3 empty
                    [0, 0, 0, 0]])       # all-empty row -> dummy step
    pred = costmodel.tile_matmul_savings(occ, 128, backend="pallas")
    csr = costmodel.tile_matmul_savings(occ, 128, backend="pallas-csr")
    # both skip the MXU work of the 7 empty tiles...
    assert pred.flops_saved == csr.flops_saved > 0
    # ...but only the compacted grid skips their DMA (dummy step charged)
    assert pred.dma_bytes_saved == 0.0
    assert csr.grid_steps_run == 2       # 1 occupied + 1 dummy
    assert csr.dma_bytes_saved == 6 * (128 * 128 * 4 + 128 * 128 * 4)
    full = costmodel.tile_matmul_savings(np.ones((2, 4)), 128,
                                         backend="pallas-csr")
    assert full.flops_saved == full.dma_bytes_saved == 0.0
    assert full.grid_steps_run == full.grid_steps_total
