"""Packed uint32 spike payload: round-trip properties (arbitrary trailing
axes, incl. non-multiples of 32), packed-popcount occupancy == the dense
pre-pass exactly, loud wrong-width rejection, routing/attribution of
packed EventTensors (packed-csr pin, explicit unpack shim, dense calls
never drifting onto packed backends), pack survival through pooling,
whole-model packed-forward parity, the committed bytes-moved ledger
(BENCH_PR7.json provenance pin), and the jaxpr proof that packed mode
materializes no f32 spike tensor between spiking layers.
"""
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import HAVE_HYPOTHESIS, given, st  # noqa: E402

from repro.core import costmodel  # noqa: E402
from repro.core.events import EventTensor, max_pool_events  # noqa: E402
from repro.core.lif import LIFConfig  # noqa: E402
from repro.core.spikes import (PACK, pack_spikes, pack_spikes_padded,  # noqa: E402
                               packed_tile_occupancy, packed_width,
                               tile_occupancy, unpack_spikes)
from repro.kernels import dispatch, ops  # noqa: E402
from repro.models.layers import lif_fire_events  # noqa: E402

REPO = Path(__file__).parent.parent


def _spikes(shape, seed, p=0.3):
    rng = np.random.RandomState(seed)
    return jnp.asarray((rng.rand(*shape) < p).astype(np.float32))


# ------------------------------------------------------------ round trip
def _assert_roundtrip(t, m, k, seed):
    s = _spikes((t, m, k), seed)
    p = pack_spikes_padded(s)
    assert p.dtype == jnp.uint32
    assert p.shape == (t, m, packed_width(k))
    full = unpack_spikes(p)
    np.testing.assert_array_equal(np.asarray(full[..., :k]), np.asarray(s))
    # pad bits are guaranteed-zero — they must never reappear as events
    np.testing.assert_array_equal(np.asarray(full[..., k:]), 0.0)
    assert int(jax.lax.population_count(p).sum()) == int(s.sum())


@pytest.mark.parametrize("k", [1, 31, 32, 33, 64, 97, 128])
def test_pack_unpack_roundtrip_fixed(k):
    _assert_roundtrip(2, 5, k, seed=k)


@given(st.integers(1, 4), st.integers(1, 9), st.integers(1, 130),
       st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip_property(t, m, k, seed):
    _assert_roundtrip(t, m, k, seed)


def test_pack_spikes_rejects_non_multiple_of_32():
    with pytest.raises(ValueError, match="not a multiple"):
        pack_spikes(_spikes((4, 33), 0))


# ------------------------------------------- packed occupancy == dense
@pytest.mark.parametrize("m,k,tm,tk", [(256, 256, 128, 128),
                                       (16, 64, 8, 32),
                                       (24, 96, 8, 32)])
def test_packed_popcount_occupancy_equals_dense_prepass(m, k, tm, tk):
    s = _spikes((m, k), seed=m + k)
    got = packed_tile_occupancy(pack_spikes(s), tm, tk, k=k)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(tile_occupancy(s, tm, tk)))


def test_packed_occupancy_pad_bits_never_inflate_counts():
    # non-multiple-of-32 channels: the padded words' high bits are zero,
    # so the packed map equals the dense map of the zero-padded tensor
    k = 100
    s = _spikes((16, k), seed=7)
    p = pack_spikes_padded(s)
    dense_padded = jnp.pad(s, ((0, 0), (0, packed_width(k) * PACK - k)))
    np.testing.assert_array_equal(
        np.asarray(packed_tile_occupancy(p, 8, 32)),
        np.asarray(tile_occupancy(dense_padded, 8, 32)))


# ---------------------------------------------- loud wrong-width rejection
def test_packed_occupancy_rejects_wrong_width():
    p = pack_spikes(_spikes((16, 64), 1))           # 2 words
    with pytest.raises(ValueError, match="does not cover"):
        packed_tile_occupancy(p, 8, 32, k=128)      # claims 4 words
    with pytest.raises(ValueError, match="not a multiple"):
        packed_tile_occupancy(p, 8, 48)             # tile_k % 32 != 0


def test_event_tensor_rejects_wrong_width_payload():
    p = pack_spikes(_spikes((16, 64), 2))
    with pytest.raises(ValueError, match="does not cover"):
        EventTensor(None, None, packed=p, feature_size=128)
    with pytest.raises(ValueError, match="uint32"):
        EventTensor(None, None, packed=p.astype(jnp.int32), feature_size=64)


def test_packed_matmul_rejects_wrong_width_operand():
    p = pack_spikes(_spikes((16, 64), 3))
    w = jnp.ones((128, 8), jnp.float32)
    with pytest.raises(ValueError, match="does not cover"):
        ops.spike_matmul_packed(p, w, packed_k=128)


# -------------------------------------------- routing and attribution
def _packed_probe(seed=0, n=24):
    drive = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, 64)) * 2.0
    et = lif_fire_events(drive, LIFConfig(), packed=True)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, n))
    return drive, et, w


def test_lif_fire_events_packed_carries_no_dense_spikes():
    drive, et, _ = _packed_probe()
    assert et.is_packed and et.spikes is None
    assert et.packed.dtype == jnp.uint32
    assert et.shape == drive.shape
    dense_et = lif_fire_events(drive, LIFConfig(), packed=False)
    np.testing.assert_array_equal(np.asarray(et.dense()),
                                  np.asarray(dense_et.spikes))
    np.testing.assert_array_equal(np.asarray(et.occupancy),
                                  np.asarray(dense_et.occupancy))


def test_packed_event_tensor_routes_to_packed_csr_and_matches_oracle():
    drive, et, w = _packed_probe()
    expect = jnp.matmul(et.dense(), w)
    with dispatch.use_backend("packed-csr-interpret", op="spike_matmul"):
        with dispatch.watch_resolutions() as rec:
            got = dispatch.spike_matmul(et, w)
    routes = {r["backend"] for r in rec if r["op"] == "spike_matmul"}
    assert routes == {"packed-csr-interpret"}, routes
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=1e-5)


def test_packed_apec_and_econv_match_dense_under_packed_pin():
    drive, et, w = _packed_probe(seed=4)
    with dispatch.use_backend("packed-csr-interpret", op="apec_matmul"):
        got = dispatch.apec_matmul(et, w, g=2)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.matmul(et.dense(), w)),
                               atol=1e-5)
    conv_drive = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 8, 32)) * 2
    cet = lif_fire_events(conv_drive, LIFConfig(), packed=True)
    wc = jax.random.normal(jax.random.PRNGKey(7), (3, 3, 32, 8))
    expect = dispatch.call_backend("econv", dispatch.REF, cet.dense(), wc,
                                   stride=1, padding="SAME")
    with dispatch.use_backend("packed-csr-interpret", op="econv"):
        got = dispatch.econv(cet, wc, stride=1, padding="SAME")
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=1e-4)


def test_packed_call_off_family_takes_explicit_unpack_shim():
    """A packed call pinned to a dense-only backend must go through the
    explicit unpack shim — warned, attributed ``+unpack`` — and still
    produce the oracle values. Never a silent reinterpret or densify."""
    _, et, w = _packed_probe(seed=8)
    dispatch.reset_fallback_warnings()
    with dispatch.use_backend(dispatch.REF, op="spike_matmul"):
        with pytest.warns(RuntimeWarning, match="unpack"):
            with dispatch.watch_resolutions() as rec:
                got = dispatch.spike_matmul(et, w)
    routes = {r["backend"] for r in rec if r["op"] == "spike_matmul"}
    assert routes == {"ref+unpack"}, routes
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.matmul(et.dense(), w)),
                               atol=1e-5)


def test_dense_calls_never_auto_select_packed_backends():
    args, kwargs = dispatch.example_inputs("spike_matmul",
                                           jax.random.PRNGKey(0))
    assert "packed" not in dispatch.resolve_name("spike_matmul", *args,
                                                 **kwargs)


# ----------------------------------------------- pack survival: pooling
def test_max_pool_packed_is_bitwise_or_of_lanes():
    s = _spikes((2, 8, 8, 64), seed=11, p=0.4)
    et = EventTensor.from_spikes(s.reshape(-1, 64), pack=True)
    spatial = EventTensor(None, None, packed=et.packed.reshape(2, 8, 8, 2),
                          feature_size=64)
    pooled = max_pool_events(spatial, 2)
    assert pooled.is_packed
    expect = jax.lax.reduce_window(s, -jnp.inf, jax.lax.max,
                                   (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    np.testing.assert_array_equal(np.asarray(pooled.dense()),
                                  np.asarray(expect))


def test_packed_only_reshape_guards_trailing_axis():
    _, et, _ = _packed_probe(seed=12)
    folded = et.reshape(-1, et.shape[-1])
    assert folded.is_packed and folded.shape == (32, 64)
    with pytest.raises(ValueError, match="explicit unpack"):
        et.reshape(2, 16 * 64)


# ---------------------------------------------- whole-model packed parity
@pytest.mark.slow
def test_spikingformer_forward_packed_matches_dense():
    from repro.configs.base import SpikingConfig
    from repro.models import spikingformer
    params = spikingformer.spikingformer_init(jax.random.PRNGKey(0),
                                              depth=1, dim=32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))

    def logits(packed):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            return np.asarray(spikingformer.spikingformer_apply(
                params, x, n_heads=4,
                spiking_cfg=SpikingConfig(t_steps=2, packed=packed)))

    np.testing.assert_allclose(logits(True), logits(False), atol=1e-4)


# ------------------------------------------------- bytes-moved cost model
def test_bytes_moved_packed_shrinks_spike_stream_32x_only():
    occ = np.array([[3, 0, 1], [0, 5, 0]], np.int32)
    dense = costmodel.matmul_bytes_moved(occ, 256, backend="pallas-csr")
    packed = costmodel.matmul_bytes_moved(occ, 256, backend="packed-csr")
    # same trimmed tile grid — only the spike payload narrows (4B -> 1b)
    assert packed.spike_hbm * 32 == dense.spike_hbm
    assert packed.weight_hbm == dense.weight_hbm
    assert packed.out_hbm == dense.out_hbm
    assert packed.total < dense.total
    assert packed.payload == "packed" and dense.payload == "dense"


def test_spike_tile_bytes_rejects_untileable_packed_width():
    with pytest.raises(ValueError):
        costmodel.spike_tile_bytes(128, 48, payload="packed")


@pytest.mark.parametrize("family", sorted(costmodel.PACKED_BYTES_POINTS))
def test_packed_bytes_points_match_committed_bench(family):
    """Provenance pin: the constants embedded in the cost model must be
    exactly the bytes-ledger rows of the committed BENCH_PR7.json, and
    the packed event stream must clear the 4x reduction floor at the
    high-sparsity points (it is 32x by construction)."""
    pts = costmodel.packed_bytes_points_from_bench(
        str(REPO / "BENCH_PR7.json"), family)
    assert pts == costmodel.PACKED_BYTES_POINTS[family]
    reduction = {pct: f32 / packed for pct, f32, packed in pts}
    for pct in (90, 97):
        assert reduction[pct] >= 4.0, (family, pct, reduction[pct])


# --------------------------------------- no f32 spikes between layers
def _sub_jaxprs(p):
    if hasattr(p, "jaxpr"):
        yield p.jaxpr
    elif hasattr(p, "eqns"):
        yield p
    elif isinstance(p, (list, tuple)):
        for x in p:
            yield from _sub_jaxprs(x)


def _f32_avals_of_shape(jaxpr, shape, hits):
    """Count eqn outputs materialized at `shape` in f32 — descending into
    sub-jaxprs (pjit/scan/custom_vjp bodies run at HBM granularity) but
    NOT into pallas_call kernels, whose internals live in VMEM; a
    pallas_call's own OUTvars do count (they land in HBM)."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = v.aval
            if (getattr(aval, "shape", None) == shape
                    and getattr(aval, "dtype", None) == jnp.float32):
                hits.append(str(eqn.primitive))
        if eqn.primitive.name == "pallas_call":
            continue
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                _f32_avals_of_shape(sub, shape, hits)


@pytest.mark.slow
def test_packed_chain_materializes_no_f32_spike_tensor():
    """The tentpole's fusion proof: under packed mode, the jaxpr of a
    fire -> matmul chain (fused Pallas emission pinned, packed-csr
    consumer pinned) contains NO f32 value of the spike shape — the
    uint32 words are the only event payload crossing HBM. The identical
    dense-pinned chain materializes the f32 spikes, validating that the
    walker actually sees them."""
    lif = LIFConfig()
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 64)) * 2.0
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))  # N != K

    def chain(packed, consumer):
        def f(x, w):
            et = lif_fire_events(x, lif, packed=packed)
            return dispatch.spike_matmul(et, w)
        with dispatch.use_backend("pallas-interpret", op="lif_scan_occ"), \
                dispatch.use_backend(consumer, op="spike_matmul"):
            return jax.make_jaxpr(f)(x, w)

    spike_shape = x.shape
    hits_packed: list = []
    _f32_avals_of_shape(chain(True, "packed-csr-interpret").jaxpr,
                        spike_shape, hits_packed)
    assert hits_packed == [], \
        f"packed chain materialized f32 spike tensors via {hits_packed}"
    hits_dense: list = []
    _f32_avals_of_shape(chain(False, "pallas-csr-interpret").jaxpr,
                        spike_shape, hits_dense)
    assert hits_dense, "walker found no f32 spikes even on the dense chain"
