"""End-to-end system behaviour: train->checkpoint->resume->serve, loss
decreases, spiking/dense parity of infrastructure, flops cross-check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import LMConfig, SpikingConfig
from repro.launch import steps as steps_mod
from repro.launch.train import train_loop
from repro.models import lm


TINY = LMConfig(name="sys-tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                spiking=SpikingConfig(t_steps=2), remat="none",
                loss_chunk=16)


def test_train_loss_decreases():
    out = train_loop(TINY, steps=25, batch=8, seq=32, lr=3e-3,
                     log_every=100)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_train_checkpoint_resume_continues(tmp_path):
    d = str(tmp_path / "ck")
    out1 = train_loop(TINY, steps=10, batch=4, seq=32, ckpt_dir=d,
                      save_every=5, log_every=100)
    out2 = train_loop(TINY, steps=15, batch=4, seq=32, ckpt_dir=d,
                      save_every=5, resume=True, log_every=100)
    # resumed run trained only steps 10..14
    assert len(out2["losses"]) == 5


def test_spiking_activations_are_binary_through_model():
    """Full-event guarantee at the system level: every LIF output that
    feeds a matmul is exactly {0,1}."""
    from repro.models.layers import lif_fire
    from repro.core.lif import LIFConfig
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8, 64))
    s = lif_fire(x, LIFConfig())
    assert bool(jnp.all((s == 0) | (s == 1)))


def test_serve_decode_state_is_constant_size_sdsa():
    """SDSA decode state does not grow with sequence length (O(d) per
    layer) — unlike the dense KV cache."""
    cfg = registry.get_reduced("tinyllama-1.1b")
    st_short = lm.init_decode_state(cfg, b=2, s=64, spiking=True)
    st_long = lm.init_decode_state(cfg, b=2, s=4096, spiking=True)
    sz = lambda st: sum(x.size for x in jax.tree.leaves(st))
    assert sz(st_short) == sz(st_long)
    kv_short = lm.init_decode_state(cfg, b=2, s=64, spiking=False)
    kv_long = lm.init_decode_state(cfg, b=2, s=4096, spiking=False)
    assert sz(kv_long) > sz(kv_short)


def test_decode_matches_prefill_last_logits_sdsa():
    """Streaming decode over a prompt reproduces prefill's last logits in
    SDSA 'or' mode — system-level equivalence of the two serving paths."""
    cfg = TINY.replace(spiking=SpikingConfig(t_steps=2, sdsa_mode="or"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    pre = steps_mod.make_prefill(cfg, spiking=True)
    logits_prefill = pre(params, {"tokens": toks})
    state = lm.init_decode_state(cfg, b=1, s=16, spiking=True)
    step = steps_mod.make_serve_step(cfg, spiking=True)
    for i in range(8):
        logits_dec, state = step(params, state, toks[:, i], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_prefill), atol=2e-2,
                               rtol=2e-2)


def test_prefill_with_state_matches_prefill():
    """Serving handoff: streaming prefill (scan of decode_step) produces
    the same last logits as batch prefill (bf16 accumulation tolerance)."""
    cfg = TINY
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, state = lm.prefill_with_state(cfg, params, toks, spiking=True)
    ref = lm.prefill(cfg, params, toks, spiking=True)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=2e-2, rtol=2e-2)
    # returned state decodes the next token without re-prefilling
    step = steps_mod.make_serve_step(cfg, spiking=True)
    nxt, _ = step(params, state, toks[:, -1], jnp.int32(8))
    assert bool(jnp.all(jnp.isfinite(nxt)))


def test_serve_server_generates():
    from repro.launch.serve import Request, Server
    cfg = registry.get_reduced("tinyllama-1.1b")
    server = Server(cfg, n_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new=4) for i in range(3)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    assert all(len(r.generated) == 4 for r in reqs)


def test_analytic_flops_cross_check():
    """Analytic model vs cost_analysis on a scan-free tiny model (n_groups
    == 1 would still scan; compare orders of magnitude with trip scaling
    accounted: n_layers=1 -> single-trip layer scan)."""
    from repro.launch import flops as flops_mod
    from repro.configs.base import ShapeSpec
    from repro.optim import adamw
    cfg = LMConfig(name="xc", family="dense", n_layers=1, d_model=128,
                   n_heads=4, n_kv_heads=4, d_ff=256, vocab=256,
                   spiking=SpikingConfig(t_steps=1), remat="none",
                   loss_chunk=64)
    shape = ShapeSpec("t", 64, 4, "train")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "labels": jnp.zeros((4, 64), jnp.int32)}
    fn = steps_mod.make_train_step(cfg, spiking=False)
    compiled = jax.jit(fn).lower(params, opt, batch).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    hlo_flops = float(ca["flops"])
    analytic = flops_mod.step_cost(cfg, shape, spiking=False).flops
    # same order of magnitude (cost_analysis includes optimizer etc.)
    assert 0.2 < analytic / hlo_flops < 5.0, (analytic, hlo_flops)
