"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs forward + one train step + one decode step on CPU,
asserting output shapes and finiteness — in both spiking and dense modes.
Paper workloads (VGG11/ResNet18/SegNet/SpikingFormer) likewise."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import CNNConfig, SpikingConfig
from repro.launch import steps as steps_mod
from repro.models import cnn, lm, spikingformer
from repro.optim import adamw


def _batch(cfg, b=2, s=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    toks = jax.random.randint(ks[0], (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.encoder_decoder:
        batch["frontend"] = jax.random.normal(
            ks[1], (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    elif cfg.n_frontend_tokens:
        batch["frontend"] = jax.random.normal(
            ks[1], (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


# The internlm2 reduced config is the suite's slowest single case
# (13-22s per mode, XLA compile-bound — see CI --durations); it guards
# no event-path contract the other arches don't, so it carries the
# `slow` marker for deselectable local runs (-m "not slow").
_ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a == "internlm2-20b" else a
    for a in registry.ARCH_IDS]


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
@pytest.mark.parametrize("spiking", [True, False])
def test_arch_forward_and_train_step(arch, spiking):
    cfg = registry.get_reduced(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hidden = lm.forward_hidden(cfg, params, batch["tokens"], spiking,
                               frontend=batch.get("frontend"))
    n_expected = 16 + (cfg.n_frontend_tokens
                       if (cfg.n_frontend_tokens and not cfg.encoder_decoder)
                       else 0)
    assert hidden.shape == (2, n_expected, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))

    opt_state = adamw.init(params)
    step = steps_mod.make_train_step(cfg, spiking=spiking)
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b_.astype(jnp.float32)))) > 0
        for a, b_ in zip(jax.tree.leaves(params),
                         jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("spiking", [True, False])
def test_arch_decode_step(arch, spiking):
    cfg = registry.get_reduced(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = lm.init_decode_state(cfg, b=2, s=32, spiking=spiking)
    step = jax.jit(steps_mod.make_serve_step(cfg, spiking))
    tok = jnp.array([1, 2], jnp.int32)
    logits, state = step(params, state, tok, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits2, _ = step(params, state, tok, jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_prefill(arch):
    cfg = registry.get_reduced(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    fn = steps_mod.make_prefill(cfg, spiking=True)
    logits = jax.jit(fn)(params, {k: v for k, v in batch.items()
                                  if k != "labels"})
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


# --------------------------------------------------- paper's own workloads
def test_vgg11_smoke():
    cfg = CNNConfig(name="vgg11", layers=cnn.VGG11_LAYERS,
                    spiking=SpikingConfig(t_steps=2))
    p = cnn.vgg11_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits, stats = cnn.vgg11_apply(cfg, p, x, collect_stats=True)
    assert logits.shape == (2, 10)
    assert len(stats) == 8                  # 8 conv layers
    assert bool(jnp.all(jnp.isfinite(logits)))
    for s in stats:                         # full-event guarantee
        assert bool(jnp.all((s == 0) | (s == 1)))


def test_resnet18_smoke():
    cfg = CNNConfig(name="resnet18", layers=(),
                    spiking=SpikingConfig(t_steps=2))
    p = cnn.resnet18_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = cnn.resnet18_apply(cfg, p, x)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_segnet_smoke():
    cfg = CNNConfig(name="segnet", layers=cnn.SEGNET_LAYERS, img=32,
                    n_classes=2, spiking=SpikingConfig(t_steps=2))
    p = cnn.segnet_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    out = cnn.segnet_apply(cfg, p, x)
    assert out.shape == (2, 32, 32, 2)      # per-pixel logits at input res
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("depth,dim", [(4, 256), (2, 512)])
def test_spikingformer_smoke(depth, dim):
    p = spikingformer.spikingformer_init(jax.random.PRNGKey(0), depth, dim,
                                         n_classes=10)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = spikingformer.spikingformer_apply(
        p, x, spiking_cfg=SpikingConfig(t_steps=2))
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
