"""End-to-end elastic restart: train on a 4x2 mesh, lose half the data
groups, resume on 2x2 with the same logical state (runs inside the shared
8-host-device subprocess; see conftest.multidevice_run)."""
import pytest


@pytest.mark.slow
def test_elastic_train_restart_smaller_mesh(multidevice_run):
    multidevice_run.check("ELASTIC_E2E")
