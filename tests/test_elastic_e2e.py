"""End-to-end elastic restart: train on a 4x2 mesh, lose half the data
groups, resume on 2x2 with the same logical state (subprocess, 8 devices).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, numpy as np
    from repro.configs.base import LMConfig, SpikingConfig
    from repro.launch.train import train_loop
    from repro.runtime.elastic import shrunk_mesh

    cfg = LMConfig(name="elastic", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                   spiking=SpikingConfig(t_steps=1), remat="none",
                   loss_chunk=16)
    d = sys.argv[1]
    ax = (jax.sharding.AxisType.Auto,) * 2

    mesh_a = jax.make_mesh((4, 2), ("data", "model"), axis_types=ax)
    out1 = train_loop(cfg, steps=6, batch=8, seq=32, ckpt_dir=d,
                      save_every=3, mesh=mesh_a, log_every=100)

    # 2 of 4 data groups "fail": plan the shrink, rebuild, resume.
    plan = shrunk_mesh((4, 2), ("data", "model"), n_failed_data_groups=2)
    assert plan.mesh_shape == (2, 2) and plan.microbatch_scale == 2
    mesh_b = jax.make_mesh(plan.mesh_shape, plan.axis_names,
                           devices=jax.devices()[:4], axis_types=ax)
    out2 = train_loop(cfg, steps=10, batch=8, seq=32, ckpt_dir=d,
                      save_every=3, resume=True, mesh=mesh_b, log_every=100)
    assert len(out2["losses"]) == 4            # resumed at step 6
    assert np.isfinite(out2["final_loss"])
    print("ELASTIC_E2E_OK", out1["final_loss"], out2["final_loss"])
""")


def test_elastic_train_restart_smaller_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT, str(tmp_path)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       timeout=500)
    assert "ELASTIC_E2E_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
