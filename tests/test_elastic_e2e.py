"""End-to-end elastic restart: train on a 4x2 mesh, lose half the data
groups, resume on 2x2 with the same logical state (runs inside the shared
8-host-device subprocess; see conftest.multidevice_run)."""
import pytest


@pytest.mark.slow
def test_elastic_train_restart_smaller_mesh(multidevice_run):
    multidevice_run.check("ELASTIC_E2E")


@pytest.mark.slow
def test_elastic_recovery_drill(multidevice_run):
    """Mid-training shard loss + torn newest checkpoint: restore walks
    back to the newest valid snapshot, reshards onto the shrunk mesh, and
    the resumed loss trajectory tracks the healthy run at tolerance."""
    multidevice_run.check("ELASTIC_DRILL")


@pytest.mark.slow
def test_elastic_packed_roundtrip(multidevice_run):
    """A SpikingConfig(packed=True) run restores onto a shrunk mesh and
    replays one step (under guard audit) with pre-failure loss parity."""
    multidevice_run.check("ELASTIC_PACKED")
