"""Continuous-batching scheduler tests.

The load-bearing one is staggered-admission decode parity: a request
admitted into a busy pool (slots at mixed positions) must generate the
SAME tokens as the same prompt decoded alone. The old serve loop
stepped the whole pool at ``pos.max()``, so a mid-stream admit wrote
KV rows / RoPE angles / causal masks at the pool-max position —
`test_shared_pos_max_is_wrong` pins that this was a REAL bug (the old
scheme demonstrably diverges), and the parity tests pin that per-slot
position vectors fix it, dense and spiking.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.serve_traces import bursty_trace, make_trace, poisson_trace
from repro.configs.base import LMConfig, SpikingConfig
from repro.launch.serve import FakeClock, ReplicaPool, Request, Server
from repro.models import lm
from repro.runtime import faults

CFG = LMConfig(name="sched-test", family="dense", n_layers=2, d_model=32,
               n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
               spiking=SpikingConfig(t_steps=1), remat="none", loss_chunk=16)

# n_heads == n_slots == 4: the dimension collision that fooled the old
# shape-guessing slot reset.
N_SLOTS = 4


def _prompts(n, lens=(5, 9, 7, 4)):
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(0, CFG.vocab, lens[i % len(lens)])))
            for i in range(n)]


def _solo(prompt, max_new, spiking):
    s = Server(CFG, n_slots=1, max_seq=64, spiking=spiking,
               clock=FakeClock())
    r = Request(rid=0, prompt=prompt, max_new=max_new)
    s.submit(r)
    s.run_until_drained()
    assert r.state == "done"
    return r.generated


# ------------------------------------------------- staggered-admission parity
@pytest.mark.parametrize("spiking", [False, True],
                         ids=["dense", "spiking"])
def test_staggered_admission_matches_solo(spiking):
    """Requests admitted at different steps into a busy pool each decode
    exactly the tokens they'd produce alone — the per-slot position fix
    end to end, greedy tokens being the bitwise-visible surface."""
    prompts = _prompts(3)
    solo = [_solo(p, 6, spiking) for p in prompts]
    srv = Server(CFG, n_slots=N_SLOTS, max_seq=64, spiking=spiking,
                 clock=FakeClock())
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    srv.submit(reqs[0])
    srv.step()
    srv.step()                       # req0 is now mid-generation
    srv.submit(reqs[1])              # admitted at a non-aligned position
    srv.step()
    srv.submit(reqs[2])              # and another offset again
    srv.run_until_drained()
    for i, r in enumerate(reqs):
        assert r.state == "done", (i, r.state, r.failure_cause)
        assert r.generated == solo[i], i
    assert all(s is None for s in srv.slot_req)     # no leaked slots


def test_shared_pos_max_is_wrong_vector_pos_is_right():
    """Regression at the decode_step level: stepping a staggered pool at
    the shared ``pos.max()`` (the old serve loop) diverges from solo
    decode, while the per-slot vector matches to 1e-5. Dense mode — the
    KV write index, RoPE angle, and causal mask are what consume pos."""
    prompt = _prompts(1)[0]
    b1 = len(prompt)

    # Solo reference: prefill then one decode step at pos=b1.
    toks = jnp.asarray([prompt], jnp.int32)
    logits_solo, st_solo = lm.prefill_chunked(
        CFG, lm.init_params(CFG, __import__("jax").random.PRNGKey(0)),
        toks, jnp.asarray([b1], jnp.int32), False, 64)
    params = lm.init_params(CFG, __import__("jax").random.PRNGKey(0))
    next_tok = jnp.argmax(logits_solo, -1).astype(jnp.int32)
    ref_logits, _ = lm.decode_step(
        CFG, params, st_solo, next_tok, jnp.int32(b1), False)

    # Pool: slot 0 parked at a LARGER position, slot 1 holds our prompt.
    pool = lm.init_decode_state(CFG, 2, 64, False)
    pool = lm.merge_slot_state(pool, st_solo, jnp.int32(1))
    pos = np.array([b1 + 5, b1], np.int32)          # staggered
    tok = jnp.asarray([0, int(next_tok[0])], jnp.int32)

    good, _ = lm.decode_step(CFG, params, pool, tok,
                             jnp.asarray(pos), False)
    np.testing.assert_allclose(np.asarray(good[1]),
                               np.asarray(ref_logits[0]),
                               rtol=1e-5, atol=1e-5)

    # The old scheme: one shared scalar position = pos.max().
    bad, _ = lm.decode_step(CFG, params, pool, tok,
                            jnp.int32(int(pos.max())), False)
    assert not np.allclose(np.asarray(bad[1]), np.asarray(ref_logits[0]),
                           rtol=1e-3, atol=1e-3)


def test_chunked_prefill_matches_streaming_prefill():
    """Bucketed masked prefill (admission path) produces the same last
    logits and decode state as the unpadded streaming prefill."""
    import jax
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    prompt = _prompts(1)[0]
    toks = jnp.asarray([prompt], jnp.int32)
    for spiking in (False, True):
        ref_logits, ref_st = lm.prefill_with_state(
            CFG, params, toks, spiking, max_seq=64)
        pad = jnp.zeros((1, 16), jnp.int32).at[0, :len(prompt)].set(
            jnp.asarray(prompt))
        got_logits, got_st = lm.prefill_chunked(
            CFG, params, pad, jnp.asarray([len(prompt)], jnp.int32),
            spiking, 64)
        np.testing.assert_allclose(np.asarray(got_logits),
                                   np.asarray(ref_logits),
                                   rtol=1e-5, atol=1e-5)
        for a, b in zip(jax.tree.leaves(got_st), jax.tree.leaves(ref_st)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-5, atol=1e-5)


def test_quarantine_then_retry_at_non_aligned_position():
    """A slot poisoned mid-stream while the pool is staggered retries
    from its prompt and still converges to the solo tokens."""
    prompts = _prompts(2)
    solo = [_solo(p, 5, True) for p in prompts]
    srv = Server(CFG, n_slots=N_SLOTS, max_seq=64, spiking=True,
                 clock=FakeClock(), backoff_s=0.01)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    srv.submit(reqs[0])
    srv.step()
    srv.step()
    srv.submit(reqs[1])              # non-aligned admit
    srv.step()
    slot_b = srv.slot_req.index(reqs[1])
    srv.state = faults.nan_decode_state(srv.state, slot=slot_b)
    srv.step()                       # -> nan_logits -> quarantine both?
    srv.run_until_drained()
    assert reqs[1].retries >= 1
    assert reqs[1].failure_cause == "nan_logits"
    assert reqs[1].state == "done"
    assert reqs[1].generated == solo[1]
    assert all(s is None for s in srv.slot_req)


# -------------------------------------------------------- structural reset
def test_reset_slot_state_is_structural_under_dim_collision():
    """With n_heads == n_slots, the head axis collides with the slot
    axis under shape-guessing (`shape[1] == n_slots` matched BOTH and
    the old reset zeroed whatever it hit). The structural reset
    addresses axis 1 by contract: slot 0 zeroed, slot 1 untouched."""
    import jax
    state = lm.init_decode_state(CFG, N_SLOTS, 16, True)
    poke = jax.tree.map(
        lambda x: jnp.full_like(x, 3.0) if jnp.issubdtype(
            x.dtype, jnp.floating) else x, state)
    out = lm.reset_slot_state(poke, 1, N_SLOTS)
    for leaf in jax.tree.leaves(out):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        assert np.all(np.asarray(leaf[:, 1], np.float32) == 0.0)
        assert np.all(np.asarray(leaf[:, 0], np.float32) == 3.0)
        assert np.all(np.asarray(leaf[:, 2], np.float32) == 3.0)


def test_reset_slot_state_rejects_nonconforming_leaf():
    """A leaf violating the (n_groups, n_slots, ...) contract fails
    LOUDLY with its path named — never silently skipped or zeroed."""
    state = lm.init_decode_state(CFG, N_SLOTS, 16, True)
    bad = [state[0]._replace(sdsa=state[0].sdsa._replace(
        status=jnp.zeros((2, N_SLOTS + 1, 4, 8))))] + list(state[1:])
    with pytest.raises(ValueError, match="slot"):
        lm.reset_slot_state(bad, 0, N_SLOTS)


# ------------------------------------------------------------- clock/deadline
def test_fake_clock_drain_never_real_sleeps():
    """Backed-off retries drain under a FakeClock by advancing fake
    time — bounded wall-clock, no real sleep (the old loop slept 5 ms of
    REAL time per idle iteration even with an injected clock)."""
    clk = FakeClock()
    srv = Server(CFG, n_slots=2, max_seq=64, spiking=True, clock=clk,
                 backoff_s=10.0)      # would be minutes of real sleeping
    req = Request(rid=0, prompt=_prompts(1)[0], max_new=3)
    srv.submit(req)
    srv.step()
    srv.state = faults.nan_decode_state(srv.state, slot=0)
    t0 = time.monotonic()
    srv.run_until_drained()
    assert time.monotonic() - t0 < 30.0     # fake backoff, real seconds
    assert clk() >= 10.0                    # waited in FAKE time
    assert req.state == "done"


def test_trace_arrivals_fire_on_fake_clock():
    clk = FakeClock()
    srv = Server(CFG, n_slots=2, max_seq=64, spiking=True, clock=clk)
    reqs = [Request(rid=i, prompt=_prompts(1)[0], max_new=2)
            for i in range(3)]
    srv.submit_at(reqs[0], 0.0)
    srv.submit_at(reqs[2], 50.0)            # far-future arrival
    srv.submit_at(reqs[1], 0.01)            # inserts in arrival order
    assert [r.rid for r in srv.arrivals] == [0, 1, 2]
    fin = srv.run_until_drained()
    assert len(fin) == 3 and all(r.state == "done" for r in reqs)
    assert clk() >= 50.0


def test_deadline_request_that_skipped_submit_fails_loud():
    """A request pushed straight into `pending` (skipping submit()) has
    no submitted_at; the old `_expire_deadlines` crashed on the None
    arithmetic. Now it's stamped at first observation and the deadline
    runs from there."""
    clk = FakeClock()
    srv = Server(CFG, n_slots=1, max_seq=64, spiking=True, clock=clk)
    busy = Request(rid=0, prompt=_prompts(1)[0], max_new=4)
    srv.submit(busy)
    srv.step()
    ghost = Request(rid=1, prompt=_prompts(1)[0], max_new=4,
                    deadline_s=0.5)
    srv.pending.append(ghost)               # bypasses submit()
    srv.step()                              # must not raise
    assert ghost.submitted_at is not None
    clk.advance(1.0)                        # past the ghost's deadline
    srv.run_until_drained()
    assert ghost.state == "failed" and ghost.failure_cause == "deadline"
    assert busy.state == "done"


# ------------------------------------------------------------------- traces
def test_trace_generators_deterministic_and_ordered():
    for name, fn in (("poisson", poisson_trace), ("bursty", bursty_trace)):
        a = fn(seed=3, n_requests=10)
        b = fn(seed=3, n_requests=10)
        assert a == b, name
        ts = [t.arrival_s for t in a]
        assert ts == sorted(ts) and ts[0] == 0.0
        assert fn(seed=4, n_requests=10) != a
    with pytest.raises(ValueError, match="unknown trace"):
        make_trace("sinusoidal")


def test_bursty_trace_replay_terminal_with_causes_no_leaks():
    """The CI smoke contract: replay a short bursty trace; every request
    reaches a terminal state with a recorded cause on failure, and no
    slot is leaked."""
    clk = FakeClock()
    srv = Server(CFG, n_slots=2, max_seq=64, spiking=True, clock=clk)
    trace = make_trace("bursty", seed=0, n_requests=8, vocab=CFG.vocab,
                       max_new=(2, 4))
    reqs = []
    for t in trace:
        r = Request(rid=t.rid, prompt=list(t.prompt), max_new=t.max_new)
        srv.submit_at(r, t.arrival_s)
        reqs.append(r)
    fin = srv.run_until_drained()
    assert len(fin) == len(reqs)
    for r in reqs:
        assert r.state in ("done", "failed")
        if r.state == "failed":
            assert r.failure_cause
    assert all(s is None for s in srv.slot_req)
    assert not srv.pending and not srv.arrivals


# ------------------------------------------------------------ replica pool
def test_replica_pool_steers_admission_to_light_replica():
    clk = FakeClock()
    pool = ReplicaPool(CFG, n_replicas=2, clock=clk, n_slots=2, max_seq=64,
                       spiking=True)
    # Pre-load replica 0 so its slots are busy.
    for i in range(2):
        pool.replicas[0].submit(
            Request(rid=100 + i, prompt=_prompts(1)[0], max_new=8))
    pool.replicas[0].step()
    r = Request(rid=0, prompt=_prompts(1)[0], max_new=2)
    idx = pool.submit(r)
    assert idx == 1                          # steered away from the load
    assert pool.imbalance_log                # skew signal recorded
    assert pool.imbalance_log[-1].imbalance >= 1.0
    pool.run_until_drained()
    assert all(req.state == "done" for req in pool.finished)


def test_replica_pool_round_robin_baseline_and_bad_balancer():
    clk = FakeClock()
    pool = ReplicaPool(CFG, n_replicas=2, balancer="round_robin",
                       clock=clk, n_slots=2, max_seq=64, spiking=True)
    idxs = [pool.submit(Request(rid=i, prompt=_prompts(1)[0], max_new=2))
            for i in range(4)]
    assert idxs == [0, 1, 0, 1]
    pool.run_until_drained()
    with pytest.raises(ValueError, match="balancer"):
        ReplicaPool(CFG, n_replicas=2, balancer="fifo")


# ---------------------------------------------------------------- scale smoke
def test_slot_pool_scales_to_many_slots():
    """Hundreds-of-slots shape check: a 64-slot pool admits a wave,
    decodes per-slot, and drains — state stays (n_groups, 64, ...)."""
    clk = FakeClock()
    srv = Server(CFG, n_slots=64, max_seq=32, spiking=True, clock=clk)
    reqs = [Request(rid=i, prompt=[i % CFG.vocab, (i * 7) % CFG.vocab],
                    max_new=2) for i in range(64)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.state == "done" for r in reqs)
    assert all(s is None for s in srv.slot_req)
