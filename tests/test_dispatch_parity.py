"""Registry-driven parity harness: every (op x backend) pair vs the `ref`
oracle, plus override/fallback semantics and an end-to-end model smoke.

Any future kernel becomes parity-tested the moment it registers — the
parametrization below enumerates the live registry, not a hand-kept list.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch

ATOL = 1e-5


@pytest.fixture(autouse=True)
def _no_ambient_backend_override(monkeypatch):
    """These tests pin resolution explicitly; a developer's exported
    EXSPIKE_BACKEND must not leak in and flip expected defaults."""
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)

# Every pair runnable on this test platform (CPU). TPU-only backends are
# exercised by the same harness when the suite runs on TPU.
PAIRS = [
    (op, be)
    for op in dispatch.op_names()
    for be in dispatch.backend_names(op)
    if jax.default_backend() in dispatch.get_backend(op, be).platforms
]


@pytest.mark.parametrize("op,backend", PAIRS,
                         ids=[f"{o}-{b}" for o, b in PAIRS])
def test_backend_matches_ref_oracle(op, backend):
    args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(0))
    expect = dispatch.call_backend(op, dispatch.REF, *args, **kwargs)
    got = dispatch.call_backend(op, backend, *args, **kwargs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), atol=ATOL)


@pytest.mark.parametrize("op", dispatch.op_names())
def test_example_inputs_are_deterministic(op):
    a1, k1 = dispatch.example_inputs(op, jax.random.PRNGKey(7))
    a2, k2 = dispatch.example_inputs(op, jax.random.PRNGKey(7))
    assert k1 == k2
    for x, y in zip(a1, a2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------ override semantics
def test_use_backend_overrides_resolution():
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(1))
    with dispatch.use_backend("pallas-interpret", op="sdsa"):
        assert dispatch.resolve_name("sdsa", *args, **kwargs) \
            == "pallas-interpret"
    assert dispatch.resolve_name("sdsa", *args, **kwargs) == dispatch.REF


def test_global_override_applies_to_all_ops():
    with dispatch.use_backend(dispatch.REF):
        for op in dispatch.op_names():
            args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(2))
            assert dispatch.resolve_name(op, *args, **kwargs) == dispatch.REF


def test_env_var_override(monkeypatch):
    args, kwargs = dispatch.example_inputs("apec_matmul",
                                           jax.random.PRNGKey(3))
    assert dispatch.resolve_name("apec_matmul", *args, **kwargs) == "jnp"
    monkeypatch.setenv(dispatch.ENV_VAR, "apec_matmul=ref")
    assert dispatch.resolve_name("apec_matmul", *args, **kwargs) \
        == dispatch.REF
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    assert dispatch.resolve_name("apec_matmul", *args, **kwargs) \
        == "pallas-interpret"


def test_unmet_constraint_falls_back_to_ref_with_warning():
    # g does not divide P: the packed APEC kernel must refuse and the call
    # must still produce the exact dense result via ref.
    s = (jax.random.uniform(jax.random.PRNGKey(4), (10, 32)) < 0.5
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 8))
    with dispatch.use_backend("pallas-interpret", op="apec_matmul"):
        with pytest.warns(RuntimeWarning, match="falling back"):
            out = dispatch.apec_matmul(s, w, g=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)


def test_auto_resolution_warns_on_capability_fallback():
    """No override at all: when the preferred auto backend refuses the
    inputs (g does not divide P), the silent-looking default path must
    still surface a RuntimeWarning, not quietly lose APEC compression."""
    s = (jax.random.uniform(jax.random.PRNGKey(10), (10, 32)) < 0.5
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(11), (32, 8))
    with pytest.warns(RuntimeWarning, match="not divisible"):
        out = dispatch.apec_matmul(s, w, g=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)


def test_unknown_backend_falls_back_to_ref_with_warning():
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(6))
    with dispatch.use_backend("no-such-backend", op="sdsa"):
        with pytest.warns(RuntimeWarning, match="not registered"):
            out = dispatch.dispatch("sdsa", *args, **kwargs)
    expect = dispatch.call_backend("sdsa", dispatch.REF, *args, **kwargs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_call_backend_raises_instead_of_falling_back():
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(8))
    kwargs["mode"] = "sum"
    with pytest.raises(ValueError, match="mode"):
        dispatch.call_backend("sdsa", "pallas-interpret", *args, **kwargs)


def test_sdsa_sum_mode_auto_falls_back_under_packed_override():
    """mode='sum' can't run on the bitwise path: override must fall back
    to ref, matching the dense result."""
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(9))
    kwargs["mode"] = "sum"
    expect = dispatch.call_backend("sdsa", dispatch.REF, *args, **kwargs)
    with dispatch.use_backend("pallas-interpret", op="sdsa"):
        with pytest.warns(RuntimeWarning):
            out = dispatch.dispatch("sdsa", *args, **kwargs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# ------------------------------------------------------- end-to-end smoke
def _tiny_spikingformer_logits():
    from repro.configs.base import SpikingConfig
    from repro.models import spikingformer
    params = spikingformer.spikingformer_init(jax.random.PRNGKey(0),
                                              depth=1, dim=32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    return spikingformer.spikingformer_apply(
        params, x, n_heads=4, spiking_cfg=SpikingConfig(t_steps=2))


@pytest.fixture(scope="module")
def default_logits():
    """Default-resolution logits, computed once for the smoke tests."""
    return np.asarray(_tiny_spikingformer_logits())


def test_model_outputs_identical_ref_vs_default(default_logits):
    """EXSPIKE_BACKEND=ref vs default resolution: identical logits (on CPU
    both resolve to jnp paths; apec/econv/sdsa routing must not drift)."""
    with dispatch.use_backend(dispatch.REF):
        ref_logits = np.asarray(_tiny_spikingformer_logits())
    np.testing.assert_allclose(default_logits, ref_logits, atol=ATOL)


def test_model_outputs_match_under_kernel_backends(default_logits):
    """Whole-model parity with the Pallas (interpret) kernels driving the
    attention core and conv stem — the acceptance gate for swapping real
    TPU kernels in later."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with dispatch.use_backend("pallas-interpret", op="sdsa"), \
                dispatch.use_backend("pallas-interpret", op="econv"):
            kernel_logits = np.asarray(_tiny_spikingformer_logits())
    np.testing.assert_allclose(kernel_logits, default_logits, atol=1e-4)


def test_env_ref_subprocess_like(default_logits, monkeypatch):
    """The documented env knob end to end: set EXSPIKE_BACKEND=ref in this
    process and check the model still produces the same logits."""
    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    assert os.environ[dispatch.ENV_VAR] == "ref"
    np.testing.assert_allclose(np.asarray(_tiny_spikingformer_logits()),
                               default_logits, atol=ATOL)
