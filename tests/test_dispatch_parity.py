"""Registry-driven parity harness: every (op x backend) pair vs the `ref`
oracle, plus override/fallback semantics and an end-to-end model smoke.

Any future kernel becomes parity-tested the moment it registers — the
parametrization below enumerates the live registry, not a hand-kept list.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch

ATOL = 1e-5


@pytest.fixture(autouse=True)
def _no_ambient_backend_override(monkeypatch):
    """These tests pin resolution explicitly; a developer's exported
    EXSPIKE_BACKEND must not leak in and flip expected defaults. Fallback
    warnings dedup per (op, from, to) per process, so each test re-arms
    them to assert its own warning independently."""
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    dispatch.reset_fallback_warnings()

# Every pair runnable on this test platform (CPU). TPU-only backends are
# exercised by the same harness when the suite runs on TPU.
PAIRS = [
    (op, be)
    for op in dispatch.op_names()
    for be in dispatch.backend_names(op)
    if jax.default_backend() in dispatch.get_backend(op, be).platforms
]


def _assert_tree_close(got, expect, atol):
    """Leaf-wise comparison — ops may return pytrees (e.g. `lif_scan_occ`
    returns (spikes, occupancy))."""
    g_leaves = jax.tree.leaves(got)
    e_leaves = jax.tree.leaves(expect)
    assert len(g_leaves) == len(e_leaves)
    for g, e in zip(g_leaves, e_leaves):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(e, np.float32), atol=atol)


@pytest.mark.parametrize("op,backend", PAIRS,
                         ids=[f"{o}-{b}" for o, b in PAIRS])
def test_backend_matches_ref_oracle(op, backend):
    args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(0))
    expect = dispatch.call_backend(op, dispatch.REF, *args, **kwargs)
    got = dispatch.call_backend(op, backend, *args, **kwargs)
    _assert_tree_close(got, expect, ATOL)


# -------------------------------------------------------- gradient parity
# Every (op x backend) pair that declares the gradient contract
# (differentiable=True or a vjp= registration): jax.grad of a fixed probe
# loss must match the ref oracle's surrogate gradients. Enumerated from
# the live registry, like the forward pass above.
DIFF_PAIRS = [
    (op, be)
    for op in dispatch.op_names()
    for be in dispatch.differentiable_backend_names(op)
    if jax.default_backend() in dispatch.get_backend(op, be).platforms
]

# Surrogate gradients are exact closed forms (ATan / transpose rules /
# ref-replay), so the only slack needed is f32 association-order drift.
GRAD_ATOL = 1e-4


def _make_probe(out_ref):
    """One fixed probe per output leaf (int leaves — non-differentiated
    aux like the `lif_scan_occ` map — probe to a constant-zero term)."""
    leaves, treedef = jax.tree.flatten(out_ref)
    probes = [jax.random.normal(jax.random.PRNGKey(42 + i), l.shape,
                                jnp.float32) for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, probes)


def _probe_loss(op, backend, kwargs, probe):
    def loss(args):
        out = dispatch.call_backend(op, backend, *args, **kwargs)
        terms = jax.tree.map(
            lambda o, pr: jnp.sum(o.astype(jnp.float32) * pr), out, probe)
        return sum(jax.tree.leaves(terms))
    return loss


@pytest.mark.parametrize("op,backend", DIFF_PAIRS,
                         ids=[f"{o}-{b}" for o, b in DIFF_PAIRS])
def test_grad_matches_ref_oracle(op, backend):
    args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(0))
    out_ref = dispatch.call_backend(op, dispatch.REF, *args, **kwargs)
    probe = _make_probe(out_ref)
    g_ref = jax.grad(_probe_loss(op, dispatch.REF, kwargs, probe))(args)
    g = jax.grad(_probe_loss(op, backend, kwargs, probe))(args)
    assert len(g) == len(g_ref)
    for got, expect in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(expect, np.float32),
                                   atol=GRAD_ATOL)


# ---------------------------------- EventTensor-carried forward parity
# The full-event pipeline's gradient contract: a forward whose consumer
# receives the producer's carried occupancy (stop-gradient aux) must
# match the dense-spike forward — values AND jax.grad — for every
# differentiable backend of every map-consuming op. Enumerated from the
# live registry like everything else.
EVENT_CONSUMER_OPS = ("spike_matmul", "apec_matmul", "econv")
EVENT_PAIRS = [
    (op, be)
    for op in EVENT_CONSUMER_OPS
    for be in dispatch.differentiable_backend_names(op)
    if jax.default_backend() in dispatch.get_backend(op, be).platforms
]


def _event_probe_setup(op):
    if op == "econv":
        drive = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 8, 8, 8)) * 2
        w = jax.random.normal(jax.random.PRNGKey(9), (3, 3, 8, 6))
        return drive, w, {"stride": 1, "padding": "SAME"}
    drive = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 48)) * 2.0
    w = jax.random.normal(jax.random.PRNGKey(9), (48, 24))
    return drive, w, ({"g": 2} if op == "apec_matmul" else {})


@pytest.mark.parametrize("op,backend", EVENT_PAIRS,
                         ids=[f"{o}-{b}" for o, b in EVENT_PAIRS])
def test_grad_through_event_tensor_forward_matches_dense(op, backend):
    from repro.core.events import conv_patch_occupancy
    from repro.core.lif import LIFConfig
    from repro.models.layers import lif_fire_events
    drive, w, kwargs = _event_probe_setup(op)
    lif = LIFConfig()

    def forward(x, carried):
        et = lif_fire_events(x, lif)           # fused producer (ref on CPU)
        kw = dict(kwargs)
        if op == "econv":
            et = et.reshape((-1,) + et.shape[2:])     # T*B fold keeps map
            if carried:
                kw["occupancy"] = conv_patch_occupancy(et, w.shape, 1,
                                                       "SAME")
        elif carried:
            kw["occupancy"] = et.occupancy_for(128, 128)
        return dispatch.call_backend(op, backend, et.spikes, w, **kw)

    out_carried = forward(drive, True)
    out_dense = forward(drive, False)
    np.testing.assert_allclose(np.asarray(out_carried),
                               np.asarray(out_dense), atol=1e-5)
    probe = jax.random.normal(jax.random.PRNGKey(42), out_dense.shape)

    def loss(carried):
        return lambda x: jnp.sum(forward(x, carried).astype(jnp.float32)
                                 * probe)

    g_carried = jax.grad(loss(True))(drive)
    g_dense = jax.grad(loss(False))(drive)
    assert bool(jnp.any(g_dense != 0))
    np.testing.assert_allclose(np.asarray(g_carried), np.asarray(g_dense),
                               atol=1e-5)


def test_every_backend_declares_gradient_contract():
    """Training resolves backends exactly like inference, so a forward-only
    registration would be a landmine: any op x backend the resolver can
    pick must be differentiable."""
    for op in dispatch.op_names():
        diff = set(dispatch.differentiable_backend_names(op))
        assert set(dispatch.backend_names(op)) == diff, \
            f"{op}: non-differentiable backends {set(dispatch.backend_names(op)) - diff}"


def test_grad_through_dispatch_resolution():
    """jax.grad through the dispatch() entry point itself (auto resolution,
    no call_backend pinning) — the path the train loop takes."""
    args, kwargs = dispatch.example_inputs("lif_scan", jax.random.PRNGKey(3))
    (x,), _ = args, kwargs

    def loss(x):
        s = dispatch.lif_scan(x, **kwargs)
        return jnp.sum(s * s.shape[-1])
    g = jax.grad(loss)(x)
    assert g.shape == x.shape
    assert bool(jnp.any(g != 0))
    assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("soft_reset,alpha", [(False, 2.0), (True, 3.0),
                                              (False, 4.0)])
def test_grad_parity_lif_hard_reset_and_alpha(soft_reset, alpha):
    """The backward kernel's hard-reset branch ((1-S) - V*sg) and the
    surrogate_alpha plumbing — neither is reachable from the canonical
    example (soft reset, alpha=2), so cover them explicitly."""
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 3, 40)) * 2.0
    probe = jax.random.normal(jax.random.PRNGKey(8), x.shape)
    kwargs = dict(decay=0.6, v_th=0.8, soft_reset=soft_reset,
                  surrogate_alpha=alpha)

    def loss(backend):
        def f(x):
            out = dispatch.call_backend("lif_scan", backend, x, **kwargs)
            return jnp.sum(out * probe)
        return f

    g_ref = jax.grad(loss(dispatch.REF))(x)
    g_pal = jax.grad(loss("pallas-interpret"))(x)
    assert bool(jnp.any(g_ref != 0))
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=GRAD_ATOL)


@pytest.mark.parametrize("dtype", [jnp.bool_, jnp.int8],
                         ids=["bool", "int8"])
@pytest.mark.parametrize("op", ["spike_matmul", "apec_matmul", "econv",
                                "tconv"])
def test_spike_ops_preserve_narrow_input_dtypes(op, dtype):
    """Binary event maps arrive as bool/int8 from quantized producers;
    dispatch entry must NOT silently upcast them (any promotion happens
    inside the op that needs it) and the activation output must come
    back in the weight dtype regardless of the spike storage dtype."""
    args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(0))
    s, w = args[0], args[1]
    expect = dispatch.dispatch(op, *args, **kwargs)
    got = dispatch.dispatch(op, s.astype(dtype), *args[1:], **kwargs)
    assert got.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=ATOL)


def test_sdsa_ops_handle_non_tile_multiple_token_counts():
    """Token counts whose sublane padding is not a block_n multiple
    (e.g. 384 > 256) must still run on the packed kernels — the wrappers
    pick a dividing block size instead of erroring at trace time."""
    for n in (300, 384):
        ks = jax.random.split(jax.random.PRNGKey(n), 3)
        q, k, v = ((jax.random.uniform(kk, (2, 1, 2, n, 40)) < 0.3)
                   .astype(jnp.float32) for kk in ks)
        expect = dispatch.call_backend("causal_sdsa", dispatch.REF, q, k, v)
        got = dispatch.call_backend("causal_sdsa", "pallas-interpret",
                                    q, k, v)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
        expect = dispatch.call_backend("sdsa", dispatch.REF, q[0], k[0], v[0])
        got = dispatch.call_backend("sdsa", "pallas-interpret",
                                    q[0], k[0], v[0])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@pytest.mark.slow
def test_grad_parity_large_lif_multi_tile():
    """Fused LIF backward across multiple (bm, bn) grid tiles and a padded
    remainder — exercises the VMEM-carry reversal beyond one program."""
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 4, 2100)) * 2.0
    probe = jax.random.normal(jax.random.PRNGKey(6), x.shape)

    def loss(backend):
        def f(x):
            out = dispatch.call_backend("lif_scan", backend, x,
                                        decay=0.5, v_th=1.0)
            return jnp.sum(out * probe)
        return f

    g_ref = jax.grad(loss(dispatch.REF))(x)
    g_pal = jax.grad(loss("pallas-interpret"))(x)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=GRAD_ATOL)


@pytest.mark.parametrize("op", dispatch.op_names())
def test_example_inputs_are_deterministic(op):
    a1, k1 = dispatch.example_inputs(op, jax.random.PRNGKey(7))
    a2, k2 = dispatch.example_inputs(op, jax.random.PRNGKey(7))
    assert k1 == k2
    for x, y in zip(a1, a2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------ override semantics
def test_use_backend_overrides_resolution():
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(1))
    with dispatch.use_backend("pallas-interpret", op="sdsa"):
        assert dispatch.resolve_name("sdsa", *args, **kwargs) \
            == "pallas-interpret"
    assert dispatch.resolve_name("sdsa", *args, **kwargs) == dispatch.REF


def test_global_override_applies_to_all_ops():
    with dispatch.use_backend(dispatch.REF):
        for op in dispatch.op_names():
            args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(2))
            assert dispatch.resolve_name(op, *args, **kwargs) == dispatch.REF


def test_env_var_override(monkeypatch):
    args, kwargs = dispatch.example_inputs("apec_matmul",
                                           jax.random.PRNGKey(3))
    assert dispatch.resolve_name("apec_matmul", *args, **kwargs) == "jnp"
    monkeypatch.setenv(dispatch.ENV_VAR, "apec_matmul=ref")
    assert dispatch.resolve_name("apec_matmul", *args, **kwargs) \
        == dispatch.REF
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    assert dispatch.resolve_name("apec_matmul", *args, **kwargs) \
        == "pallas-interpret"


def test_unmet_constraint_falls_back_to_ref_with_warning():
    # g does not divide P: the packed APEC kernel must refuse and the call
    # must still produce the exact dense result via ref.
    s = (jax.random.uniform(jax.random.PRNGKey(4), (10, 32)) < 0.5
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 8))
    with dispatch.use_backend("pallas-interpret", op="apec_matmul"):
        with pytest.warns(RuntimeWarning, match="falling back"):
            out = dispatch.apec_matmul(s, w, g=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)


def test_auto_resolution_warns_on_capability_fallback():
    """No override at all: when the preferred auto backend refuses the
    inputs (g does not divide P), the silent-looking default path must
    still surface a RuntimeWarning, not quietly lose APEC compression."""
    s = (jax.random.uniform(jax.random.PRNGKey(10), (10, 32)) < 0.5
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(11), (32, 8))
    with pytest.warns(RuntimeWarning, match="not divisible"):
        out = dispatch.apec_matmul(s, w, g=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)


def test_csr_constraint_degrades_to_pallas_not_ref():
    """pallas-csr's declared fallback chain: a CSR-only constraint failure
    (g=3 does not divide the 128-row tile) must degrade to the predicated
    pallas kernel — same family, comparable sweep — never straight to ref.
    """
    s = (jax.random.uniform(jax.random.PRNGKey(20), (12, 32)) < 0.5
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(21), (32, 8))
    with dispatch.use_backend("pallas-csr-interpret", op="apec_matmul"):
        with pytest.warns(RuntimeWarning, match="degrading to "
                          "'pallas-interpret'"):
            assert dispatch.resolve_name("apec_matmul", s, w, g=3) \
                == "pallas-interpret"
        # the same degrade edge is deduped per process — re-arm so the
        # dispatch below demonstrably warns again on its own
        dispatch.reset_fallback_warnings()
        with pytest.warns(RuntimeWarning):
            out = dispatch.apec_matmul(s, w, g=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)


def test_csr_fallback_chain_ends_at_ref_when_whole_family_refuses():
    """When the chained backend can't take the inputs either (P % g fails
    for every packed path), the walk must still terminate at ref."""
    s = (jax.random.uniform(jax.random.PRNGKey(22), (10, 32)) < 0.5
         ).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(23), (32, 8))
    with dispatch.use_backend("pallas-csr-interpret", op="apec_matmul"):
        with pytest.warns(RuntimeWarning, match="falling back"):
            out = dispatch.apec_matmul(s, w, g=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(s @ w), atol=ATOL)


def test_unknown_backend_falls_back_to_ref_with_warning():
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(6))
    with dispatch.use_backend("no-such-backend", op="sdsa"):
        with pytest.warns(RuntimeWarning, match="not registered"):
            out = dispatch.dispatch("sdsa", *args, **kwargs)
    expect = dispatch.call_backend("sdsa", dispatch.REF, *args, **kwargs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_call_backend_raises_instead_of_falling_back():
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(8))
    kwargs["mode"] = "sum"
    with pytest.raises(ValueError, match="mode"):
        dispatch.call_backend("sdsa", "pallas-interpret", *args, **kwargs)


def test_sdsa_sum_mode_auto_falls_back_under_packed_override():
    """mode='sum' can't run on the bitwise path: override must fall back
    to ref, matching the dense result."""
    args, kwargs = dispatch.example_inputs("sdsa", jax.random.PRNGKey(9))
    kwargs["mode"] = "sum"
    expect = dispatch.call_backend("sdsa", dispatch.REF, *args, **kwargs)
    with dispatch.use_backend("pallas-interpret", op="sdsa"):
        with pytest.warns(RuntimeWarning):
            out = dispatch.dispatch("sdsa", *args, **kwargs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# ------------------------------------------------------- end-to-end smoke
def _tiny_spikingformer_logits():
    from repro.configs.base import SpikingConfig
    from repro.models import spikingformer
    params = spikingformer.spikingformer_init(jax.random.PRNGKey(0),
                                              depth=1, dim=32)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
    return spikingformer.spikingformer_apply(
        params, x, n_heads=4, spiking_cfg=SpikingConfig(t_steps=2))


@pytest.fixture(scope="module")
def default_logits():
    """Default-resolution logits, computed once for the smoke tests."""
    return np.asarray(_tiny_spikingformer_logits())


def test_model_outputs_identical_ref_vs_default(default_logits):
    """EXSPIKE_BACKEND=ref vs default resolution: identical logits (on CPU
    both resolve to jnp paths; apec/econv/sdsa routing must not drift)."""
    with dispatch.use_backend(dispatch.REF):
        ref_logits = np.asarray(_tiny_spikingformer_logits())
    np.testing.assert_allclose(default_logits, ref_logits, atol=ATOL)


def test_model_outputs_match_under_kernel_backends(default_logits):
    """Whole-model parity with the Pallas (interpret) kernels driving the
    attention core and conv stem — the acceptance gate for swapping real
    TPU kernels in later."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with dispatch.use_backend("pallas-interpret", op="sdsa"), \
                dispatch.use_backend("pallas-interpret", op="econv"):
            kernel_logits = np.asarray(_tiny_spikingformer_logits())
    np.testing.assert_allclose(kernel_logits, default_logits, atol=1e-4)


def test_env_ref_subprocess_like(default_logits, monkeypatch):
    """The documented env knob end to end: set EXSPIKE_BACKEND=ref in this
    process and check the model still produces the same logits."""
    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    assert os.environ[dispatch.ENV_VAR] == "ref"
    np.testing.assert_allclose(np.asarray(_tiny_spikingformer_logits()),
                               default_logits, atol=ATOL)
