"""Checkpointing: roundtrip, corruption, retention, resume, elastic reshard."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jax.random.normal(k, (4,), jnp.float32)
                  .astype(jnp.bfloat16)}}


def test_save_restore_roundtrip_bitwise(tmp_path):
    tree = _tree()
    checkpointer.save(str(tmp_path), 5, tree)
    out = checkpointer.restore(str(tmp_path / "step_000000005"), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_corruption_detected(tmp_path):
    tree = _tree()
    checkpointer.save(str(tmp_path), 1, tree)
    ckpt = tmp_path / "step_000000001"
    # flip a byte in one leaf
    f = ckpt / "leaf_00000.npy"
    data = bytearray(f.read_bytes())
    data[-1] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(IOError, match="checksum"):
        checkpointer.restore(str(ckpt), tree)


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(10, tree)
    # fake a crashed save: committed marker missing
    bad = tmp_path / "step_000000020"
    bad.mkdir()
    (bad / "manifest.json").write_text(json.dumps({"leaves": []}))
    assert mgr.latest_step() == 10


def test_rolling_retention(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]


def test_restore_latest_skips_corrupt(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    mgr.save(1, tree)
    mgr.save(2, tree)
    f = tmp_path / "step_000000002" / "leaf_00000.npy"
    data = bytearray(f.read_bytes())
    data[-1] ^= 0xFF
    f.write_bytes(bytes(data))
    step, _ = mgr.restore_latest(tree)
    assert step == 1                          # fell back past corruption


def test_truncated_leaf_detected_before_load(tmp_path):
    """A leaf shorter than its manifest `nbytes` (writer died mid-flush)
    is rejected by the size check — before np.load ever parses it."""
    from repro.runtime import faults
    tree = _tree()
    checkpointer.save(str(tmp_path), 3, tree)
    faults.truncate_checkpoint(str(tmp_path / "step_000000003"),
                               keep_bytes=16)
    with pytest.raises(IOError, match="truncated"):
        checkpointer.restore(str(tmp_path / "step_000000003"), tree)


def test_restore_latest_walks_back_past_truncation(tmp_path):
    from repro.runtime import faults
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    mgr.save(1, tree)
    mgr.save(2, tree)
    faults.truncate_checkpoint(str(tmp_path / "step_000000002"))
    step, out = mgr.restore_latest(tree)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_walks_back_past_dropped_leaf(tmp_path):
    """A vanished leaf file (lost shard) raises OSError inside restore;
    restore_latest treats it as corruption, not a crash."""
    from repro.runtime import faults
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    mgr.save(1, tree)
    mgr.save(2, tree)
    faults.drop_checkpoint_file(str(tmp_path / "step_000000002"))
    step, _ = mgr.restore_latest(tree)
    assert step == 1


def test_all_checkpoints_corrupt_returns_none(tmp_path):
    from repro.runtime import faults
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    mgr.save(1, tree)
    faults.truncate_checkpoint(str(tmp_path / "step_000000001"))
    step, out = mgr.restore_latest(tree)
    assert step is None                       # caller starts fresh
    assert out is tree


def test_manifest_promises_leaf_sizes(tmp_path):
    tree = _tree()
    checkpointer.save(str(tmp_path), 1, tree)
    with open(tmp_path / "step_000000001" / "manifest.json") as f:
        manifest = json.load(f)
    for meta in manifest["leaves"]:
        path = tmp_path / "step_000000001" / meta["file"]
        assert meta["nbytes"] == path.stat().st_size > 0


def test_async_save_then_wait(tmp_path):
    tree = _tree()
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, tree)
    mgr.wait()
    assert mgr.latest_step() == 7


@pytest.mark.slow
def test_elastic_restore_different_mesh(multidevice_run):
    """Checkpoint written on a 4x2 mesh restores onto 2x2 (shared
    8-host-device subprocess — the main test process keeps its single
    device; see conftest.multidevice_run)."""
    multidevice_run.check("CKPT_ELASTIC")


def test_shrunk_mesh_plan():
    from repro.runtime.elastic import shrunk_mesh
    plan = shrunk_mesh((16, 16), ("data", "model"), n_failed_data_groups=3)
    assert plan.mesh_shape == (8, 16)        # largest divisor mesh
    assert plan.microbatch_scale == 2        # keep global batch via accum
