"""Sharding rules validated against the production mesh shapes for every
assigned arch (AbstractMesh — no devices needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.launch.mesh import abstract_mesh
from repro.models import lm
from repro.runtime import sharding


def _mesh(multi_pod=False):
    if multi_pod:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = registry.get_config(arch)
    mesh = _mesh(multi_pod)
    abs_params = lm.abstract_params(cfg)
    specs = sharding.param_specs(cfg, abs_params, mesh)
    problems = sharding.validate_specs(abs_params, specs, mesh)
    assert not problems, problems[:5]


@pytest.mark.parametrize("arch", ["mistral-large-123b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b"])
def test_big_arch_params_are_model_sharded(arch):
    """The big archs must not replicate their matrices (HBM would blow)."""
    cfg = registry.get_config(arch)
    mesh = _mesh()
    abs_params = lm.abstract_params(cfg)
    specs = sharding.param_specs(cfg, abs_params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    replicated_big = [
        (p, l.shape) for (p, l), s in zip(flat, flat_s)
        if l.size > 64 * 1024 * 1024 and all(ax is None for ax in s)]
    assert not replicated_big, replicated_big[:5]


def test_batch_axes_divisibility():
    mesh = _mesh(multi_pod=True)
    assert sharding.batch_axes(mesh, 256) == ("pod", "data")
    assert sharding.batch_axes(mesh, 32) == ("pod", "data")
    assert sharding.batch_axes(mesh, 2) == ("pod",)
    assert sharding.batch_axes(mesh, 1) == ()
    single = _mesh()
    assert sharding.batch_axes(single, 128) == ("data",)
    assert sharding.batch_axes(single, 8) == ()   # 8 % 16 != 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_state_specs_build(arch):
    cfg = registry.get_config(arch)
    mesh = _mesh()
    import functools
    state_abs = jax.eval_shape(functools.partial(
        lm.init_decode_state, cfg, 128, 1024, False))
    specs = sharding.decode_state_specs(cfg, state_abs, mesh)
    problems = sharding.validate_specs(state_abs, specs, mesh)
    assert not problems, problems[:5]


def test_fsdp_shards_optimizer_dim():
    cfg = registry.get_config("mistral-large-123b")
    assert cfg.fsdp
    mesh = _mesh()
    abs_params = lm.abstract_params(cfg)
    specs = sharding.param_specs(cfg, abs_params, mesh)
    # embed spec should carry the data axis for FSDP
    assert specs["embed"] == P("model", "data")
