import os
# 512 *host* (CPU) devices; pin the platform so jax never probes the TPU
# runtime (a multi-minute libtpu timeout on TPU-toolchain images with no
# TPU attached — the dry-run is a CPU-side compile study by design).
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (arch x shape) cell, lower + compile the real step function on
the production mesh — single-pod (16, 16) and multi-pod (2, 16, 16) — with
ShapeDtypeStruct inputs (no allocation), then record:

  * memory_analysis()      — proves the cell fits per-device HBM,
  * cost_analysis()        — raw HLO FLOPs/bytes (loop bodies counted once),
  * collective bytes       — HLO-parsed, while-trip-count scaled,
  * analytic step cost     — trip-count-aware FLOPs/bytes (launch.flops),

into results/dryrun/<arch>__<shape>__<mesh>.json for the roofline stage.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--cells a:s,a:s,...]
"""
import argparse
import functools
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.launch import flops as flops_mod
from repro.launch import hlo_analysis, specs, steps
from repro.launch.mesh import make_production_mesh, chips, use_concrete_mesh
from repro.models import lm
from repro.optim import adamw
from repro.runtime import sharding


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _mem_report(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:        # backend without memory analysis
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    return out


def _cost_report(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    ca = ca[0] if isinstance(ca, list) else ca
    if ca is None:
        return {}
    keep = {}
    for k in ("flops", "bytes accessed", "transcendentals", "utilization"):
        if k in ca:
            keep[k.replace(" ", "_")] = float(ca[k])
    return keep


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mode: str | None = None, cfg_override=None):
    """Returns (record dict, lowered, compiled)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override or registry.get_config(arch)
    shape = registry.get_shape(shape_name)
    spiking = specs.spiking_for_shape(shape) if mode is None \
        else (mode == "spiking")

    params_abs = specs.abstract_params(cfg)
    pspecs = sharding.param_specs(cfg, params_abs, mesh)
    problems = sharding.validate_specs(params_abs, pspecs, mesh)
    if problems:
        raise ValueError(f"sharding divisibility problems: {problems[:5]}")
    p_sh = _named(mesh, pspecs)
    repl = NamedSharding(mesh, P())

    t0 = time.time()
    with mesh, use_concrete_mesh(mesh):
        if shape.kind == "train":
            opt_abs = jax.eval_shape(functools.partial(
                adamw.init, cfg=adamw.AdamWConfig(
                    state_dtype=cfg.opt_state_dtype)), params_abs)
            o_sh = adamw.AdamWState(
                step=repl, mu=_named(mesh, pspecs), nu=_named(mesh, pspecs))
            batch_abs = specs.train_batch_spec(cfg, shape)
            b_sh = _named(mesh, sharding.batch_specs(cfg, batch_abs, mesh))
            fn = steps.make_train_step(cfg, spiking=spiking, mesh=mesh)
            metrics_sh = {"loss": repl, "grad_norm": repl}
            lowered = jax.jit(
                fn, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, metrics_sh),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            batch_abs = specs.prefill_spec(cfg, shape)
            b_sh = _named(mesh, sharding.batch_specs(cfg, batch_abs, mesh))
            fn = steps.make_prefill(cfg, spiking, mesh=mesh)
            bs = sharding.batch_axes(mesh, shape.global_batch) or None
            out_sh = NamedSharding(mesh, P(
                bs, "model" if cfg.vocab % mesh.shape["model"] == 0
                else None))
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh), out_shardings=out_sh,
            ).lower(params_abs, batch_abs)
        else:  # decode / long_decode
            state_abs, tok_abs, pos_abs = specs.decode_specs(
                cfg, shape, spiking)
            s_specs = sharding.decode_state_specs(cfg, state_abs, mesh)
            s_sh = _named(mesh, s_specs)
            bs = None if cfg.tp2d else \
                (sharding.batch_axes(mesh, shape.global_batch) or None)
            tok_sh = NamedSharding(mesh, P(bs))
            logits_sh = NamedSharding(mesh, P(
                bs, "model" if cfg.vocab % mesh.shape["model"] == 0
                else None))
            fn = steps.make_serve_step(cfg, spiking, mesh=mesh)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, s_sh, tok_sh, repl),
                out_shardings=(logits_sh, s_sh), donate_argnums=(1,),
            ).lower(params_abs, state_abs, tok_abs, pos_abs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    coll_raw = hlo_analysis.collective_bytes_unscaled(hlo)
    analytic = flops_mod.step_cost(cfg, shape, spiking)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips(make_production_mesh(multi_pod=multi_pod)),
        "mode": "spiking" if spiking else "dense",
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_report(compiled),
        "cost_analysis_raw": _cost_report(compiled),
        "collective_bytes": coll,
        "collective_bytes_unscaled": coll_raw,
        "analytic": analytic.asdict(),
        "hlo_chars": len(hlo),
    }
    return record, lowered, compiled


def run_cell(arch, shape_name, multi_pod, out_dir, mode=None,
             cfg_override=None, suffix=""):
    name = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if mode:
        name += f"__{mode}"
    if suffix:
        name += f"__{suffix}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    try:
        record, _, _ = lower_cell(arch, shape_name, multi_pod, mode,
                                  cfg_override=cfg_override)
        record["variant"] = suffix or "baseline"
    except Exception as e:
        record = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "ok": False, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    status = "OK" if record.get("ok") else f"FAIL ({record.get('error')})"
    print(f"[dryrun] {name}: {status}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cells", default=None,
                    help="comma list of arch:shape pairs")
    ap.add_argument("--mode", default=None, choices=["spiking", "dense"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = [(a, s) for a, s in registry.all_cells()]
    elif args.cells:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    n_ok = 0
    for arch, shape_name in cells:
        rec = run_cell(arch, shape_name, args.multi_pod, args.out, args.mode)
        n_ok += bool(rec.get("ok"))
    print(f"[dryrun] {n_ok}/{len(cells)} cells OK")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
