"""Serving launcher: continuous-batching decode over slot-based state.

A fixed pool of batch slots shares one decode state (the SDSA/SSM states
and KV caches are per-slot along the batch axis). Requests queue in, get
assigned a free slot, decode until their token budget, then release the
slot — the standard continuous-batching pattern, with the twist that in
spiking mode the per-slot state is O(d) (SDSA status vectors), so slot
turnover costs no cache re-prefill, only a state reset.

CLI: python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import LMConfig
from repro.kernels import dispatch
from repro.launch import steps as steps_mod
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: LMConfig, n_slots: int = 4, max_seq: int = 256,
                 spiking: Optional[bool] = None, seed: int = 0, mesh=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.spiking = cfg.spiking.enabled if spiking is None else spiking
        self.mesh = mesh
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.state = lm.init_decode_state(cfg, n_slots, max_seq, self.spiking)
        self.pos = np.zeros(n_slots, np.int32)       # per-slot position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        # The continuous-batching decode step traces under the mesh, so
        # spike matmuls inside resolve mesh-aware (per-shard capability
        # checks on the slot batch — the axis a deployment shards over
        # 'data') and distributed decode keeps the event kernels. The
        # mesh steers RESOLUTION only; placing params/state on it is the
        # deployment's in_shardings.
        self._step = jax.jit(
            steps_mod.make_serve_step(cfg, self.spiking, mesh=mesh))
        self.steps_executed = 0

    def submit(self, req: Request):
        self.pending.append(req)

    def _assign_slots(self):
        for i in range(self.n_slots):
            if self.slot_req[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[i] = req
                self.pos[i] = 0
                # Reset this slot's state by feeding prompt tokens below.
                req._feed = list(req.prompt)   # tokens still to prefill

    def step(self):
        """One batched decode step across all active slots."""
        self._assign_slots()
        tokens = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[i] = True
            if req._feed:                       # prompt prefill (streaming)
                tokens[i] = req._feed.pop(0)
            else:
                tokens[i] = req.generated[-1] if req.generated \
                    else (req.prompt[-1] if req.prompt else 0)
        if not active.any():
            return False
        pos = jnp.int32(int(self.pos.max()))    # aligned stepping
        logits, self.state = self._step(self.params, self.state,
                                        jnp.asarray(tokens), pos)
        self.steps_executed += 1
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.pos[i] += 1
            if not req._feed:                   # generating phase
                req.generated.append(int(next_tokens[i]))
                if len(req.generated) >= req.max_new \
                        or self.pos[i] >= self.max_seq - 1:
                    req.done = True
                    self.slot_req[i] = None     # release slot
        return True

    def run_until_drained(self, max_steps: int = 10_000):
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.step() and not self.pending:
                break
        return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="kernel backend override, same grammar as "
                         "EXSPIKE_BACKEND (e.g. 'ref' or 'sdsa=pallas,ref')")
    ap.add_argument("--mesh", action="store_true",
                    help="resolve kernel dispatch mesh-aware against the "
                         "host mesh (per-shard capability checks, degrade "
                         "attribution printed below); array placement is "
                         "unchanged — sharding the slot batch is the "
                         "deployment's jit in_shardings' job")
    args = ap.parse_args()
    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    if args.backend:
        os.environ[dispatch.ENV_VAR] = args.backend
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    print(f"[serve] kernel backends"
          f"{' (mesh-aware)' if mesh is not None else ''}: "
          f"{dispatch.resolved_backends(mesh=mesh)}")
    server = Server(cfg, n_slots=args.slots,
                    spiking=False if args.dense else None, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab, 8)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total_new} tokens, "
          f"{server.steps_executed} steps, {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:4]}... -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
