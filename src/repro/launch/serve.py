"""Serving launcher: a continuous-batching scheduler over slot-based state.

A pool of batch slots shares one decode state (the SDSA/SSM states and
KV caches are per-slot along the batch axis). Requests arrive on a
trace clock, queue in, get assigned a free slot, are PREFILLED in one
bucketed chunked call (prefill/decode disaggregation — not streamed
token-at-a-time through the decode step), then decode at their OWN
per-slot position until their token budget, and release the slot.

The per-slot position vector is the load-bearing fix: the pool steps
with ``pos: (n_slots,)`` so a slot admitted while others are
mid-generation writes its KV rows / RoPE angles / causal mask at ITS
position — decoding a request in a busy pool is bitwise the same as
decoding it alone (tests/test_serve_scheduler.py pins this). The old
loop stepped everyone at ``pos.max()``, a latent correctness bug masked
only by aligned-wave admission.

The spiking payoff this cashes in: per-slot SDSA state is O(d), so slot
turnover costs no cache re-prefill — exactly what makes large
continuous-batching pools cheap (`reset_slot_state` / `merge_slot_state`
in models/lm.py are the structural slot surgery). `ReplicaPool` layers
multi-replica dispatch on top, steering admission toward event-light
replicas with `runtime/straggler.occupancy_imbalance` as the load
signal (event skew IS the load).

CLI: python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --requests 6 --max-new 16
     python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --trace bursty --requests 24 --slots 8 --replicas 2
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import os
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import LMConfig
from repro.kernels import dispatch
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.runtime.straggler import OccupancyImbalance, occupancy_imbalance


class FakeClock:
    """Deterministic injectable clock for scheduler tests: ``clock()``
    reads, ``clock.advance(dt)`` moves time. `run_until_drained` advances
    an advanceable injected clock across backoff/arrival waits instead of
    real-sleeping (a real ``time.sleep`` under a fake clock spins the
    drain loop to its step cap without ever opening a gate)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass
class Request:
    """One generation request with an explicit lifecycle.

    `state` walks pending -> running -> done|failed; every exit path
    (completion, deadline, prefill/decode fault, retry exhaustion)
    records a terminal state and releases the slot — a request is never
    silently lost. `failure_cause` keeps the LAST fault even when a
    retry later succeeds (observability of flaky slots); terminal
    failure iff ``state == "failed"``.
    """
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- guarded-execution fields ---
    deadline_s: Optional[float] = None   # wall-clock budget from submit()
    max_retries: int = 2                 # quarantine re-enqueue budget
    state: str = "pending"               # pending|running|done|failed
    failure_cause: Optional[str] = None  # last fault seen (terminal or not)
    retries: int = 0
    submitted_at: Optional[float] = None
    not_before: float = 0.0              # backoff gate (monotonic clock)
    # --- trace / latency fields ---
    arrival_s: Optional[float] = None    # trace arrival, relative to epoch
    finished_at: Optional[float] = None  # terminal timestamp (clock domain)


@dataclasses.dataclass(frozen=True)
class ReplicaLoad:
    """One replica's admission-time load: slot pressure plus event load.

    `event_occ` is the mean nonzero fraction of the busy slots' SDSA
    status vectors — accumulated spike traffic, the O(d)-cheap per-slot
    proxy for the occupied-tile counts the kernels will walk. Event skew
    is the load (NEURAL): two replicas with equal busy counts can carry
    very different event work, and `score` folds that in so admission
    steers toward the event-light replica."""
    busy: int
    queued: int
    event_occ: float

    @property
    def score(self) -> float:
        return self.busy + self.queued + self.event_occ * max(self.busy, 1)


# Shared jit caches: Servers with the same (hashable, frozen) LMConfig
# reuse one compiled decode step / prefill family instead of retracing
# per instance — slot parity tests and replica pools construct many
# Servers over one config.
_STEP_CACHE: dict = {}


def _cached_jit(kind: str, cfg: LMConfig, spiking: bool, mesh, max_seq: int):
    key = (kind, cfg, spiking, id(mesh) if mesh is not None else None,
           max_seq)
    fn = _STEP_CACHE.get(key)
    if fn is None:
        if kind == "step":
            fn = jax.jit(steps_mod.make_serve_step(cfg, spiking, mesh=mesh))
        else:
            fn = jax.jit(steps_mod.make_prefill_state(
                cfg, spiking, mesh=mesh, max_seq=max_seq))
        _STEP_CACHE[key] = fn
    return fn


class Server:
    def __init__(self, cfg: LMConfig, n_slots: int = 4, max_seq: int = 256,
                 spiking: Optional[bool] = None, seed: int = 0, mesh=None,
                 clock=time.monotonic, backoff_s: float = 0.05,
                 prefill_bucket_min: int = 8):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.spiking = cfg.spiking.enabled if spiking is None else spiking
        self.mesh = mesh
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.state = lm.init_decode_state(cfg, n_slots, max_seq, self.spiking)
        self.pos = np.zeros(n_slots, np.int32)       # per-slot position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        self.arrivals: List[Request] = []            # trace queue, by arrival_s
        self.epoch: Optional[float] = None           # t0 for arrival offsets
        self.finished: List[Request] = []            # done AND failed
        self._clock = clock                          # injectable for tests
        self.backoff_s = backoff_s                   # retry backoff base
        self.prefill_bucket_min = prefill_bucket_min
        # The continuous-batching decode step traces under the mesh, so
        # spike matmuls inside resolve mesh-aware (per-shard capability
        # checks on the slot batch — the axis a deployment shards over
        # 'data') and distributed decode keeps the event kernels. The
        # mesh steers RESOLUTION only; placing params/state on it is the
        # deployment's in_shardings.
        self._step = _cached_jit("step", cfg, self.spiking, mesh, max_seq)
        # Bucketed chunked prefill (admission): one compile per pow2
        # prompt-length bucket, shared across Servers of this config.
        self._prefill = _cached_jit("prefill", cfg, self.spiking, mesh,
                                    max_seq)
        self.steps_executed = 0
        self.prefills_executed = 0

    # --------------------------------------------------------- submission
    def submit(self, req: Request):
        if req.submitted_at is None:
            req.submitted_at = self._clock()
        req.state = "pending"
        self.pending.append(req)

    def submit_at(self, req: Request, arrival_s: float):
        """Queue `req` to arrive `arrival_s` seconds after the server's
        epoch (set at the first step) — the async-admission entry point
        for trace replay. The request is not visible to the scheduler (and
        its deadline clock does not start) until it arrives."""
        req.arrival_s = float(arrival_s)
        keys = [r.arrival_s for r in self.arrivals]
        self.arrivals.insert(bisect.bisect_right(keys, req.arrival_s), req)

    def _admit_arrivals(self, now: float):
        if self.epoch is None:
            self.epoch = now
        while self.arrivals \
                and self.epoch + self.arrivals[0].arrival_s <= now:
            self.submit(self.arrivals.pop(0))

    # ------------------------------------------------------ slot lifecycle
    def _reset_slot_state(self, i: int):
        """Zero slot i's decode state structurally (models/lm.py
        `reset_slot_state`: every leaf is (n_groups, n_slots, ...), slot
        batch = axis 1 — validated loudly, never shape-guessed). In
        spiking mode this is O(d) per layer (the SDSA status vectors);
        the dense KV cache pays its size."""
        self.state = lm.reset_slot_state(self.state, i, self.n_slots)
        self.pos[i] = 0

    def _finish(self, i: int, req: Request, state: str,
                cause: Optional[str] = None):
        """Terminal exit: record the outcome and release the slot."""
        req.state = state
        req.done = state == "done"
        req.finished_at = self._clock()
        if cause is not None:
            req.failure_cause = cause
        self.finished.append(req)
        if i >= 0:
            self.slot_req[i] = None
            self.pos[i] = 0

    def _quarantine(self, i: int, cause: str):
        """Non-terminal fault on slot i: reset the slot, re-enqueue the
        request with bounded retries + exponential backoff, or fail it
        terminally when the retry budget is spent. Partial output is
        discarded — a retried request regenerates from its prompt."""
        req = self.slot_req[i]
        self.slot_req[i] = None
        self._reset_slot_state(i)
        if req is None:
            return
        req.failure_cause = cause
        if req.retries >= req.max_retries:
            self._finish(-1, req, "failed", cause)
            return
        req.retries += 1
        req.generated = []
        req.state = "pending"
        req.not_before = self._clock() + self.backoff_s * (2 ** (req.retries - 1))
        self.pending.append(req)

    def _expire_deadlines(self, now: float):
        """Deadline is terminal on every path: active slots are released,
        queued requests never admitted. A request that reached the
        scheduler without going through submit() (direct pending append,
        replica handoff) is stamped here at first observation — the
        deadline clock never dereferences a missing timestamp."""
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req.submitted_at is None:
                req.submitted_at = now
            if req.deadline_s is not None \
                    and now - req.submitted_at > req.deadline_s:
                self._finish(i, req, "failed", "deadline")
        kept = []
        for req in self.pending:
            if req.submitted_at is None:
                req.submitted_at = now
            if req.deadline_s is not None \
                    and now - req.submitted_at > req.deadline_s:
                self._finish(-1, req, "failed", "deadline")
            else:
                kept.append(req)
        self.pending = kept

    # ---------------------------------------------------------- admission
    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket_min
        while b < n:
            b *= 2
        return b

    def _admit(self, i: int, req: Request):
        """Assign slot i and chunk-prefill the prompt in one bucketed
        call: the fresh single-request state is scattered into the pool
        (merge overwrites EVERY leaf of the slot — admission never
        inherits a previous occupant's KV rows or SDSA status) and the
        slot's position starts at len(prompt). The first generated token
        comes from the prefill's last-position logits."""
        req.state = "running"
        self.slot_req[i] = req
        prompt = list(req.prompt) if req.prompt else [0]
        n = len(prompt)
        toks = np.zeros((1, self._bucket(n)), np.int32)
        toks[0, :n] = prompt
        try:
            logits, single = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray([n], jnp.int32))
            logits_np = np.asarray(logits)[0]
        except Exception as e:
            self._quarantine(i, f"prefill_error:{type(e).__name__}")
            return
        if not np.isfinite(logits_np).all():
            self._quarantine(i, "nan_logits")
            return
        self.state = lm.merge_slot_state(self.state, single, jnp.int32(i))
        self.pos[i] = n
        self.prefills_executed += 1
        req.generated.append(int(logits_np.argmax()))
        self._maybe_complete(i, req)

    def _maybe_complete(self, i: int, req: Request):
        if len(req.generated) >= req.max_new \
                or self.pos[i] >= self.max_seq - 1:
            self._finish(i, req, "done")

    def _assign_slots(self, now: float):
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        kept, admitted = [], []
        for req in self.pending:
            if len(req.prompt) >= self.max_seq:
                self._finish(-1, req, "failed", "prompt_too_long")
            elif free and req.not_before <= now:
                admitted.append((free.pop(0), req))
            else:
                kept.append(req)
        self.pending = kept
        for i, req in admitted:
            self._admit(i, req)

    # --------------------------------------------------------- load signal
    def occupancy_load(self) -> ReplicaLoad:
        """Admission-time load: busy slots, queue depth, and the event
        occupancy of the busy slots' SDSA statuses (spiking mode; 0.0
        dense — a dense replica's event load is its slot count)."""
        busy = [i for i, r in enumerate(self.slot_req) if r is not None]
        ev = 0.0
        if busy and self.spiking:
            nz = tot = 0
            for layer in self.state:
                if layer.sdsa is None:
                    continue
                status = np.asarray(
                    layer.sdsa.status[:, busy].astype(jnp.float32))
                nz += int(np.count_nonzero(status))
                tot += status.size
            if tot:
                ev = nz / tot
        return ReplicaLoad(busy=len(busy),
                           queued=len(self.pending) + len(self.arrivals),
                           event_occ=ev)

    # -------------------------------------------------------------- stepping
    def step(self):
        """One batched decode step across all active slots, at their
        per-slot positions. Every fault has an exit path: a raising
        prefill/decode quarantines (bounded retries), non-finite logits
        quarantine their slot, and deadline overruns fail terminally —
        no slot leaks, no request is dropped without a recorded cause."""
        now = self._clock()
        self._admit_arrivals(now)
        self._expire_deadlines(now)
        self._assign_slots(now)
        tokens = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[i] = True
            tokens[i] = req.generated[-1] if req.generated \
                else (req.prompt[-1] if req.prompt else 0)
        if not active.any():
            return False
        pos = jnp.asarray(self.pos)          # per-slot positions (n_slots,)
        try:
            logits, new_state = self._step(self.params, self.state,
                                           jnp.asarray(tokens), pos)
            logits_np = np.asarray(logits)
        except Exception as e:   # decode fault: the batch can't attribute
            # a raising step to one slot, so every active slot quarantines
            # (healthy requests spend one retry and regenerate).
            for i, req in enumerate(self.slot_req):
                if req is not None:
                    self._quarantine(i, f"decode_error:{type(e).__name__}")
            return True
        self.state = new_state
        self.steps_executed += 1
        finite = np.isfinite(logits_np).all(axis=-1)
        next_tokens = np.argmax(logits_np, axis=-1)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if not finite[i]:
                # NaN/inf logits: poisoned slot state or params. Reset
                # the slot and re-enqueue — never emit a poisoned token.
                self._quarantine(i, "nan_logits")
                continue
            self.pos[i] += 1
            req.generated.append(int(next_tokens[i]))
            self._maybe_complete(i, req)
        return True

    # ------------------------------------------------------------- draining
    def _next_gate(self, now: float) -> Optional[float]:
        """Earliest future instant anything becomes actionable: a backoff
        gate opening or a trace arrival. None when nothing is queued."""
        gates = [r.not_before for r in self.pending]
        if self.arrivals:
            gates.append((self.epoch if self.epoch is not None else now)
                         + self.arrivals[0].arrival_s)
        return min(gates) if gates else None

    def _idle_wait(self):
        """Nothing active but work queued: wait for the next gate. An
        advanceable injected clock (FakeClock) is advanced directly —
        deterministic tests never real-sleep; the real clock sleeps in
        small increments."""
        now = self._clock()
        gate = self._next_gate(now)
        delay = max((gate - now) if gate is not None else 0.0, 1e-4)
        advance = getattr(self._clock, "advance", None)
        if advance is not None:
            advance(delay)
        elif self._clock is time.monotonic:
            time.sleep(min(delay, 0.005))
        # else: a bare injected callable can't be advanced — do NOT
        # real-sleep against fake time; the drain loop spends a step.

    def run_until_drained(self, max_steps: int = 10_000):
        """Drive until no request is active, pending, or still arriving
        (or `max_steps`). Returns the finished requests — done and
        terminally failed."""
        for _ in range(max_steps):
            stepped = self.step()
            if not stepped:
                if not self.pending and not self.arrivals:
                    break
                self._idle_wait()
        return self.finished


class ReplicaPool:
    """Multi-replica dispatch: N Servers over one model, admission
    steered by the occupancy-imbalance load signal.

    Each arriving request is routed to the replica with the lowest
    `ReplicaLoad.score` (busy slots + queue depth + event occupancy of
    the busy slots — event skew is the load, so two equally-busy
    replicas are told apart by the spike traffic their slots carry).
    Every routing decision records a
    `runtime.straggler.occupancy_imbalance` over the per-replica scores
    in `imbalance_log` — the same max/mean skew signal the sharded
    training path monitors, here driving admission instead of
    rebalancing. ``balancer="round_robin"`` is the load-blind baseline.
    """

    def __init__(self, cfg: LMConfig, n_replicas: int = 2,
                 balancer: str = "occupancy", clock=time.monotonic,
                 **server_kw):
        if balancer not in ("occupancy", "round_robin"):
            raise ValueError(f"unknown balancer {balancer!r}")
        # Same seed per replica: true replicas of one model.
        self.replicas = [Server(cfg, clock=clock, **server_kw)
                         for _ in range(n_replicas)]
        self.balancer = balancer
        self._clock = clock
        self._rr = 0
        self.arrivals: List[Request] = []
        self.epoch: Optional[float] = None
        self.imbalance_log: List[OccupancyImbalance] = []

    def _dispatch(self, req: Request):
        loads = [r.occupancy_load() for r in self.replicas]
        # Integer-scaled scores feed the same skew summary the training
        # straggler monitor uses; imbalance 1.0 = perfectly balanced.
        self.imbalance_log.append(occupancy_imbalance(
            [int(round(100 * ld.score)) for ld in loads]))
        if self.balancer == "round_robin":
            idx = self._rr
            self._rr = (self._rr + 1) % len(self.replicas)
        else:
            idx = min(range(len(loads)), key=lambda j: loads[j].score)
        self.replicas[idx].submit(req)
        return idx

    def submit(self, req: Request):
        return self._dispatch(req)

    def submit_at(self, req: Request, arrival_s: float):
        """Route at ARRIVAL, not submission — load is only current when
        the request actually shows up."""
        req.arrival_s = float(arrival_s)
        keys = [r.arrival_s for r in self.arrivals]
        self.arrivals.insert(bisect.bisect_right(keys, req.arrival_s), req)

    def step(self) -> bool:
        now = self._clock()
        if self.epoch is None:
            self.epoch = now
        while self.arrivals and self.epoch + self.arrivals[0].arrival_s <= now:
            self._dispatch(self.arrivals.pop(0))
        stepped = [r.step() for r in self.replicas]
        return any(stepped)

    @property
    def finished(self) -> List[Request]:
        return [req for r in self.replicas for req in r.finished]

    def _idle_wait(self):
        now = self._clock()
        gates = [g for g in (r._next_gate(now) for r in self.replicas)
                 if g is not None]
        if self.arrivals:
            gates.append((self.epoch if self.epoch is not None else now)
                         + self.arrivals[0].arrival_s)
        delay = max((min(gates) - now) if gates else 0.0, 1e-4)
        advance = getattr(self._clock, "advance", None)
        if advance is not None:
            advance(delay)
        elif self._clock is time.monotonic:
            time.sleep(min(delay, 0.005))

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            stepped = self.step()
            if not stepped:
                if not self.arrivals and not any(
                        r.pending or r.arrivals for r in self.replicas):
                    break
                self._idle_wait()
        return self.finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--replicas", type=int, default=1,
                    help="multi-replica dispatch: >1 runs a ReplicaPool "
                         "with occupancy-steered admission")
    ap.add_argument("--trace", default=None,
                    choices=("poisson", "bursty"),
                    help="replay a synthetic arrival trace "
                         "(benchmarks/serve_traces.py) instead of "
                         "submitting everything at t=0")
    ap.add_argument("--backend", default=None,
                    help="kernel backend override, same grammar as "
                         "EXSPIKE_BACKEND (e.g. 'ref' or 'sdsa=pallas,ref')")
    ap.add_argument("--mesh", action="store_true",
                    help="resolve kernel dispatch mesh-aware against the "
                         "host mesh (per-shard capability checks, degrade "
                         "attribution printed below); array placement is "
                         "unchanged — sharding the slot batch is the "
                         "deployment's jit in_shardings' job")
    args = ap.parse_args()
    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    if args.backend:
        os.environ[dispatch.ENV_VAR] = args.backend
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    print(f"[serve] kernel backends"
          f"{' (mesh-aware)' if mesh is not None else ''}: "
          f"{dispatch.resolved_backends(mesh=mesh)}")
    kw = dict(n_slots=args.slots,
              spiking=False if args.dense else None, mesh=mesh)
    server = (ReplicaPool(cfg, n_replicas=args.replicas, **kw)
              if args.replicas > 1 else Server(cfg, **kw))
    rng = np.random.default_rng(0)
    if args.trace:
        from benchmarks.serve_traces import make_trace
        trace = make_trace(args.trace, seed=0, n_requests=args.requests,
                           vocab=cfg.vocab, max_new=(args.max_new,
                                                     args.max_new))
        reqs = []
        for t in trace:
            r = Request(rid=t.rid, prompt=list(t.prompt), max_new=t.max_new)
            server.submit_at(r, t.arrival_s)
            reqs.append(r)
    else:
        reqs = [Request(rid=i,
                        prompt=[int(t) for t in rng.integers(0, cfg.vocab, 8)],
                        max_new=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            server.submit(r)
    t0 = time.time()
    server.run_until_drained()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in reqs)
    servers = server.replicas if isinstance(server, ReplicaPool) \
        else [server]
    steps = sum(s.steps_executed for s in servers)
    prefills = sum(s.prefills_executed for s in servers)
    print(f"[serve] {len(reqs)} requests, {total_new} tokens, "
          f"{steps} decode steps + {prefills} prefills, {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s)")
    if isinstance(server, ReplicaPool) and server.imbalance_log:
        last = server.imbalance_log[-1]
        print(f"[serve] admission load signal: {last.as_fields()}")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:4]}... -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
