"""Serving launcher: continuous-batching decode over slot-based state.

A fixed pool of batch slots shares one decode state (the SDSA/SSM states
and KV caches are per-slot along the batch axis). Requests queue in, get
assigned a free slot, decode until their token budget, then release the
slot — the standard continuous-batching pattern, with the twist that in
spiking mode the per-slot state is O(d) (SDSA status vectors), so slot
turnover costs no cache re-prefill, only a state reset.

CLI: python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import LMConfig
from repro.kernels import dispatch
from repro.launch import steps as steps_mod
from repro.models import lm


@dataclasses.dataclass
class Request:
    """One generation request with an explicit lifecycle.

    `state` walks pending -> running -> done|failed; every exit path
    (completion, deadline, decode fault, retry exhaustion) records a
    terminal state and releases the slot — a request is never silently
    lost. `failure_cause` keeps the LAST fault even when a retry later
    succeeds (observability of flaky slots); terminal failure iff
    ``state == "failed"``.
    """
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- guarded-execution fields ---
    deadline_s: Optional[float] = None   # wall-clock budget from submit()
    max_retries: int = 2                 # quarantine re-enqueue budget
    state: str = "pending"               # pending|running|done|failed
    failure_cause: Optional[str] = None  # last fault seen (terminal or not)
    retries: int = 0
    submitted_at: Optional[float] = None
    not_before: float = 0.0              # backoff gate (monotonic clock)


class Server:
    def __init__(self, cfg: LMConfig, n_slots: int = 4, max_seq: int = 256,
                 spiking: Optional[bool] = None, seed: int = 0, mesh=None,
                 clock=time.monotonic, backoff_s: float = 0.05):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.spiking = cfg.spiking.enabled if spiking is None else spiking
        self.mesh = mesh
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        self.state = lm.init_decode_state(cfg, n_slots, max_seq, self.spiking)
        self.pos = np.zeros(n_slots, np.int32)       # per-slot position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        self.finished: List[Request] = []            # done AND failed
        self._clock = clock                          # injectable for tests
        self.backoff_s = backoff_s                   # retry backoff base
        # The continuous-batching decode step traces under the mesh, so
        # spike matmuls inside resolve mesh-aware (per-shard capability
        # checks on the slot batch — the axis a deployment shards over
        # 'data') and distributed decode keeps the event kernels. The
        # mesh steers RESOLUTION only; placing params/state on it is the
        # deployment's in_shardings.
        self._step = jax.jit(
            steps_mod.make_serve_step(cfg, self.spiking, mesh=mesh))
        self.steps_executed = 0

    def submit(self, req: Request):
        if req.submitted_at is None:
            req.submitted_at = self._clock()
        req.state = "pending"
        self.pending.append(req)

    # ------------------------------------------------------ slot lifecycle
    def _reset_slot_state(self, i: int):
        """Zero slot i's decode state (leaves are stacked
        ``(n_groups, n_slots, ...)`` — slot batch = axis 1). In spiking
        mode this is O(d) per layer (the SDSA status vectors), the cheap
        turnover the serve docstring advertises; the dense KV cache pays
        its size. Re-prefilling the prompt rebuilds the state."""
        def zero(x):
            if hasattr(x, "ndim") and x.ndim >= 2 \
                    and x.shape[1] == self.n_slots:
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        self.state = jax.tree.map(zero, self.state)
        self.pos[i] = 0

    def _finish(self, i: int, req: Request, state: str,
                cause: Optional[str] = None):
        """Terminal exit: record the outcome and release the slot."""
        req.state = state
        req.done = state == "done"
        if cause is not None:
            req.failure_cause = cause
        self.finished.append(req)
        if i >= 0:
            self.slot_req[i] = None

    def _quarantine(self, i: int, cause: str):
        """Non-terminal fault on slot i: reset the slot, re-enqueue the
        request with bounded retries + exponential backoff, or fail it
        terminally when the retry budget is spent. Partial output is
        discarded — a retried request regenerates from its prompt."""
        req = self.slot_req[i]
        self.slot_req[i] = None
        self._reset_slot_state(i)
        if req is None:
            return
        req.failure_cause = cause
        if req.retries >= req.max_retries:
            self._finish(-1, req, "failed", cause)
            return
        req.retries += 1
        req.generated = []
        req.state = "pending"
        req.not_before = self._clock() + self.backoff_s * (2 ** (req.retries - 1))
        self.pending.append(req)

    def _expire_deadlines(self, now: float):
        """Deadline is terminal on every path: active slots are released,
        queued requests never admitted."""
        for i, req in enumerate(self.slot_req):
            if req is not None and req.deadline_s is not None \
                    and now - req.submitted_at > req.deadline_s:
                self._finish(i, req, "failed", "deadline")
        kept = []
        for req in self.pending:
            if req.deadline_s is not None \
                    and now - req.submitted_at > req.deadline_s:
                self._finish(-1, req, "failed", "deadline")
            else:
                kept.append(req)
        self.pending = kept

    def _assign_slots(self, now: float):
        admissible = [r for r in self.pending if r.not_before <= now]
        for i in range(self.n_slots):
            if self.slot_req[i] is None and admissible:
                req = admissible.pop(0)
                self.pending.remove(req)
                self.slot_req[i] = req
                req.state = "running"
                self.pos[i] = 0
                # Reset this slot's state by feeding prompt tokens below.
                req._feed = list(req.prompt)   # tokens still to prefill

    def step(self):
        """One batched decode step across all active slots. Every fault
        has an exit path: a raising decode step quarantines the batch
        (bounded retries), non-finite logits quarantine their slot, and
        deadline overruns fail terminally — no slot leaks, no request is
        dropped without a recorded cause."""
        now = self._clock()
        self._expire_deadlines(now)
        self._assign_slots(now)
        tokens = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[i] = True
            if req._feed:                       # prompt prefill (streaming)
                tokens[i] = req._feed.pop(0)
            else:
                tokens[i] = req.generated[-1] if req.generated \
                    else (req.prompt[-1] if req.prompt else 0)
        if not active.any():
            return False
        pos = jnp.int32(int(self.pos.max()))    # aligned stepping
        try:
            logits, new_state = self._step(self.params, self.state,
                                           jnp.asarray(tokens), pos)
            logits_np = np.asarray(logits)
        except Exception as e:   # decode fault: the batch can't attribute
            # a raising step to one slot, so every active slot quarantines
            # (healthy requests spend one retry and regenerate).
            for i, req in enumerate(self.slot_req):
                if req is not None:
                    self._quarantine(i, f"decode_error:{type(e).__name__}")
            return True
        self.state = new_state
        self.steps_executed += 1
        finite = np.isfinite(logits_np).all(axis=-1)
        next_tokens = np.argmax(logits_np, axis=-1)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if not finite[i]:
                # NaN/inf logits: poisoned slot state or params. Reset
                # the slot and re-enqueue — never emit a poisoned token.
                self._quarantine(i, "nan_logits")
                continue
            self.pos[i] += 1
            if not req._feed:                   # generating phase
                req.generated.append(int(next_tokens[i]))
                if len(req.generated) >= req.max_new \
                        or self.pos[i] >= self.max_seq - 1:
                    self._finish(i, req, "done")
        return True

    def run_until_drained(self, max_steps: int = 10_000):
        """Drive until no request is active or pending (or `max_steps`).
        Returns the finished requests — done and terminally failed."""
        for _ in range(max_steps):
            stepped = self.step()
            if not stepped:
                if not self.pending:
                    break
                time.sleep(0.005)      # everyone backing off: let it lapse
        return self.finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="kernel backend override, same grammar as "
                         "EXSPIKE_BACKEND (e.g. 'ref' or 'sdsa=pallas,ref')")
    ap.add_argument("--mesh", action="store_true",
                    help="resolve kernel dispatch mesh-aware against the "
                         "host mesh (per-shard capability checks, degrade "
                         "attribution printed below); array placement is "
                         "unchanged — sharding the slot batch is the "
                         "deployment's jit in_shardings' job")
    args = ap.parse_args()
    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    if args.backend:
        os.environ[dispatch.ENV_VAR] = args.backend
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    print(f"[serve] kernel backends"
          f"{' (mesh-aware)' if mesh is not None else ''}: "
          f"{dispatch.resolved_backends(mesh=mesh)}")
    server = Server(cfg, n_slots=args.slots,
                    spiking=False if args.dense else None, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab, 8)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total_new} tokens, "
          f"{server.steps_executed} steps, {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:4]}... -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
