"""Launchers: mesh, dry-run, training loop, serving loop."""
