"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these. Shape policy (DESIGN.md §4):

  train_4k     -> train_step   (spiking mode: the paper's technique)
  prefill_32k  -> serve_prefill (spiking)
  decode_32k   -> serve_step   (dense baseline: real GQA KV cache of 32k)
  long_500k    -> serve_step   (spiking: SDSA/SSM O(d) state — the
                  sub-quadratic path; dense baseline would be quadratic
                  and is skipped for this shape)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSpec
from repro.models import lm


def spiking_for_shape(shape: ShapeSpec) -> bool:
    return shape.kind != "decode"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def frontend_spec(cfg: LMConfig, b: int) -> jax.ShapeDtypeStruct | None:
    if cfg.encoder_decoder:
        return _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.n_frontend_tokens:
        return _sds((b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return None


def train_batch_spec(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.n_frontend_tokens if cfg.n_frontend_tokens else s
    batch = {
        "tokens": _sds((b, s_text), jnp.int32),
        "labels": _sds((b, s_text), jnp.int32),
    }
    fe = frontend_spec(cfg, b)
    if fe is not None:
        batch["frontend"] = fe
    return batch


def prefill_spec(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.n_frontend_tokens if cfg.n_frontend_tokens else s
    out = {"tokens": _sds((b, s_text), jnp.int32)}
    fe = frontend_spec(cfg, b)
    if fe is not None:
        out["frontend"] = fe
    return out


def decode_specs(cfg: LMConfig, shape: ShapeSpec, spiking: bool
                 ) -> Tuple[Any, Any, Any]:
    """(state_abstract, token_spec, pos_spec) for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    state = jax.eval_shape(functools.partial(
        lm.init_decode_state, cfg, b, s, spiking))
    return state, _sds((b,), jnp.int32), _sds((), jnp.int32)


def abstract_params(cfg: LMConfig):
    return lm.abstract_params(cfg)
