"""Analytic FLOP/byte model — trip-count-aware roofline numerators.

XLA's HloCostAnalysis counts a while-loop body ONCE (verified empirically:
a 10-step scan of a 256^3 matmul reports 33.5 MFLOP, the unrolled loop
335 MFLOP). Our models scan over layer groups / microbatches / sequence,
so compiled cost_analysis() undercounts by exactly those trip counts.
This module computes the true per-step FLOPs/bytes from the config — the
numbers are exact for matmuls (they dominate) and conservative for
elementwise traffic — and the dry-run reports BOTH (analytic primary,
cost_analysis raw as cross-check; they agree within tolerance on
scan-free reduced models, see tests/test_flops.py).

Conventions: 1 MAC = 2 FLOPs. Backward = 2x forward matmul FLOPs;
remat="full" adds 1x forward recompute. Spiking multiplies the block path
by T micro-timesteps (the LM head runs once on the T-averaged hidden).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import LMConfig, ShapeSpec
from repro.models.lm import layer_pattern


@dataclasses.dataclass
class StepCost:
    flops: float               # total FLOPs per step (all chips)
    hbm_bytes: float           # total HBM bytes touched per step (all chips)
    model_flops_6nd: float     # 6*N_active*D reference
    useful_ratio: float        # model_flops / flops

    def asdict(self):
        return dataclasses.asdict(self)


def _block_fwd_macs_per_token(cfg: LMConfig, spec, n_ctx: int,
                              spiking: bool) -> float:
    """Forward MACs per token for one block of kind `spec`."""
    d, dh = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    macs = 0.0
    if spec.kind == "attn":
        macs += d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d   # q,k,v,o
        if not spiking:
            ctx = min(n_ctx, cfg.sliding_window or n_ctx)
            macs += 2 * ctx * h * dh * 0.5            # causal scores + pv
        # SDSA: elementwise AND/OR only (counted in elementwise term)
    elif spec.kind == "mamba":
        hy = cfg.hybrid
        di = hy.expand * d
        r = max(16, d // 16)
        macs += d * 2 * di + hy.d_conv * di + di * (r + 2 * hy.d_state) \
            + r * di + 2 * di * hy.d_state + di * d
    elif spec.kind == "mlstm":
        macs += 4 * d * d + 3 * cfg.n_heads * dh * dh
    elif spec.kind == "slstm":
        macs += 4 * d * d + 4 * cfg.n_heads * dh * dh
    if spec.ffn == "mlp":
        macs += 3 * d * cfg.d_ff if cfg.d_ff else 0
        if spec.kind == "slstm":
            macs += 3 * d * ((4 * d) // 3)
    elif spec.ffn == "moe":
        m = cfg.moe
        macs += d * m.n_experts + m.top_k * 3 * d * m.d_ff_expert
        macs += 3 * d * (m.n_shared * m.d_ff_expert)
    return macs


def _elementwise_flops_per_token(cfg: LMConfig, spec) -> float:
    """LIF fire stages + SDSA logic + norms, per token per timestep."""
    d = cfg.d_model
    f = 10 * d                                   # norms/residual/LIF on d
    if spec.kind == "attn":
        f += 5 * cfg.n_heads * cfg.head_dim * 3  # q/k/v LIF + SDSA AND/OR
    if spec.ffn == "mlp":
        f += 5 * cfg.d_ff
    elif spec.ffn == "moe":
        f += 5 * cfg.moe.top_k * cfg.moe.d_ff_expert
    return f


def forward_flops(cfg: LMConfig, n_tokens: float, n_ctx: int,
                  spiking: bool) -> float:
    """Forward FLOPs for n_tokens (decoder stack + head)."""
    pattern, n_groups = layer_pattern(cfg)
    t = cfg.spiking.t_steps if spiking else 1
    per_tok = 0.0
    for spec in pattern:
        per_tok += 2 * _block_fwd_macs_per_token(cfg, spec, n_ctx, spiking)
        per_tok += _elementwise_flops_per_token(cfg, spec)
    per_tok *= n_groups * t
    per_tok += 2 * cfg.d_model * cfg.vocab       # head (post T-average)
    total = per_tok * n_tokens
    if cfg.encoder_decoder:
        enc_tok = cfg.encoder_seq * (n_tokens / max(n_ctx, 1))
        enc_per = (2 * _block_fwd_macs_per_token(
            cfg, _EncSpec, cfg.encoder_seq, spiking)
            + _elementwise_flops_per_token(cfg, _EncSpec)) \
            * cfg.n_encoder_layers * t
        # cross-attention projections in every decoder layer
        cross = 2 * (cfg.d_model * cfg.n_heads * cfg.head_dim * 2) \
            * cfg.n_layers * t * n_tokens
        total += enc_per * enc_tok + cross
    return total


class _EncSpecT:
    kind = "attn"
    ffn = "mlp"


_EncSpec = _EncSpecT()


def param_bytes(cfg: LMConfig) -> float:
    from repro.models.lm import param_count
    return param_count(cfg) * 2.0               # bf16


def _act_bytes(cfg: LMConfig, n_tokens: float, spiking: bool,
               train: bool) -> float:
    """Activation HBM traffic (write+read) estimate."""
    t = cfg.spiking.t_steps if spiking else 1
    d_ff = cfg.d_ff or (cfg.moe.top_k * cfg.moe.d_ff_expert if cfg.moe
                        else 2 * cfg.d_model)
    per_layer_tok = (6 * cfg.d_model + 2 * d_ff) * t
    rw = 2.0                                     # write + read
    passes = 1.0
    if train:
        passes = 2.0 + (1.0 if cfg.remat == "full" else 0.0)
    return per_layer_tok * cfg.n_layers * n_tokens * 2.0 * rw * passes


def step_cost(cfg: LMConfig, shape: ShapeSpec, spiking: bool) -> StepCost:
    from repro.models.lm import active_param_count, param_count
    b, s = shape.global_batch, shape.seq_len
    n_active = active_param_count(cfg)
    pb = param_bytes(cfg)

    if shape.kind == "train":
        n_tokens = float(b) * s
        fwd = forward_flops(cfg, n_tokens, s, spiking)
        remat_extra = 1.0 if cfg.remat == "full" else 0.0
        flops = fwd * (3.0 + remat_extra)
        # params read (fwd+bwd [+remat]) + grads f32 rw + AdamW states rw
        sdt = 2 if cfg.opt_state_dtype == "bfloat16" else 4
        opt_bytes = param_count(cfg) * (4 * 2 + 2 * sdt * 2 + 2 * 2)
        hbm = pb * (2 + remat_extra) * max(1, cfg.microbatches) + opt_bytes \
            + _act_bytes(cfg, n_tokens, spiking, True)
        model_f = 6.0 * n_active * n_tokens
    elif shape.kind == "prefill":
        n_tokens = float(b) * s
        flops = forward_flops(cfg, n_tokens, s, spiking)
        hbm = pb + _act_bytes(cfg, n_tokens, spiking, False)
        model_f = 2.0 * n_active * n_tokens
    else:   # decode / long_decode: one token per sequence
        n_tokens = float(b)
        flops = forward_flops(cfg, n_tokens, s, spiking)
        hbm = pb + _act_bytes(cfg, n_tokens, spiking, False)
        if not spiking:
            # dense KV cache read: B*S*KV*dh*2(K,V)*2B per attn layer
            pattern, n_groups = layer_pattern(cfg)
            n_attn = sum(1 for sp in pattern if sp.kind == "attn") * n_groups
            hbm += float(b) * s * cfg.n_kv_heads * cfg.head_dim * 2 * 2 \
                * n_attn
        else:
            # O(d) SDSA statuses / SSM states r+w
            hbm += float(b) * cfg.d_model * 4 * 2 * cfg.n_layers
        model_f = 2.0 * n_active * n_tokens
    return StepCost(flops=flops, hbm_bytes=hbm, model_flops_6nd=model_f,
                    useful_ratio=model_f / max(flops, 1.0))
