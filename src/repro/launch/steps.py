"""Step-function factories: train_step / serve_prefill / serve_step.

These close over the config and return pure functions suitable for
jax.jit(in_shardings=..., out_shardings=..., donate_argnums=...) — the
exact functions the dry-run lowers and the real launchers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import lm
from repro.optim import adamw, grad_compress, schedule as sched


def _under_mesh(fn: Callable, mesh) -> Callable:
    """Wrap a step function so kernel dispatch resolves mesh-aware while
    it traces: every registry op inside sees the ambient mesh (per-shard
    capability checks, mesh_aware filtering). Resolution is trace-time,
    so wrapping the function — not the call site — is what guarantees a
    later retrace (new shapes, donated-buffer miss) still resolves under
    the mesh."""
    if mesh is None:
        return fn
    from repro.kernels import dispatch

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with dispatch.use_mesh(mesh):
            return fn(*args, **kwargs)
    return wrapped


def make_train_step(
    cfg: LMConfig,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    schedule_fn: Callable = sched.constant,
    spiking: Optional[bool] = None,
    grad_compression: bool = False,
    mesh=None,
) -> Callable:
    """train_step(params, opt_state, [ef_state,] batch) -> (... , metrics).

    Microbatch gradient accumulation (cfg.microbatches) runs as a scan so
    the per-microbatch backward (and its data-parallel collectives) overlap
    the next microbatch's forward in the XLA pipeline — the standard
    compute/comm overlap trick.

    `mesh`: the mesh the step will execute under — spike matmuls (and
    every other registry op) in the model then resolve mesh-aware, so the
    distributed path keeps the event-driven kernels instead of silently
    running dense math.
    """
    if opt_cfg is None:
        opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    spk = cfg.spiking.enabled if spiking is None else spiking
    m = max(1, cfg.microbatches)

    def loss_of(params, batch):
        return lm.loss_fn(cfg, params, batch, spk)

    def grads_of(params, batch):
        if m == 1:
            return jax.value_and_grad(loss_of)(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_of)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(body, (0.0, zero_g), micro)
        return loss_sum / m, jax.tree.map(lambda g: g / m, g_sum)

    if not grad_compression:
        def train_step(params, opt_state, batch):
            loss, grads = grads_of(params, batch)
            lr_scale = schedule_fn(opt_state.step)
            new_params, new_opt = adamw.update(
                grads, opt_state, params, opt_cfg, lr_scale)
            metrics = {"loss": loss,
                       "grad_norm": adamw.global_norm(grads)}
            return new_params, new_opt, metrics
        return _under_mesh(train_step, mesh)

    def train_step_ef(params, opt_state, ef_state, batch):
        loss, grads = grads_of(params, batch)
        wire, scales, new_ef = grad_compress.compress(grads, ef_state)
        grads = grad_compress.decompress(wire, scales)
        lr_scale = schedule_fn(opt_state.step)
        new_params, new_opt = adamw.update(
            grads, opt_state, params, opt_cfg, lr_scale)
        metrics = {"loss": loss, "grad_norm": adamw.global_norm(grads)}
        return new_params, new_opt, new_ef, metrics
    return _under_mesh(train_step_ef, mesh)


def make_prefill(cfg: LMConfig, spiking: bool, mesh=None) -> Callable:
    def serve_prefill(params, batch: Dict[str, Any]):
        return lm.prefill(cfg, params, batch["tokens"], spiking,
                          frontend=batch.get("frontend"))
    return _under_mesh(serve_prefill, mesh)


def make_serve_step(cfg: LMConfig, spiking: bool, mesh=None) -> Callable:
    """serve_step(params, state, token (B,), pos) -> (logits, state).

    `pos` is a scalar (aligned stepping: streaming prefill, dry-run
    shapes) or a per-slot (B,) vector — the continuous-batching serve
    loop passes its per-slot position vector so every slot decodes at
    its own position (see lm.decode_step)."""
    def serve_step(params, state, token, pos):
        return lm.decode_step(cfg, params, state, token, pos, spiking)
    return _under_mesh(serve_step, mesh)


def make_prefill_state(cfg: LMConfig, spiking: bool, mesh=None,
                       max_seq: int = 256) -> Callable:
    """prefill_state(params, tokens (B, L), length (B,)) ->
    (last logits (B, vocab), decode state at per-slot pos = length).

    The bucketed masked prefill the serve scheduler admits requests
    with (prefill/decode disaggregation): one jit trace per (B, L)
    bucket, pad steps masked out of every state write. `max_seq` sizes
    the dense KV cache (ignored by O(d) spiking state)."""
    def prefill_state(params, tokens, length):
        return lm.prefill_chunked(cfg, params, tokens, length, spiking,
                                  max_seq)
    return _under_mesh(prefill_state, mesh)
