"""Training launcher: the real loop the examples drive.

Wires together every substrate: sharded synthetic data pipeline, AdamW +
schedule, optional gradient compression, rolling async checkpoints with
auto-resume, straggler monitoring, and mesh-sharded jit execution. Works
on the single CPU device (examples/tests) and unchanged on a real mesh —
only `mesh` and the shard index change.

CLI: python -m repro.launch.train --arch tinyllama-1.1b --steps 50 \
        --reduced --batch 8 --seq 128 [--resume] [--ckpt-dir ...]
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.configs.base import LMConfig
from repro.data import pipeline, synthetic
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw, schedule as sched
from repro.runtime import sharding
from repro.runtime.straggler import StragglerMonitor


def train_loop(cfg: LMConfig, *, steps: int = 50, batch: int = 8,
               seq: int = 128, seed: int = 0, ckpt_dir: Optional[str] = None,
               save_every: int = 20, resume: bool = False,
               log_every: int = 10, lr: float = 1e-3,
               mesh: Optional[jax.sharding.Mesh] = None,
               spiking: Optional[bool] = None) -> dict:
    mesh = mesh or make_host_mesh()
    spk = cfg.spiking.enabled if spiking is None else spiking

    # Training routes through the backend registry exactly like inference
    # — and, since the step traces under the mesh, resolution is
    # mesh-aware: capability checks run per data shard, the CSR family
    # degrades down its fallback chain instead of dropping to dense math,
    # and the attribution ("backend<-requested") records any degrade.
    if spk:
        from repro.kernels import dispatch
        resolved = " ".join(
            f"{op}={be}"
            for op, be in dispatch.resolved_backends(mesh=mesh).items())
        print(f"[train] dispatch backends (mesh-aware): {resolved}")

    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt_cfg = adamw.AdamWConfig(lr=lr, state_dtype=cfg.opt_state_dtype)
    opt_state = adamw.init(params, opt_cfg)

    pspecs = sharding.param_specs(cfg, params, mesh)
    p_sh = sharding.named(mesh, pspecs)
    repl = NamedSharding(mesh, P())
    o_sh = adamw.AdamWState(step=repl, mu=p_sh, nu=p_sh)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    schedule_fn = functools.partial(
        sched.warmup_cosine, warmup_steps=max(2, steps // 20),
        total_steps=steps)
    step_fn = steps_mod.make_train_step(cfg, opt_cfg, schedule_fn,
                                        spiking=spk, mesh=mesh)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir, save_every=save_every) \
        if ckpt_dir else None
    start_step = 0
    if mgr and resume:
        latest, restored = mgr.restore_latest((params, opt_state),
                                              (p_sh, o_sh))
        if latest is not None:
            params, opt_state = restored
            start_step = latest
            print(f"[train] resumed from step {latest}")

    n_shards = mesh.shape.get("data", 1)
    local_b = max(1, batch // n_shards)

    def make_batch(shard, step):
        return synthetic.lm_batch(seed, shard, step, local_b, seq, cfg.vocab)

    pipe = pipeline.ShardedPipeline(make_batch, n_shards, shard=0,
                                    start_step=start_step).start()
    mon = StragglerMonitor()
    losses = []
    t_start = time.time()
    it = iter(pipe)
    for step in range(start_step, steps):
        host_batch = next(it)
        dev_batch = {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
        mon.step_start()
        params, opt_state, metrics = jit_step(params, opt_state, dev_batch)
        loss = float(metrics["loss"])
        report = mon.step_end()
        losses.append(loss)
        if report.get("flagged"):
            print(f"[straggler] step {step}: {report['seconds']:.2f}s "
                  f"(ema {report.get('ema', 0):.2f}s)")
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({report['seconds']:.2f}s)")
        if mgr and mgr.should_save(step):
            mgr.save(step, (params, opt_state))
    pipe.stop()
    if mgr:
        mgr.save(steps, (params, opt_state))
        mgr.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "seconds": time.time() - t_start, "params": params,
            "opt_state": opt_state,
            "straggler_flags": mon.flagged_steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dense", action="store_true",
                    help="dense baseline instead of spiking")
    args = ap.parse_args()
    cfg = (registry.get_reduced(args.arch) if args.reduced
           else registry.get_config(args.arch))
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, resume=args.resume, lr=args.lr,
                     spiking=None if not args.dense else False)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"in {out['seconds']:.1f}s")


if __name__ == "__main__":
    main()
