"""HLO-text analysis: collective bytes with while-loop trip-count scaling.

cost_analysis() has no collective statistics, so we parse the
post-partitioning HLO (compiled.as_text()): sum the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction. Collectives inside scan-generated `while`
bodies execute trip-count times but appear once in the text, so we build
the computation call graph (while/call/conditional), extract each loop's
trip count from the comparison constant in its condition computation, and
scale bottom-up.

Byte convention: result-shape bytes of the collective (for all-gather this
is the gathered size — an upper bound on per-chip wire bytes; for
all-reduce it equals the tensor size, a lower bound on the 2x ring
traffic). The roofline applies the per-algorithm wire factors on top
(see benchmarks/roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    """Returns (computation name -> instruction lines, entry name)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = ""
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and stripped.endswith("{") \
                and (stripped.startswith("%") or stripped.startswith("ENTRY")):
            tok = stripped
            is_entry = tok.startswith("ENTRY")
            if is_entry:
                tok = tok[len("ENTRY"):].strip()
            name = tok.split(" ")[0].split("(")[0].lstrip("%")
            cur = name
            comps[cur] = []
            if is_entry:
                entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps, entry


def _result_bytes(line: str, op: str) -> int:
    """Bytes of the instruction's result shape(s) (text before the op name)."""
    idx = line.find(f" {op}(")
    if idx < 0:
        idx = line.find(f" {op}-start(")
    head = line[:idx] if idx >= 0 else line.split("(")[0]
    eq = head.find("=")
    return _shape_bytes(head[eq + 1:] if eq >= 0 else head)


def _trip_count(cond_lines: List[str]) -> int:
    """Extract the loop bound from a scan condition computation."""
    const = 0
    for line in cond_lines:
        if "constant(" in line and ("s32" in line or "u32" in line):
            m = re.search(r"constant\((\d+)\)", line)
            if m:
                const = max(const, int(m.group(1)))
    return max(const, 1)


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Trip-count-scaled collective bytes by kind, plus 'total'."""
    comps, entry = split_computations(hlo)

    # per-computation local collective bytes + sub-calls
    local: Dict[str, Dict[str, float]] = {}
    calls: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    while_re = re.compile(
        r"\bwhile\(.*condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
    call_re = re.compile(r"(?:\bcalls=|to_apply=)%?([\w\.\-]+)")

    for name, lines in comps.items():
        bucket: Dict[str, float] = defaultdict(float)
        for line in lines:
            if "-done" in line:        # async pair: count the -start only
                continue
            matched_coll = False
            for op in _COLLECTIVES:
                if re.search(rf"\b{op}(-start)?\(", line):
                    bucket[op] += _result_bytes(line, op)
                    matched_coll = True
                    break
            if matched_coll:
                continue
            m = while_re.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                calls[name].append((body, trips))
                calls[name].append((cond, trips))
            else:
                for cm in call_re.finditer(line):
                    if cm.group(1) in comps:
                        calls[name].append((cm.group(1), 1))
        local[name] = dict(bucket)

    memo: Dict[str, Dict[str, float]] = {}

    def total_of(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack:
            return {}
        out: Dict[str, float] = defaultdict(float)
        for k, v in local.get(name, {}).items():
            out[k] += v
        for child, mult in calls.get(name, []):
            sub = total_of(child, stack + (name,))
            for k, v in sub.items():
                out[k] += v * mult
        memo[name] = dict(out)
        return memo[name]

    if not entry:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    result = {k: float(v) for k, v in total_of(entry).items()}
    result["total"] = float(sum(result.values()))
    return result


def collective_bytes_unscaled(hlo: str) -> Dict[str, float]:
    """Flat text scan (no trip scaling) — the naive lower bound."""
    bucket: Dict[str, float] = defaultdict(float)
    for line in hlo.splitlines():
        line = line.strip()
        if "-done" in line:
            continue
        for op in _COLLECTIVES:
            if re.search(rf"\b{op}(-start)?\(", line):
                bucket[op] += _result_bytes(line, op)
                break
    out = {k: float(v) for k, v in bucket.items()}
    out["total"] = float(sum(out.values()))
    return out
