"""Production mesh construction (deliverable e) + JAX version compat.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests see the real single CPU device).

Target: TPU v5e pods — 256 chips/pod as a (16, 16) (data, model) mesh;
multi-pod prepends a "pod" axis: (2, 16, 16). Hardware constants used by
the roofline are defined here as the single source of truth.

Compat: this repo runs on JAX back to 0.4.37, which predates
`jax.sharding.AxisType`, the `axis_types=` kwarg of `jax.make_mesh`, the
two-argument `AbstractMesh(shape, names)` signature, and
`jax.sharding.set_mesh`. The helpers below paper over all four; every
mesh construction in src/ and tests/ goes through them.
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Optional, Sequence

import jax


# TPU v5e per-chip constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link


# ------------------------------------------------------------ compat shims
AxisType = getattr(jax.sharding, "AxisType", None)

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
    if hasattr(jax, "make_mesh") else False)


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,)*n}`` on JAX versions that support it, else
    ``{}`` (pre-AxisType JAX treats every axis as Auto already)."""
    if AxisType is None or not _MAKE_MESH_HAS_AXIS_TYPES:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the installed JAX has
    them, and without the kwarg where it doesn't."""
    kwargs = axis_types_kwargs(len(tuple(axes)))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def abstract_mesh(shape: Sequence[int],
                  axes: Sequence[str]) -> "jax.sharding.AbstractMesh":
    """Device-free mesh for spec validation, on old and new signatures:
    new JAX takes (axis_sizes, axis_names); 0.4.x takes shape_tuple."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


@contextlib.contextmanager
def use_concrete_mesh(mesh: Optional[jax.sharding.Mesh]):
    """`jax.sharding.set_mesh` where it exists; no-op otherwise (the
    `with mesh:` context callers already hold covers pjit resolution)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is None or mesh is None:
        yield
    else:
        with set_mesh(mesh):
            yield


def current_mesh():
    """The mesh installed by ``with mesh:`` / ``set_mesh`` — the abstract
    mesh on new JAX, the physical context mesh on 0.4.x — or None when no
    mesh context is active (callers fall back to unsharded paths)."""
    get_abs = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abs is not None:
        m = get_abs()
        if m is not None and getattr(m, "axis_names", ()):
            return m
        return None
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return m if getattr(m, "axis_names", ()) else None


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    """`jax.shard_map` across JAX versions (kwarg renamed check_rep ->
    check_vma in new releases; old releases only have the experimental
    entry point)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_rep)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_rep)


# --------------------------------------------------------------- factories
def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))


def chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
