"""Production mesh construction (deliverable e).

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests see the real single CPU device).

Target: TPU v5e pods — 256 chips/pod as a (16, 16) (data, model) mesh;
multi-pod prepends a "pod" axis: (2, 16, 16). Hardware constants used by
the roofline are defined here as the single source of truth.
"""
from __future__ import annotations

import jax


# TPU v5e per-chip constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
