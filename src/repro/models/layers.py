"""Shared model layers: params as plain pytrees, pure apply functions.

Conventions
-----------
* Params are nested dicts of jax.Arrays; init functions are traceable so
  `jax.eval_shape(init)` yields allocation-free abstract trees for the
  dry-run (ShapeDtypeStruct stand-ins).
* Sharding is name-based: `runtime.sharding` maps param-tree paths to
  PartitionSpecs, so layers stay sharding-agnostic.
* Spiking layers take/return an explicit leading T axis (micro-timesteps);
  LIF is the only op that couples timesteps.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.events import EventTensor
from repro.core.lif import LIFConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------- init utils
def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.truncated_normal(key, -2, 2, (vocab, d), jnp.float32)
            * 0.02).astype(dtype)


# ------------------------------------------------------------------- norms
def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, d_head: int, theta: float = 1e4) -> tuple:
    """positions: (..., N) int -> (sin, cos) of shape (..., N, d_head/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., N, H, d_head); sin/cos: (..., N, d_head/2) broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ hybrid scope
def hybrid_scope(spiking_cfg):
    """Dispatch scope a model's apply body runs under.

    `SpikingConfig.hybrid=True` turns on density-adaptive routing: every
    matmul-form op that receives a carried occupancy map picks dense vs
    event per call from the calibrated cost model (bucketed, so jit sees
    a bounded route set). Off (the default) keeps auto/override
    resolution exactly as before — zero behavior change.
    """
    import contextlib
    if getattr(spiking_cfg, "hybrid", False):
        from repro.kernels.dispatch import use_hybrid
        return use_hybrid()
    return contextlib.nullcontext()


# --------------------------------------------------------------- LIF helper
def lif_fire(x: jax.Array, lif_cfg: LIFConfig) -> jax.Array:
    """Binarize pre-activations into spikes over the leading T axis.

    x: (T, ...) membrane drive -> (T, ...) binary spikes. This is the FPE
    fire stage; in spiking mode every heavy op consumes its output.
    Routed through the backend registry: `ref` (lax.scan) by default on
    CPU, the fused Pallas kernel on TPU / under ``EXSPIKE_BACKEND``
    override. Every backend carries the ATan surrogate gradient (the
    Pallas kernel via its reversed-scan backward kernel), so training
    resolves backends exactly like inference — no ref pin.
    """
    from repro.kernels.dispatch import dispatch
    return dispatch("lif_scan", x, decay=lif_cfg.decay, v_th=lif_cfg.v_th,
                    soft_reset=lif_cfg.soft_reset,
                    surrogate_alpha=lif_cfg.surrogate_alpha)


def lif_fire_events(x: jax.Array, lif_cfg: LIFConfig,
                    packed: bool = False) -> EventTensor:
    """Fire AND carry the event metadata: the full-event producer.

    Routes through `lif_scan_occ`, whose Pallas backend emits the
    (128, 128) per-tile occupancy map while the spike tile is still in
    VMEM (ref computes it with `tile_occupancy` — identical map). The
    returned `EventTensor` flows to the next layer's event op, which
    skips its own dense occupancy pre-pass; the map is stop-gradient aux,
    so `jax.grad` matches the dense-spike forward exactly.

    `packed=True` makes the uint32 spike words the canonical payload:
    the fused kernel packs in the same VMEM pass that popcounts (the
    occupancy map is a free byproduct of packing), the returned
    EventTensor is packed-only (spikes=None — no f32 spike tensor ever
    materializes between layers), and dispatch routes it to `packed-csr`
    backends. Forward-only: the words are stop-gradient aux, so packed
    mode is an inference path (training keeps dense spikes).
    """
    from repro.kernels.dispatch import dispatch
    s, occ, chunks = dispatch("lif_scan_occ", x, decay=lif_cfg.decay,
                              v_th=lif_cfg.v_th,
                              soft_reset=lif_cfg.soft_reset,
                              surrogate_alpha=lif_cfg.surrogate_alpha,
                              packed=packed)
    if packed:
        return EventTensor(None, occ, chunks=chunks, packed=s,
                           feature_size=x.shape[-1])
    return EventTensor(s, occ, chunks=chunks)


# --------------------------------------------------------------- SwiGLU MLP
def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p: Params, x: jax.Array, spiking: bool,
              lif_cfg: LIFConfig | None = None) -> jax.Array:
    """SwiGLU in dense mode; spike-gated two-matmul MLP in spiking mode.

    Spiking mode (x is binary (T, ...)): hidden drive = x @ (w_gate + w_up)
    is fired through LIF (binary hidden spikes), then down-projected —
    every matmul sees binary activations (full-event execution). SiLU
    gating is replaced by the LIF threshold, the FPE analog.

    Full-event mode (x is an `EventTensor`): both up-projections consume
    the ONE carried occupancy map, the hidden fire re-emits metadata
    fused, and the down-projection consumes that — zero standalone
    occupancy pre-passes inside the block. (The dispatch route passes the
    map; work-list compaction from it is tiny-map work per consumer. The
    per-instance `EventTensor.csr()` cache serves direct `kernels.ops`
    callers.)
    """
    if isinstance(x, EventTensor):
        from repro.kernels import dispatch as _d
        h = _d.spike_matmul(x, p["w_gate"]) + _d.spike_matmul(x, p["w_up"])
        # Packedness propagates: a packed input re-fires packed, so the
        # hidden spikes also never materialize as f32.
        h = lif_fire_events(h, lif_cfg, packed=x.is_packed)
        return _d.spike_matmul(h, p["w_down"])
    if spiking:
        h = x @ (p["w_gate"].astype(x.dtype))
        h = h + x @ (p["w_up"].astype(x.dtype))
        h = lif_fire(h, lif_cfg)
        return h @ p["w_down"].astype(h.dtype)
    g = x @ p["w_gate"].astype(x.dtype)
    u = x @ p["w_up"].astype(x.dtype)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) \
        @ p["w_down"].astype(x.dtype)
