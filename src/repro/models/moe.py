"""Mixture-of-experts with sort-based token dispatch (EP-shardable).

Dispatch is the MaxText/megablocks-style sort: top-k expert ids per token,
stable-sort token slots by expert, rank-within-expert capacity check, and
scatter into (E, capacity, d) expert batches. Under GSPMD with experts
sharded over the `model` axis and tokens over `data`, the scatter/gather
lower to all-to-all — the canonical EP collective.

Spiking mode: expert inputs are binary spike tensors, the router is an
event-driven FC (one weight-row accumulate per active spike — the EAFC
pattern applied to routing), and expert hidden activations re-binarize
through LIF. Shared experts (qwen2-moe) are fused into one wide always-on
MLP.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.lif import LIFConfig
from .layers import dense_init, lif_fire, mlp_apply, mlp_init

Params = Dict[str, Any]


def moe_init(key, d_model: int, d_ff_expert: int, n_experts: int,
             n_shared: int = 0, dtype=jnp.bfloat16,
             bank_size: int = 0) -> Params:
    """bank_size > n_experts pads the expert BANK with dead experts so the
    expert dim divides the mesh (even EP); the router stays n_experts wide,
    so dead experts never receive tokens."""
    bank = max(n_experts, bank_size)
    ks = jax.random.split(key, 5)

    def expert_bank(k, d_in, d_out):
        kk = jax.random.split(k, bank)
        return jax.vmap(lambda key_: dense_init(key_, d_in, d_out, dtype))(kk)

    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": expert_bank(ks[1], d_model, d_ff_expert),
        "w_up": expert_bank(ks[2], d_model, d_ff_expert),
        "w_down": expert_bank(ks[3], d_ff_expert, d_model),
    }
    if n_shared:
        p["shared"] = mlp_init(ks[4], d_model, n_shared * d_ff_expert, dtype)
    return p


def _maybe_constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint when the ambient mesh has the axes; no-op
    on meshless CPU tests."""
    from repro.launch.mesh import current_mesh
    try:
        mesh = current_mesh()
        names = set(getattr(mesh, "axis_names", ()) or ())
        wanted = {a for s_ in spec if s_ is not None
                  for a in ((s_,) if isinstance(s_, str) else s_)}
        if wanted and wanted.issubset(names):
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec(*spec))
    except Exception:
        pass
    return x


def moe_apply(
    p: Params, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
    normalize_weights: bool = True, spiking: bool = False,
    lif_cfg: LIFConfig | None = None, dispatch_groups: int = 1,
) -> jax.Array:
    """x: (..., N, D) -> (..., N, D). Leading axes (incl. T) are token-flattened.

    dispatch_groups > 1 splits tokens into data-shard-aligned groups
    (leading dim sharded over `data`): the scatter/gather of the sort-based
    dispatch then stays shard-local (a vmapped local scatter) and only the
    grouped expert buffer — the true EP dispatch payload — crosses devices
    as an all-to-all. Without this, GSPMD lowers the global scatter as
    zero-buffer + full all-reduce of (E, C, D) per layer (§Perf cell B).
    """
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    s = xt.shape[0]
    e = p["router"].shape[-1]          # routable experts
    e_bank = p["w_gate"].shape[0]      # possibly padded bank (even EP)
    g = max(1, dispatch_groups)
    if s % g:
        g = 1
    s_loc = s // g

    capacity = int(s_loc * top_k / e * capacity_factor)
    capacity = max(8, -(-capacity // 8) * 8)                # round up to 8

    xg = _maybe_constrain(xt.reshape(g, s_loc, d), "data", None, None)

    def dispatch_one(xl):
        """(s_loc, d) -> ((e_bank, C, d), combine aux) — purely local."""
        logits = (xl.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(probs, top_k)          # (s_loc, k)
        if normalize_weights:
            weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
        flat_ids = ids.reshape(-1)
        sort_idx = jnp.argsort(flat_ids, stable=True)
        sorted_ids = flat_ids[sort_idx]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
        rank = jnp.arange(s_loc * top_k) - starts[sorted_ids]
        keep = rank < capacity
        dest = jnp.where(keep, sorted_ids * capacity + rank,
                         e_bank * capacity)
        tok_idx = sort_idx // top_k
        gathered = xl[tok_idx] * keep[:, None].astype(xl.dtype)
        buf = jnp.zeros((e_bank * capacity + 1, d), xl.dtype
                        ).at[dest].set(gathered)
        return (buf[: e_bank * capacity].reshape(e_bank, capacity, d),
                (tok_idx, dest, weights.reshape(-1)[sort_idx], keep))

    expert_in_g, aux = jax.vmap(dispatch_one)(xg)   # (g, e_bank, C, d)
    expert_in_g = _maybe_constrain(expert_in_g, "data", None, None, None)
    # EP regroup: (g, e, C, d) -> (e, g*C, d); data->model all-to-all.
    expert_in = expert_in_g.transpose(1, 0, 2, 3).reshape(
        e_bank, g * capacity, d)
    expert_in = _maybe_constrain(expert_in, "model", None, None)

    # Expert FFN (binary activations in spiking mode -> LIF re-fire).
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"].astype(xt.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"].astype(xt.dtype))
    if spiking:
        h = lif_fire((h + u)[None], lif_cfg)[0]
    else:
        h = jax.nn.silu(h.astype(jnp.float32)).astype(xt.dtype) * u
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(xt.dtype))
    expert_out = _maybe_constrain(expert_out, "model", None, None)

    out_g = expert_out.reshape(e_bank, g, capacity, d).transpose(1, 0, 2, 3)
    out_g = _maybe_constrain(out_g, "data", None, None, None)

    def combine_one(eo, aux_one):
        tok_idx, dest, w_sorted, keep = aux_one
        flat = eo.reshape(e_bank * capacity, d)
        out_sorted = flat[jnp.minimum(dest, e_bank * capacity - 1)]
        out_sorted = out_sorted * keep[:, None].astype(flat.dtype)
        return jnp.zeros((s_loc, d), flat.dtype).at[tok_idx].add(
            out_sorted * w_sorted[:, None].astype(flat.dtype))

    combined = jax.vmap(combine_one)(out_g, aux).reshape(s, d)

    if "shared" in p:
        combined = combined + mlp_apply(
            p["shared"], xt, spiking=spiking, lif_cfg=lif_cfg).reshape(s, d)
    return combined.reshape(orig_shape)


def moe_apply_shard_map(
    p: Params, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
    normalize_weights: bool = True, spiking: bool = False,
    lif_cfg: LIFConfig | None = None,
) -> jax.Array:
    """Manual-EP MoE via shard_map — the collective-optimal formulation.

    Layout facts this exploits: activations are batch-sharded over
    (pod, data) and REPLICATED over `model`; expert banks are EP-sharded
    over `model`. So every model shard already holds every token: it can
    locally select the tokens routed to its own experts (no dispatch
    collective at all), run its local expert FFNs, and contribute its
    partial outputs to a single psum over `model` — (s_loc, d) bf16 per
    layer, the information-theoretic minimum for EP combine. GSPMD's
    lowering of the same math scatter/gathers multi-TB zero-buffers
    (§Perf cell B: 409 s -> see EXPERIMENTS.md).
    """
    from repro.launch.mesh import current_mesh
    mesh = current_mesh()
    names = set(getattr(mesh, "axis_names", ()) or ())
    if "model" not in names:
        return moe_apply(p, x, top_k=top_k, capacity_factor=capacity_factor,
                         normalize_weights=normalize_weights,
                         spiking=spiking, lif_cfg=lif_cfg)
    bt_axes = tuple(a for a in ("pod", "data") if a in names)
    e_bank = p["w_gate"].shape[0]
    e = p["router"].shape[-1]
    m = mesh.shape["model"]
    e_loc = e_bank // m
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    s = xt.shape[0]
    n_b = 1
    for a in bt_axes:
        n_b *= mesh.shape[a]
    s_loc = s // n_b
    capacity = int(s_loc * top_k / e * capacity_factor)
    capacity = max(8, -(-capacity // 8) * 8)

    def block(xl, router, wg, wu, wd):
        xl = xl.reshape(-1, d)                       # (s_loc, d) replicated
        j = jax.lax.axis_index("model")
        logits = (xl.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(probs, top_k)
        if normalize_weights:
            weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
        flat_ids = ids.reshape(-1)
        sort_idx = jnp.argsort(flat_ids, stable=True)
        sorted_ids = flat_ids[sort_idx]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
        rank = jnp.arange(s_loc * top_k) - starts[sorted_ids]
        mine = (sorted_ids // e_loc) == j            # my experts only
        keep = (rank < capacity) & mine
        dest = jnp.where(keep, (sorted_ids % e_loc) * capacity + rank,
                         e_loc * capacity)
        tok_idx = sort_idx // top_k
        gathered = xl[tok_idx] * keep[:, None].astype(xl.dtype)
        buf = jnp.zeros((e_loc * capacity + 1, d), xl.dtype
                        ).at[dest].set(gathered)
        expert_in = buf[: e_loc * capacity].reshape(e_loc, capacity, d)
        h = jnp.einsum("ecd,edf->ecf", expert_in, wg.astype(xl.dtype))
        u = jnp.einsum("ecd,edf->ecf", expert_in, wu.astype(xl.dtype))
        if spiking:
            h = lif_fire((h + u)[None], lif_cfg)[0]
        else:
            h = jax.nn.silu(h.astype(jnp.float32)).astype(xl.dtype) * u
        eo = jnp.einsum("ecf,efd->ecd", h, wd.astype(xl.dtype))
        flat = eo.reshape(e_loc * capacity, d)
        out_sorted = flat[jnp.minimum(dest, e_loc * capacity - 1)]
        out_sorted = out_sorted * keep[:, None].astype(flat.dtype)
        w_sorted = weights.reshape(-1)[sort_idx].astype(flat.dtype)
        local = jnp.zeros((s_loc, d), flat.dtype).at[tok_idx].add(
            out_sorted * w_sorted[:, None])
        return jax.lax.psum(local, "model")          # EP combine: (s_loc, d)

    from repro.launch.mesh import shard_map
    P = jax.sharding.PartitionSpec
    out = shard_map(
        block, mesh=mesh,
        in_specs=(P(bt_axes or None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(bt_axes or None, None),
        check_rep=False,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        out = out + mlp_apply(
            p["shared"], xt, spiking=spiking, lif_cfg=lif_cfg).reshape(s, d)
    return out.reshape(orig_shape)


def aux_load_balance_loss(logits: jax.Array, ids: jax.Array, n_experts: int,
                          top_k: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (used by train loops)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(ids, n_experts).sum(axis=1) / top_k
    ce = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(me * ce)
