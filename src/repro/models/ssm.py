"""Recurrent-state blocks: Mamba (jamba hybrid) and xLSTM (mLSTM/sLSTM).

These families carry O(d·d_state) recurrent state instead of a KV cache,
which is why they run the 500k-token decode shape natively. They are
kindred to the paper's LIF machinery — input-dependent state updates — and
in spiking mode their block outputs are fired through LIF so downstream
matmuls stay event-driven (DESIGN.md §4). Sequence recurrences use
`jax.lax.scan` (single compiled loop body; analytic FLOP accounting in the
roofline handles trip counts).

Decode states here are POSITION-FREE: the recurrences fold each token
into fixed-shape carries, so the serve scheduler's per-slot position
vector never indexes into them (only the dense KV cache consumes
positions). Under the slot-pool layout (models/lm.py
`init_decode_state`) every state leaf is stacked `(n_groups, n_slots,
...)` with the slot batch at axis 1 — `*_state_init(b, ...)` is called
with b = n_slots, and slot surgery (`reset_slot_state` /
`merge_slot_state`) addresses leaves structurally by that contract.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, Any]


# =============================================================== Mamba (S6)
class MambaState(NamedTuple):
    h: jax.Array        # (B, d_inner, d_state)
    conv: jax.Array     # (B, d_conv-1, d_inner) rolling conv window


def mamba_init(key, d_model: int, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: int | None = None,
               dtype=jnp.bfloat16) -> Params:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(16, d_model // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                   * 0.1).astype(dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, d_state + 1, dtype=jnp.float32)[None], (d_inner, 1))),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _mamba_scan_step(h, inputs, a):
    """h: (B, d_inner, d_state); one selective-SSM step.

    Scan xs/ys are bf16 (the stacked (N, B, d_inner) buffers dominate jamba
    training memory otherwise); the recurrence itself runs f32.
    """
    xt, dt, bt, ct = inputs      # (B,di) bf16, (B,di) f32, (B,ds) bf16 x2
    xt32, bt32, ct32 = (t.astype(jnp.float32) for t in (xt, bt, ct))
    da = jnp.exp(dt[..., None] * a[None])                   # (B,di,ds)
    h = h * da + dt[..., None] * xt32[..., None] * bt32[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, ct32)
    return h, y.astype(jnp.bfloat16)


def mamba_apply(p: Params, x: jax.Array, state: MambaState | None = None,
                d_state: int = 16, d_conv: int = 4):
    """x: (B, N, D) -> (B, N, D), optionally carrying decode state.

    Returns (out, new_state). Full-sequence mode initializes zero state.
    """
    b, n, d = x.shape
    d_inner = p["in_proj"].shape[-1] // 2
    dt_rank = p["x_proj"].shape[-1] - 2 * d_state

    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                       # (B,N,di)

    # Depthwise causal conv (window d_conv) with carried history.
    if state is None:
        hist = jnp.zeros((b, d_conv - 1, d_inner), xs.dtype)
        h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    else:
        hist, h0 = state.conv.astype(xs.dtype), state.h
    xpad = jnp.concatenate([hist, xs], axis=1)              # (B,N+c-1,di)
    idx = jnp.arange(n)[:, None] + jnp.arange(d_conv)[None, :]
    windows = xpad[:, idx, :]                               # (B,N,c,di)
    xc = jnp.einsum("bncd,cd->bnd", windows, p["conv_w"].astype(xs.dtype))
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(xs.dtype)

    proj = xc @ p["x_proj"].astype(xc.dtype)
    dt, bmat, cmat = jnp.split(
        proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        (dt @ p["dt_proj"].astype(dt.dtype)).astype(jnp.float32))
    a = -jnp.exp(p["a_log"])                                 # (di,ds)

    hN, ys = jax.lax.scan(
        lambda h, inp: _mamba_scan_step(h, inp, a),
        h0,
        (xc.swapaxes(0, 1), dt.swapaxes(0, 1),
         bmat.swapaxes(0, 1), cmat.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + (xc * p["d_skip"].astype(xc.dtype))  # (B,N,di)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"].astype(y.dtype)
    new_hist = xpad[:, n:, :] if n >= d_conv - 1 else xpad[:, -(d_conv - 1):, :]
    return out, MambaState(h=hN, conv=new_hist.astype(jnp.bfloat16))


def mamba_state_init(b: int, d_model: int, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2) -> MambaState:
    d_inner = expand * d_model
    return MambaState(h=jnp.zeros((b, d_inner, d_state), jnp.float32),
                      conv=jnp.zeros((b, d_conv - 1, d_inner), jnp.bfloat16))


# ================================================================== mLSTM
class MLSTMState(NamedTuple):
    c: jax.Array    # (B, H, dh, dh) matrix memory
    n: jax.Array    # (B, H, dh) normalizer
    m: jax.Array    # (B, H) stabilizer


def mlstm_init(key, d_model: int, n_heads: int, dtype=jnp.bfloat16) -> Params:
    dh = d_model // n_heads
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_init(d_model),
        "w_q": dense_init(ks[0], d_model, d_model, dtype),
        "w_k": dense_init(ks[1], d_model, d_model, dtype),
        "w_v": dense_init(ks[2], d_model, d_model, dtype),
        "w_i": dense_init(ks[3], d_model, n_heads, dtype),
        "w_f": dense_init(ks[4], d_model, n_heads, dtype),
        "w_o": dense_init(ks[5], d_model, d_model, dtype),
        "out_norm": rmsnorm_init(dh),
    }


def _mlstm_step(state: MLSTMState, inp, dh: float):
    q, k, v, i_raw, f_raw = inp   # (B,H,dh) x3, (B,H) x2
    c, n, m = state
    m_new = jnp.maximum(f_raw + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_raw + m - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), 1.0)
    return MLSTMState(c, n, m_new), (num / den[..., None]).astype(jnp.bfloat16)


def mlstm_apply(p: Params, x: jax.Array, n_heads: int,
                state: MLSTMState | None = None):
    """mLSTM block: (B, N, D) -> (B, N, D) with matrix-memory recurrence."""
    b, nn, d = x.shape
    dh = d // n_heads
    xh = rmsnorm(p["norm"], x)

    def heads(w):
        return (xh @ w.astype(xh.dtype)).reshape(b, nn, n_heads, dh) \
            .astype(jnp.float32)
    q, k, v = heads(p["w_q"]) / (dh ** 0.5), heads(p["w_k"]), heads(p["w_v"])
    i_raw = (xh @ p["w_i"].astype(xh.dtype)).astype(jnp.float32)
    f_raw = jax.nn.log_sigmoid(
        (xh @ p["w_f"].astype(xh.dtype)).astype(jnp.float32))

    if state is None:
        state = mlstm_state_init(b, d, n_heads)
    state, ys = jax.lax.scan(
        lambda s, inp: _mlstm_step(s, inp, dh),
        state,
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         i_raw.swapaxes(0, 1), f_raw.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1)                                    # (B,N,H,dh)
    y = rmsnorm(p["out_norm"], y).reshape(b, nn, d).astype(x.dtype)
    return x + y @ p["w_o"].astype(x.dtype), state


def mlstm_state_init(b: int, d_model: int, n_heads: int) -> MLSTMState:
    dh = d_model // n_heads
    return MLSTMState(
        c=jnp.zeros((b, n_heads, dh, dh), jnp.float32),
        n=jnp.zeros((b, n_heads, dh), jnp.float32),
        m=jnp.full((b, n_heads), -1e30, jnp.float32))


# ================================================================== sLSTM
class SLSTMState(NamedTuple):
    c: jax.Array   # (B, D)
    n: jax.Array   # (B, D)
    h: jax.Array   # (B, D)
    m: jax.Array   # (B, D)


def slstm_init(key, d_model: int, n_heads: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 9)
    dh = d_model // n_heads

    def rec(k):  # block-diagonal recurrent weights, one block per head
        return (jax.random.normal(k, (n_heads, dh, dh), jnp.float32)
                / (dh ** 0.5)).astype(dtype)

    return {
        "norm": rmsnorm_init(d_model),
        "w_i": dense_init(ks[0], d_model, d_model, dtype),
        "w_f": dense_init(ks[1], d_model, d_model, dtype),
        "w_z": dense_init(ks[2], d_model, d_model, dtype),
        "w_o": dense_init(ks[3], d_model, d_model, dtype),
        "r_i": rec(ks[4]), "r_f": rec(ks[5]), "r_z": rec(ks[6]),
        "r_o": rec(ks[7]),
        "w_out": dense_init(ks[8], d_model, d_model, dtype),
    }


def _slstm_step(state: SLSTMState, inp, p, n_heads):
    xi, xf, xz, xo = inp          # (B, D) pre-activations each
    c, n, h, m = state
    b, d = h.shape
    dh = d // n_heads
    hh = h.reshape(b, n_heads, dh)

    def rmul(r):
        return jnp.einsum("bhd,hde->bhe", hh, r.astype(jnp.float32)) \
            .reshape(b, d)
    i_raw = xi + rmul(p["r_i"])
    f_raw = xf + rmul(p["r_f"])
    z = jnp.tanh(xz + rmul(p["r_z"]))
    o = jax.nn.sigmoid(xo + rmul(p["r_o"]))
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_raw) + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(jax.nn.log_sigmoid(f_raw) + m - m_new)
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    h = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h, m_new), h.astype(jnp.bfloat16)


def slstm_apply(p: Params, x: jax.Array, n_heads: int,
                state: SLSTMState | None = None):
    """sLSTM block: (B, N, D) -> (B, N, D), scalar memory + recurrence."""
    b, nn, d = x.shape
    xh = rmsnorm(p["norm"], x)
    pre = [(xh @ p[w].astype(xh.dtype)).astype(jnp.float32)
           for w in ("w_i", "w_f", "w_z", "w_o")]
    if state is None:
        state = slstm_state_init(b, d)
    state, hs = jax.lax.scan(
        lambda s, inp: _slstm_step(s, inp, p, n_heads),
        state, tuple(t.swapaxes(0, 1) for t in pre))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    return x + y @ p["w_out"].astype(x.dtype), state


def slstm_state_init(b: int, d_model: int) -> SLSTMState:
    z = jnp.zeros((b, d_model), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((b, d_model), -1e30, jnp.float32))
