"""Model zoo: unified LM builder + the paper's own SNN workloads."""
from . import cnn, layers, lm, moe, spikingformer, ssm, transformer

__all__ = ["cnn", "layers", "lm", "moe", "spikingformer", "ssm", "transformer"]
