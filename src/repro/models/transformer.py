"""Attention layers: dense GQA (baseline / KV-cache serving) and SDSA
(the paper's Attention Core) — plus their decode counterparts.

Dense GQA is the "TConv analogue": softmax attention with RoPE, optional
qk-norm (qwen3) and sliding window (mixtral), O(N^2) with a real KV cache.
SDSA is the paper's technique: binary Q/K/V spikes, causal cumulative-OR
status vector, O(N) compute and O(d) decode state (DESIGN.md §2).

Spiking tensors carry a leading T axis (micro-timesteps).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFConfig
from repro.kernels import dispatch
from .layers import apply_rope, dense_init, lif_fire, rmsnorm, rope_angles

Params = Dict[str, Any]


def attn_init(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
              qk_norm: bool = False, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "w_k": dense_init(ks[1], d_model, n_kv * d_head, dtype),
        "w_v": dense_init(ks[2], d_model, n_kv * d_head, dtype),
        "w_o": dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((d_head,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((d_head,), jnp.float32)}
    return p


def _project_qkv(p: Params, x: jax.Array, n_heads: int, n_kv: int,
                 d_head: int):
    """x: (..., N, D) -> q (..., N, H, dh), k/v (..., N, KV, dh)."""
    q = (x @ p["w_q"].astype(x.dtype)).reshape(x.shape[:-1] + (n_heads, d_head))
    k = (x @ p["w_k"].astype(x.dtype)).reshape(x.shape[:-1] + (n_kv, d_head))
    v = (x @ p["w_v"].astype(x.dtype)).reshape(x.shape[:-1] + (n_kv, d_head))
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(..., N, KV, dh) -> (..., N, KV*n_rep, dh) head replication (GQA)."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


# ------------------------------------------------------------- dense (GQA)
def attention_dense(
    p: Params, x: jax.Array, *, n_heads: int, n_kv: int, d_head: int,
    causal: bool = True, window: int | None = None, qk_norm: bool = False,
    rope_theta: float = 1e4, kv_block: int = 1024,
) -> jax.Array:
    """Full-sequence softmax GQA. x: (B, N, D) -> (B, N, D).

    For N > kv_block, runs blockwise (flash-style) online-softmax over KV
    chunks via lax.scan — O(N * kv_block) live score memory instead of
    O(N^2) (production memory behaviour without a fused kernel).
    """
    b, n, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, d_head)
    if qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    positions = jnp.arange(n)
    sin, cos = rope_angles(positions, d_head, rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    k = _repeat_kv(k, n_heads // n_kv)
    v = _repeat_kv(v, n_heads // n_kv)
    # (B, H, N, dh)
    q, k, v = (t.swapaxes(-3, -2) for t in (q, k, v))
    scale = d_head ** -0.5

    if n <= kv_block:
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
        scores = scores + _mask(n, n, 0, causal, window)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    else:
        out = _blockwise_attention(q, k, v, scale, causal, window, kv_block)
    out = out.swapaxes(-3, -2).reshape(b, n, n_heads * d_head)
    return out @ p["w_o"].astype(out.dtype)


def _mask(nq: int, nk: int, k_start: int, causal: bool,
          window: int | None) -> jax.Array:
    qpos = jnp.arange(nq)[:, None]
    kpos = (k_start + jnp.arange(nk))[None, :]
    m = jnp.zeros((nq, nk), jnp.float32)
    if causal:
        m = jnp.where(kpos > qpos, -jnp.inf, m)
    if window is not None:
        m = jnp.where(kpos < qpos - window + 1, -jnp.inf, m)
    return m


def _blockwise_attention(q, k, v, scale, causal, window, kv_block):
    """Online-softmax over KV chunks (flash-attention recurrence in JAX)."""
    b, h, n, dh = q.shape
    n_blocks = n // kv_block
    k_blocks = k.reshape(b, h, n_blocks, kv_block, dh).transpose(2, 0, 1, 3, 4)
    v_blocks = v.reshape(b, h, n_blocks, kv_block, dh).transpose(2, 0, 1, 3, 4)

    def body(carry, blk):
        m_run, l_run, acc = carry
        kb, vb, idx = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kb).astype(jnp.float32) * scale
        s = s + _mask_dyn(n, kv_block, idx * kv_block, causal, window)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, h, n), -jnp.inf, jnp.float32),
            jnp.zeros((b, h, n), jnp.float32),
            jnp.zeros((b, h, n, dh), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, init, (k_blocks, v_blocks, jnp.arange(n_blocks)))
    return (acc / jnp.maximum(l_f, 1e-30)[..., None]).astype(q.dtype)


def _mask_dyn(nq, nk, k_start, causal, window):
    qpos = jnp.arange(nq)[:, None]
    kpos = (k_start + jnp.arange(nk))[None, :]
    m = jnp.zeros((nq, nk), jnp.float32)
    if causal:
        m = jnp.where(kpos > qpos, -jnp.inf, m)
    if window is not None:
        m = jnp.where(kpos < qpos - window + 1, -jnp.inf, m)
    return m


class KVCache(NamedTuple):
    k: jax.Array      # (B, S, KV, dh)
    v: jax.Array      # (B, S, KV, dh)


def kv_cache_init(b: int, s: int, n_kv: int, d_head: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(k=jnp.zeros((b, s, n_kv, d_head), dtype),
                   v=jnp.zeros((b, s, n_kv, d_head), dtype))


def attention_dense_decode(
    p: Params, x_t: jax.Array, cache: KVCache, pos: jax.Array, *,
    n_heads: int, n_kv: int, d_head: int, window: int | None = None,
    qk_norm: bool = False, rope_theta: float = 1e4,
    masked_cache_update: bool = True,
) -> tuple[jax.Array, KVCache]:
    """One-token GQA decode. x_t: (B, D); pos: scalar or per-batch (B,)
    current positions.

    Per-batch positions are what continuous batching needs: a slot
    admitted mid-stream decodes at ITS position (RoPE angle, cache write
    index, causal mask), not the pool maximum. A scalar pos broadcasts —
    aligned callers (streaming prefill, dry-run shapes) are unchanged.

    masked_cache_update=True writes the new K/V via an arithmetic one-hot
    merge instead of dynamic_update_slice: elementwise on the (possibly
    sequence-sharded) cache, so SPMD never reshards/all-gathers it — the
    DUS form triggers XLA's "involuntary full rematerialization" of the
    whole cache per token when S is the sharded dim (§Perf cell A).
    """
    b, _ = x_t.shape
    s_len = cache.k.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k, v = _project_qkv(p, x_t[:, None, :], n_heads, n_kv, d_head)
    if qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    sin, cos = rope_angles(pos[:, None], d_head, rope_theta)   # (B,1,dh/2)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    if masked_cache_update:
        hit = (jnp.arange(s_len)[None, :] == pos[:, None])[..., None, None]
        new_k = jnp.where(hit, k.astype(cache.k.dtype), cache.k)
        new_v = jnp.where(hit, v.astype(cache.v.dtype), cache.v)
    else:
        new_k = jax.vmap(lambda c, u, p_: jax.lax.dynamic_update_slice(
            c, u, (p_, 0, 0)))(cache.k, k.astype(cache.k.dtype), pos)
        new_v = jax.vmap(lambda c, u, p_: jax.lax.dynamic_update_slice(
            c, u, (p_, 0, 0)))(cache.v, v.astype(cache.v.dtype), pos)
    # Grouped-query scores WITHOUT materializing the repeated cache:
    # repeating KV to H heads broadcasts a (B,S,H,dh) tensor whose head dim
    # must align with the model-sharded Q — SPMD then replicates the whole
    # cache per token (208 GB/step on mistral decode_32k, §Perf cell A).
    # Grouping Q as (B, KV, rep, dh) keeps the cache S-sharded; only Q
    # (a few MB) moves.
    rep = n_heads // n_kv
    qg = q[:, 0, :, :].reshape(b, n_kv, rep, d_head)         # (B,KV,rep,dh)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, new_k).astype(jnp.float32)
    scores = scores * (d_head ** -0.5)
    kpos = jnp.arange(s_len)[None, None, None, :]
    pos_b = pos[:, None, None, None]
    valid = kpos <= pos_b
    if window is not None:
        valid = valid & (kpos > pos_b - window)
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs, new_v)        # (B,KV,rep,dh)
    out = out.reshape(b, n_heads * d_head)
    return out @ p["w_o"].astype(out.dtype), KVCache(new_k, new_v)


# ----------------------------------------------------------------- SDSA
def attention_sdsa(
    p: Params, s: jax.Array, *, n_heads: int, n_kv: int, d_head: int,
    lif_cfg: LIFConfig, mode: str = "or", causal: bool = True,
) -> jax.Array:
    """Spike-driven self-attention over a spike sequence.

    s: (T, B, N, D) binary. Q/K/V drives are fired through LIF (binary),
    then: status[i] = cumOR_{j<=i} over tokens and micro-steps of K AND V;
    out = Q AND status (paper Fig. 6, causal form for LMs). Cost O(N),
    decode state O(d). GQA grouping applies to K/V spikes as in dense.

    Both forms route through the backend registry: the causal prefix-
    OR/sum is the `causal_sdsa` op (ref cummax form on CPU, bit-packed
    prefix-OR kernels elsewhere); the non-causal pool folds micro-steps
    into the token axis of the stateless `sdsa` op (status is one global
    OR/sum either way). `attention_sdsa_decode` is the streaming form of
    the same ops, property-tested equal.
    """
    q, k, v = _project_qkv(p, s, n_heads, n_kv, d_head)
    q, k, v = (lif_fire(t, lif_cfg) for t in (q, k, v))
    k = _repeat_kv(k, n_heads // n_kv)
    v = _repeat_kv(v, n_heads // n_kv)
    t, b, n = s.shape[0], s.shape[1], s.shape[2]
    # (T,B,N,H,dh) -> (T,B,H,N,dh): registry ops take the token axis at -2.
    qh, kh, vh = (x.swapaxes(2, 3) for x in (q, k, v))
    if causal:
        out = dispatch.causal_sdsa(qh, kh, vh, mode=mode)
    else:
        def fold(x):                             # (T,B,H,N,dh)->(B,H,T*N,dh)
            return x.transpose(1, 2, 0, 3, 4).reshape(
                b, n_heads, t * n, d_head)
        pooled = dispatch.sdsa(fold(qh), fold(kh), fold(vh), mode=mode)
        out = pooled.reshape(b, n_heads, t, n, d_head).transpose(2, 0, 1, 3, 4)
    out = out.swapaxes(2, 3)                     # back to (T,B,N,H,dh)
    if mode == "sum":
        out = lif_fire(out, lif_cfg)             # FPE re-binarization
    out = out.reshape(t, b, n, n_heads * d_head)
    return out @ p["w_o"].astype(out.dtype)


class SDSAState(NamedTuple):
    status: jax.Array   # (B, H, dh) running OR/sum over all past events


def sdsa_state_init(b: int, n_heads: int, d_head: int,
                    dtype=jnp.bfloat16) -> SDSAState:
    return SDSAState(status=jnp.zeros((b, n_heads, d_head), dtype))


def attention_sdsa_decode(
    p: Params, s_t: jax.Array, state: SDSAState, *, n_heads: int, n_kv: int,
    d_head: int, lif_cfg: LIFConfig, mode: str = "or",
) -> tuple[jax.Array, SDSAState]:
    """One-token SDSA decode. s_t: (T, B, D) spikes for the new token.

    Folds the token's K/V spike phases into the O(d) status (the on-the-fly
    OR of Sec. III-C), then attends Q — exactly the streaming form of
    `attention_sdsa` (property-tested equal).
    """
    q, k, v = _project_qkv(p, s_t, n_heads, n_kv, d_head)   # (T,B,heads,dh)
    q, k, v = (lif_fire(t, lif_cfg) for t in (q, k, v))
    k = _repeat_kv(k, n_heads // n_kv)
    v = _repeat_kv(v, n_heads // n_kv)
    kv = k * v
    phase = jnp.max(kv, axis=0) if mode == "or" else jnp.sum(kv, axis=0)
    status = jnp.maximum(state.status, phase.astype(state.status.dtype)) \
        if mode == "or" else state.status + phase.astype(state.status.dtype)
    out = q * status[None].astype(q.dtype)
    if mode == "sum":
        out = lif_fire(out, lif_cfg)
    t, b = s_t.shape[0], s_t.shape[1]
    out = out.reshape(t, b, n_heads * d_head)
    return out @ p["w_o"].astype(out.dtype), SDSAState(status)
