"""SpikingFormer-L-D (the paper's transformer workloads, Table II).

Structure per the SpikingFormer line of work, matching the paper's
benchmark split (Fig. 7): a Spiking Patch Splitting (SPS) conv stem that
downsamples 32x32 CIFAR images into 8x8 = 64 tokens of dimension D, then
L encoder blocks of spike-driven self-attention (SSA — the Attention Core
semantics) + spiking MLP (FFN). Membrane shortcut residuals; rate-decoded
classification head.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import SpikingConfig
from repro.core.events import max_pool_events
from repro.core.lif import LIFConfig
from repro.kernels import dispatch
from .cnn import _conv_init
from .layers import dense_init, hybrid_scope, lif_fire, lif_fire_events

Params = Dict[str, Any]


def spikingformer_init(key, depth: int, dim: int, n_classes: int = 10,
                       in_ch: int = 3) -> Params:
    keys = iter(jax.random.split(key, 16 + 8 * depth))
    sps_dims = (dim // 8, dim // 4, dim // 2, dim)
    p: Params = {"sps": [], "blocks": []}
    ci = in_ch
    for co in sps_dims:
        p["sps"].append(_conv_init(next(keys), 3, ci, co))
        ci = co
    for _ in range(depth):
        p["blocks"].append({
            "w_q": dense_init(next(keys), dim, dim, jnp.float32),
            "w_k": dense_init(next(keys), dim, dim, jnp.float32),
            "w_v": dense_init(next(keys), dim, dim, jnp.float32),
            "w_o": dense_init(next(keys), dim, dim, jnp.float32),
            "w_fc1": dense_init(next(keys), dim, 4 * dim, jnp.float32),
            "w_fc2": dense_init(next(keys), 4 * dim, dim, jnp.float32),
        })
    p["head"] = dense_init(next(keys), dim, n_classes, jnp.float32)
    return p


def spikingformer_apply(p: Params, x: jax.Array, n_heads: int = 8,
                        spiking_cfg: SpikingConfig = SpikingConfig(t_steps=4),
                        collect_stats: bool = False):
    """x: (B, 32, 32, C) -> logits (B, n_classes) [, spike maps]."""
    with hybrid_scope(spiking_cfg):
        return _spikingformer_body(p, x, n_heads, spiking_cfg, collect_stats)


def _spikingformer_body(p, x, n_heads, spiking_cfg, collect_stats):
    lif = LIFConfig(decay=spiking_cfg.lif_decay, v_th=spiking_cfg.lif_vth)
    t = spiking_cfg.t_steps
    b = x.shape[0]
    s = jnp.broadcast_to(x[None], (t,) + x.shape)
    stats: List[jax.Array] = []

    # SPS: conv -> LIF x4, maxpool after stages 2 and 3 (32 -> 8).
    # Registry-routed econv over the flattened (T*B) batch: dense TConv on
    # CPU, im2col + occupancy-skipping spike matmul on TPU. Stage 0 eats
    # the direct-coded (multi-bit) image, which the event path doesn't
    # model (OPT1 territory) — it stays on the dense oracle. From stage 1
    # on the stream is full-event: the fire stage emits spikes WITH their
    # occupancy map (`lif_fire_events`), the (T,B)->(T*B) fold and the
    # pooling both carry it forward, and each econv consumes it instead
    # of re-deriving occupancy from the activation it was just handed.
    from repro.core.econv import econv, tconv
    packed = getattr(spiking_cfg, "packed", False)
    for i, w in enumerate(p["sps"]):
        tb = s.shape[:2]
        flat = s.reshape((-1,) + s.shape[2:])
        drive = tconv(flat, w) if i == 0 else econv(flat, w)
        drive = drive.reshape(tb + drive.shape[1:])
        s = lif_fire_events(drive, lif, packed=packed)
        if i in (1, 2):
            s = max_pool_events(s, 2)    # packed payload pools bitwise-OR
        if collect_stats:
            stats.append(s.dense())

    dim = s.shape[-1]
    n_tok = s.shape[2] * s.shape[3]
    tokens = s.reshape(t, b, n_tok, dim)         # (T,B,N,D), map survives
    # The membrane residual stream is continuous-valued from here on —
    # `.dense()` is the explicit unpack at the SPS/transformer boundary.
    x_mp = tokens.dense()

    for blk in p["blocks"]:
        # SSA: q/k/v spikes -> Attention Core (non-causal OR form). The
        # head split changes the trailing axis, so no map is carried into
        # SDSA (which consumes packed words, not occupancy, anyway).
        sq = lif_fire(x_mp @ blk["w_q"], lif).reshape(
            t, b, n_tok, n_heads, dim // n_heads)
        sk = lif_fire(x_mp @ blk["w_k"], lif).reshape(
            t, b, n_tok, n_heads, dim // n_heads)
        sv = lif_fire(x_mp @ blk["w_v"], lif).reshape(
            t, b, n_tok, n_heads, dim // n_heads)
        attn = dispatch.sdsa(sq.swapaxes(2, 3), sk.swapaxes(2, 3),
                             sv.swapaxes(2, 3), mode=spiking_cfg.sdsa_mode)
        attn = attn.swapaxes(2, 3).reshape(t, b, n_tok, dim)
        if collect_stats:
            stats.append(attn)
        x_mp = x_mp + attn @ blk["w_o"]
        # Spiking MLP (FFN): full-event — both fires carry their maps and
        # both projections consume them through the registry matmul. In
        # packed mode both fires emit uint32 words and the projections
        # route to the packed-csr family (no f32 spikes in between).
        h = lif_fire_events(x_mp, lif, packed=packed)
        h = lif_fire_events(dispatch.spike_matmul(h, blk["w_fc1"]), lif,
                            packed=packed)
        if collect_stats:
            stats.append(h.dense())
        x_mp = x_mp + dispatch.spike_matmul(h, blk["w_fc2"])

    feats = jnp.mean(lif_fire(x_mp, lif), axis=(0, 2))      # rate + token avg
    logits = feats @ p["head"]
    return (logits, stats) if collect_stats else logits
