"""Unified LM builder: every assigned architecture from one LMConfig.

A model is a repeated *pattern* of heterogeneous blocks (dense = 1-long
pattern; jamba = 8-long Mamba/attn pattern; xlstm = 8-long mLSTM/sLSTM
pattern), scanned over `n_groups` repetitions with stacked params — one
compiled body per pattern regardless of depth (88-layer mistral compiles
the same HLO size as 22-layer tinyllama).

Two execution modes per model (DESIGN.md §4):
  spiking=True  — the paper's technique: LIF-fired binary activations into
                  every matmul, SDSA attention (O(N) / O(d) state), event
                  accounting; leading T micro-timestep axis.
  spiking=False — the dense ANN baseline (softmax GQA, SiLU MLP), used for
                  the decode_32k KV-cache serving shape and for
                  baseline-vs-technique comparisons.

All functions are pure; params/state are pytrees; `jax.eval_shape` over
`init_params` gives allocation-free abstract trees for the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core.lif import LIFConfig
from . import moe as moe_lib
from . import ssm
from . import transformer as tfm
from .layers import dense_init, embed_init, lif_fire, mlp_apply, mlp_init, \
    rmsnorm, rmsnorm_init

Params = Dict[str, Any]


# ------------------------------------------------------------ pattern plan
class BlockSpec(NamedTuple):
    kind: str          # attn | mamba | mlstm | slstm
    ffn: str           # mlp | moe | none


def layer_pattern(cfg: LMConfig) -> Tuple[List[BlockSpec], int]:
    """Return (pattern, n_groups) with n_layers == len(pattern) * n_groups."""
    if cfg.xlstm is not None:
        period = cfg.xlstm.period
        pat = [BlockSpec("slstm" if i == cfg.xlstm.slstm_index else "mlstm",
                         "none") for i in range(period)]
        assert cfg.n_layers % period == 0
        return pat, cfg.n_layers // period

    def ffn_kind(layer_idx: int) -> str:
        if cfg.moe is None:
            return "mlp"
        return "moe" if layer_idx % cfg.moe.moe_every == cfg.moe.moe_offset \
            else "mlp"

    if cfg.hybrid is not None:
        period = cfg.hybrid.period
        pat = [BlockSpec(
            "attn" if i == cfg.hybrid.attn_index else "mamba", ffn_kind(i))
            for i in range(period)]
        assert cfg.n_layers % period == 0
        return pat, cfg.n_layers // period

    period = cfg.moe.moe_every if cfg.moe is not None else 1
    pat = [BlockSpec("attn", ffn_kind(i)) for i in range(period)]
    assert cfg.n_layers % period == 0
    return pat, cfg.n_layers // period


def lif_cfg_of(cfg: LMConfig) -> LIFConfig:
    return LIFConfig(decay=cfg.spiking.lif_decay, v_th=cfg.spiking.lif_vth)


# ------------------------------------------------------------------- init
def _block_init(cfg: LMConfig, spec: BlockSpec, key, cross: bool) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {}
    if spec.kind == "attn":
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["attn"] = tfm.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm)
    elif spec.kind == "mamba":
        hy = cfg.hybrid
        p["ln1"] = rmsnorm_init(cfg.d_model)
        p["mamba"] = ssm.mamba_init(ks[0], cfg.d_model, hy.d_state,
                                    hy.d_conv, hy.expand)
    elif spec.kind == "mlstm":
        p["mlstm"] = ssm.mlstm_init(ks[0], cfg.d_model, cfg.n_heads)
    elif spec.kind == "slstm":
        p["slstm"] = ssm.slstm_init(ks[0], cfg.d_model, cfg.n_heads)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, (4 * cfg.d_model) // 3)
    if cross and spec.kind == "attn":
        p["cross_ln"] = rmsnorm_init(cfg.d_model)
        p["cross_attn"] = tfm.attn_init(ks[2], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, False)
    if spec.ffn == "mlp":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff)
    elif spec.ffn == "moe":
        m = cfg.moe
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["moe"] = moe_lib.moe_init(ks[3], cfg.d_model, m.d_ff_expert,
                                    m.n_experts, m.n_shared,
                                    bank_size=m.bank_size)
    return p


def _stack_init(cfg: LMConfig, key, n_groups: int, pattern: List[BlockSpec],
                cross: bool) -> List[Params]:
    """Per pattern position: params stacked over the group axis."""
    out = []
    for i, spec in enumerate(pattern):
        pos_key = jax.random.fold_in(key, i)
        keys = jax.random.split(pos_key, n_groups)
        out.append(jax.vmap(
            lambda k: _block_init(cfg, spec, k, cross))(keys))
    return out


def init_params(cfg: LMConfig, key: jax.Array) -> Params:
    pattern, n_groups = layer_pattern(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
        "blocks": _stack_init(cfg, ks[1], n_groups, pattern,
                              cross=cfg.encoder_decoder),
        "final_norm": rmsnorm_init(cfg.d_model),
        "lm_head": dense_init(ks[2], cfg.d_model, cfg.vocab),
    }
    if cfg.encoder_decoder:
        enc_pattern = [BlockSpec("attn", "mlp")]
        p["encoder"] = {
            "blocks": _stack_init(cfg, ks[3], cfg.n_encoder_layers,
                                  enc_pattern, cross=False),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
    if cfg.n_frontend_tokens or cfg.encoder_seq:
        # Stub frontend projection (assignment: precomputed embeddings in).
        p["frontend_proj"] = dense_init(ks[4], cfg.d_model, cfg.d_model)
    return p


def abstract_params(cfg: LMConfig) -> Params:
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


# -------------------------------------------------------- block application
def _apply_block(cfg: LMConfig, spec: BlockSpec, p: Params, x: jax.Array,
                 spiking: bool, *, causal: bool = True,
                 enc_kv: Optional[tuple] = None) -> jax.Array:
    """Full-sequence block. x: (T,B,N,D) spiking / (B,N,D) dense."""
    lif = lif_cfg_of(cfg)
    if spec.kind == "attn":
        if spiking:
            s = lif_fire(rmsnorm(p["ln1"], x), lif)
            a = tfm.attention_sdsa(
                p["attn"], s, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                d_head=cfg.head_dim, lif_cfg=lif,
                mode=cfg.spiking.sdsa_mode, causal=causal)
        else:
            a = tfm.attention_dense(
                p["attn"], rmsnorm(p["ln1"], x), n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, causal=causal,
                window=cfg.sliding_window, qk_norm=cfg.qk_norm,
                rope_theta=cfg.rope_theta)
        x = x + a
        if enc_kv is not None and "cross_attn" in p:
            x = x + _cross_attn_full(cfg, p, x, enc_kv, spiking)
    elif spec.kind == "mamba":
        def mamba_one(xb):
            out, _ = ssm.mamba_apply(p["mamba"], rmsnorm(p["ln1"], xb),
                                     None, cfg.hybrid.d_state,
                                     cfg.hybrid.d_conv)
            return out
        if spiking:
            s = lif_fire(rmsnorm(p["ln1"], x), lif)
            out, _ = jax.vmap(lambda st: ssm.mamba_apply(
                p["mamba"], st, None, cfg.hybrid.d_state,
                cfg.hybrid.d_conv))(s)
            x = x + out
        else:
            x = x + mamba_one(x)
    elif spec.kind == "mlstm":
        if spiking:
            s = lif_fire(x, lif)
            out, _ = jax.vmap(lambda st: ssm.mlstm_apply(
                p["mlstm"], st, cfg.n_heads))(s)
            x = out
        else:
            x, _ = ssm.mlstm_apply(p["mlstm"], x, cfg.n_heads)
    elif spec.kind == "slstm":
        if spiking:
            s = lif_fire(x, lif)
            out, _ = jax.vmap(lambda st: ssm.slstm_apply(
                p["slstm"], st, cfg.n_heads))(s)
            x = out
        else:
            x, _ = ssm.slstm_apply(p["slstm"], x, cfg.n_heads)

    if spec.ffn == "mlp":
        h = rmsnorm(p["ln2"], x)
        if spiking:
            h = lif_fire(h, lif)
        x = x + mlp_apply(p["mlp"], h, spiking=spiking, lif_cfg=lif)
    elif spec.ffn == "moe":
        h = rmsnorm(p["ln2"], x)
        if spiking:
            h = lif_fire(h, lif)
        if cfg.moe_shard_map:
            moe_out = moe_lib.moe_apply_shard_map(
                p["moe"], h, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, spiking=spiking,
                lif_cfg=lif)
        else:
            moe_out = moe_lib.moe_apply(
                p["moe"], h, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, spiking=spiking,
                lif_cfg=lif, dispatch_groups=cfg.moe_dispatch_groups)
        x = x + moe_out
    return x


def _cross_attn_full(cfg, p, x, enc_kv, spiking):
    """Cross-attention to (pre-projected) encoder keys/values."""
    k_enc, v_enc = enc_kv
    lif = lif_cfg_of(cfg)
    h = rmsnorm(p["cross_ln"], x)
    if spiking:
        q = lif_fire(h, lif)
        qh = (q @ p["cross_attn"]["w_q"].astype(q.dtype)).reshape(
            q.shape[:-1] + (cfg.n_heads, cfg.head_dim))
        qh = lif_fire(qh, lif)
        status = jnp.max(k_enc * v_enc, axis=-3)           # (B,KV,dh) OR
        status = jnp.repeat(status, cfg.n_heads // cfg.n_kv_heads, axis=-2)
        out = qh * status[None, :, None]
        out = out.reshape(q.shape[:-1] + (cfg.n_heads * cfg.head_dim,))
        return out @ p["cross_attn"]["w_o"].astype(out.dtype)
    qh = (h @ p["cross_attn"]["w_q"].astype(h.dtype)).reshape(
        h.shape[:-1] + (cfg.n_heads, cfg.head_dim))
    rep = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(k_enc, rep, axis=-2).swapaxes(-3, -2)  # (B,H,S,dh)
    vv = jnp.repeat(v_enc, rep, axis=-2).swapaxes(-3, -2)
    qq = qh.swapaxes(-3, -2)
    sc = jnp.einsum("...hqd,...hkd->...hqk", qq, kk).astype(jnp.float32)
    pr = jax.nn.softmax(sc * cfg.head_dim ** -0.5, axis=-1).astype(h.dtype)
    out = jnp.einsum("...hqk,...hkd->...hqd", pr, vv).swapaxes(-3, -2)
    out = out.reshape(h.shape[:-1] + (cfg.n_heads * cfg.head_dim,))
    return out @ p["cross_attn"]["w_o"].astype(out.dtype)


# ------------------------------------------------------------ full forward
def _remat_wrap(cfg: LMConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(fn, policy=policy)


def forward_hidden(cfg: LMConfig, params: Params, tokens: jax.Array,
                   spiking: bool, frontend: Optional[jax.Array] = None,
                   causal: bool = True) -> jax.Array:
    """tokens (B, N) -> final hidden (B, N, D) (T-averaged if spiking)."""
    pattern, n_groups = layer_pattern(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)           # (B,N,D)
    if frontend is not None and not cfg.encoder_decoder:
        # VLM-style stub frontend: precomputed patch embeds prepended to
        # the decoder stream. (Audio frontends feed the encoder instead.)
        fe = frontend @ params["frontend_proj"].astype(frontend.dtype)
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
    if spiking:
        x = jnp.broadcast_to(x[None], (cfg.spiking.t_steps,) + x.shape)

    enc_kv = None
    if cfg.encoder_decoder:
        enc_hidden = _encoder_forward(cfg, params, frontend, spiking)
        enc_kv = enc_hidden  # per-layer projection happens inside blocks
    x = _run_blocks(cfg, params["blocks"], x, spiking, pattern, n_groups,
                    causal, enc_kv)
    if spiking:
        x = jnp.mean(x, axis=0)                             # rate decoding
    return rmsnorm(params["final_norm"], x)


def _unshard_weights(tree):
    """ZeRO-3 per-layer weight gather: constrain every matrix to replicated
    right before use. Without this GSPMD may keep weights sharded and
    gather the (1000x larger) activations instead (§Perf cell C)."""
    from repro.launch.mesh import current_mesh
    try:
        mesh = current_mesh()
        if not (getattr(mesh, "axis_names", None)):
            return tree
    except Exception:
        return tree

    def one(w):
        if w.ndim < 2:
            return w
        return jax.lax.with_sharding_constraint(
            w, jax.sharding.PartitionSpec(*([None] * w.ndim)))
    return jax.tree.map(one, tree)


def _run_blocks(cfg, blocks, x, spiking, pattern, n_groups, causal, enc_kv):
    # Heterogeneous patterns (jamba's 8-layer group) nest a second remat
    # around each sub-layer: backward then holds ONE sub-layer's internals
    # instead of the whole group's — 8x smaller live set at the cost of one
    # extra forward (already paid by remat="full").
    nested = cfg.remat == "full" and len(pattern) > 1

    def sub_block(spec, i):
        def f(x, group_params):
            kv = None
            if enc_kv is not None and spec.kind == "attn":
                kv = _project_enc_kv(cfg, group_params[i], enc_kv, spiking)
            return _apply_block(cfg, spec, group_params[i], x, spiking,
                                causal=causal, enc_kv=kv)
        return jax.checkpoint(f) if nested else f

    subs = [sub_block(spec, i) for i, spec in enumerate(pattern)]

    def group_body(x, group_params):
        if cfg.pure_fsdp:
            group_params = _unshard_weights(group_params)
        for f in subs:
            x = f(x, group_params)
        return x, None

    body = _remat_wrap(cfg, group_body)
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, tuple(blocks))
    return x


def _project_enc_kv(cfg, p, enc_hidden, spiking):
    """Project encoder hidden into this layer's cross K/V (heads layout)."""
    if "cross_attn" not in p:
        return None
    pa = p["cross_attn"]
    h = enc_hidden
    k = (h @ pa["w_k"].astype(h.dtype)).reshape(
        h.shape[:-1] + (cfg.n_kv_heads, cfg.head_dim))
    v = (h @ pa["w_v"].astype(h.dtype)).reshape(
        h.shape[:-1] + (cfg.n_kv_heads, cfg.head_dim))
    if spiking:
        lif = lif_cfg_of(cfg)
        k = lif_fire(k[None], lif)[0]
        v = lif_fire(v[None], lif)[0]
    return k, v


def _encoder_forward(cfg: LMConfig, params: Params,
                     frontend: Optional[jax.Array], spiking: bool):
    """Whisper-style encoder over stub frame embeddings (non-causal)."""
    enc = params["encoder"]
    fe = frontend
    if fe is None:
        raise ValueError("encoder-decoder arch requires frontend embeddings")
    x = fe @ params["frontend_proj"].astype(fe.dtype)
    if spiking:
        x = jnp.broadcast_to(x[None], (cfg.spiking.t_steps,) + x.shape)
    pattern = [BlockSpec("attn", "mlp")]
    x = _run_blocks(cfg, enc["blocks"], x, spiking, pattern,
                    cfg.n_encoder_layers, causal=False, enc_kv=None)
    if spiking:
        x = jnp.mean(x, axis=0)
    return rmsnorm(enc["final_norm"], x)


# ------------------------------------------------------------------- loss
def chunked_ce_loss(hidden: jax.Array, w_head: jax.Array, labels: jax.Array,
                    chunk: int) -> jax.Array:
    """Cross-entropy without materializing (N, vocab) logits: scan over
    sequence chunks, rematerialized in backward (memory = chunk x vocab)."""
    b, n, d = hidden.shape
    if n % chunk:
        chunk = n  # fall back for tiny smoke shapes
    nc = n // chunk
    h_c = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    l_c = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hh, ll = xs
        logits = (hh @ w_head.astype(hh.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        mask = (ll >= 0).astype(jnp.float32)
        return (carry[0] + jnp.sum((lse - tgt) * mask),
                carry[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (h_c, l_c))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
            spiking: bool) -> jax.Array:
    hidden = forward_hidden(cfg, params, batch["tokens"], spiking,
                            frontend=batch.get("frontend"))
    if cfg.pure_fsdp:
        # gather the head once, not once per CE chunk
        params = {**params, "lm_head": _unshard_weights(
            {"w": params["lm_head"]})["w"]}
    labels = batch["labels"]
    if cfg.n_frontend_tokens and "frontend" in batch:
        # frontend positions carry no LM loss
        pad = -jnp.ones(labels.shape[:1] + (cfg.n_frontend_tokens,),
                        labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return chunked_ce_loss(hidden, params["lm_head"], labels, cfg.loss_chunk)


# ------------------------------------------------------------------ serving
class LayerState(NamedTuple):
    """Union state for one pattern position (unused fields are None)."""
    kv: Any = None          # tfm.KVCache        (dense attn decode)
    sdsa: Any = None        # tfm.SDSAState      (spiking attn decode)
    mamba: Any = None       # ssm.MambaState
    mlstm: Any = None
    slstm: Any = None
    cross_kv: Any = None    # (k_enc, v_enc) static
    cross_status: Any = None


def init_state(cfg: LMConfig, spec: BlockSpec, b: int, s: int, spiking: bool,
               n_groups: int) -> LayerState:
    """Stacked (n_groups, ...) decode state for one pattern position."""
    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), tree)

    st = LayerState()
    if spec.kind == "attn":
        if spiking:
            st = st._replace(sdsa=stack(tfm.sdsa_state_init(
                b, cfg.n_heads, cfg.head_dim)))
        else:
            st = st._replace(kv=stack(tfm.kv_cache_init(
                b, s, cfg.n_kv_heads, cfg.head_dim)))
    elif spec.kind == "mamba":
        st = st._replace(mamba=stack(ssm.mamba_state_init(
            b, cfg.d_model, cfg.hybrid.d_state, cfg.hybrid.d_conv,
            cfg.hybrid.expand)))
    elif spec.kind == "mlstm":
        st = st._replace(mlstm=stack(ssm.mlstm_state_init(
            b, cfg.d_model, cfg.n_heads)))
    elif spec.kind == "slstm":
        st = st._replace(slstm=stack(ssm.slstm_state_init(b, cfg.d_model)))
    if cfg.encoder_decoder and spec.kind == "attn":
        k_enc = jnp.zeros((b, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                          jnp.bfloat16)
        if spiking:
            st = st._replace(cross_status=stack(
                jnp.zeros((b, cfg.n_heads, cfg.head_dim), jnp.bfloat16)))
        else:
            st = st._replace(cross_kv=stack((k_enc, k_enc)))
    return st


def init_decode_state(cfg: LMConfig, b: int, s: int, spiking: bool):
    """Decode-state layout contract (load-bearing for the serve loop):
    the state is a list of LayerState, one per pattern position, and
    EVERY array leaf is stacked ``(n_groups, b, ...)`` — the slot batch
    is axis 1 of every leaf. `reset_slot_state` / `merge_slot_state`
    index that axis structurally; nothing shape-guesses."""
    pattern, n_groups = layer_pattern(cfg)
    return [init_state(cfg, spec, b, s, spiking, n_groups)
            for spec in pattern]


def decode_step(cfg: LMConfig, params: Params, state: list,
                token: jax.Array, pos: jax.Array, spiking: bool):
    """One serving step. token: (B,) int32; pos: scalar int32 OR per-slot
    (B,) int32 positions.

    Per-slot positions are the continuous-batching contract: each batch
    slot decodes at ITS OWN position (KV-cache write index, RoPE angle,
    causal mask), so a request admitted while others are mid-generation
    is bitwise-identical to decoding it alone. A scalar pos broadcasts to
    every slot — the aligned special case (streaming prefill, dry-run
    shapes) — never the other way around.

    Returns (logits (B, vocab), new_state). Dense mode appends to the KV
    cache; spiking mode updates O(d) SDSA statuses (position-free — the
    paper's serving payoff); SSM kinds update their recurrent states.
    """
    pattern, n_groups = layer_pattern(cfg)
    lif = lif_cfg_of(cfg)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), token.shape)
    x = jnp.take(params["embed"], token, axis=0)            # (B, D)
    if spiking:
        x = jnp.broadcast_to(x[None], (cfg.spiking.t_steps,) + x.shape)

    def group_body(x, xs):
        group_params, group_state = xs
        new_states = []
        for i, spec in enumerate(pattern):
            p, st = group_params[i], group_state[i]
            x, st = _apply_block_decode(cfg, spec, p, st, x, pos, spiking)
            new_states.append(st)
        return x, tuple(new_states)

    x, new_state = jax.lax.scan(
        group_body, x, (tuple(params["blocks"]), tuple(state)))
    if spiking:
        x = jnp.mean(x, axis=0)
    h = rmsnorm(params["final_norm"], x)
    logits = (h @ params["lm_head"].astype(h.dtype)).astype(jnp.float32)
    return logits, list(new_state)


def _apply_block_decode(cfg, spec, p, st: LayerState, x, pos, spiking):
    lif = lif_cfg_of(cfg)
    if spec.kind == "attn":
        if spiking:
            s = lif_fire(rmsnorm(p["ln1"], x), lif)          # (T,B,D)
            a, new_sdsa = tfm.attention_sdsa_decode(
                p["attn"], s, st.sdsa, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, lif_cfg=lif,
                mode=cfg.spiking.sdsa_mode)
            x = x + a
            st = st._replace(sdsa=new_sdsa)
            if st.cross_status is not None:
                q = lif_fire(rmsnorm(p["cross_ln"], x), lif)
                qh = (q @ p["cross_attn"]["w_q"].astype(q.dtype)).reshape(
                    q.shape[:-1] + (cfg.n_heads, cfg.head_dim))
                out = lif_fire(qh, lif) * st.cross_status[None].astype(q.dtype)
                out = out.reshape(q.shape[:-1] + (-1,))
                x = x + out @ p["cross_attn"]["w_o"].astype(x.dtype)
        else:
            a, new_kv = tfm.attention_dense_decode(
                p["attn"], rmsnorm(p["ln1"], x), st.kv, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                d_head=cfg.head_dim, window=cfg.sliding_window,
                qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
                masked_cache_update=cfg.decode_masked_update)
            x = x + a
            st = st._replace(kv=new_kv)
            if st.cross_kv is not None:
                x = x + _cross_attn_full(
                    cfg, p, x[:, None, :], st.cross_kv, False)[:, 0, :]
    elif spec.kind == "mamba":
        h = rmsnorm(p["ln1"], x)
        if spiking:
            h = lif_fire(h, lif)
            h = jnp.mean(h, axis=0)                          # collapse T
        out, new_m = ssm.mamba_apply(p["mamba"], h[:, None, :], st.mamba,
                                     cfg.hybrid.d_state, cfg.hybrid.d_conv)
        out = out[:, 0, :]
        if spiking:
            out = jnp.broadcast_to(out[None], x.shape)
        x = x + out
        st = st._replace(mamba=new_m)
    elif spec.kind == "mlstm":
        h = jnp.mean(lif_fire(x, lif), axis=0) if spiking else x
        out, new_s = ssm.mlstm_apply(p["mlstm"], h[:, None, :], cfg.n_heads,
                                     st.mlstm)
        out = out[:, 0, :]
        x = jnp.broadcast_to(out[None], x.shape) if spiking else out
        st = st._replace(mlstm=new_s)
    elif spec.kind == "slstm":
        h = jnp.mean(lif_fire(x, lif), axis=0) if spiking else x
        out, new_s = ssm.slstm_apply(p["slstm"], h[:, None, :], cfg.n_heads,
                                     st.slstm)
        out = out[:, 0, :]
        x = jnp.broadcast_to(out[None], x.shape) if spiking else out
        st = st._replace(slstm=new_s)

    if spec.ffn != "none":
        h = rmsnorm(p["ln2"], x)
        if spiking:
            h = lif_fire(h, lif)
        if spec.ffn == "mlp":
            x = x + mlp_apply(p["mlp"], h, spiking=spiking, lif_cfg=lif)
        else:
            moe_fn = moe_lib.moe_apply_shard_map if cfg.moe_shard_map \
                else moe_lib.moe_apply
            x = x + moe_fn(
                p["moe"], h[..., None, :], top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, spiking=spiking,
                lif_cfg=lif)[..., 0, :]
    return x, st


def prefill(cfg: LMConfig, params: Params, tokens: jax.Array, spiking: bool,
            frontend: Optional[jax.Array] = None):
    """Full-sequence prefill returning last-position logits.

    (For SDSA/SSM serving the production path re-uses forward_hidden and
    folds states via the streaming updates; the dry-run lowers this
    function for the prefill_32k shape.)
    """
    hidden = forward_hidden(cfg, params, tokens, spiking, frontend=frontend)
    h_last = hidden[:, -1, :]
    return (h_last @ params["lm_head"].astype(h_last.dtype)).astype(jnp.float32)


def prefill_with_state(cfg: LMConfig, params: Params, tokens: jax.Array,
                       spiking: bool, max_seq: Optional[int] = None):
    """Streaming prefill producing the decode state (serving handoff).

    Scans `decode_step` over the prompt — for SDSA/SSM this is the O(N)
    streaming form (state is O(d)); for dense mode it fills the KV cache.
    Returns (last-position logits, state ready for generation at pos=N).
    """
    b, n = tokens.shape
    state = init_decode_state(cfg, b, max_seq or n, spiking)

    def body(st, i):
        logits, st = decode_step(cfg, params, st, tokens[:, i], i, spiking)
        return st, logits

    state, logits_seq = jax.lax.scan(body, state, jnp.arange(n))
    return logits_seq[-1], state


def prefill_chunked(cfg: LMConfig, params: Params, tokens: jax.Array,
                    length: jax.Array, spiking: bool, max_seq: int):
    """Bucketed streaming prefill for continuous-batching admission.

    tokens: (B, L) prompts right-padded to a shared bucket length L;
    length: (B,) true prompt lengths (0 < length <= L). Scans decode_step
    over the L positions but masks every state write (and the last-logit
    capture) to steps ``i < length`` per slot, so pad tokens never touch
    the KV cache, the SDSA status, or the SSM recurrences — the padded
    run's state is bitwise what the unpadded run of each prompt alone
    would produce. One jit trace serves every prompt in the (L, B)
    bucket; the serve scheduler pads prompt lengths to pow2 buckets so
    admission cost is O(log max_prompt) compiles, not one per length.

    Returns (last-position logits (B, vocab), decode state positioned at
    ``pos = length`` per slot — ready for `decode_step` with a per-slot
    position vector).
    """
    b, pad_len = tokens.shape
    state = init_decode_state(cfg, b, max_seq, spiking)
    length = jnp.asarray(length, jnp.int32)

    def body(carry, i):
        st, last = carry
        logits, new_st = decode_step(
            cfg, params, st, tokens[:, i],
            jnp.broadcast_to(i.astype(jnp.int32), (b,)), spiking)
        live = i < length                                   # (B,)

        def sel(new, old):
            # leaves are (n_groups, B, ...): mask on the slot axis (1)
            m = live.reshape((1, b) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)
        st = jax.tree.map(sel, new_st, st)
        last = jnp.where(live[:, None], logits, last)
        return (st, last), None

    init_last = jnp.zeros((b, cfg.vocab), jnp.float32)
    (state, last), _ = jax.lax.scan(
        body, (state, init_last), jnp.arange(pad_len))
    return last, state


# ----------------------------------------------- slot-state surgery (serve)
def _check_slot_leaf(path, leaf, n_slots: int):
    if leaf is None:
        return
    if getattr(leaf, "ndim", 0) < 2 or leaf.shape[1] != n_slots:
        raise ValueError(
            f"decode-state leaf at {jax.tree_util.keystr(path)} has shape "
            f"{getattr(leaf, 'shape', None)} — not slot-batched "
            f"(expected (n_groups, {n_slots}, ...)). The decode-state "
            f"contract (init_decode_state) puts the slot batch at axis 1 "
            f"of every leaf; refusing to shape-guess.")


def reset_slot_state(state: list, slot: int, n_slots: int) -> list:
    """Zero slot `slot` of every decode-state leaf, STRUCTURALLY.

    Uses the documented layout (every leaf is ``(n_groups, n_slots,
    ...)``; see `init_decode_state`) instead of matching any pytree leaf
    whose shape[1] happens to equal n_slots — a coincidental dimension
    (e.g. 4 heads in a 4-slot pool on an unstacked aux leaf) must not be
    silently zeroed, and a non-conforming leaf must not be silently
    skipped (stale state leaking into the slot's next occupant). Any
    leaf that violates the contract raises loudly.

    In spiking mode this is O(d) per layer (the SDSA status vectors) —
    the cheap slot turnover the serve loop's docstring advertises; the
    dense KV cache pays its size.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    for path, leaf in flat:
        _check_slot_leaf(path, leaf, n_slots)
    return jax.tree.map(
        lambda x: x.at[:, slot].set(jnp.zeros_like(x[:, slot])), state)


def merge_slot_state(pool_state: list, single_state: list,
                     slot: jax.Array) -> list:
    """Scatter a freshly-prefilled single-request state (leaves
    ``(n_groups, 1, ...)``) into slot `slot` of the pool state (leaves
    ``(n_groups, n_slots, ...)``). Overwrites EVERY leaf of the slot, so
    admission never inherits a previous occupant's KV rows or SDSA
    status — merge IS the reset. Jit this with donate_argnums=(0,) to
    update the pool in place."""
    return jax.tree.map(
        lambda pool, one: pool.at[:, slot].set(one[:, 0].astype(pool.dtype)),
        pool_state, single_state)


def param_count(cfg: LMConfig) -> int:
    tree = abstract_params(cfg)
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_param_count(cfg: LMConfig) -> int:
    """Active params per token (MoE top-k instead of all experts)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    tree = abstract_params(cfg)
    import numpy as np
    expert_leaves = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = [getattr(k, "key", None) for k in path]
        if any(k in ("w_gate", "w_up", "w_down") for k in keys) and \
           any(k == "moe" for k in keys):
            expert_leaves += int(np.prod(leaf.shape))
    active_frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - expert_leaves * (1 - active_frac))
