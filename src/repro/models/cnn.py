"""The paper's own SCNN workloads: spiking VGG11, ResNet18, SegNet.

Faithful to the evaluated stack (Sec. IV): LIF neurons (tau=0.5), T=4
timesteps, direct-coded first layer (OPT1), event-driven-equivalent convs
(OPT2), and an EAFC avgpool+FC head (OPT3). Residual connections add
membrane drives before the fire stage — the Residual Spike SRAM path of
Fig. 3.

Every conv — stem, strided downsamples, and the segmentation decoder's
transposed convs — routes through the backend registry (`econv` / `tconv`
ops) with micro-timesteps folded into the batch axis, so the whole stack
is parity-tested, benchmarked, and differentiable per backend. The first
layer eats the direct-coded (multi-bit) drive: the ref/pallas backends are
exact for it; the per-event scatter (``econv=jnp``) assumes binary inputs
and is only meaningful from the first spiking layer on (OPT1 territory).

`apply(..., collect_stats=True)` returns per-layer spike maps for the
Fig. 2 / Fig. 7 sparsity + APEC benchmarks.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig, CNNLayer
from repro.core.direct_coding import quantize
from repro.core.econv import conv_transpose, econv
from repro.core.eafc import eafc
from repro.core.events import EventTensor, max_pool_events
from repro.core.lif import LIFConfig
from .layers import hybrid_scope, lif_fire_events

Params = Dict[str, Any]


def _fire(drive: jax.Array, lif: LIFConfig,
          packed: bool = False) -> EventTensor:
    """Fire stage with fused metadata emission: spikes + occupancy leave
    the LIF together (`lif_scan_occ`), so the next conv's event kernel
    consumes the carried map instead of re-scanning the activation.
    `packed=True` emits uint32 words as the canonical payload (no f32
    spike tensor between layers; inference-only)."""
    return lif_fire_events(drive, lif, packed=packed)


def _conv_seq(s, w: jax.Array, stride: int = 1) -> jax.Array:
    """(T,B,H,W,C) drive through the registry `econv` op, T folded into
    the batch (one conv on T*B images instead of a vmap of T convs).
    `s` may be an `EventTensor` — the (T,B)->(T*B) fold preserves the
    trailing channel axis, so the carried map survives into the conv."""
    t, b = s.shape[:2]
    out = econv(s.reshape((t * b,) + s.shape[2:]), w, stride=stride)
    return out.reshape((t, b) + out.shape[1:])


def _tconv_seq(s, w: jax.Array, stride: int) -> jax.Array:
    """(T,B,H,W,C) spikes through the registry `tconv` (transposed conv)."""
    t, b = s.shape[:2]
    out = conv_transpose(s.reshape((t * b,) + s.shape[2:]), w, stride=stride)
    return out.reshape((t, b) + out.shape[1:])

# ------------------------------------------------------- model definitions
VGG11_LAYERS: Tuple[CNNLayer, ...] = (
    CNNLayer("conv", 64), CNNLayer("maxpool"),
    CNNLayer("conv", 128), CNNLayer("maxpool"),
    CNNLayer("conv", 256), CNNLayer("conv", 256), CNNLayer("maxpool"),
    CNNLayer("conv", 512), CNNLayer("conv", 512), CNNLayer("maxpool"),
    CNNLayer("conv", 512), CNNLayer("conv", 512),
)

SEGNET_LAYERS: Tuple[CNNLayer, ...] = (   # 8C3-16C3-32C3-32C3-16TC3-2TC3
    CNNLayer("conv", 8), CNNLayer("conv", 16, stride=2),
    CNNLayer("conv", 32, stride=2), CNNLayer("conv", 32),
    CNNLayer("tconv", 16, stride=2), CNNLayer("tconv", 2, stride=2),
)

RESNET18_STAGES = ((64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2))


def _conv_init(key, k: int, ci: int, co: int) -> jax.Array:
    scale = (2.0 / (k * k * ci)) ** 0.5
    return jax.random.normal(key, (k, k, ci, co), jnp.float32) * scale


# ------------------------------------------------------------------- VGG11
def vgg11_init(cfg: CNNConfig, key) -> Params:
    p: Params = {"convs": []}
    ci = cfg.in_ch
    keys = jax.random.split(key, len(VGG11_LAYERS) + 1)
    spatial = cfg.img
    for i, layer in enumerate(VGG11_LAYERS):
        if layer.kind == "conv":
            p["convs"].append(_conv_init(keys[i], layer.kernel, ci, layer.out_ch))
            ci = layer.out_ch
        else:
            p["convs"].append(None)
            spatial //= 2
    pooled = spatial // cfg.fc_pool
    p["fc"] = jax.random.normal(
        keys[-1], (pooled * pooled * ci, cfg.n_classes), jnp.float32) \
        * (1.0 / (pooled * pooled * ci)) ** 0.5
    return p


def vgg11_apply(cfg: CNNConfig, p: Params, x: jax.Array,
                collect_stats: bool = False):
    """x: (B, H, W, C) image -> logits (B, n_classes) [, spike maps]."""
    with hybrid_scope(cfg.spiking):
        return _vgg11_body(cfg, p, x, collect_stats)


def _vgg11_body(cfg, p, x, collect_stats):
    lif = LIFConfig(decay=cfg.spiking.lif_decay, v_th=cfg.spiking.lif_vth)
    t = cfg.spiking.t_steps
    q, scale = quantize(x, cfg.direct_coding_bits)
    s = jnp.broadcast_to((q.astype(jnp.float32) * scale)[None],
                         (t,) + x.shape)   # direct-coded drive, each step
    packed = getattr(cfg.spiking, "packed", False)
    stats: List[jax.Array] = []
    for layer, w in zip(VGG11_LAYERS, p["convs"]):
        if layer.kind == "maxpool":
            # pooling keeps the carried map alive (tile-map dilation);
            # a packed payload pools its words bitwise-OR.
            s = max_pool_events(s, layer.pool)
            continue
        drive = _conv_seq(s, w)
        s = _fire(drive, lif, packed)     # binary spikes + occupancy map
        if collect_stats:
            stats.append(s.dense())
    # EAFC head (OPT3): event-driven avgpool+FC over every timestep.
    # `.dense()` is the one explicit unpack point for a packed payload
    # (eafc has no packed backend).
    logits = jnp.mean(jax.vmap(lambda st: eafc(st, p["fc"],
                                               cfg.fc_pool))(s.dense()),
                      axis=0)
    return (logits, stats) if collect_stats else logits


# ---------------------------------------------------------------- ResNet18
def resnet18_init(cfg: CNNConfig, key) -> Params:
    keys = iter(jax.random.split(key, 64))
    p: Params = {"stem": _conv_init(next(keys), 3, cfg.in_ch, 64), "blocks": []}
    ci = 64
    for co, n_blocks, stride in RESNET18_STAGES:
        for b in range(n_blocks):
            s0 = stride if b == 0 else 1
            blk = {
                "conv1": _conv_init(next(keys), 3, ci, co),
                "conv2": _conv_init(next(keys), 3, co, co),
                "stride": s0,
            }
            if s0 != 1 or ci != co:
                blk["proj"] = _conv_init(next(keys), 1, ci, co)
            p["blocks"].append(blk)
            ci = co
    pooled = cfg.img // 8 // cfg.fc_pool
    p["fc"] = jax.random.normal(
        next(keys), (pooled * pooled * ci, cfg.n_classes), jnp.float32) \
        * (1.0 / (pooled * pooled * ci)) ** 0.5
    return p


def resnet18_apply(cfg: CNNConfig, p: Params, x: jax.Array,
                   collect_stats: bool = False):
    with hybrid_scope(cfg.spiking):
        return _resnet18_body(cfg, p, x, collect_stats)


def _resnet18_body(cfg, p, x, collect_stats):
    lif = LIFConfig(decay=cfg.spiking.lif_decay, v_th=cfg.spiking.lif_vth)
    t = cfg.spiking.t_steps
    q, scale = quantize(x, cfg.direct_coding_bits)
    xin = jnp.broadcast_to((q.astype(jnp.float32) * scale)[None],
                           (t,) + x.shape)
    drive = _conv_seq(xin, p["stem"])
    packed = getattr(cfg.spiking, "packed", False)
    s = _fire(drive, lif, packed)
    stats: List[jax.Array] = [s.dense()] if collect_stats else []
    for blk in p["blocks"]:
        st0 = blk["stride"]
        h = _conv_seq(s, blk["conv1"], stride=st0)
        h = _fire(h, lif, packed)
        h2 = _conv_seq(h, blk["conv2"])
        # Residual Spike SRAM path: shortcut drives added pre-fire (the
        # sum is membrane drive, not spikes — metadata re-emits at _fire).
        # The identity shortcut is a drive-summand, so it goes through
        # `.dense()` — an explicit unpack, never a silent densify.
        short = _conv_seq(s, blk["proj"], stride=st0) if "proj" in blk \
            else s.dense()
        s = _fire(h2 + short, lif, packed)
        if collect_stats:
            stats.append(s.dense())
    logits = jnp.mean(jax.vmap(lambda ss: eafc(ss, p["fc"],
                                               cfg.fc_pool))(s.dense()),
                      axis=0)
    return (logits, stats) if collect_stats else logits


# ------------------------------------------------------------------ SegNet
def segnet_init(cfg: CNNConfig, key) -> Params:
    keys = iter(jax.random.split(key, 16))
    p: Params = {"convs": []}
    ci = cfg.in_ch
    for layer in SEGNET_LAYERS:
        p["convs"].append(_conv_init(next(keys), layer.kernel, ci,
                                     layer.out_ch))
        ci = layer.out_ch
    return p


def segnet_apply(cfg: CNNConfig, p: Params, x: jax.Array,
                 collect_stats: bool = False):
    """x: (B, H, W, C) -> per-pixel logits (B, H, W, 2)."""
    with hybrid_scope(cfg.spiking):
        return _segnet_body(cfg, p, x, collect_stats)


def _segnet_body(cfg, p, x, collect_stats):
    lif = LIFConfig(decay=cfg.spiking.lif_decay, v_th=cfg.spiking.lif_vth)
    t = cfg.spiking.t_steps
    q, scale = quantize(x, cfg.direct_coding_bits)
    s = jnp.broadcast_to((q.astype(jnp.float32) * scale)[None], (t,) + x.shape)
    packed = getattr(cfg.spiking, "packed", False)
    stats: List[jax.Array] = []
    mp_total = jnp.zeros(())
    for i, (layer, w) in enumerate(zip(SEGNET_LAYERS, p["convs"])):
        last = i == len(SEGNET_LAYERS) - 1
        if layer.kind == "conv":
            drive = _conv_seq(s, w, stride=layer.stride)
        else:  # transposed conv (decoder upsampling): registry `tconv` op
            drive = _tconv_seq(s, w, stride=layer.stride)
        if last:
            return (jnp.mean(drive, axis=0), stats) if collect_stats \
                else jnp.mean(drive, axis=0)
        s = _fire(drive, lif, packed)
        if collect_stats:
            stats.append(s.dense())
    raise AssertionError("unreachable")
