"""AER (address-event representation) utilities — the Sparse Core analog.

The Sparse Core (Sec. III, Fig. 4) fetches spike words, extracts one valid
event position per cycle via a lowest-set-bit priority encoder + LUT, and
pushes (position) entries into the AER FIFO that triggers the EPE Core.

On TPU we keep two views of the same information:
  * a dense binary tensor (what the MXU paths consume), and
  * packed words + per-tile occupancy (what the Pallas kernels consume).
This module provides the reference event-filter semantics (for tests and
for the cycle cost model, which needs exact per-word event counts), plus
sparsity instrumentation used throughout the benchmarks.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .spikes import (build_csr, pack_spikes, pack_spikes_padded,
                     packed_width, popcount, tile_occupancy, unpack_spikes)


class EventStream(NamedTuple):
    """Padded AER stream: linear addresses + validity mask."""
    addr: jax.Array   # (max_events,) int32 linear index into the flat map
    valid: jax.Array  # (max_events,) bool


def fast_event_filter(word: jax.Array, width: int = 32) -> jax.Array:
    """Reference of the hardware fast event filter on one packed word.

    Emits the bit positions of set bits in ascending order (lowest active
    bit first — the one-hot + LUT scheme), padded with -1. Static output
    length = `width`.
    """
    positions = jnp.arange(width, dtype=jnp.int32)
    set_mask = ((word >> positions.astype(jnp.uint32)) & jnp.uint32(1)).astype(bool)
    order = jnp.argsort(~set_mask, stable=True)      # set bits first, ascending
    sorted_pos = jnp.where(jnp.sort(~set_mask) == 0, positions[order], -1)
    return sorted_pos.astype(jnp.int32)


def to_event_stream(s: jax.Array, max_events: int) -> EventStream:
    """Flatten a binary tensor into a padded AER stream (raster order)."""
    flat = s.reshape(-1)
    (lin,) = jnp.nonzero(flat, size=max_events, fill_value=-1)
    return EventStream(addr=lin.astype(jnp.int32), valid=lin >= 0)


def events_per_position(s: jax.Array) -> jax.Array:
    """(..., P, C) -> (..., P) active-channel counts per spatial position
    ("spike events ... collected at the same spatial location", Alg. 1 l.9).
    """
    return jnp.sum(s.astype(jnp.int32), axis=-1)


def word_event_counts(s: jax.Array, axis: int = -1) -> jax.Array:
    """Popcount per packed 32-channel word (Spike SRAM word granularity)."""
    return popcount(pack_spikes(s, axis=axis))


# ======================================================================
# EventTensor — the full-event inter-layer carrier
# ======================================================================
# The paper's architecture keeps event *metadata* flowing alongside the
# spikes: the AER FIFO is filled by the producer (the fire stage), never
# re-derived by scanning the dense activation. `EventTensor` is that
# contract on TPU: binary spikes plus the per-tile occupancy map the fused
# LIF kernel emitted while writing them (and, lazily, the map's `TileCSR`
# compaction), registered as a pytree so it flows through jit/shard_map
# between layers. Consumers (`kernels.ops` / the dispatch entry points)
# take it in place of a dense spike tensor and skip their own occupancy
# pre-pass.
#
# Occupancy contract
# ------------------
# `occupancy[i, j]` covers tile (i, j) of the zero-padded
# (rows, K) = (prod(shape[:-1]), shape[-1]) flattening of `spikes` under
# `tiling` — exactly what `kernels.ops.padded_occupancy` computes and what
# every matmul-form consumer tiles by. Counts are UPPER BOUNDS with an
# exact zero set: occupancy[i, j] == 0 guarantees the tile holds no
# events (consumers only branch on > 0), while propagated maps
# (`window_occupancy`) may over-count. A map built for a different tiling
# or tile grid is rejected loudly (`occupancy_for` raises) — silently
# gating the wrong tiles would corrupt outputs.
#
# `chunks` is the same information at the producer's native granularity —
# per (CHUNK=8-row, tile_k-lane) block counts, shape (MT*16, KT), the raw
# per-chunk popcounts the fused LIF kernel emits before they are
# aggregated 16:1 into `occupancy`. It exists so window PROPAGATION
# (im2col, pooling) can dilate at 8-row resolution instead of 128-row
# tiles: a tile-granular dilation marks ~3x the occupied tiles and hands
# the compacted kernel back the grid steps the carried route just saved.
# Consumers never read `chunks`; only propagation does.
#
# When a carried map survives a transform, and when it must be dropped
# ----------------------------------------------------------------------
# * reshapes that PRESERVE the trailing (channel/feature) axis — merging
#   or splitting lead axes, e.g. (T,B,H,W,C)->(T*B,H,W,C) or
#   (T,B,8,8,D)->(T,B,64,D) — keep rows and K intact: the map survives
#   (`EventTensor.reshape` carries it).
# * reshapes that change the trailing axis (head splits, flatten-to-1D),
#   slicing, padding, or any transform that moves events to new
#   addresses: the map is DROPPED (occupancy=None) — consumers re-derive
#   or run dense. `EventTensor.reshape` applies this rule automatically.
# * local window transforms with raster-monotone address maps (conv
#   im2col patches, pooling, strided patch extraction): the map is
#   *propagated* on tile granularity (`window_occupancy`) — a
#   conservative interval dilation on the tiny (MT,) tile map, never a
#   re-scan of the spike tensor.
# * non-spike transforms (matmul outputs, membrane sums): the result is
#   not binary — it is not an EventTensor at all until the next fire
#   stage re-emits one.
#
# Packed payload (PR 7)
# ---------------------
# `packed` optionally replaces `spikes` as the canonical payload: uint32
# words along the channel axis (bit i of word w = channel w*32+i, the
# `spikes.pack_spikes` little-endian layout, zero-padded to whole words),
# shape = spikes.shape[:-1] + (ceil(K/32),). In packed-only mode
# (spikes=None) the logical shape/dtype live in the `feature_size` /
# `spike_dtype` static aux, and NOTHING densifies silently: `.dense()` is
# the one explicit unpack point (what `as_spikes` calls for the ops with
# no packed backend), dispatch routes packed calls only to backends
# declaring `payload="packed"`, and the fallback chain unpacks via an
# attributed shim. Pack survival mirrors the occupancy rules: last-axis-
# preserving reshapes keep the words (rows regroup, bits don't move);
# last-axis-changing reshapes RAISE on a packed-only tensor (call
# `.dense()` first — the loud spelling of the densify); spatial max-pool
# pools words bitwise-OR (the per-bit max of binary lanes), so the packed
# payload survives pooling with the maps. The packed payload is
# forward-only: it is integer-typed aux under autodiff (float0
# cotangent); training paths carry dense spikes.


CHUNK = 8    # fine-map row granularity: the LIF kernel's block_m


@jax.tree_util.register_pytree_node_class
class EventTensor:
    """Binary spikes + producer-emitted per-tile occupancy (see module
    notes for the contract). `occupancy=None` is a valid degenerate state
    (metadata lost to a transform); consumers then re-derive. `chunks` is
    the optional fine (8-row) map used only by window propagation."""

    __slots__ = ("spikes", "occupancy", "tiling", "chunks", "packed",
                 "feature_size", "spike_dtype", "_csr_cache")

    def __init__(self, spikes: Optional[jax.Array],
                 occupancy: Optional[jax.Array],
                 tiling: Tuple[int, int] = (128, 128),
                 chunks: Optional[jax.Array] = None,
                 packed: Optional[jax.Array] = None,
                 feature_size: Optional[int] = None,
                 spike_dtype=None):
        self.spikes = spikes
        self.occupancy = occupancy
        self.tiling = tuple(tiling)
        self.chunks = chunks
        self.packed = packed
        self._csr_cache = None
        if spikes is None and packed is None:
            raise ValueError("EventTensor needs a payload: spikes, packed, "
                             "or both")
        if spikes is not None and hasattr(spikes, "shape"):
            feature_size = spikes.shape[-1]
            spike_dtype = spikes.dtype
        elif feature_size is None:
            raise ValueError(
                "packed-only EventTensor needs feature_size= (the logical "
                "channel count; the word axis alone is ambiguous)")
        self.feature_size = feature_size
        self.spike_dtype = jnp.dtype(spike_dtype or jnp.float32)
        if packed is not None and hasattr(packed, "shape"):
            if packed.dtype != jnp.uint32:
                raise ValueError(
                    f"EventTensor packed payload must be uint32 words, got "
                    f"{packed.dtype}")
            want_w = packed_width(self.feature_size)
            if packed.shape[-1] != want_w:
                raise ValueError(
                    f"EventTensor packed width {packed.shape[-1]} words "
                    f"does not cover feature_size {self.feature_size} "
                    f"(want {want_w})")
            if spikes is not None and hasattr(spikes, "shape") \
                    and tuple(packed.shape[:-1]) != tuple(spikes.shape[:-1]):
                raise ValueError(
                    f"EventTensor packed lead shape "
                    f"{tuple(packed.shape[:-1])} does not match spikes "
                    f"{tuple(spikes.shape[:-1])}")
        if occupancy is not None and hasattr(occupancy, "shape") \
                and self._has_shapes():
            want = self.expected_map_shape(*self.tiling)
            if tuple(occupancy.shape) != want:
                raise ValueError(
                    f"EventTensor occupancy shape {tuple(occupancy.shape)} "
                    f"does not cover spikes {tuple(self.shape)} under "
                    f"tiling {self.tiling} (expected {want})")
            if chunks is not None and tuple(chunks.shape) != (
                    want[0] * (self.tiling[0] // CHUNK), want[1]):
                raise ValueError(
                    f"EventTensor chunk map {tuple(chunks.shape)} does not "
                    f"refine occupancy {want} at {CHUNK}-row granularity")

    def _has_shapes(self) -> bool:
        payload = self.spikes if self.spikes is not None else self.packed
        return hasattr(payload, "shape")

    # ------------------------------------------------------------ pytree
    def tree_flatten(self):
        return ((self.spikes, self.occupancy, self.chunks, self.packed),
                (self.tiling, self.feature_size, self.spike_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        spikes, occupancy, chunks, packed = children
        obj = object.__new__(cls)
        obj.spikes = spikes
        obj.occupancy = occupancy
        obj.tiling = aux[0]
        obj.chunks = chunks
        obj.packed = packed
        obj.feature_size = aux[1]
        obj.spike_dtype = aux[2]
        obj._csr_cache = None
        return obj

    # ------------------------------------------------------- array facade
    @property
    def shape(self):
        if self.spikes is not None:
            return self.spikes.shape
        return self.packed.shape[:-1] + (self.feature_size,)

    @property
    def dtype(self):
        return self.spikes.dtype if self.spikes is not None \
            else self.spike_dtype

    @property
    def ndim(self):
        return self.spikes.ndim if self.spikes is not None \
            else self.packed.ndim

    @property
    def is_packed(self) -> bool:
        """True when the canonical payload is the uint32 words (no dense
        spikes carried — the no-f32-between-layers mode)."""
        return self.spikes is None

    @property
    def rows(self) -> int:
        return int(np.prod(self.shape[:-1]))

    def expected_map_shape(self, tile_m: int, tile_k: int) -> Tuple[int, int]:
        k = self.shape[-1]
        return (-(-self.rows // tile_m), -(-k // tile_k))

    def __repr__(self):
        occ = None if self.occupancy is None else tuple(self.occupancy.shape)
        payload = "packed" if self.is_packed else "spikes"
        return (f"EventTensor({payload}={tuple(self.shape)}, occupancy={occ}, "
                f"tiling={self.tiling})")

    # ------------------------------------------------------------- carrier
    @classmethod
    def from_spikes(cls, spikes: jax.Array,
                    tiling: Tuple[int, int] = (128, 128),
                    pack: bool = False) -> "EventTensor":
        """Re-derive the map from dense spikes (ONE standalone pre-pass,
        at chunk granularity; the tile map is its 16:1 aggregation) — the
        entry point for producers without fused emission. Prefer the
        fused `lif_scan_occ` dispatch op, which emits the maps for free.
        `pack=True` additionally packs the spikes to uint32 words and
        makes THEM the canonical payload (packed-only tensor, dense view
        dropped) — the eager-side mirror of `lif_fire_events(packed=True)`.
        """
        tm, tk = tiling
        k = spikes.shape[-1]
        s2 = spikes.reshape(-1, k)
        s2 = jnp.pad(s2, (((0, (-s2.shape[0]) % tm), (0, (-k) % tk))))
        chunks = tile_occupancy(s2, CHUNK, tk)
        per = tm // CHUNK
        occ = jnp.sum(chunks.reshape(-1, per, chunks.shape[1]), axis=1)
        if pack:
            words = jax.lax.stop_gradient(
                pack_spikes_padded(spikes, axis=-1))
            return cls(None, jax.lax.stop_gradient(occ), tiling,
                       jax.lax.stop_gradient(chunks), packed=words,
                       feature_size=k, spike_dtype=spikes.dtype)
        return cls(spikes, jax.lax.stop_gradient(occ), tiling,
                   jax.lax.stop_gradient(chunks))

    def dense(self) -> jax.Array:
        """The dense spike view — THE explicit densify point for a
        packed-only tensor (unpack words, slice the logical channels,
        cast to the recorded spike dtype). Never called implicitly by
        dispatch routing; ops with no packed backend reach it through
        `as_spikes`."""
        if self.spikes is not None:
            return self.spikes
        out = unpack_spikes(self.packed, axis=-1, dtype=self.spike_dtype)
        return out[..., :self.feature_size]

    def occupancy_for(self, tile_m: int, tile_k: int) -> Optional[jax.Array]:
        """The carried map, validated for a consumer tiling — None when no
        map is carried, ValueError (loud, never silent) when the carried
        map was built for a different tiling or tile grid."""
        if self.occupancy is None:
            return None
        if (tile_m, tile_k) != self.tiling:
            raise ValueError(
                f"EventTensor occupancy built for tiling {self.tiling} "
                f"used with tiling {(tile_m, tile_k)}; drop to .spikes or "
                f"rebuild with from_spikes")
        want = self.expected_map_shape(tile_m, tile_k)
        if tuple(self.occupancy.shape) != want:
            raise ValueError(
                f"EventTensor occupancy shape "
                f"{tuple(self.occupancy.shape)} does not match tile grid "
                f"{want} for spikes {tuple(self.shape)}")
        return self.occupancy

    def csr(self, tile_m: int = 128, tile_k: int = 128):
        """Lazily build (and cache per instance/trace) the `TileCSR`
        compaction of the carried map; None when no map is carried."""
        occ = self.occupancy_for(tile_m, tile_k)
        if occ is None:
            return None
        if self._csr_cache is None:
            self._csr_cache = build_csr(occ, tile_m, tile_k)
        return self._csr_cache

    def reshape(self, *shape) -> "EventTensor":
        """Reshape the spikes; the carried maps survive iff the trailing
        axis is preserved (rows regroup, addresses don't move — see the
        module contract), else they are dropped. A packed payload follows
        the same rule — and on a packed-ONLY tensor a trailing-axis change
        RAISES instead of silently unpacking (call `.dense()` first)."""
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        shape = tuple(int(d) for d in shape)
        k = self.shape[-1]
        neg = [i for i, d in enumerate(shape) if d < 0]
        if neg:
            known = int(np.prod([d for d in shape if d >= 0]))
            shape = tuple(int(np.prod(self.shape)) // max(known, 1)
                          if d < 0 else d for d in shape)
        keep = bool(shape) and shape[-1] == k
        if self.spikes is None and not keep:
            raise ValueError(
                f"reshape to {shape} changes the packed trailing axis "
                f"({k}); a packed-only EventTensor cannot re-bucket bits "
                f"— call .dense() (the explicit unpack) first")
        spikes = None if self.spikes is None else self.spikes.reshape(shape)
        packed = self.packed
        if packed is not None:
            packed = packed.reshape(shape[:-1] + (packed.shape[-1],)) \
                if keep else None
        return EventTensor(spikes, self.occupancy if keep else None,
                           self.tiling, self.chunks if keep else None,
                           packed=packed, feature_size=k,
                           spike_dtype=self.spike_dtype)

    def astype(self, dtype) -> "EventTensor":
        """Cast the dense view's dtype. On a packed-only tensor the words
        are dtype-free — only the recorded unpack dtype changes."""
        spikes = None if self.spikes is None else self.spikes.astype(dtype)
        return EventTensor(spikes, self.occupancy, self.tiling, self.chunks,
                           packed=self.packed,
                           feature_size=self.feature_size,
                           spike_dtype=dtype)


def as_spikes(x):
    """Dense view of an array-or-EventTensor operand (for a packed-only
    tensor this is the explicit `.dense()` unpack — the documented
    densify point for ops without a packed backend)."""
    return x.dense() if isinstance(x, EventTensor) else x


# ----------------------------------------------- occupancy propagation
def window_occupancy(et: EventTensor, window: Tuple[int, int], stride: int,
                     out_hw: Tuple[int, int], out_k: int,
                     padding: str = "SAME"):
    """Propagate a carried map through a raster-monotone spatial window
    transform (im2col patch extraction, pooling) WITHOUT touching the
    dense tensor.

    `et.spikes` is (N, H, W, C)-shaped (any lead axes folded into N);
    the transform maps output position (n, y, x) onto the input window
    anchored at n*H*W + y*stride*W + x*stride with spatial extent
    `window`. Each output row block's event bound is the interval sum of
    the input CHUNK counts its windows can reach (8-row granularity — the
    fused LIF emission's native resolution, via `et.chunks`, falling back
    to the 128-row tile map when only that is carried): one cumsum over
    the tiny map, two gathers. Counts over-approximate, but a zero is
    exact: if no input chunk in reach holds events, every output row in
    the block is all-zero. Returns (tile map (MT_out, KT_out), chunk map
    (MT_out*16, KT_out)) or (None, None).
    """
    occ = et.occupancy_for(*et.tiling)
    if occ is None or et.ndim < 4:
        return None, None
    kh, kw = window
    h, w_, _ = et.shape[-3:]
    n = int(np.prod(et.shape[:-3]))
    ho, wo = out_hw
    tm, tk = et.tiling
    per = tm // CHUNK
    out_rows = n * ho * wo
    mt_out = -(-out_rows // tm)
    kt_out = -(-out_k // tk)
    # Input counts at chunk granularity (prefer the carried fine map; a
    # coarse-only carrier spreads each tile's count over its 16 chunks —
    # still conservative, just a wider dilation).
    fine = et.chunks if et.chunks is not None else occ
    xp = jnp if isinstance(fine, jax.core.Tracer) else np
    fine = xp.asarray(fine)
    if et.chunks is not None:
        cnt8 = xp.sum(fine, axis=1)                        # (MT_in*16,)
    else:
        cnt8 = xp.repeat(xp.sum(fine, axis=1), per)
    in_chunks = cnt8.shape[0]
    # The window of output position (n, y, x) covers input rows
    # [y*stride - pad_top, y*stride - pad_top + kh - 1] (likewise cols),
    # so the raster reach around the anchor a = (y*stride)*w_ + x*stride
    # is ASYMMETRIC: back by exactly the leading padding, forward by the
    # rest of the window. XLA's SAME convention puts floor(pad/2) first;
    # VALID pads nothing, so windows only extend forward. The previous
    # symmetric (k-1) bound marked up to k-1 rows of out-of-image chunks
    # occupied behind every straddling window — on stride > 1 pooling and
    # non-divisible H/W that handed the compacted kernel back the very
    # boundary tiles the carried map had excluded.
    if padding == "SAME":
        pad_top = max((ho - 1) * stride + kh - h, 0) // 2
        pad_left = max((wo - 1) * stride + kw - w_, 0) // 2
    else:                                    # VALID: window starts at anchor
        pad_top = pad_left = 0
    back_halo = pad_top * w_ + pad_left
    fwd_halo = (kh - 1 - pad_top) * w_ + (kw - 1 - pad_left)
    # Anchor interval per output chunk: anchors are monotone in raster
    # order, so chunk c's reach is [anchor(first row)-halo,
    # anchor(last row)+halo], clamped to the owning image (windows never
    # cross image boundaries — unclamped intervals would bleed a
    # neighbor image's events into this one's boundary tiles).
    # Concrete maps take the numpy path (chosen above): they are a few
    # hundred entries, and ~20 eager jnp dispatches would cost more than
    # the dense pre-pass this propagation replaces.
    out_chunks = mt_out * per
    q_lo = CHUNK * xp.arange(out_chunks)
    q_hi = xp.minimum(q_lo + CHUNK - 1, out_rows - 1)
    q_lo = xp.minimum(q_lo, out_rows - 1)    # zero-pad tail chunks below

    def reach(q, sign):
        n_i, rem = q // (ho * wo), q % (ho * wo)
        y, x = rem // wo, rem % wo
        a = n_i * (h * w_) + (y * stride) * w_ + x * stride
        if sign < 0:
            return xp.maximum(a - back_halo, n_i * (h * w_))
        return xp.minimum(a + fwd_halo, (n_i + 1) * (h * w_) - 1)

    csum = xp.concatenate(
        [xp.zeros((1,), cnt8.dtype), xp.cumsum(cnt8)])
    lo = xp.clip(reach(q_lo, -1) // CHUNK, 0, in_chunks)
    hi = xp.clip(reach(q_hi, +1) // CHUNK + 1, 0, in_chunks)
    live = (CHUNK * xp.arange(out_chunks)) < out_rows
    bound = ((csum[hi] - csum[lo]) * live).astype(xp.int32)
    chunks_out = xp.broadcast_to(bound[:, None], (out_chunks, kt_out))
    occ_out = xp.sum(chunks_out.reshape(mt_out, per, kt_out), axis=1)
    if xp is np:
        return jnp.asarray(occ_out), jnp.asarray(chunks_out)
    return occ_out, chunks_out


def conv_patch_occupancy(et: EventTensor, w_shape: Tuple[int, ...],
                         stride: int, padding: str) -> Optional[jax.Array]:
    """Carried map for the im2col patch matrix of a conv over `et`
    ((N,H,W,C) spikes, HWIO weights): rows = output positions, K =
    C*kh*kw. None when no map is carried or the geometry is unsupported
    (the consumer then re-derives)."""
    if et.occupancy is None or et.ndim < 4:
        return None
    kh, kw, ci, co = w_shape
    h, w_ = et.shape[-3:-1]
    if padding == "SAME":
        ho, wo = -(-h // stride), -(-w_ // stride)
    elif padding == "VALID":
        ho, wo = (h - kh) // stride + 1, (w_ - kw) // stride + 1
    else:
        return None
    if ho <= 0 or wo <= 0:
        return None
    occ, _ = window_occupancy(et, (kh, kw), stride, (ho, wo), ci * kh * kw,
                              padding)
    return occ


def max_pool_events(et, pool: int):
    """Spatial max-pool of (..., H, W, C) spikes with the carried maps
    propagated (chunk-granular window dilation) instead of dropped.
    Accepts a dense array too (returns a dense array). A packed-only
    tensor pools its uint32 words bitwise-OR — per bit, OR of binary
    lanes IS the max — so the payload stays packed through pooling."""
    if isinstance(et, EventTensor) and et.is_packed:
        p = et.packed
        window = (1,) * (p.ndim - 3) + (pool, pool, 1)
        pooled_p = jax.lax.reduce_window(
            p, jnp.uint32(0), jax.lax.bitwise_or, window, window, "VALID")
        h, w_, _ = et.shape[-3:]
        occ = chunks = None
        if et.occupancy is not None and et.ndim >= 4:
            occ, chunks = window_occupancy(et, (pool, pool), pool,
                                           (h // pool, w_ // pool),
                                           et.feature_size, padding="VALID")
        return EventTensor(None, occ, et.tiling, chunks, packed=pooled_p,
                           feature_size=et.feature_size,
                           spike_dtype=et.spike_dtype)
    s = as_spikes(et)
    window = (1,) * (s.ndim - 3) + (pool, pool, 1)
    pooled = jax.lax.reduce_window(s, -jnp.inf, jax.lax.max, window, window,
                                   "VALID")
    if not isinstance(et, EventTensor) or et.occupancy is None \
            or et.ndim < 4:
        if isinstance(et, EventTensor):
            return EventTensor(pooled, None, et.tiling)
        return pooled
    h, w_, c = s.shape[-3:]
    occ, chunks = window_occupancy(et, (pool, pool), pool,
                                   (h // pool, w_ // pool), c,
                                   padding="VALID")
    return EventTensor(pooled, occ, et.tiling, chunks)


def layer_sparsity_report(name: str, s: jax.Array) -> dict:
    """Instrumentation record used by the Fig. 2 / Fig. 7 benchmarks."""
    total = float(jnp.asarray(s.size))
    active = float(jnp.sum(s.astype(jnp.float32)))
    return {
        "layer": name,
        "total_sites": total,
        "events": active,
        "sparsity": 1.0 - active / max(total, 1.0),
    }
