"""AER (address-event representation) utilities — the Sparse Core analog.

The Sparse Core (Sec. III, Fig. 4) fetches spike words, extracts one valid
event position per cycle via a lowest-set-bit priority encoder + LUT, and
pushes (position) entries into the AER FIFO that triggers the EPE Core.

On TPU we keep two views of the same information:
  * a dense binary tensor (what the MXU paths consume), and
  * packed words + per-tile occupancy (what the Pallas kernels consume).
This module provides the reference event-filter semantics (for tests and
for the cycle cost model, which needs exact per-word event counts), plus
sparsity instrumentation used throughout the benchmarks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spikes import pack_spikes, popcount


class EventStream(NamedTuple):
    """Padded AER stream: linear addresses + validity mask."""
    addr: jax.Array   # (max_events,) int32 linear index into the flat map
    valid: jax.Array  # (max_events,) bool


def fast_event_filter(word: jax.Array, width: int = 32) -> jax.Array:
    """Reference of the hardware fast event filter on one packed word.

    Emits the bit positions of set bits in ascending order (lowest active
    bit first — the one-hot + LUT scheme), padded with -1. Static output
    length = `width`.
    """
    positions = jnp.arange(width, dtype=jnp.int32)
    set_mask = ((word >> positions.astype(jnp.uint32)) & jnp.uint32(1)).astype(bool)
    order = jnp.argsort(~set_mask, stable=True)      # set bits first, ascending
    sorted_pos = jnp.where(jnp.sort(~set_mask) == 0, positions[order], -1)
    return sorted_pos.astype(jnp.int32)


def to_event_stream(s: jax.Array, max_events: int) -> EventStream:
    """Flatten a binary tensor into a padded AER stream (raster order)."""
    flat = s.reshape(-1)
    (lin,) = jnp.nonzero(flat, size=max_events, fill_value=-1)
    return EventStream(addr=lin.astype(jnp.int32), valid=lin >= 0)


def events_per_position(s: jax.Array) -> jax.Array:
    """(..., P, C) -> (..., P) active-channel counts per spatial position
    ("spike events ... collected at the same spatial location", Alg. 1 l.9).
    """
    return jnp.sum(s.astype(jnp.int32), axis=-1)


def word_event_counts(s: jax.Array, axis: int = -1) -> jax.Array:
    """Popcount per packed 32-channel word (Spike SRAM word granularity)."""
    return popcount(pack_spikes(s, axis=axis))


def layer_sparsity_report(name: str, s: jax.Array) -> dict:
    """Instrumentation record used by the Fig. 2 / Fig. 7 benchmarks."""
    total = float(jnp.asarray(s.size))
    active = float(jnp.sum(s.astype(jnp.float32)))
    return {
        "layer": name,
        "total_sites": total,
        "events": active,
        "sparsity": 1.0 - active / max(total, 1.0),
    }
