"""OPT2 — event-driven convolution (Algorithm 1, lines 5-16).

TConv maps each output neuron to a receptive-field reduction; its cost is
fixed by geometry and, under irregular sparsity, PEs assigned to quiet
neurons idle (workload imbalance). EConv inverts the mapping: each *input
spike event* scatters its kxk weight patch into the membrane potentials of
all C_o output channels at its location, so every active cycle contributes
a valid update and cost scales with event count (paper Fig. 1/2).

Three formulations, all numerically equal on binary inputs (tested):

  tconv            — `lax.conv_general_dilated` oracle (the TConv baseline).
  econv_scatter    — faithful event-list execution of Algorithm 1: extract
                     AER events (channel, y, x), fetch the event's weight
                     slice, scatter-add into the output map. Uses a static
                     `max_events` bound (padding with no-op events), the
                     JAX-traceable analogue of 'while AER FIFO non-empty'.
  (kernels/)       — the tiled Pallas spike-matmul with occupancy skipping
                     is the TPU-performance realization; see kernels/.

Layout: NHWC activations, HWIO weights, 'SAME' padding, stride 1 for the
event forms (the paper's accelerator likewise handles stride-1 3x3 kernels
in the EPE clusters; strided layers fall back to tconv).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def tconv(s: jax.Array, w: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    """TConv oracle. s: (N,H,W,Ci) binary; w: (kh,kw,Ci,Co).

    Binary spikes arrive in whatever dtype the caller stores them
    (bool/int8 event maps, f32 surrogate outputs); lax.conv demands
    matching operand dtypes, so the spike operand is promoted to the
    weight dtype HERE — inside the op, not silently at dispatch entry.
    The output is an activation in w.dtype either way.
    """
    return jax.lax.conv_general_dilated(
        s.astype(w.dtype), w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def extract_events(s: jax.Array, max_events: int) -> Tuple[jax.Array, jax.Array]:
    """AER extraction: indices of active spikes in a (H,W,Ci) map.

    Returns (idx (max_events, 3) int32 rows [h, w, ci], valid (max_events,)).
    Mirrors the Sparse Core's fast event filter: one valid (position,
    channel) event per cycle into the AER FIFO. `max_events` is the static
    capacity (H*W*Ci worst case); unused slots are masked no-ops.
    """
    flat = s.reshape(-1)
    (lin,) = jnp.nonzero(flat, size=max_events, fill_value=-1)
    valid = lin >= 0
    lin_c = jnp.where(valid, lin, 0)
    h_, w_, ci = jnp.unravel_index(lin_c, s.shape)
    idx = jnp.stack([h_, w_, ci], axis=-1).astype(jnp.int32)
    return idx, valid


def econv_scatter(
    s: jax.Array, w: jax.Array, max_events: int | None = None
) -> jax.Array:
    """Event-driven convolution by per-event weight scatter (stride 1, SAME).

    s: (N,H,W,Ci) binary; w: (kh,kw,Ci,Co). For each event (h,w,ci), adds
    w[:, :, ci, :] into out[h-kh//2 : ..., w-kw//2 : ..., :] — the "fixed
    spatial influence range" of Fig. 1(b). Channel-level parallelism across
    C_o is implicit (the scatter writes all output channels), matching the
    32-cluster EPE parallelism.
    """
    n, hh, ww, ci_dim = s.shape
    kh, kw, _, co = w.shape
    if kh % 2 == 0 or kw % 2 == 0:
        raise ValueError("econv_scatter supports odd kernels (paper uses 3x3)")
    if max_events is None:
        max_events = hh * ww * ci_dim
    pad_h, pad_w = kh // 2, kw // 2
    # Scatter is the transpose of correlation: an event at (h, w) lands on
    # out[h - dy + ph, w - dx + pw] with weight w[dy, dx], i.e. the weight
    # patch is applied spatially flipped over the (kh, kw) target window.
    w_flip = w[::-1, ::-1, :, :]

    def one_image(si):
        idx, valid = extract_events(si, max_events)
        out = jnp.zeros((hh + 2 * pad_h, ww + 2 * pad_w, co), jnp.float32)

        def body(k, out):
            h_, w_, c_ = idx[k, 0], idx[k, 1], idx[k, 2]
            patch = w_flip[:, :, c_, :] * valid[k].astype(w.dtype)
            # (kh,kw,Co) target window starting at (h, w) in padded coords.
            return jax.lax.dynamic_update_slice(
                out,
                jax.lax.dynamic_slice(out, (h_, w_, 0), (kh, kw, co)) + patch,
                (h_, w_, 0))

        out = jax.lax.fori_loop(0, max_events, body, out)
        return out[pad_h:pad_h + hh, pad_w:pad_w + ww, :]

    return jax.vmap(one_image)(s.astype(jnp.float32))


def econv(s, w: jax.Array, stride: int = 1,
          padding: str = "SAME") -> jax.Array:
    """Event convolution routed through the backend registry.

    Default resolution: `ref` (lax TConv) on CPU, im2col + the
    occupancy-skipping spike matmul on TPU; ``EXSPIKE_BACKEND=econv=jnp``
    selects the faithful per-event scatter form. `s` may be an
    `core.events.EventTensor`: its carried map is propagated through the
    im2col window so the event kernels skip their patch-tensor pre-pass.
    """
    from repro.kernels import dispatch as _dispatch  # lazy: no import cycle
    return _dispatch.econv(s, w, stride=stride, padding=padding)


# ------------------------------------------------- transposed convolution
def conv_transpose_ref(s: jax.Array, w: jax.Array, stride: int = 2,
                       padding: str = "SAME") -> jax.Array:
    """Transposed-conv oracle (the segmentation decoder's upsampling op;
    `ref` backend of the `tconv` registry op). s: (N,H,W,Ci); w:
    (kh,kw,Ci,Co) -> (N, H*stride, W*stride, Co) for SAME. Bool/int8
    spike operands are promoted to w.dtype here (see `tconv`)."""
    return jax.lax.conv_transpose(
        s.astype(w.dtype), w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_transpose_pads(k: int, stride: int, padding: str):
    """lax.conv_transpose's padding arithmetic, reproduced for the explicit
    zero-insertion forms (equality is covered by the parity harness)."""
    import math
    if padding == "SAME":
        pad_len = k + stride - 2
        pad_a = k - 1 if stride > k - 1 else int(math.ceil(pad_len / 2))
    elif padding == "VALID":
        pad_len = k + stride - 2 + max(k - stride, 0)
        pad_a = k - 1
    else:
        raise ValueError(f"unsupported padding {padding!r}")
    return pad_a, pad_len - pad_a


def upsample_events(s: jax.Array, stride: int, kh: int, kw: int,
                    padding: str) -> jax.Array:
    """Zero-insert + pad so a stride-1 VALID conv equals the transposed
    conv: events keep their binarity, only their spatial addresses dilate
    (the event-driven view of fractional striding)."""
    n, h, w_, ci = s.shape
    up = jnp.zeros((n, (h - 1) * stride + 1, (w_ - 1) * stride + 1, ci),
                   s.dtype)
    up = up.at[:, ::stride, ::stride].set(s)
    (pa, pb), (pc, pd) = (_conv_transpose_pads(k, stride, padding)
                          for k in (kh, kw))
    return jnp.pad(up, ((0, 0), (pa, pb), (pc, pd), (0, 0)))


def conv_transpose_upsampled(s: jax.Array, w: jax.Array, stride: int = 2,
                             padding: str = "SAME") -> jax.Array:
    """`jnp` backend of `tconv`: explicit zero-insertion, then a plain
    stride-1 VALID conv — numerically identical to the oracle, and the
    intermediate stays binary for binary inputs."""
    up = upsample_events(s, stride, w.shape[0], w.shape[1], padding)
    return jax.lax.conv_general_dilated(
        up.astype(w.dtype), w, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv_transpose(s, w: jax.Array, stride: int = 2,
                   padding: str = "SAME") -> jax.Array:
    """Transposed conv routed through the backend registry (`tconv` op).
    EventTensor inputs lose their map here (zero-insertion dilates event
    addresses — the documented invalidation rule)."""
    from repro.kernels import dispatch as _dispatch  # lazy: no import cycle
    return _dispatch.tconv(s, w, stride=stride, padding=padding)


def econv_gather(s: jax.Array, w: jax.Array) -> jax.Array:
    """Dense event-form: same per-position accumulation order as Algorithm 1
    (loop over positions, accumulate active channels' weight patches) but
    vectorized — used as a mid-level oracle between tconv and the scatter.
    Mathematically identical to tconv for stride 1 / SAME.
    """
    return tconv(s, w, 1, "SAME")


def event_ops(s: jax.Array, co: int, k: int) -> jax.Array:
    """EConv accumulation count: n_events * C_o * k^2 (paper Sec. III-A2)."""
    return jnp.sum(s.astype(jnp.int64)) * co * k * k


def tconv_ops(h: int, w: int, ci: int, co: int, k: int) -> int:
    """TConv MAC count: H*W*k^2*Ci*Co (dense, sparsity-independent)."""
    return h * w * k * k * ci * co
