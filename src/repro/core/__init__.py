"""ExSpike core: the paper's contribution as composable JAX modules.

  surrogate     — spike function with ATan surrogate gradient
  lif           — LIF neuron dynamics (scan reference; Pallas kernel in kernels/)
  spikes        — bit-packing, popcount, tile occupancy (event filter analog)
  direct_coding — OPT1: bit-sliced direct coding (Algorithm 1, l.1-4)
  econv         — OPT2: event-driven convolution (Algorithm 1, l.5-16)
  eafc          — OPT3: fused event-driven avgpool+FC (Algorithm 1, l.17-24)
  sdsa          — spike-driven self-attention (Attention Core, Fig. 6)
  apec          — adjacent-position event compression (Eq. 1-4, Fig. 5)
  events        — AER streams + sparsity instrumentation (Sparse Core)
  costmodel     — analytic cycle/GOPS model (Figs. 2/8, Tables I/II)
"""
from . import apec, costmodel, direct_coding, eafc, econv, events, sdsa, spikes, surrogate
from . import lif as lif  # noqa: PLC0414 — keep module importable by name
from .lif import LIFConfig, lif_scan, lif_step, multistep_lif
from .surrogate import spike

__all__ = [
    "apec", "costmodel", "direct_coding", "eafc", "econv", "events", "lif",
    "sdsa", "spikes", "surrogate", "LIFConfig", "lif_scan", "lif_step",
    "multistep_lif", "spike",
]
