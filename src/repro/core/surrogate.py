"""Surrogate-gradient spike function.

The Heaviside step `s = 1[v >= 0]` has zero gradient a.e.; SNN training
(SpikingJelly convention, used by the paper's training setup, Sec. IV)
replaces the backward pass with a smooth surrogate. We use the ATan
surrogate, SpikingJelly's default:

    d s / d v  :=  alpha / (2 * (1 + (pi/2 * alpha * v)^2))

Forward output is an exact binary {0,1} tensor, so all downstream
"full-event" guarantees (bitwise SDSA, APEC overlap logic, event counting)
hold bit-exactly during training as well.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_ALPHA = 2.0


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def spike(v: jax.Array, alpha: float = DEFAULT_ALPHA) -> jax.Array:
    """Binary spike: Heaviside(v) with ATan surrogate gradient."""
    return (v >= 0).astype(v.dtype)


def _spike_fwd(v, alpha):
    return (v >= 0).astype(v.dtype), v


def _spike_bwd(alpha, v, g):
    # ATan surrogate derivative (SpikingJelly `surrogate.ATan`).
    half_pi_alpha = 0.5 * math.pi * alpha
    dv = alpha / 2.0 / (1.0 + (half_pi_alpha * v) ** 2)
    return (g * dv.astype(g.dtype),)


spike.defvjp(_spike_fwd, _spike_bwd)


def spike_st(v: jax.Array) -> jax.Array:
    """Straight-through variant (identity backward); used in ablations."""

    @jax.custom_vjp
    def _st(x):
        return (x >= 0).astype(x.dtype)

    def _fwd(x):
        return (x >= 0).astype(x.dtype), None

    def _bwd(_, g):
        return (g,)

    _st.defvjp(_fwd, _bwd)
    return _st(v)
