"""Leaky integrate-and-fire (LIF) neuron dynamics.

The paper (Sec. II) uses LIF neurons with tau = 0.5 trained in SpikingJelly.
We adopt the decay-multiplier form

    v[t+1] = decay * v[t] + x[t]
    s[t]   = Heaviside(v[t+1] - v_th)        (surrogate gradient in bwd)
    reset:  soft: v <- v - s * v_th          (membrane-potential subtraction)
            hard: v <- v * (1 - s)

with decay = tau = 0.5 and v_th = 1.0 by default. The temporal loop is a
`jax.lax.scan` here (the pure-JAX reference); `repro.kernels.lif_scan`
provides the fused Pallas kernel that keeps `v` resident in VMEM across the
temporal loop — the TPU analogue of the paper's MPE stage, which keeps
membrane potentials in on-chip registers between eFIFO pushes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .surrogate import spike


@dataclasses.dataclass(frozen=True)
class LIFConfig:
    decay: float = 0.5          # tau in the paper's notation
    v_th: float = 1.0
    soft_reset: bool = True
    surrogate_alpha: float = 2.0


def lif_step(
    v: jax.Array, x: jax.Array, cfg: LIFConfig = LIFConfig()
) -> Tuple[jax.Array, jax.Array]:
    """One LIF timestep. Returns (new membrane potential, spikes)."""
    v = cfg.decay * v + x
    s = spike(v - cfg.v_th, cfg.surrogate_alpha)
    if cfg.soft_reset:
        v = v - s * cfg.v_th
    else:
        v = v * (1.0 - s)
    return v, s


def lif_scan(
    x: jax.Array, cfg: LIFConfig = LIFConfig(), v0: jax.Array | None = None
) -> jax.Array:
    """Run LIF over the leading time axis. x: (T, ...) -> spikes (T, ...)."""
    if v0 is None:
        v0 = jnp.zeros_like(x[0])

    def step(v, xt):
        v, s = lif_step(v, xt, cfg)
        return v, s

    _, s = jax.lax.scan(step, v0, x)
    return s


def lif_scan_with_state(
    x: jax.Array, v0: jax.Array, cfg: LIFConfig = LIFConfig()
) -> Tuple[jax.Array, jax.Array]:
    """Like `lif_scan` but also returns the final membrane state (serving)."""

    def step(v, xt):
        v, s = lif_step(v, xt, cfg)
        return v, s

    vT, s = jax.lax.scan(step, v0, x)
    return vT, s


def multistep_lif(x: jax.Array, cfg: LIFConfig = LIFConfig()) -> jax.Array:
    """LIF over axis 0 (= T micro-timesteps). Alias used by model code."""
    return lif_scan(x, cfg)
