"""OPT1 — direct coding via bit-slicing (Algorithm 1, lines 1-4).

The first layer of a direct-coded SNN receives multi-bit fixed-point
activations, which breaks pure event-driven execution. ExSpike quantizes
the input to signed B-bit fixed point, bit-slices it into B binary planes,
and duplicates/shifts the weights so the coding layer runs as binary
shift-and-accumulate — exactly representable on the same event machinery
as every other layer.

Signed two's complement: value = -b_{B-1} 2^{B-1} + sum_{i<B-1} b_i 2^i,
so the MSB plane's weight copy carries a negative scale. The decomposition
is exact in integer arithmetic, which the tests assert bit-exactly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, bits: int, x_max: float | None = None) -> Tuple[jax.Array, jax.Array]:
    """Symmetric signed quantization to `bits` bits.

    Returns (q, scale) with q int32 in [-2^{B-1}, 2^{B-1}-1] and
    x ~= q * scale.
    """
    if x_max is None:
        x_max = jnp.max(jnp.abs(x))
    qmax = 2 ** (bits - 1) - 1
    scale = x_max / qmax
    q = jnp.clip(jnp.round(x / scale), -(qmax + 1), qmax).astype(jnp.int32)
    return q, scale


def bit_slice(q: jax.Array, bits: int) -> jax.Array:
    """Slice signed int q into B binary planes, leading axis (B, ...).

    Plane b holds bit b of the two's-complement representation (in
    `bits`-bit width). Planes are exact binary {0,1} float tensors — i.e.
    spike events, as consumed by the event-driven layers.
    """
    uq = q.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(bits, dtype=jnp.uint32)
    planes = (uq[None, ...] >> shifts.reshape((bits,) + (1,) * q.ndim)) & jnp.uint32(1)
    return planes.astype(jnp.float32)


def plane_scales(bits: int, scale: jax.Array | float = 1.0) -> jax.Array:
    """Per-plane weight scale (the paper's DuplicateShift): 2^b, MSB negative."""
    s = 2.0 ** jnp.arange(bits, dtype=jnp.float32)
    s = s.at[bits - 1].set(-s[bits - 1])  # two's-complement sign plane
    return s * scale


def direct_coded_matmul(
    x: jax.Array, w: jax.Array, bits: int = 8, x_max: float | None = None
) -> jax.Array:
    """Event-form first-layer matmul: bit-sliced x against shifted weights.

    Equivalent to (quantize(x) * scale) @ w, but every multiply is a
    binary-activation accumulate — the paper's multiplier-free claim.
    x: (..., K); w: (K, N).
    """
    q, scale = quantize(x, bits, x_max)
    planes = bit_slice(q, bits)                      # (B, ..., K) binary
    scales = plane_scales(bits, scale)               # (B,)
    # One binary matmul per plane; scale-and-add (shift-accumulate analog).
    per_plane = jnp.einsum("b...k,kn->b...n", planes, w)
    return jnp.einsum("b,b...n->...n", scales, per_plane)


def direct_coded_conv(
    x: jax.Array,
    w: jax.Array,
    bits: int = 8,
    stride: int = 1,
    padding: str = "SAME",
    x_max: float | None = None,
) -> jax.Array:
    """Event-form direct-coding conv layer (NHWC, HWIO weights)."""
    q, scale = quantize(x, bits, x_max)
    planes = bit_slice(q, bits)                      # (B, N, H, W, C)
    scales = plane_scales(bits, scale)

    def one_plane(p):
        return jax.lax.conv_general_dilated(
            p, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    per_plane = jax.vmap(one_plane)(planes)
    return jnp.einsum("b,bnhwc->nhwc", scales, per_plane)


def reference_quantized_matmul(
    x: jax.Array, w: jax.Array, bits: int = 8, x_max: float | None = None
) -> jax.Array:
    """Oracle: dequantized fixed-point matmul the event form must match."""
    q, scale = quantize(x, bits, x_max)
    return (q.astype(jnp.float32) * scale) @ w


def reference_quantized_conv(
    x: jax.Array, w: jax.Array, bits: int = 8, stride: int = 1,
    padding: str = "SAME", x_max: float | None = None,
) -> jax.Array:
    q, scale = quantize(x, bits, x_max)
    return jax.lax.conv_general_dilated(
        q.astype(jnp.float32) * scale, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
