"""OPT3 — event-driven average-pool + fully-connected fusion (EAFC).

Average pooling divides spike counts by the window size, producing
non-binary intermediates that break event purity (Sec. II-B). ExSpike
folds the 1/pool^2 scale into the FC weights *offline* and drives the FC
directly from the pre-pool spike events (Algorithm 1, lines 17-24): for a
pre-pool event at (h, w, c), the FC update uses the weight rows belonging
to pooled position (h//p, w//p) and channel c, scaled by 1/p^2.

Exact for divisible windows (what the paper's models use); equivalence is
property-tested against avgpool -> flatten -> FC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def avgpool2d(s: jax.Array, pool: int) -> jax.Array:
    """(N,H,W,C) -> (N,H/p,W/p,C) mean pooling (the non-event baseline)."""
    n, h, w, c = s.shape
    return s.reshape(n, h // pool, pool, w // pool, pool, c).mean(axis=(2, 4))


def avgpool_fc_ref(s: jax.Array, w_fc: jax.Array, pool: int) -> jax.Array:
    """Oracle: avgpool -> flatten (H',W',C order) -> FC.

    w_fc: (H/p * W/p * C, n_out).
    """
    pooled = avgpool2d(s, pool)
    flat = pooled.reshape(pooled.shape[0], -1)
    return flat @ w_fc


def scale_fc_weights(w_fc: jax.Array, pool: int) -> jax.Array:
    """Offline weight scaling (Sec. III-B): each weight divided by pool^2."""
    return w_fc / float(pool * pool)


def eafc(s: jax.Array, w_fc: jax.Array, pool: int) -> jax.Array:
    """Event-driven fused avgpool+FC on pre-pool spikes.

    s: (N,H,W,C) binary; w_fc: (H/p * W/p * C, n_out). Every pre-pool event
    at (h,w,c) contributes w_scaled[row(h//p, w//p, c)] — implemented as a
    position-summed einsum over the pooling window so each active event
    performs exactly one weight-row accumulation (binary activations), with
    no non-binary intermediate.
    """
    n, h, w, c = s.shape
    hp, wp = h // pool, w // pool
    ws = scale_fc_weights(w_fc, pool).reshape(hp, wp, c, -1)
    # Group pre-pool positions by their pooled cell; events inside a cell
    # share the same weight row (scaled), exactly Algorithm 1 lines 18-23.
    sg = s.reshape(n, hp, pool, wp, pool, c)
    return jnp.einsum("nhawbc,hwco->no", sg, ws)


def eafc_event_ops(s: jax.Array, n_out: int) -> jax.Array:
    """EAFC accumulation count: one n_out-row accumulate per active event."""
    return jnp.sum(s.astype(jnp.int64)) * n_out
