"""Analytic cost/cycle model of the ExSpike accelerator.

The FPGA's LUT/FF/BRAM accounting does not transfer to TPU, but the
paper's *performance economics* do: event-proportional work (Fig. 1c),
per-layer latency split into weight-ready / buffer / calculation cycles
(Fig. 8), and GOPS-style throughput (Table II). This module is the single
source of those numbers for the benchmark suite, parameterized by the
paper's published configuration:

  * 200 MHz clock, 352 PEs (= 32 EPE clusters x (3x3 WPE + MPE + FPE)),
  * 32 output channels in parallel (one per cluster), reused over
    ceil(C_o / 32) groups (Algorithm 1, line 5),
  * one valid event filtered per cycle (Sparse Core),
  * weight fetch of C_o x k^2 bytes per unique event position.

"GOPS" follows the paper's convention of counting the dense-equivalent
synaptic operations retired per second (so sparsity and APEC raise
GOPS by reducing cycles for the same nominal op count).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExSpikeHW:
    clock_hz: float = 200e6
    n_clusters: int = 32          # parallel output channels
    wpe_per_cluster: int = 9      # 3x3 WPE units
    n_pe: int = 352               # 32 x (9 WPE + MPE + FPE)
    weight_bytes: int = 1         # 8-bit fixed-point weights
    mp_bytes: int = 2             # 16-bit membrane potentials
    weight_bw_bytes_per_cycle: int = 16   # Weight SRAM read port width
    power_w_baseline: float = 1.593       # Table I
    power_w_apec2: float = 1.700          # Table I


@dataclasses.dataclass
class LayerCycles:
    """Fig. 8 decomposition for one layer."""
    name: str
    weight: float      # waiting-for-weight-ready cycles
    buffer: float      # eFIFO/buffer cycles
    calc: float        # accumulation cycles
    events: float      # valid events executed
    dense_ops: float   # dense-equivalent synaptic ops (for GOPS)

    @property
    def total(self) -> float:
        return self.weight + self.buffer + self.calc


def conv_layer_cycles(
    name: str,
    n_events: float,
    n_unique_positions: float,
    h: int, w: int, ci: int, co: int, k: int,
    hw: ExSpikeHW = ExSpikeHW(),
    apec_group: int = 1,
    apec_eliminated: float = 0.0,
    apec_overlap_positions: float = 0.0,
) -> LayerCycles:
    """Cycle model of one EConv layer on the EPE Core.

    calc cycles: each event accumulates a k^2 patch across C_o channels;
    32 channels run in parallel, k^2 WPEs run in parallel, so an event
    costs ceil(C_o/32) cycles. APEC removes `apec_eliminated` events but
    adds overlap partial-sum reuse (buffer) and extra weight-ready traffic
    for overlap groups — exactly the Fig. 8 trade-off.
    """
    groups = int(np.ceil(co / hw.n_clusters))
    exec_events = n_events - apec_eliminated
    calc = exec_events * groups
    # Weight fetch: per unique event position per group, a k^2 x 32-wide
    # weight block. APEC's overlap pass reuses the weight stream of the
    # group's first member (the psum is cached, not the weights), but the
    # extra pass stalls the weight pipeline at group boundaries — modeled
    # as a 0.25-position penalty per overlapping group (the Weight-cycle
    # growth visible in Fig. 8).
    wbytes_per_pos = k * k * hw.n_clusters * hw.weight_bytes
    weight_positions = n_unique_positions + 0.25 * apec_overlap_positions
    weight = weight_positions * groups * wbytes_per_pos / hw.weight_bw_bytes_per_cycle
    # Buffer: one eFIFO push per executed event + overlap psum cache traffic.
    buffer = exec_events * 0.125 + apec_overlap_positions * k * k / hw.wpe_per_cluster
    dense_ops = 2.0 * h * w * k * k * ci * co   # MAC = 2 ops, dense equivalent
    return LayerCycles(name, weight, buffer, calc, exec_events, dense_ops)


def fc_layer_cycles(
    name: str, n_events: float, n_in: int, n_out: int,
    hw: ExSpikeHW = ExSpikeHW(),
) -> LayerCycles:
    """EAFC Core: one weight-row accumulate per event (Sec. III-B)."""
    groups = int(np.ceil(n_out / hw.n_clusters))
    calc = n_events * groups
    weight = n_events * groups * hw.n_clusters * hw.weight_bytes / hw.weight_bw_bytes_per_cycle
    return LayerCycles(name, weight, calc * 0.125, calc, n_events, 2.0 * n_in * n_out)


def sdsa_cycles(
    name: str, n_tokens: int, d: int, hw: ExSpikeHW = ExSpikeHW()
) -> LayerCycles:
    """Attention Core: stage-1 AND/OR on the fly with V write-back, stage-2
    AND per Q row; d bits per cycle across clusters."""
    lanes = hw.n_clusters * hw.wpe_per_cluster * 32  # bit-parallel logic lanes
    stage1 = n_tokens * d / lanes
    stage2 = n_tokens * d / lanes
    dense_ops = 2.0 * n_tokens * n_tokens * d        # softmax-attn equivalent
    return LayerCycles(name, 0.0, stage1, stage2, n_tokens * d, dense_ops)


@dataclasses.dataclass(frozen=True)
class TileSkipSavings:
    """What a tile-skipping spike-matmul backend actually saves, with the
    FLOP ledger and the DMA ledger kept separate — the paper's no-events-
    no-work claim has two currencies on TPU and the backends differ in
    which they pay out:

      * the predicated `pallas` kernel (`pl.when` inside a dense grid)
        saves the MXU FLOPs of empty tiles but still runs every grid step
        and still streams every spike/weight tile HBM->VMEM;
      * the event-compacted `pallas-csr` kernel saves the same FLOPs AND
        the tile DMA, because empty tiles never enter the grid (dummy
        steps for all-empty rows are the only residue).
    """
    backend: str
    grid_steps_total: int     # dense grid: MT*KT steps per output N-tile
    grid_steps_run: int
    flops_total: float        # dense-equivalent MXU flops
    flops_saved: float
    dma_bytes_total: float    # spike + weight tile HBM->VMEM traffic
    dma_bytes_saved: float

    @property
    def flops_fraction_saved(self) -> float:
        return self.flops_saved / self.flops_total if self.flops_total else 0.0

    @property
    def dma_fraction_saved(self) -> float:
        return self.dma_bytes_saved / self.dma_bytes_total \
            if self.dma_bytes_total else 0.0


def tile_matmul_savings(
    occupancy: "np.ndarray",
    n: int,
    *,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    spike_bytes: int = 4,
    weight_bytes: int = 4,
    backend: str = "pallas",
) -> TileSkipSavings:
    """FLOPs-saved vs DMA-saved of one (M, K) x (K, N) spike matmul.

    `occupancy`: the (MT, KT) per-tile event-count map the kernels consume
    (`core.spikes.tile_occupancy`). `backend`: "pallas" (predicated dense
    grid) or "pallas-csr" (event-compacted grid). The CSR accounting
    charges one dummy step per all-empty m-tile row — those rows must
    still be visited to zero their output blocks, and the dummy's spike/
    weight tile fetch is real traffic.
    """
    occ = np.asarray(occupancy)
    mt, kt = occ.shape
    nt = int(np.ceil(n / block_n))
    occupied = int(np.count_nonzero(occ > 0))
    empty = mt * kt - occupied
    empty_rows = int(np.sum(~(occ > 0).any(axis=1)))
    per_tile_flops = 2.0 * block_m * block_k * block_n
    per_step_dma = float(block_m * block_k * spike_bytes
                         + block_k * block_n * weight_bytes)
    steps_total = mt * kt * nt
    flops_total = steps_total * per_tile_flops
    flops_saved = empty * nt * per_tile_flops     # both backends skip MXU
    if backend == "pallas":                       # predicated: full grid,
        steps_run = steps_total                   # full tile traffic
        dma_saved = 0.0
    elif backend == "pallas-csr":
        steps_run = (occupied + empty_rows) * nt
        dma_saved = (steps_total - steps_run) * per_step_dma
    else:
        raise ValueError(f"unknown tile-skipping backend {backend!r}")
    return TileSkipSavings(
        backend=backend,
        grid_steps_total=steps_total,
        grid_steps_run=steps_run,
        flops_total=flops_total,
        flops_saved=flops_saved,
        dma_bytes_total=steps_total * per_step_dma,
        dma_bytes_saved=dma_saved,
    )


def summarize(layers: list[LayerCycles], hw: ExSpikeHW = ExSpikeHW(),
              apec: bool = False) -> dict:
    """Network-level Table II style metrics."""
    cycles = sum(l.total for l in layers)
    ops = sum(l.dense_ops for l in layers)
    latency_s = cycles / hw.clock_hz
    gops = ops / latency_s / 1e9 if latency_s > 0 else 0.0
    power = hw.power_w_apec2 if apec else hw.power_w_baseline
    return {
        "cycles": cycles,
        "latency_ms": latency_s * 1e3,
        "fps": 1.0 / latency_s if latency_s > 0 else 0.0,
        "gops": gops,
        "gops_per_w": gops / power,
        "gops_per_w_per_pe": gops / power / hw.n_pe,
        "total_events": sum(l.events for l in layers),
    }
