"""Analytic cost/cycle model of the ExSpike accelerator.

The FPGA's LUT/FF/BRAM accounting does not transfer to TPU, but the
paper's *performance economics* do: event-proportional work (Fig. 1c),
per-layer latency split into weight-ready / buffer / calculation cycles
(Fig. 8), and GOPS-style throughput (Table II). This module is the single
source of those numbers for the benchmark suite, parameterized by the
paper's published configuration:

  * 200 MHz clock, 352 PEs (= 32 EPE clusters x (3x3 WPE + MPE + FPE)),
  * 32 output channels in parallel (one per cluster), reused over
    ceil(C_o / 32) groups (Algorithm 1, line 5),
  * one valid event filtered per cycle (Sparse Core),
  * weight fetch of C_o x k^2 bytes per unique event position.

"GOPS" follows the paper's convention of counting the dense-equivalent
synaptic operations retired per second (so sparsity and APEC raise
GOPS by reducing cycles for the same nominal op count).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExSpikeHW:
    clock_hz: float = 200e6
    n_clusters: int = 32          # parallel output channels
    wpe_per_cluster: int = 9      # 3x3 WPE units
    n_pe: int = 352               # 32 x (9 WPE + MPE + FPE)
    weight_bytes: int = 1         # 8-bit fixed-point weights
    mp_bytes: int = 2             # 16-bit membrane potentials
    weight_bw_bytes_per_cycle: int = 16   # Weight SRAM read port width
    power_w_baseline: float = 1.593       # Table I
    power_w_apec2: float = 1.700          # Table I


@dataclasses.dataclass
class LayerCycles:
    """Fig. 8 decomposition for one layer."""
    name: str
    weight: float      # waiting-for-weight-ready cycles
    buffer: float      # eFIFO/buffer cycles
    calc: float        # accumulation cycles
    events: float      # valid events executed
    dense_ops: float   # dense-equivalent synaptic ops (for GOPS)

    @property
    def total(self) -> float:
        return self.weight + self.buffer + self.calc


def conv_layer_cycles(
    name: str,
    n_events: float,
    n_unique_positions: float,
    h: int, w: int, ci: int, co: int, k: int,
    hw: ExSpikeHW = ExSpikeHW(),
    apec_group: int = 1,
    apec_eliminated: float = 0.0,
    apec_overlap_positions: float = 0.0,
) -> LayerCycles:
    """Cycle model of one EConv layer on the EPE Core.

    calc cycles: each event accumulates a k^2 patch across C_o channels;
    32 channels run in parallel, k^2 WPEs run in parallel, so an event
    costs ceil(C_o/32) cycles. APEC removes `apec_eliminated` events but
    adds overlap partial-sum reuse (buffer) and extra weight-ready traffic
    for overlap groups — exactly the Fig. 8 trade-off.
    """
    groups = int(np.ceil(co / hw.n_clusters))
    exec_events = n_events - apec_eliminated
    calc = exec_events * groups
    # Weight fetch: per unique event position per group, a k^2 x 32-wide
    # weight block. APEC's overlap pass reuses the weight stream of the
    # group's first member (the psum is cached, not the weights), but the
    # extra pass stalls the weight pipeline at group boundaries — modeled
    # as a 0.25-position penalty per overlapping group (the Weight-cycle
    # growth visible in Fig. 8).
    wbytes_per_pos = k * k * hw.n_clusters * hw.weight_bytes
    weight_positions = n_unique_positions + 0.25 * apec_overlap_positions
    weight = weight_positions * groups * wbytes_per_pos / hw.weight_bw_bytes_per_cycle
    # Buffer: one eFIFO push per executed event + overlap psum cache traffic.
    buffer = exec_events * 0.125 + apec_overlap_positions * k * k / hw.wpe_per_cluster
    dense_ops = 2.0 * h * w * k * k * ci * co   # MAC = 2 ops, dense equivalent
    return LayerCycles(name, weight, buffer, calc, exec_events, dense_ops)


def fc_layer_cycles(
    name: str, n_events: float, n_in: int, n_out: int,
    hw: ExSpikeHW = ExSpikeHW(),
) -> LayerCycles:
    """EAFC Core: one weight-row accumulate per event (Sec. III-B)."""
    groups = int(np.ceil(n_out / hw.n_clusters))
    calc = n_events * groups
    weight = n_events * groups * hw.n_clusters * hw.weight_bytes / hw.weight_bw_bytes_per_cycle
    return LayerCycles(name, weight, calc * 0.125, calc, n_events, 2.0 * n_in * n_out)


def sdsa_cycles(
    name: str, n_tokens: int, d: int, hw: ExSpikeHW = ExSpikeHW()
) -> LayerCycles:
    """Attention Core: stage-1 AND/OR on the fly with V write-back, stage-2
    AND per Q row; d bits per cycle across clusters."""
    lanes = hw.n_clusters * hw.wpe_per_cluster * 32  # bit-parallel logic lanes
    stage1 = n_tokens * d / lanes
    stage2 = n_tokens * d / lanes
    dense_ops = 2.0 * n_tokens * n_tokens * d        # softmax-attn equivalent
    return LayerCycles(name, 0.0, stage1, stage2, n_tokens * d, dense_ops)


@dataclasses.dataclass(frozen=True)
class TileSkipSavings:
    """What a tile-skipping spike-matmul backend actually saves, with the
    FLOP ledger and the DMA ledger kept separate — the paper's no-events-
    no-work claim has two currencies on TPU and the backends differ in
    which they pay out:

      * the predicated `pallas` kernel (`pl.when` inside a dense grid)
        saves the MXU FLOPs of empty tiles but still runs every grid step
        and still streams every spike/weight tile HBM->VMEM;
      * the event-compacted `pallas-csr` kernel saves the same FLOPs AND
        the tile DMA, because empty tiles never enter the grid (dummy
        steps for all-empty rows are the only residue).
    """
    backend: str
    grid_steps_total: int     # dense grid: MT*KT steps per output N-tile
    grid_steps_run: int
    flops_total: float        # dense-equivalent MXU flops
    flops_saved: float
    dma_bytes_total: float    # spike + weight tile HBM->VMEM traffic
    dma_bytes_saved: float

    @property
    def flops_fraction_saved(self) -> float:
        return self.flops_saved / self.flops_total if self.flops_total else 0.0

    @property
    def dma_fraction_saved(self) -> float:
        return self.dma_bytes_saved / self.dma_bytes_total \
            if self.dma_bytes_total else 0.0


PACK = 32                 # channels per uint32 spike word (core.spikes.PACK)
PACK_WORD_BYTES = 4


def spike_tile_bytes(block_m: int, block_k: int, payload: str = "dense",
                     spike_bytes: int = 4) -> float:
    """HBM bytes of one (block_m, block_k) spike tile in `payload` form.

    "dense": block_k elements of `spike_bytes` each (the f32 route).
    "packed": block_k/32 uint32 words — the 32x compression the packed-csr
    family streams instead. block_k must stay a multiple of 32 (the
    kernels' word tiling; 128-blocks are).
    """
    if payload == "packed":
        if block_k % PACK:
            raise ValueError(f"packed tile needs block_k % {PACK} == 0, "
                             f"got {block_k}")
        return float(block_m * (block_k // PACK) * PACK_WORD_BYTES)
    if payload != "dense":
        raise ValueError(f"unknown spike payload {payload!r}")
    return float(block_m * block_k * spike_bytes)


def spike_payload_bytes(rows: int, k: int, payload: str = "dense",
                        spike_bytes: int = 4) -> float:
    """One HBM materialization of a (rows, k) spike tensor — what the
    producing fire stage writes out (and a re-deriving pre-pass reads
    back). Packed emission writes ceil(k/32) uint32 words per row."""
    if payload == "packed":
        return float(rows) * (-(-k // PACK)) * PACK_WORD_BYTES
    if payload != "dense":
        raise ValueError(f"unknown spike payload {payload!r}")
    return float(rows) * k * spike_bytes


def tile_matmul_savings(
    occupancy: "np.ndarray",
    n: int,
    *,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    spike_bytes: int = 4,
    weight_bytes: int = 4,
    backend: str = "pallas",
    payload: str = "dense",
) -> TileSkipSavings:
    """FLOPs-saved vs DMA-saved of one (M, K) x (K, N) spike matmul.

    `occupancy`: the (MT, KT) per-tile event-count map the kernels consume
    (`core.spikes.tile_occupancy`). `backend`: "pallas" (predicated dense
    grid), "pallas-csr" (event-compacted grid), or "packed-csr" (the same
    compacted grid streaming uint32 words — implies payload="packed").
    The CSR accounting charges one dummy step per all-empty m-tile row —
    those rows must still be visited to zero their output blocks, and the
    dummy's spike/weight tile fetch is real traffic.

    `payload` sets the per-step spike-tile DMA currency (dense elements vs
    packed words), so the DMA-saved column states the route's own traffic
    honestly instead of charging f32 bytes to a packed stream. The saved
    FRACTION is payload-invariant (total and saved scale together); the
    absolute dma_bytes_* differ 32x on the spike side.
    """
    if backend == "packed-csr":
        payload = "packed"
    occ = np.asarray(occupancy)
    mt, kt = occ.shape
    nt = int(np.ceil(n / block_n))
    occupied = int(np.count_nonzero(occ > 0))
    empty = mt * kt - occupied
    empty_rows = int(np.sum(~(occ > 0).any(axis=1)))
    per_tile_flops = 2.0 * block_m * block_k * block_n
    per_step_dma = (spike_tile_bytes(block_m, block_k, payload, spike_bytes)
                    + block_k * block_n * weight_bytes)
    steps_total = mt * kt * nt
    flops_total = steps_total * per_tile_flops
    flops_saved = empty * nt * per_tile_flops     # both backends skip MXU
    if backend == "pallas":                       # predicated: full grid,
        steps_run = steps_total                   # full tile traffic
        dma_saved = 0.0
    elif backend in ("pallas-csr", "packed-csr"):
        steps_run = (occupied + empty_rows) * nt
        dma_saved = (steps_total - steps_run) * per_step_dma
    else:
        raise ValueError(f"unknown tile-skipping backend {backend!r}")
    return TileSkipSavings(
        backend=backend,
        grid_steps_total=steps_total,
        grid_steps_run=steps_run,
        flops_total=flops_total,
        flops_saved=flops_saved,
        dma_bytes_total=steps_total * per_step_dma,
        dma_bytes_saved=dma_saved,
    )


# ---------------------------------------------------------------------------
# Bytes-moved ledger (PR 7): absolute HBM traffic per op, packed vs f32.
#
# The DMA ledger above answers "what fraction of this route's own tile
# traffic does compaction save"; the bytes ledger answers the PR 7
# question — how many HBM bytes actually move, in each payload. Three
# components are kept separate because only one responds to packing:
#
#   spike_hbm  — event-payload tile reads (steps_run x spike tile bytes).
#                This is the traffic event compression acts on: 32x down
#                when the words stay packed end to end.
#   weight_hbm — weight tile reads. Route-invariant between payloads (the
#                packed and f32 CSR kernels run the SAME trimmed grid), so
#                it is reported, never folded into the headline reduction.
#   out_hbm    — output tile writes (mt x nt tiles, once each). Invariant.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BytesMoved:
    """Absolute modeled HBM traffic of one matmul-form op call."""
    backend: str
    payload: str
    spike_hbm: float     # spike/event tile reads (the compressible stream)
    weight_hbm: float    # weight tile reads (payload-invariant)
    out_hbm: float       # output tile writes (payload-invariant)

    @property
    def total(self) -> float:
        return self.spike_hbm + self.weight_hbm + self.out_hbm


def matmul_bytes_moved(
    occupancy: "np.ndarray",
    n: int,
    *,
    block_m: int = 128,
    block_k: int = 128,
    block_n: int = 128,
    backend: str = "pallas-csr",
    payload: str = "dense",
    spike_bytes: int = 4,
    weight_bytes: int = 4,
    out_bytes: int = 4,
) -> BytesMoved:
    """Modeled HBM bytes in/out of one (M, K) x (K, N) spike matmul.

    Same grid accounting as `tile_matmul_savings` (full grid for the
    predicated "pallas" backend; occupied + empty-row-dummy steps for the
    csr family), with the spike stream priced in its actual payload:
    backend "packed-csr" forces payload="packed" (uint32 words, 1/32 the
    dense bytes per tile).
    """
    if backend == "packed-csr":
        payload = "packed"
    occ = np.asarray(occupancy)
    mt, kt = occ.shape
    nt = int(np.ceil(n / block_n))
    if backend == "pallas":
        steps_run = mt * kt * nt
    elif backend in ("pallas-csr", "packed-csr"):
        occupied = int(np.count_nonzero(occ > 0))
        empty_rows = int(np.sum(~(occ > 0).any(axis=1)))
        steps_run = (occupied + empty_rows) * nt
    else:
        raise ValueError(f"unknown tile-skipping backend {backend!r}")
    return BytesMoved(
        backend=backend,
        payload=payload,
        spike_hbm=steps_run * spike_tile_bytes(block_m, block_k, payload,
                                               spike_bytes),
        weight_hbm=float(steps_run) * block_k * block_n * weight_bytes,
        out_hbm=float(mt * nt) * block_m * block_n * out_bytes,
    )


@dataclasses.dataclass(frozen=True)
class DmaOverlap:
    """How much of one op call's weight-tile DMA hides behind compute.

    The serial CSR kernels let the Pallas block pipeline fetch the weight
    tile for step t as part of step t's setup: every weight byte is on the
    critical path (`bytes_stalled`). The `-pipe` variants instead start the
    fetch for occupied step t+1 while step t's dot runs, so only the
    warm-up copy of each N-tile iteration is exposed — everything after it
    lands behind compute (`bytes_prefetched`). Dummy / clamp-padding steps
    are DMA-free under pipelining (the gate skips them), while the serial
    block pipeline still pays their fetch.
    """
    backend: str
    pipelined: bool
    bytes_total: float        # weight bytes fetched across the whole grid
    bytes_prefetched: float   # started >= 1 step before their dot lands
    bytes_stalled: float      # exposed: compute waits on the copy

    @property
    def overlap_fraction(self) -> float:
        return (self.bytes_prefetched / self.bytes_total
                if self.bytes_total else 0.0)


def dma_overlap_ledger(
    occupancy: "np.ndarray",
    n: int,
    *,
    block_k: int = 128,
    block_n: int = 128,
    backend: str = "pallas-csr",
    pipelined: bool = False,
    weight_bytes: int = 4,
) -> DmaOverlap:
    """Model the prefetched/stalled split of weight-tile DMA for one call.

    Same grid accounting as `matmul_bytes_moved` (occupied steps plus one
    dummy per all-empty m-tile row for the csr family, times the N-tile
    count). Steady-state model of the `-pipe` kernels' contract
    (`kernels.spike_matmul._weight_prefetch`):

      * serial: every step's weight fetch is exposed, dummies included;
      * pipelined: occupied steps fetch, dummy steps are DMA-free, and
        exactly one warm-up fetch per N-tile iteration is exposed.

    For APEC pass the union map (`(occ_res > 0) | (occ_ov > 0)` as
    counts): the pipe gate fetches when either branch will dot.
    """
    occ = np.asarray(occupancy)
    mt, kt = occ.shape
    nt = int(np.ceil(n / block_n))
    tile_bytes = float(block_k * block_n * weight_bytes)
    occupied = int(np.count_nonzero(occ > 0))
    empty_rows = int(np.sum(~(occ > 0).any(axis=1)))
    if backend == "pallas":
        if pipelined:
            raise ValueError("pipelined variants exist only for the csr "
                             "family (dense pallas uses the block pipeline)")
        fetches = mt * kt * nt
        prefetched = 0
    elif backend in ("pallas-csr", "packed-csr"):
        if pipelined:
            fetches = occupied * nt
            prefetched = max(0, fetches - (nt if occupied else 0))
        else:
            fetches = (occupied + empty_rows) * nt
            prefetched = 0
    else:
        raise ValueError(f"unknown tile-skipping backend {backend!r}")
    total = fetches * tile_bytes
    pre = prefetched * tile_bytes
    return DmaOverlap(
        backend=backend, pipelined=pipelined, bytes_total=total,
        bytes_prefetched=pre, bytes_stalled=total - pre)


# --------------------------------------------------------------------------
# Hybrid dense<->event route calibration (PR 6)
#
# The hybrid dispatch mode needs a *predicate*: given the carried occupancy
# map's occupied-tile count, is the event-compacted (pallas-csr family)
# route cheaper than the predicated-dense (pallas family) route?  The two
# ledgers above say what each route pays structurally — dense runs every
# grid step (full DMA) and spends MXU only on occupied tiles; event runs
# only occupied steps plus one dummy per all-empty m-tile row, at a
# per-step compaction overhead (scalar prefetch + trimmed-grid setup).
# The two unknowns are machine-relative rates:
#
#   r — MXU work per occupied step, in units of one step's tile DMA
#   h — event-route per-step overhead, same units
#
# Both are *fit against the committed BENCH_PR3.json sparsity sweeps*
# (the measured predicated-vs-compacted crossover this repo has been
# tracking since PR 3) rather than hand-tuned: see
# ROUTE_CALIBRATION_POINTS and fit_route_params below.
# --------------------------------------------------------------------------

import functools
import json
import math
import re

# Geometry of the BENCH_PR3 sparsity sweep rows (benchmarks/sparsity_sweep):
# (M, K, N) = (512, 512, 256) at 128-blocks -> a 4x4 occupancy map, 16 tiles.
CALIBRATION_TILES_M = 4
CALIBRATION_TILES_K = 4

# (occupied_tiles, t_dense_us, t_event_us) per op, transcribed from the two
# sweeps committed in BENCH_PR3.json (rows `sparsity/<op>/pallas[-csr]/s*`;
# occupied = occupancy_fraction * 16).  test_hybrid_dispatch asserts this
# table equals crossover_points_from_bench("BENCH_PR3.json", op) so the
# embedded constants cannot drift from the committed artifact.
ROUTE_CALIBRATION_POINTS: dict[str, tuple[tuple[int, float, float], ...]] = {
    "spike_matmul": (
        (16, 19865.0, 22432.2), (13, 18517.1, 21322.9),
        (6, 12198.0, 11972.8), (3, 14113.5, 10709.5), (1, 11965.9, 6704.7),
        (16, 17170.3, 22083.6), (13, 17011.4, 20597.0),
        (6, 10876.1, 9943.2), (3, 10093.7, 10834.9), (1, 8829.8, 5846.8),
    ),
    "apec_matmul": (
        (16, 22323.9, 27813.6), (13, 25166.1, 25116.2),
        (6, 15328.0, 15821.9), (3, 19160.7, 14200.1), (1, 12176.5, 9935.8),
        (16, 27109.4, 28301.1), (13, 19246.3, 25143.1),
        (6, 20903.1, 16601.1), (3, 18878.6, 14265.4), (1, 14449.1, 9039.2),
    ),
}

_SPARSITY_ROW = re.compile(
    r"^sparsity/(?P<op>[\w-]+)/(?P<route>pallas(?:-csr)?)/s\d+,"
    r"(?P<us>[\d.]+),.*?occupancy=(?P<occ>[\d.]+)")


def crossover_points_from_bench(path: str, op: str,
                                ) -> tuple[tuple[int, float, float], ...]:
    """Re-derive (occupied_tiles, t_dense_us, t_event_us) from a committed
    benchmark JSON (BENCH_PR3.json schema) — the provenance check for
    ROUTE_CALIBRATION_POINTS."""
    with open(path) as f:
        payload = json.load(f)
    total = CALIBRATION_TILES_M * CALIBRATION_TILES_K
    points: list[tuple[int, float, float]] = []
    for sweep in payload["sweeps"]:
        dense: dict[int, float] = {}
        event: dict[int, float] = {}
        for row in sweep["rows"]:
            m = _SPARSITY_ROW.match(row)
            if not m or m.group("op") != op:
                continue
            occupied = round(float(m.group("occ")) * total)
            side = event if m.group("route") == "pallas-csr" else dense
            side[occupied] = float(m.group("us"))
        for occupied in sorted(set(dense) & set(event), reverse=True):
            points.append((occupied, dense[occupied], event[occupied]))
    return tuple(points)


# (sparsity_pct, spike_mb_f32, spike_mb_packed) per model family,
# transcribed from the e2e bytes-ledger rows committed in BENCH_PR7.json
# (rows `e2e_event/<family>/bytes/s*`). The MB values are MODELED (from
# the deterministic clustered-spike occupancy maps via matmul_bytes_moved
# + spike_payload_bytes), so regeneration reproduces them exactly.
# test_packed_events asserts this table equals
# packed_bytes_points_from_bench("BENCH_PR7.json", family) — the embedded
# constants cannot drift from the committed artifact — and that the
# packed reduction clears 4x at the 90/97% points (it is ~32x by
# construction: same trimmed grid, 1/32 the bytes per spike tile).
PACKED_BYTES_POINTS: dict[str, tuple[tuple[int, float, float], ...]] = {
    "cnn": (
        (50, 4.75, 0.148), (60, 4.625, 0.145), (80, 2.938, 0.092),
        (90, 2.875, 0.09), (97, 2.875, 0.09),
    ),
    "spikingformer": (
        (50, 6.5, 0.203), (60, 5.625, 0.176), (80, 4.312, 0.135),
        (90, 3.562, 0.111), (97, 3.5, 0.109),
    ),
}

_PACKED_BYTES_ROW = re.compile(
    r"^e2e_event/(?P<family>[\w-]+)/bytes/s(?P<pct>\d+),[\d.]+,"
    r".*?spike_mb_f32=(?P<f32>[\d.]+);spike_mb_packed=(?P<packed>[\d.]+)")


def packed_bytes_points_from_bench(path: str, family: str,
                                   ) -> tuple[tuple[int, float, float], ...]:
    """Re-derive (sparsity_pct, spike_mb_f32, spike_mb_packed) from a
    committed benchmark JSON (BENCH_PR7.json schema) — the provenance
    check for PACKED_BYTES_POINTS."""
    with open(path) as f:
        payload = json.load(f)
    points: list[tuple[int, float, float]] = []
    for sweep in payload["sweeps"]:
        for row in sweep["rows"]:
            m = _PACKED_BYTES_ROW.match(row)
            if not m or m.group("family") != family:
                continue
            points.append((int(m.group("pct")), float(m.group("f32")),
                           float(m.group("packed"))))
    return tuple(points)


@functools.lru_cache(maxsize=None)
def _expected_empty_rows(occupied: int, mt: int, kt: int) -> float:
    """Expected all-empty m-tile rows when `occupied` tiles land uniformly
    on an (mt, kt) map — matches the clustered-spike generators, which
    permute exactly n_live tiles.  Each empty row costs the event route a
    dummy step (tile_matmul_savings charges the same)."""
    total = mt * kt
    occupied = max(0, min(int(occupied), total))
    if occupied > total - kt:
        return 0.0
    return mt * math.comb(total - kt, occupied) / math.comb(total, occupied)


def route_step_costs(occupied: int, mt: int, kt: int,
                     r: float, h: float) -> tuple[float, float]:
    """(dense_cost, event_cost) of one matmul-form call, in units of one
    grid step's tile DMA.  Same structural accounting as
    tile_matmul_savings (per output N-tile, so nt cancels):

      dense: every one of the mt*kt steps streams its tiles; only the
             `occupied` steps spend MXU work (r each).
      event: only occupied steps plus the all-empty-row dummies run, each
             paying DMA + the compaction overhead h; dummies skip the MXU
             (their occ=0 predicates the accumulate off, same as dense's
             empty steps).
    """
    dummies = _expected_empty_rows(occupied, mt, kt)
    dense = mt * kt + r * occupied
    event = occupied * (1.0 + r + h) + dummies * (1.0 + h)
    return dense, event


def fit_route_params(points: tuple[tuple[int, float, float], ...],
                     mt: int = CALIBRATION_TILES_M,
                     kt: int = CALIBRATION_TILES_K) -> tuple[float, float]:
    """Fit (r, h) by coarse log-grid least squares on the *ratio*
    event/dense (ratios cancel the unknown us-per-step scale, so the two
    timing sweeps calibrate two unitless rates)."""
    grid = np.geomspace(0.02, 20.0, 61)
    best = (math.inf, 1.0, 1.0)
    for r in grid:
        for h in grid:
            err = 0.0
            for occupied, t_dense, t_event in points:
                dense, event = route_step_costs(occupied, mt, kt, r, h)
                err += (math.log(event / dense)
                        - math.log(t_event / t_dense)) ** 2
            if err < best[0]:
                best = (err, float(r), float(h))
    return best[1], best[2]


@functools.lru_cache(maxsize=None)
def calibrated_route_params(op: str) -> tuple[float, float]:
    """(r, h) for `op`; econv shares spike_matmul's calibration (it lowers
    to the same spike-matmul tile grids via im2col)."""
    points = ROUTE_CALIBRATION_POINTS.get(op)
    if points is None:
        points = ROUTE_CALIBRATION_POINTS["spike_matmul"]
    return fit_route_params(points)


def event_route_wins(op: str, occupied: int, mt: int, kt: int) -> bool:
    """The hybrid predicate: does the event-compacted route cost less than
    the predicated-dense route at this occupied-tile count?"""
    r, h = calibrated_route_params(op)
    dense, event = route_step_costs(occupied, mt, kt, r, h)
    return event < dense


# --- pow2 occupancy buckets (same idiom as the CSR step caps) -------------
# bucket(c) = bit_length(c): 0 | 1 | 2-3 | 4-7 | 8-15 | ...  jit then sees
# at most bit_length(mt*kt)+1 routes per map shape, never one per count.

def pow2_bucket(count: int) -> int:
    """Band index of an occupied-tile count (concrete ints)."""
    return int(count).bit_length()


def pow2_bucket_traced(count, max_bits: int):
    """Traced bit_length: #{i < max_bits : count >= 2**i}. `max_bits` is
    static (total_tiles.bit_length()), so the result stays in range."""
    import jax.numpy as jnp
    thresholds = jnp.asarray(2, jnp.int32) ** jnp.arange(max_bits,
                                                         dtype=jnp.int32)
    return jnp.sum((count >= thresholds).astype(jnp.int32))


def num_buckets(total_tiles: int) -> int:
    return int(total_tiles).bit_length() + 1


def bucket_representative(bucket: int, total_tiles: int) -> int:
    """Midpoint-ish count of band `bucket` (0, 1, 3, 6, 12, ...), clamped
    to the map's tile total — the concrete count the predicate is asked
    about on behalf of the whole band."""
    return min(int(total_tiles), (3 << bucket) >> 2)


def hybrid_route_table(op: str, mt: int, kt: int) -> tuple[bool, ...]:
    """Per-bucket route choice for an (mt, kt) map: True = event route."""
    total = mt * kt
    return tuple(
        event_route_wins(op, bucket_representative(b, total), mt, kt)
        for b in range(num_buckets(total)))


def hybrid_event_bucket_threshold(op: str, mt: int, kt: int) -> int:
    """Largest bucket routed to the event kernel, taking the leading-True
    prefix of hybrid_route_table (routes must be monotone in occupancy for
    a single lax.cond boundary); -1 when dense always wins."""
    table = hybrid_route_table(op, mt, kt)
    threshold = 0
    while threshold < len(table) and table[threshold]:
        threshold += 1
    return threshold - 1


def summarize(layers: list[LayerCycles], hw: ExSpikeHW = ExSpikeHW(),
              apec: bool = False) -> dict:
    """Network-level Table II style metrics."""
    cycles = sum(l.total for l in layers)
    ops = sum(l.dense_ops for l in layers)
    latency_s = cycles / hw.clock_hz
    gops = ops / latency_s / 1e9 if latency_s > 0 else 0.0
    power = hw.power_w_apec2 if apec else hw.power_w_baseline
    return {
        "cycles": cycles,
        "latency_ms": latency_s * 1e3,
        "fps": 1.0 / latency_s if latency_s > 0 else 0.0,
        "gops": gops,
        "gops_per_w": gops / power,
        "gops_per_w_per_pe": gops / power / hw.n_pe,
        "total_events": sum(l.events for l in layers),
    }
