"""Spike-driven self-attention (SDSA) — the Attention Core (Sec. III-C).

The paper computes attention over binary Q, K, V spikes in two stages:

  Stage 1 (KV):   kv_mask = K AND V              (elementwise, N x d)
                  status  = column-wise OR of kv_mask   (d bits)
  Stage 2 (QKV):  attn[i] = Q[i] AND status      (per row)

Properties that matter at system level (all tested):
  * linear in sequence length N — no N x N score matrix;
  * the entire cross-token state is the d-bit status vector, so streaming
    decode carries O(d) state per head ("KV cache" of constant size) —
    this is what makes the 500k-token long-context shape sub-quadratic;
  * status is a monotone, permutation-invariant OR-reduction, so prefill
    and token-by-token decode agree exactly.

The OR form is the paper's hardware semantics and is used for inference.
For training, OR saturates gradients, so we also provide the sum form used
by the Spike-driven Transformer line of work (SDSA as Q * sum_t(K_t * V_t),
followed by an LIF fire stage) — `mode="sum"`. Both keep binary inputs and
avoid softmax/QK^T entirely.

Shapes: (..., N, d) where d is the per-head dim; heads live in leading axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_status_or(k: jax.Array, v: jax.Array) -> jax.Array:
    """Stage 1, OR form: (..., N, d) -> (..., d) binary status vector."""
    kv = k * v                      # AND for binary tensors
    return jnp.max(kv, axis=-2)     # column-wise OR


def kv_status_sum(k: jax.Array, v: jax.Array) -> jax.Array:
    """Stage 1, sum form: integer-valued column accumulation."""
    return jnp.sum(k * v, axis=-2)


def sdsa_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
             mode: str = "or") -> jax.Array:
    """Dense-jnp SDSA (the `ref` oracle of the dispatch registry).

    mode="or": paper-faithful Attention Core output (binary).
    mode="sum": accumulated form; caller applies LIF/threshold to re-binarize
    (the FPE stage in hardware does exactly this fire step).
    """
    if mode == "or":
        status = kv_status_or(k, v)
    elif mode == "sum":
        status = kv_status_sum(k, v)
    else:
        raise ValueError(f"unknown SDSA mode: {mode}")
    return q * status[..., None, :, ]


def sdsa(q: jax.Array, k: jax.Array, v: jax.Array, mode: str = "or") -> jax.Array:
    """Full SDSA. q,k,v: (..., N, d) binary spikes -> (..., N, d).

    Routes through the backend registry (`kernels.dispatch`): the dense
    oracle by default on CPU, the bit-packed Pallas kernels on TPU, or
    whatever ``EXSPIKE_BACKEND`` selects.
    """
    from repro.kernels.dispatch import dispatch   # lazy: no import cycle
    return dispatch("sdsa", q, k, v, mode=mode)


def causal_sdsa_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    mode: str = "or") -> jax.Array:
    """Causal (LM) SDSA — the `ref` oracle of the `causal_sdsa` registry op.

    q, k, v: (T, ..., N, d) binary spikes with T the micro-timestep axis
    and N the token axis. The kv mask first pools over micro-steps, then
    status[i] accumulates causally over tokens j <= i (paper Fig. 6,
    causal form for LMs):

      mode="or":  status = cumOR  (cummax on {0,1});  out = Q AND status
      mode="sum": status = cumsum of event counts;    out = Q * status

    The token-by-token streaming form (`sdsa_decode_update` /
    `attention_sdsa_decode`) is property-equal: prefix-OR/sum is exactly
    the fold of per-token updates.
    """
    kv = k * v                                     # AND   (T, ..., N, d)
    if mode == "or":
        phase = jnp.max(kv, axis=0)                # OR over micro-steps
        status = jax.lax.cummax(phase, axis=phase.ndim - 2)  # prefix-OR

    elif mode == "sum":
        phase = jnp.sum(kv, axis=0)
        status = jnp.cumsum(phase, axis=-2)
    else:
        raise ValueError(f"unknown SDSA mode: {mode}")
    return q * status[None]


def causal_sdsa_packed_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           mode: str = "or") -> jax.Array:
    """Bit-packed pure-jnp causal SDSA (uint32 word semantics, no Pallas):
    pack -> AND -> OR-fold T -> associative prefix-OR -> AND -> unpack."""
    del mode                                       # "or" only (supports-gated)
    from .spikes import PACK, pack_spikes, unpack_spikes
    t = q.shape[0]
    lead, (n, d) = q.shape[1:-2], q.shape[-2:]
    pad = (-d) % PACK

    def prep(x):
        x = x.reshape(t, -1, n, d)
        return pack_spikes(jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad))),
                           axis=-1)

    qp, kp, vp = prep(q), prep(k), prep(v)
    kv = jax.lax.reduce(kp & vp, jnp.uint32(0), jax.lax.bitwise_or, (0,))
    status = jax.lax.associative_scan(jnp.bitwise_or, kv, axis=-2)
    out = unpack_spikes(qp & status[None], axis=-1, dtype=q.dtype)[..., :d]
    return out.reshape((t,) + lead + (n, d))


def causal_sdsa(q: jax.Array, k: jax.Array, v: jax.Array,
                mode: str = "or") -> jax.Array:
    """Causal SDSA routed through the backend registry (`kernels.dispatch`).

    q, k, v: (T, ..., N, d) binary spikes -> (T, ..., N, d).
    """
    from repro.kernels.dispatch import dispatch   # lazy: no import cycle
    return dispatch("causal_sdsa", q, k, v, mode=mode)


def sdsa_decode_init(head_shape: tuple, mode: str = "or", dtype=jnp.float32) -> jax.Array:
    """Initial streaming state: zeros(..., d)."""
    del mode
    return jnp.zeros(head_shape, dtype)


def sdsa_decode_update(
    status: jax.Array, k_t: jax.Array, v_t: jax.Array, mode: str = "or"
) -> jax.Array:
    """Fold one token's K,V spikes into the running status (O(d) update).

    Mirrors the hardware's on-the-fly OR during V write-back (Sec. III-C).
    """
    kv = k_t * v_t
    if mode == "or":
        return jnp.maximum(status, kv)
    return status + kv


def sdsa_decode_attend(q_t: jax.Array, status: jax.Array) -> jax.Array:
    """Stage 2 for one token: Q AND/times status."""
    return q_t * status


def sdsa_cross(q: jax.Array, k_enc: jax.Array, v_enc: jax.Array, mode: str = "or") -> jax.Array:
    """Cross-attention variant (whisper decoder): status from encoder K,V."""
    return sdsa(q, k_enc, v_enc, mode=mode)


def sdsa_ops(n: int, d: int) -> int:
    """Logic-op count: stage1 AND (N*d) + OR-reduce (N*d) + stage2 AND (N*d).

    Contrast with softmax attention's 2*N^2*d MACs — the Fig. 6 economics.
    """
    return 3 * n * d


def softmax_attention_ops(n: int, d: int) -> int:
    return 2 * n * n * d
