"""Spike-tensor utilities: bit-packing, popcount, tile occupancy.

The paper stores spike sequences so that "each address in the Spike SRAM
stores spike data from all input channels at the same spatial location"
(Sec. III-A, feature 1) and filters events with a priority encoder. On TPU
the unit of event-driven execution is a VMEM tile, not a wire, so the
equivalents are:

  * bit-packed spike words (uint32 lanes) for the VPU logic paths
    (SDSA AND/OR, APEC overlap extraction) — 32x memory-traffic reduction
    over bf16 0/1 tensors;
  * per-tile occupancy maps (popcount > 0) that let the Pallas spike-matmul
    kernel skip all-zero tiles — the block-level analogue of the paper's
    fast event filter + AER FIFO.
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

PACK = 32  # bits per packed word

# --------------------------------------------------- pre-pass instrumentation
# `tile_occupancy` is the *standalone* dense occupancy pre-pass — a full
# read of a spike-sized tensor just to learn which tiles hold events. The
# full-event pipeline's whole point (EventTensor + the fused LIF emission)
# is that between spiking layers this pass never runs; the watcher stack
# lets tests and benchmarks count (at trace/eager call time) how many
# dense pre-passes a code path actually paid for.
_PREPASS_WATCHERS: list = []


@contextlib.contextmanager
def watch_occupancy_prepasses():
    """Context manager yielding a mutable record of `tile_occupancy` calls
    made while active: {"calls": n, "elements": total input elements}."""
    rec = {"calls": 0, "elements": 0}
    _PREPASS_WATCHERS.append(rec)
    try:
        yield rec
    finally:
        _PREPASS_WATCHERS.remove(rec)


def pack_spikes(s: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a binary {0,1} tensor into uint32 words along `axis`.

    The packed axis length must be a multiple of 32 (pad upstream).
    Bit i of word w corresponds to channel w*32 + i (little-endian).
    """
    s = jnp.moveaxis(s, axis, -1)
    c = s.shape[-1]
    if c % PACK != 0:
        raise ValueError(f"pack axis {c} not a multiple of {PACK}")
    bits = s.reshape(s.shape[:-1] + (c // PACK, PACK)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(PACK, dtype=jnp.uint32))
    packed = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_spikes(p: jax.Array, axis: int = -1, dtype=jnp.float32) -> jax.Array:
    """Inverse of `pack_spikes`."""
    p = jnp.moveaxis(p, axis, -1)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (p[..., None] >> shifts) & jnp.uint32(1)
    out = bits.reshape(p.shape[:-1] + (p.shape[-1] * PACK,)).astype(dtype)
    return jnp.moveaxis(out, -1, axis)


def popcount(p: jax.Array) -> jax.Array:
    """Per-word population count of packed spikes."""
    return jax.lax.population_count(p)


def packed_width(k: int) -> int:
    """Number of uint32 words covering `k` bits (ceil division)."""
    return -(-int(k) // PACK)


def pack_spikes_padded(s: jax.Array, axis: int = -1) -> jax.Array:
    """`pack_spikes` for arbitrary axis lengths: the packed axis is
    zero-padded up to the next multiple of 32, so the last word's high
    bits are guaranteed-zero padding (consumers slice logical channels
    back out with `unpack_spikes(...)[..., :k]`)."""
    s = jnp.moveaxis(s, axis, -1)
    pad = (-s.shape[-1]) % PACK
    if pad:
        widths = [(0, 0)] * (s.ndim - 1) + [(0, pad)]
        s = jnp.pad(s, widths)
    return jnp.moveaxis(pack_spikes(s, axis=-1), -1, axis)


def packed_tile_occupancy(p: jax.Array, tile_m: int, tile_k: int,
                          k: Optional[int] = None) -> jax.Array:
    """`tile_occupancy` computed from uint32-packed spike words.

    `p` is a (..., M, KW) packed matrix (KW words of 32 channels each);
    the map covers the UNPACKED (M, KW*32) matrix tiled (tile_m, tile_k)
    — identical counts to `tile_occupancy` on the dense tensor, derived
    from per-word popcounts, so packing makes the occupancy pre-pass 32x
    cheaper instead of impossible. `k` (logical channel count) only
    validates that the word axis covers it; pad bits are zero by the
    `pack_spikes_padded` contract and never inflate a count. Deliberately
    NOT ticking the dense pre-pass watchers: this is the packed path's
    cheap byproduct, not the full-width read the watchers exist to catch.
    """
    m, kw = p.shape[-2], p.shape[-1]
    if k is not None and packed_width(k) != kw:
        raise ValueError(
            f"packed width {kw} words does not cover k={k} "
            f"(want {packed_width(k)})")
    if tile_k % PACK:
        raise ValueError(f"tile_k {tile_k} not a multiple of {PACK}")
    kt_words = tile_k // PACK
    if m % tile_m or kw % kt_words:
        raise ValueError(
            f"packed shape ({m},{kw}) not tileable by ({tile_m},{kt_words})")
    counts = popcount(p).astype(jnp.int32)
    t = counts.reshape(counts.shape[:-2]
                       + (m // tile_m, tile_m, kw // kt_words, kt_words))
    return jnp.sum(t, axis=(-3, -1))


def event_count(s: jax.Array) -> jax.Array:
    """Total number of active events in a binary spike tensor."""
    return jnp.sum(s.astype(jnp.int32))


def sparsity(s: jax.Array) -> jax.Array:
    """Fraction of zeros (the paper's per-layer 'input sparsity', Fig. 2)."""
    return 1.0 - jnp.mean(s.astype(jnp.float32))


def tile_occupancy(s: jax.Array, tile_m: int, tile_k: int) -> jax.Array:
    """Occupancy map over (M, K) spike matrix tiled (tile_m, tile_k).

    Returns an int32 (M/tile_m, K/tile_k) array of per-tile event counts.
    Zero entries are tiles the event-driven matmul kernel can skip entirely
    (the TPU analogue of 'AER FIFO empty -> no computation triggered').
    """
    m, k = s.shape[-2], s.shape[-1]
    if m % tile_m or k % tile_k:
        raise ValueError(f"shape ({m},{k}) not tileable by ({tile_m},{tile_k})")
    for rec in _PREPASS_WATCHERS:
        rec["calls"] += 1
        rec["elements"] += int(np.prod(s.shape))
    t = s.reshape(s.shape[:-2] + (m // tile_m, tile_m, k // tile_k, tile_k))
    # Count nonzeros, not a sum-cast: fractional drive (direct-coded first
    # layer) must never truncate to an "empty" tile and get skipped.
    return jnp.sum((t != 0).astype(jnp.int32), axis=(-3, -1))


def occupancy_fraction(s: jax.Array, tile_m: int, tile_k: int) -> jax.Array:
    """Fraction of non-empty tiles — predicts the tile-skip speedup."""
    occ = tile_occupancy(s, tile_m, tile_k)
    return jnp.mean((occ > 0).astype(jnp.float32))


class TileCSR(NamedTuple):
    """CSR-of-tiles event stream for the compacted spike-matmul grid.

    The occupancy map is the tile-granular AER FIFO; this is that FIFO
    *drained into a work list*: one entry per occupied (m-tile, k-tile),
    row-major, so the Pallas `pallas-csr` kernel's grid walks occupied
    tiles only instead of predicating inside a dense (i, j, k) grid.

    Fields (cap = number of grid steps, static):
      row_ptr     (MT+1,) int32 — canonical CSR row pointers over m-tiles
                  (row i's occupied k-tiles are entries row_ptr[i]:row_ptr[i+1])
      tile_m_idx  (cap,)  int32 — m-tile index per grid step
      tile_k_idx  (cap,)  int32 — k-tile index per grid step
      occ         (cap,)  int32 — per-step event count, already masked to 0
                  on dummy steps (see below) and on padding steps
      valid       (cap,)  int32 — 1 on real steps (occupied tiles AND the
                  dummy row visits), 0 on clamp padding
      tiling      optional (tile_m, tile_k) this CSR was built for
      map_shape   (MT, KT) of the occupancy map it was compacted from —
                  together with `tiling` lets consumers reject a work
                  list built for different tiles or a different tile grid
                  (wrong k-tile indices would be silently wrong)

    Two kinds of non-compute step keep the kernel correct:
      * every m-tile row with no occupied tiles gets one *dummy* step at
        k-tile 0 (occ=0) so its output block is still visited and zeroed
        — Pallas does not zero unvisited output blocks;
      * when `cap` exceeds the real step count (the traced/jit path, where
        the count is data-dependent), trailing *padding* steps repeat the
        last real step's tile indices, so their block index maps resolve to
        the already-resident tiles: no new DMA, and occ=0 skips the MXU.

    Built by `occupancy_to_csr`: with concrete occupancy (outside jit —
    the benchmark / serve pre-pass) cap is trimmed to the exact count, so
    empty tiles cost zero grid steps; under tracing cap falls back to
    MT*KT and empty tiles cost a (DMA-free, FLOP-free) clamped step.
    """
    row_ptr: jax.Array
    tile_m_idx: jax.Array
    tile_k_idx: jax.Array
    occ: jax.Array
    valid: jax.Array
    tiling: Optional[tuple] = None
    map_shape: Optional[tuple] = None

    @property
    def n_steps(self) -> int:
        return self.tile_k_idx.shape[0]

    @property
    def n_rows(self) -> int:
        return self.row_ptr.shape[0] - 1

    def check_compatible(self, tile_m: int, tile_k: int,
                         mt: int, kt: int) -> None:
        """Raise when this CSR was built for a different tiling or a
        different (MT, KT) tile grid — its step indices would gate the
        wrong tiles silently. Skipped per-tag for untagged CSRs and when
        a tag's ints crossed a jit boundary (became tracers)."""
        for got, want, what in ((self.tiling, (tile_m, tile_k), "tiling"),
                                (self.map_shape, (mt, kt), "tile grid")):
            if got is None or not isinstance(got[0], int):
                continue
            if tuple(got) != want:
                raise ValueError(
                    f"TileCSR built for {what} {tuple(got)} used with "
                    f"{what} {want}")


def occupancy_to_csr(occ: jax.Array, cap: Optional[int] = None,
                     tiling: Optional[tuple] = None) -> TileCSR:
    """Compact a (MT, KT) per-tile occupancy map into a `TileCSR` work list.

    `cap` bounds the step count (static). Default: the exact count
    (occupied tiles + one dummy per empty row) when `occ` is concrete,
    MT*KT under tracing. A caller-supplied `cap` must cover the real count
    — concrete inputs are checked exactly; traced inputs are checked
    against the static lower bound of MT (one dummy step per m-tile row,
    so all-empty maps still zero every output block) and beyond that
    silently truncate (pass the worst case, MT*KT, when unsure).
    """
    mt, kt = occ.shape
    if not isinstance(occ, jax.core.Tracer):
        # Concrete pre-pass (numpy): trim cap to the exact step count so
        # the kernel grid is literally `occupied tiles only`.
        occ_np = np.asarray(occ)
        mask = occ_np > 0
        mask2 = mask.copy()
        mask2[:, 0] |= ~mask.any(axis=1)          # dummy step per empty row
        flat = np.nonzero(mask2.ravel())[0]
        total = len(flat)
        if cap is None:
            cap = total
        elif cap < total:
            raise ValueError(f"cap {cap} < required steps {total}")
        steps = np.concatenate(
            [flat, np.full(cap - total, flat[-1], np.int64)])
        valid = (np.arange(cap) < total).astype(np.int32)
        row_ptr = np.concatenate(
            [[0], np.cumsum(mask2.sum(axis=1))]).astype(np.int32)
        occ_steps = occ_np.ravel()[steps].astype(np.int32) \
            * mask.ravel()[steps] * valid
        return TileCSR(jnp.asarray(row_ptr),
                       jnp.asarray((steps // kt).astype(np.int32)),
                       jnp.asarray((steps % kt).astype(np.int32)),
                       jnp.asarray(occ_steps), jnp.asarray(valid), tiling,
                       (mt, kt))
    if cap is None:
        cap = mt * kt
    elif cap < mt:
        # Static lower bound: every m-tile row needs at least its dummy
        # step or its output block is never visited — Pallas leaves
        # unvisited blocks unzeroed, so an all-empty map with cap < MT
        # would return garbage rows, silently. The data-dependent exact
        # count can't be checked under tracing; the row count can.
        raise ValueError(
            f"cap {cap} < {mt} m-tile rows: every row needs >= 1 step "
            f"(dummy steps zero all-empty rows' output blocks)")
    mask = occ > 0
    mask2 = mask.at[:, 0].set(mask[:, 0] | ~jnp.any(mask, axis=1))
    flat, = jnp.nonzero(mask2.ravel(), size=cap, fill_value=0)
    total = jnp.sum(mask2.astype(jnp.int32))
    last = flat[jnp.maximum(total - 1, 0)]
    arange = jnp.arange(cap)
    steps = jnp.where(arange < total, flat, last)  # clamp padding -> no DMA
    valid = (arange < total).astype(jnp.int32)
    row_ptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.sum(mask2, axis=1)).astype(jnp.int32)])
    occ_steps = (occ.ravel()[steps] * mask.ravel()[steps] * valid
                 ).astype(jnp.int32)
    return TileCSR(row_ptr, (steps // kt).astype(jnp.int32),
                   (steps % kt).astype(jnp.int32), occ_steps, valid, tiling,
                   (mt, kt))


def tile_csr(s: jax.Array, tile_m: int, tile_k: int,
             cap: Optional[int] = None) -> TileCSR:
    """Occupancy pre-pass + CSR compaction of a (M, K) spike matrix."""
    return occupancy_to_csr(tile_occupancy(s, tile_m, tile_k), cap=cap,
                            tiling=(tile_m, tile_k))


def build_csr(occ: jax.Array, block_m: int, block_k: int) -> TileCSR:
    """Occupancy map -> `TileCSR` work list with the power-of-two step-count
    bucket (dense-capped, `pow2_step_cap` — shared between the single-device
    wrappers, the per-shard pre-pass, and `EventTensor.csr`, so every
    consumer buckets identically). Traced maps keep the dense cap (one
    compile); concrete maps trim to occupied tiles and bucket."""
    tiling = (block_m, block_k)
    if isinstance(occ, jax.core.Tracer):
        return occupancy_to_csr(occ, tiling=tiling)
    exact = occupancy_to_csr(occ, tiling=tiling)
    mt, kt = occ.shape
    cap = pow2_step_cap(exact.n_steps, mt * kt)
    if cap == exact.n_steps:
        return exact
    return occupancy_to_csr(occ, cap=cap, tiling=tiling)


def pow2_step_cap(n_steps: int, dense: int) -> int:
    """Round a CSR step count up to the next power of two, capped at the
    dense bound.

    The concrete pre-pass trims the grid to the occupied-tile count, but a
    *different* count per call (or per shard) would compile a fresh kernel
    core every time occupancy shifts. Padding steps are DMA/FLOP-free by
    design, so bucketing the cap at powers of two bounds the distinct grid
    sizes at O(log(dense)) while keeping the grid within 2x of exact.
    """
    n_steps = max(1, int(n_steps))
    return min(int(dense), 1 << (n_steps - 1).bit_length())


class RebalancePlan(NamedTuple):
    """Occupancy-weighted assignment of 128-row tile rows to shards.

    `perm` is a permutation of the map's tile-row indices: shard i owns
    rows `perm[i*rps:(i+1)*rps]` (rps = MT/n_shards), each shard's slice
    sorted ascending so a shard's local map keeps global row order.
    Built from the carried map alone — never from gathered spikes — and
    deterministic for a fixed map (ties break on row index, then shard
    index). `pre`/`post_per_shard` are occupied-tile counts under the
    static row-contiguous split vs this assignment, the before/after the
    straggler ledger records."""
    perm: np.ndarray
    pre_per_shard: tuple
    post_per_shard: tuple

    @property
    def n_shards(self) -> int:
        return len(self.pre_per_shard)

    @property
    def identity(self) -> bool:
        return bool((self.perm == np.arange(len(self.perm))).all())

    @property
    def improves(self) -> bool:
        """True iff the assignment strictly lowers the most-occupied
        shard — the max/mean imbalance metric (mean is split-invariant).
        With one tile row per shard a permutation can only relabel
        shards, so this is False and callers skip the payload gather."""
        return max(self.post_per_shard) < max(self.pre_per_shard)

    def inverse(self) -> np.ndarray:
        return np.argsort(self.perm)


def rebalance_shard_plan(occ: jax.Array, n_shards: int) -> RebalancePlan:
    """Plan an occupancy-weighted shard split of a concrete (MT, KT) map.

    Greedy heaviest-first: tile rows sorted by occupied-tile count
    (descending, row index breaking ties) are assigned to the currently
    lightest shard with spare capacity (every shard owns exactly
    MT/n_shards rows — shard_map's equal-split contract). A bounded
    stolen-tile tail pass then swaps rows between the heaviest and
    lightest shards while a swap strictly narrows the max-min spread —
    the residual imbalance greedy leaves when heavy rows arrive early.

    Same concreteness contract as `shard_occupancy_to_csr`: the plan is
    an eager pre-pass on the tiny map (raises on tracers) and never
    gathers payload data.
    """
    if isinstance(occ, jax.core.Tracer):
        raise ValueError(
            "rebalance_shard_plan is an eager (concrete) pre-pass on the "
            "carried occupancy map; it cannot run under tracing")
    mt, _ = occ.shape
    if mt % n_shards:
        raise ValueError(
            f"occupancy rows {mt} not divisible by {n_shards} shards")
    rps = mt // n_shards
    occ_np = np.asarray(occ)
    weight = (occ_np > 0).sum(axis=1).astype(np.int64)   # per tile row
    pre = tuple(int(weight[i * rps:(i + 1) * rps].sum())
                for i in range(n_shards))

    # Greedy LPT with fixed per-shard capacity.
    order = np.lexsort((np.arange(mt), -weight))
    members: list = [[] for _ in range(n_shards)]
    load = [0] * n_shards
    for r in order:
        i = min((i for i in range(n_shards) if len(members[i]) < rps),
                key=lambda i: (load[i], i))
        members[i].append(int(r))
        load[i] += int(weight[r])

    # Stolen-tile tail pass: swap one row between the heaviest and
    # lightest shard while that strictly narrows max-min. Bounded — each
    # accepted swap reduces an integer spread, but cap iterations anyway.
    for _ in range(4 * n_shards):
        h = max(range(n_shards), key=lambda i: (load[i], i))
        l = min(range(n_shards), key=lambda i: (load[i], i))
        spread = load[h] - load[l]
        if spread <= 1:
            break
        best = None
        for rh in members[h]:
            for rl in members[l]:
                d = int(weight[rh]) - int(weight[rl])
                if 0 < d < spread:
                    # post-swap spread contribution of this pair
                    gap = abs(spread - 2 * d)
                    key = (gap, rh, rl)
                    if best is None or key < best[0]:
                        best = (key, rh, rl)
        if best is None:
            break
        _, rh, rl = best
        members[h].remove(rh)
        members[l].remove(rl)
        members[h].append(rl)
        members[l].append(rh)
        load[h] += int(weight[rl]) - int(weight[rh])
        load[l] += int(weight[rh]) - int(weight[rl])

    members = [sorted(m) for m in members]
    perm = np.concatenate([np.asarray(m, dtype=np.int64) for m in members])
    return RebalancePlan(perm=perm, pre_per_shard=pre,
                         post_per_shard=tuple(int(x) for x in load))


def shard_occupancy_to_csr(occ: jax.Array, n_shards: int,
                           tiling: Optional[tuple] = None, *,
                           plan: Optional[RebalancePlan] = None) -> list:
    """Per-shard CSR pre-pass for mesh execution: one work list per data
    shard, built from that shard's rows of the occupancy map only.

    The (MT, KT) map is split row-contiguously into `n_shards` local
    (MT/n_shards, KT) maps — exactly the rows each shard of a row-sharded
    spike matrix owns — and each is compacted independently: no shard's
    work list depends on another shard's occupancy, which is what lets the
    sharded pre-pass run without gathering the global map (each device
    computes its own from its resident spikes).

    All shards share ONE `pow2_step_cap` bucket (sized by the most
    occupied shard), so every per-shard grid is congruent: the compiled
    kernel core is identical across shards, the per-shard CSRs stack into
    batched arrays, and one shard's occupancy shift re-buckets — and hence
    recompiles — only when it crosses a power-of-two boundary, never
    because a *different* shard changed.

    `plan`: optional `RebalancePlan` (from `rebalance_shard_plan` on this
    same map) — shard i then compacts the map rows the plan assigns it
    (still a numpy fancy-index slice, still one shared cap) instead of
    the static contiguous block. The caller owns permuting the payload
    rows to match (see `runtime.sharding.event_op_sharded`).

    Concrete maps only (the eager serve/benchmark pre-pass). Under
    tracing the split is the mesh's job: inside shard_map each shard
    compacts its local occupancy via `occupancy_to_csr`'s traced path.
    """
    if isinstance(occ, jax.core.Tracer):
        raise ValueError(
            "shard_occupancy_to_csr is the eager (concrete) pre-pass; "
            "under tracing each shard compacts its local occupancy inside "
            "shard_map via occupancy_to_csr")
    mt, kt = occ.shape
    if mt % n_shards:
        raise ValueError(
            f"occupancy rows {mt} not divisible by {n_shards} shards")
    rows = mt // n_shards
    occ_np = np.asarray(occ)
    # Keep the per-shard maps as NUMPY: inside a jit trace,
    # `jnp.asarray(np_array)` lifts the constant into the trace (a
    # tracer), which would silently flip `occupancy_to_csr` onto its
    # traced path — staging the whole compaction into the program and
    # losing the trimmed grid the concrete pre-pass exists for. Numpy
    # slices stay concrete no matter what trace is ambient.
    if plan is not None:
        if len(plan.perm) != mt or plan.n_shards != n_shards:
            raise ValueError(
                f"plan covers {len(plan.perm)} rows x {plan.n_shards} "
                f"shards, map has {mt} rows x {n_shards} shards")
        locals_ = [occ_np[plan.perm[i * rows:(i + 1) * rows]]
                   for i in range(n_shards)]
    else:
        locals_ = [occ_np[i * rows:(i + 1) * rows] for i in range(n_shards)]
    exact = [occupancy_to_csr(o, tiling=tiling) for o in locals_]
    cap = pow2_step_cap(max(c.n_steps for c in exact), rows * kt)
    if all(c.n_steps == cap for c in exact):
        return exact
    return [occupancy_to_csr(o, cap=cap, tiling=tiling) for o in locals_]


def stack_shard_csrs(csrs: list) -> TileCSR:
    """Stack per-shard `TileCSR`s (equal caps — `shard_occupancy_to_csr`
    guarantees it) into one TileCSR with a leading shard axis per field,
    ready to feed shard_map with `P('data')` specs: each shard receives
    its own work list and the global map never materializes on any device.
    The static tags stay the (identical) per-shard ones, so in-shard
    compatibility checks validate against local tile grids."""
    caps = {c.n_steps for c in csrs}
    if len(caps) != 1:
        raise ValueError(f"per-shard caps differ: {sorted(caps)}")
    tags = {(c.tiling, c.map_shape) for c in csrs}
    if len(tags) != 1:
        raise ValueError(f"per-shard CSR tags differ: {tags}")
    return TileCSR(*[jnp.stack([getattr(c, f) for c in csrs])
                     for f in ("row_ptr", "tile_m_idx", "tile_k_idx",
                               "occ", "valid")],
                   csrs[0].tiling, csrs[0].map_shape)


def to_binary(x: jax.Array) -> jax.Array:
    """Clamp any tensor to exact {0,1} in its own dtype (defensive)."""
    return (x > 0).astype(x.dtype)
