"""Spike-tensor utilities: bit-packing, popcount, tile occupancy.

The paper stores spike sequences so that "each address in the Spike SRAM
stores spike data from all input channels at the same spatial location"
(Sec. III-A, feature 1) and filters events with a priority encoder. On TPU
the unit of event-driven execution is a VMEM tile, not a wire, so the
equivalents are:

  * bit-packed spike words (uint32 lanes) for the VPU logic paths
    (SDSA AND/OR, APEC overlap extraction) — 32x memory-traffic reduction
    over bf16 0/1 tensors;
  * per-tile occupancy maps (popcount > 0) that let the Pallas spike-matmul
    kernel skip all-zero tiles — the block-level analogue of the paper's
    fast event filter + AER FIFO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PACK = 32  # bits per packed word


def pack_spikes(s: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a binary {0,1} tensor into uint32 words along `axis`.

    The packed axis length must be a multiple of 32 (pad upstream).
    Bit i of word w corresponds to channel w*32 + i (little-endian).
    """
    s = jnp.moveaxis(s, axis, -1)
    c = s.shape[-1]
    if c % PACK != 0:
        raise ValueError(f"pack axis {c} not a multiple of {PACK}")
    bits = s.reshape(s.shape[:-1] + (c // PACK, PACK)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(PACK, dtype=jnp.uint32))
    packed = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_spikes(p: jax.Array, axis: int = -1, dtype=jnp.float32) -> jax.Array:
    """Inverse of `pack_spikes`."""
    p = jnp.moveaxis(p, axis, -1)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (p[..., None] >> shifts) & jnp.uint32(1)
    out = bits.reshape(p.shape[:-1] + (p.shape[-1] * PACK,)).astype(dtype)
    return jnp.moveaxis(out, -1, axis)


def popcount(p: jax.Array) -> jax.Array:
    """Per-word population count of packed spikes."""
    return jax.lax.population_count(p)


def event_count(s: jax.Array) -> jax.Array:
    """Total number of active events in a binary spike tensor."""
    return jnp.sum(s.astype(jnp.int32))


def sparsity(s: jax.Array) -> jax.Array:
    """Fraction of zeros (the paper's per-layer 'input sparsity', Fig. 2)."""
    return 1.0 - jnp.mean(s.astype(jnp.float32))


def tile_occupancy(s: jax.Array, tile_m: int, tile_k: int) -> jax.Array:
    """Occupancy map over (M, K) spike matrix tiled (tile_m, tile_k).

    Returns an int32 (M/tile_m, K/tile_k) array of per-tile event counts.
    Zero entries are tiles the event-driven matmul kernel can skip entirely
    (the TPU analogue of 'AER FIFO empty -> no computation triggered').
    """
    m, k = s.shape[-2], s.shape[-1]
    if m % tile_m or k % tile_k:
        raise ValueError(f"shape ({m},{k}) not tileable by ({tile_m},{tile_k})")
    t = s.reshape(s.shape[:-2] + (m // tile_m, tile_m, k // tile_k, tile_k))
    # Count nonzeros, not a sum-cast: fractional drive (direct-coded first
    # layer) must never truncate to an "empty" tile and get skipped.
    return jnp.sum((t != 0).astype(jnp.int32), axis=(-3, -1))


def occupancy_fraction(s: jax.Array, tile_m: int, tile_k: int) -> jax.Array:
    """Fraction of non-empty tiles — predicts the tile-skip speedup."""
    occ = tile_occupancy(s, tile_m, tile_k)
    return jnp.mean((occ > 0).astype(jnp.float32))


def to_binary(x: jax.Array) -> jax.Array:
    """Clamp any tensor to exact {0,1} in its own dtype (defensive)."""
    return (x > 0).astype(x.dtype)
