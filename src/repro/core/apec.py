"""APEC — adjacent-position event compression (Sec. III-A2, Fig. 5).

Adjacent spatial positions exhibit correlated spike activity, so their
channel spike sequences overlap. APEC groups g adjacent positions,
extracts the shared overlap

    O_G = AND_{i=1..g} S_i                                   (Eq. 1)

computes the overlap's contribution ONCE (caching its partial sums), and
then adds each position's disjoint residual R_i = S_i AND NOT O_G. Because
convolution / FC accumulation is linear in the input events, the
reorganization is numerically exact. Savings:

    dN_event = (g-1) |O_G|                                   (Eq. 2)
    dC       = (g-1) |O_G| * C_o * k^2                       (Eq. 3)

with overhead M_ov ~ C_o * k^2 * w_acc bits of partial-sum storage
(Eq. 4). Higher-order overlap |O_G| shrinks with g, so G2 wins in practice
(paper Fig. 7) — our benchmarks reproduce that trade-off from measured
spike statistics.

On TPU the same decomposition is applied at tile granularity: grouped
columns of the spike matrix are rewritten as [overlap, residual...] so the
occupancy-skipping matmul kernel sees strictly sparser residual tiles.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


def group_adjacent(s: jax.Array, g: int, axis: int = -2) -> jax.Array:
    """Reshape (..., P, C) -> (..., P/g, g, C): groups of g adjacent positions.

    For CNN feature maps, callers flatten (H, W) row-major first so groups
    are horizontally adjacent pixels (the paper's Fig. 5 layout); for token
    sequences, groups are adjacent tokens (see DESIGN.md §4).
    """
    s = jnp.moveaxis(s, axis, -2)
    p = s.shape[-2]
    if p % g != 0:
        raise ValueError(f"positions {p} not divisible by group {g}")
    out = s.reshape(s.shape[:-2] + (p // g, g, s.shape[-1]))
    return out


def ungroup(sg: jax.Array) -> jax.Array:
    """Inverse of `group_adjacent` (axis restored to -2)."""
    return sg.reshape(sg.shape[:-3] + (sg.shape[-3] * sg.shape[-2], sg.shape[-1]))


def apec_decompose(s: jax.Array, g: int) -> Tuple[jax.Array, jax.Array]:
    """Overlap/residual decomposition of grouped positions.

    s: (..., P, C) binary. Returns (overlap (..., P/g, C),
    residual (..., P/g, g, C)) with  s_i == overlap OR residual_i  and
    overlap AND residual_i == 0 for every member i (Fig. 5 semantics).
    """
    sg = group_adjacent(s, g)                       # (..., G, g, C)
    overlap = jnp.min(sg, axis=-2)                  # AND over group members
    residual = sg * (1.0 - overlap[..., None, :])   # S_i AND NOT O_G
    return overlap, residual


def apec_reconstruct(overlap: jax.Array, residual: jax.Array) -> jax.Array:
    """Rebuild the original grouped spikes (for equivalence tests)."""
    sg = jnp.maximum(residual, overlap[..., None, :])
    return ungroup(sg)


def apec_matmul_jnp(s: jax.Array, w: jax.Array, g: int) -> jax.Array:
    """Event accumulation through APEC: W.T @ s_i per position, but the
    overlap's partial sum is computed once per group and reused.

    s: (..., P, C); w: (C, F). Returns (..., P, F), exactly s @ w.
    (This is the `jnp` backend of the dispatch registry; `ref` is the
    plain dense s @ w it must match.)
    """
    overlap, residual = apec_decompose(s, g)
    psum_ov = overlap @ w                            # cached partial sums
    psum_res = residual @ w                          # unique contributions
    out = psum_res + psum_ov[..., None, :]           # reuse across members
    return out.reshape(s.shape[:-1] + (w.shape[-1],))


def apec_matmul(s, w: jax.Array, g: int) -> jax.Array:
    """APEC matmul routed through the backend registry: the overlap-reuse
    jnp form by default, packed Pallas kernels under TPU / override.
    `s` may be an `core.events.EventTensor` (carried occupancy)."""
    from repro.kernels import dispatch as _dispatch  # lazy: no import cycle
    return _dispatch.apec_matmul(s, w, g=g)


@dataclasses.dataclass(frozen=True)
class ApecStats:
    events_before: jax.Array      # sum_i |S_i|
    events_after: jax.Array       # |O_G| + sum_i |R_i| per the compressed stream
    eliminated: jax.Array         # (g-1)|O_G|  (Eq. 2)
    overlap_mean: jax.Array       # mean |O_G| per group (paper's inset metric)
    reduction_ratio: jax.Array    # before/after (paper reports 1.35-1.62x)
    groups_with_overlap: jax.Array  # groups whose overlap pass actually runs

    def accum_savings(self, co: int, k: int) -> jax.Array:
        """Eq. 3: eliminated accumulations for a k x k conv with C_o outputs."""
        return self.eliminated * co * k * k


def apec_stats(s: jax.Array, g: int) -> ApecStats:
    """Measure APEC event statistics on a spike tensor (paper Fig. 7 inputs)."""
    overlap, residual = apec_decompose(s, g)
    ov = jnp.sum(overlap, dtype=jnp.float64) if overlap.dtype == jnp.float64 \
        else jnp.sum(overlap.astype(jnp.float32))
    res = jnp.sum(residual.astype(jnp.float32))
    before = jnp.sum(s.astype(jnp.float32))
    after = ov + res
    overlap_mean = ov / jnp.maximum(
        jnp.prod(jnp.asarray(overlap.shape[:-1], jnp.float32)), 1.0)
    return ApecStats(
        events_before=before,
        events_after=after,
        eliminated=(g - 1) * ov,
        overlap_mean=overlap_mean,
        reduction_ratio=before / jnp.maximum(after, 1.0),
        groups_with_overlap=jnp.sum(
            (jnp.sum(overlap, axis=-1) > 0).astype(jnp.float32)),
    )


def apec_overhead_bits(co: int, k: int, w_acc: int = 16) -> int:
    """Eq. 4: overlap partial-sum storage, M_ov ~ C_o k^2 w_acc bits."""
    return co * k * k * w_acc


def apec_spatial(s_map: jax.Array, g: int) -> Tuple[jax.Array, jax.Array]:
    """APEC over a (N,H,W,C) feature map grouping horizontally adjacent
    pixels (Fig. 5). Returns (overlap (N,H,W/g,C), residual (N,H,W/g,g,C))."""
    n, h, w, c = s_map.shape
    if w % g != 0:
        raise ValueError(f"width {w} not divisible by APEC group {g}")
    flat = s_map.reshape(n, h * w, c)
    overlap, residual = apec_decompose(flat, g)
    return (overlap.reshape(n, h, w // g, c),
            residual.reshape(n, h, w // g, g, c))
