"""Data substrate: synthetic generators + sharded prefetching pipeline."""
from . import pipeline, synthetic
__all__ = ["pipeline", "synthetic"]
