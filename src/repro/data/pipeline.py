"""Host-sharded, prefetching data pipeline.

Each host process generates only its shard of the global batch (shard
index = its slice of the mesh's batch axes), double-buffered on a
background thread so step N+1's host work overlaps step N's device work.
The iterator state is a single step counter: checkpoint-restore and
elastic resharding (different shard count) resume exactly, because the
generators are (seed, shard, step)-deterministic.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class ShardedPipeline:
    def __init__(self, make_batch: Callable[[int, int], dict],
                 n_shards: int, shard: int, start_step: int = 0,
                 prefetch: int = 2):
        """make_batch(shard, step) -> dict of np arrays (local shard)."""
        self._make = make_batch
        self.n_shards = n_shards
        self.shard = shard
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(self.shard, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self) -> "ShardedPipeline":
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def __iter__(self) -> Iterator[dict]:
        if self._thread is None:
            self.start()
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1     # checkpointable position
        return batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)

    # ------------------------------------------------------ checkpointing
    def state_dict(self) -> dict:
        return {"step": self.step, "n_shards": self.n_shards,
                "shard": self.shard}

    @classmethod
    def restore(cls, make_batch, state: dict, *, n_shards: int | None = None,
                shard: int | None = None, prefetch: int = 2):
        """Resume; pass new n_shards/shard after an elastic reshard."""
        return cls(make_batch, n_shards or state["n_shards"],
                   shard if shard is not None else state["shard"],
                   start_step=state["step"], prefetch=prefetch)


def device_put_batch(batch: dict, shardings: dict | None = None) -> dict:
    """Move a host batch onto devices with the given shardings."""
    if shardings is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, shardings.get(k)) for k, v in batch.items()}
