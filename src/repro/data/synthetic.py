"""Synthetic data generators (offline container: no external datasets).

Token streams come from a deterministic order-1 Markov chain over the
vocab — structured enough that the LM loss demonstrably falls during the
example training runs, unlike uniform noise. Image/segmentation data are
procedurally generated CIFAR-shaped tensors with class-dependent texture
statistics, so the paper-model examples can train end-to-end. Everything
is seeded per (shard, step): regeneration after restart/elastic reshard is
exact, which the checkpoint tests rely on.
"""
from __future__ import annotations

import numpy as np


def _rng(seed: int, shard: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, shard, step]))


def markov_tokens(seed: int, shard: int, step: int, batch: int, seq: int,
                  vocab: int) -> np.ndarray:
    """Order-1 Markov token batch (B, S+1) int32 — callers shift for labels."""
    rng = _rng(seed, shard, step)
    # Sparse deterministic transition structure derived from the seed:
    # each token t prefers (a*t + b) mod V with high probability.
    a = 6364136223846793005 % vocab or 1
    b = seed % vocab
    out = np.empty((batch, seq + 1), np.int64)
    out[:, 0] = rng.integers(0, vocab, batch)
    greedy = rng.random((batch, seq)) < 0.8
    rand = rng.integers(0, vocab, (batch, seq))
    for i in range(seq):
        nxt = (a * out[:, i] + b) % vocab
        out[:, i + 1] = np.where(greedy[:, i], nxt, rand[:, i])
    return out.astype(np.int32)


def lm_batch(seed: int, shard: int, step: int, batch: int, seq: int,
             vocab: int) -> dict:
    toks = markov_tokens(seed, shard, step, batch, seq, vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def class_images(seed: int, shard: int, step: int, batch: int, img: int = 32,
                 channels: int = 3, n_classes: int = 10) -> dict:
    """Class-conditional textured images (B,H,W,C) in [0,1] + labels."""
    rng = _rng(seed, shard, step)
    labels = rng.integers(0, n_classes, batch)
    yy, xx = np.mgrid[0:img, 0:img].astype(np.float32) / img
    imgs = np.empty((batch, img, img, channels), np.float32)
    for i, c in enumerate(labels):
        fx, fy = 1 + c % 5, 1 + c // 5
        base = 0.5 + 0.35 * np.sin(2 * np.pi * (fx * xx + fy * yy))
        noise = rng.normal(0, 0.1, (img, img, channels))
        phase = 2 * np.pi * np.arange(channels) / channels + c
        imgs[i] = np.clip(
            base[..., None] * (0.8 + 0.2 * np.cos(phase)) + noise, 0, 1)
    return {"image": imgs, "label": labels.astype(np.int32)}


def seg_batch(seed: int, shard: int, step: int, batch: int,
              img: int = 64) -> dict:
    """Lane-like segmentation task: diagonal stripe masks (B,H,W) in {0,1}."""
    rng = _rng(seed, shard, step)
    imgs = rng.normal(0.5, 0.15, (batch, img, img, 3)).astype(np.float32)
    masks = np.zeros((batch, img, img), np.int32)
    yy, xx = np.mgrid[0:img, 0:img]
    for i in range(batch):
        slope = rng.uniform(-1, 1)
        offset = rng.uniform(0.3, 0.7) * img
        width = rng.uniform(2, 6)
        lane = np.abs(yy - (slope * (xx - img / 2) + offset)) < width
        masks[i] = lane
        imgs[i, lane] += 0.4
    return {"image": np.clip(imgs, 0, 1), "mask": masks}
