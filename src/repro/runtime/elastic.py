"""Elastic scaling: restart onto a different mesh after node failure.

The checkpoint stores logical (unsharded) values; `reshard_restore` builds
shardings for the *new* mesh and loads into it, and the data pipeline
resumes from its step counter with the new shard count. `shrunk_mesh`
computes the largest valid mesh after removing failed hosts: the `model`
axis is preserved (param TP divisibility), the `data`/`pod` axes shrink —
so the global batch per step is preserved by raising grad-accumulation
microbatches instead (returned as part of the plan).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import LMConfig
from . import sharding


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatch_scale: int      # multiply cfg.microbatches by this


def shrunk_mesh(old_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
                n_failed_data_groups: int) -> ElasticPlan:
    """Drop `n_failed_data_groups` rows from the data axis; keep model."""
    shape = list(old_shape)
    data_idx = axis_names.index("data")
    old_data = shape[data_idx]
    new_data = old_data - n_failed_data_groups
    # keep data axis a power-of-two divisor of the old (batch divisibility)
    while new_data > 1 and old_data % new_data:
        new_data -= 1
    if new_data < 1:
        raise RuntimeError("no healthy data groups left")
    shape[data_idx] = new_data
    return ElasticPlan(tuple(shape), axis_names,
                       microbatch_scale=old_data // new_data)


def reshard_restore(cfg: LMConfig, mgr: CheckpointManager,
                    abstract_tree: Any, new_mesh: Mesh,
                    ) -> Tuple[Optional[int], Any]:
    """Restore the latest checkpoint onto `new_mesh` (different topology OK)."""
    specs = sharding.param_specs(cfg, abstract_tree, new_mesh)
    shardings = sharding.named(new_mesh, specs)
    return mgr.restore_latest(abstract_tree, shardings)
