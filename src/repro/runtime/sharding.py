"""Sharding rules: param-tree paths / state structures -> PartitionSpecs.

Policy (DESIGN.md §5):
  * tensor-parallel over `model`: vocab, d_ff, flattened head dims, experts
    (EP when n_experts divides the axis, else TP inside experts);
  * batch over (`pod`, `data`) — as many of those axes as divide B;
  * FSDP (cfg.fsdp): the non-TP matrix dim of params & optimizer moments is
    additionally sharded over `data` (ZeRO-3 analogue; GSPMD inserts the
    all-gathers);
  * KV caches: kv-heads over `model` when divisible, else sequence over
    `model`; SDSA statuses: heads over `model`;
  * block params carry a leading layer-group axis (scan stacking) — specs
    get a None prefix.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig


# ------------------------------------------------------------ mesh helpers
def model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def batch_axes(mesh: Mesh, b: int, include_model: bool = False
               ) -> Tuple[str, ...]:
    """Largest prefix of ('pod','data'[,'model']) whose product divides b.

    include_model=True is the pure-FSDP regime: no tensor parallelism, the
    whole mesh is data-parallel (small-model training)."""
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = [a for a in names if a in mesh.shape]
    out, prod = [], 1
    for a in axes:
        if b % (prod * mesh.shape[a]) == 0:
            out.append(a)
            prod *= mesh.shape[a]
    return tuple(out)


def _bspec(mesh: Mesh, b: int):
    ax = batch_axes(mesh, b)
    return ax if ax else None


# ------------------------------------------------------------ param specs
_COL_NAMES = {"w_q", "w_k", "w_v", "w_gate", "w_up", "in_proj", "dt_proj",
              "frontend_proj", "w_i", "w_f", "w_z", "lm_head"}
_ROW_NAMES = {"w_o", "w_down", "out_proj", "x_proj", "w_out"}


def tp_axes(cfg: LMConfig, mesh: Mesh):
    """Tensor-parallel mesh axes: ('model',) normally; (data, model) for
    the tp2d serving regime (weights resident, no per-step FSDP gather)."""
    if getattr(cfg, "tp2d", False):
        return tuple(a for a in ("data", "model") if a in mesh.shape)
    return ("model",)


def _param_rule(path: Tuple[str, ...], shape: Tuple[int, ...],
                cfg: LMConfig, mesh: Mesh) -> P:
    tp = tp_axes(cfg, mesh)
    m = int(np.prod([mesh.shape[a] for a in tp]))
    tp_spec = tp if len(tp) > 1 else tp[0]
    fsdp = "data" if (cfg.fsdp and "data" in mesh.shape
                      and not getattr(cfg, "tp2d", False)) else None
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    in_blocks = "blocks" in path

    def wrap(*spec):
        if in_blocks:
            return P(None, *spec)
        return P(*spec)

    core = shape[1:] if in_blocks else shape
    m1 = model_size(mesh)   # single-axis fallback when 2D doesn't divide

    if getattr(cfg, "pure_fsdp", False):
        # ZeRO-3: no TP — shard ONE (largest divisible) dim of every matrix
        # over the full (data x model) mesh purely for storage; GSPMD
        # gathers weights per layer because activations are batch-sharded
        # over the whole mesh.
        axes_all = tuple(a for a in ("data", "model") if a in mesh.shape)
        import numpy as _np
        n_all = int(_np.prod([mesh.shape[a] for a in axes_all]))
        if len(core) >= 2:
            order = sorted(range(len(core)), key=lambda i: -core[i])
            for nshards, ax in ((n_all, axes_all), (m1, "model")):
                for i in order:
                    if core[i] % nshards == 0:
                        return wrap(*[ax if j == i else None
                                      for j in range(len(core))])
        return wrap(*([None] * len(core)))

    def tp_for(dim: int):
        """Largest of (2D tp axes, model-only, nothing) dividing `dim`."""
        if dim % m == 0:
            return tp_spec
        if dim % m1 == 0:
            return "model"
        return None

    if name == "embed":
        v_ax = tp_for(shape[0])
        if v_ax is not None:
            return P(v_ax, fsdp)                     # vocab-sharded table
        return P(None, tp_for(shape[1]) or fsdp)     # odd vocab (whisper)
    if name == "lm_head":
        v_ax = tp_for(shape[1])
        if v_ax is not None:
            return P(fsdp, v_ax)
        return P(tp_for(shape[0]) or fsdp, None)
    if name in ("r_i", "r_f", "r_z", "r_o"):         # tiny per-head recurrences
        return wrap(*([None] * len(core)))
    if len(core) == 3 and name in ("w_gate", "w_up", "w_down"):
        e = core[0]
        e_ax = tp_for(e)
        # (pjit in_shardings require even splits, so uneven expert counts
        # must be padded at the model level — MoESpec.pad_experts_to.)
        if e_ax is not None:                         # expert parallelism
            return wrap(e_ax, fsdp, None) if name != "w_down" \
                else wrap(e_ax, None, fsdp)
        # TP inside experts (mixtral 8e on 16-way model)
        if name == "w_down":
            return wrap(None, tp_for(core[1]), fsdp)
        return wrap(None, fsdp, tp_for(core[2]))
    if name in ("w_i", "w_f") and len(core) == 2 and core[1] <= 128:
        return wrap(None, None)                      # mLSTM gate vectors
    if name in _COL_NAMES and len(core) == 2:
        ax = tp_for(core[1])
        if ax is None:
            return wrap(fsdp, None)
        return wrap(fsdp, ax)
    if name in _ROW_NAMES and len(core) == 2:
        ax = tp_for(core[0])
        if ax is None:
            return wrap(None, fsdp)
        return wrap(ax, fsdp)
    if name == "conv_w":
        return wrap(None, tp_for(core[1]))
    if name == "a_log":
        return wrap(tp_for(core[0]), None)
    if name == "d_skip":
        return wrap(tp_for(core[0]))
    # norms, router, everything else: replicate (tiny)
    return wrap(*([None] * len(core)))


def _path_str(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def param_specs(cfg: LMConfig, abstract_params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching the param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        rule_path = tuple(x for x in _path_str(path) if not x.isdigit())
        spec = _param_rule(
            rule_path if rule_path else ("param",), leaf.shape, cfg, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------------- batch specs
def batch_specs(cfg: LMConfig, batch: Dict[str, Any], mesh: Mesh) -> Dict:
    out = {}
    include_model = getattr(cfg, "pure_fsdp", False)
    for k, v in batch.items():
        b = v.shape[0]
        bs = batch_axes(mesh, b, include_model=include_model) or None
        out[k] = P(bs, *([None] * (v.ndim - 1)))
    return out


# ------------------------------------------------------------- state specs
def decode_state_specs(cfg: LMConfig, state: Any, mesh: Mesh) -> Any:
    """Specs for the (list of LayerState) decode state, built structurally
    from the LayerState fields (no shape guessing)."""
    from repro.models.lm import LayerState
    m = model_size(mesh)
    tp2d = getattr(cfg, "tp2d", False)
    tp = tp_axes(cfg, mesh)
    m2 = int(np.prod([mesh.shape[a] for a in tp]))

    def kv_cache_spec(x):            # (G, B, S, KV, dh)
        _, b, s_len, kv, _ = x.shape
        if tp2d:
            # weights own the data axis: keep B unsharded, spread the
            # sequence over every TP axis (cache slice stays local)
            if s_len % m2 == 0:
                return P(None, None, tp if len(tp) > 1 else tp[0],
                         None, None)
            return P(None, None, "model" if s_len % m == 0 else None,
                     None, None)
        bs = _bspec(mesh, b)
        if kv % m == 0:
            return P(None, bs, None, "model", None)
        if s_len % m == 0:
            return P(None, bs, "model", None, None)
        return P(None, bs, None, None, None)

    def bs_of(b):
        return None if tp2d else _bspec(mesh, b)

    def status_spec(x):              # (G, B, H, dh)
        _, b, h, _ = x.shape
        return P(None, bs_of(b), "model" if h % m == 0 else None, None)

    def dim2_model_spec(x):          # shard dim 2 over model if divisible
        rest = [None] * (x.ndim - 3)
        d2 = "model" if x.shape[2] % m == 0 else None
        return P(None, bs_of(x.shape[1]), d2, *rest)

    def dim3_model_spec(x):          # shard last dim over model if divisible
        mid = [None] * (x.ndim - 3)
        dl = "model" if x.shape[-1] % m == 0 else None
        return P(None, bs_of(x.shape[1]), *mid, dl)

    def batch_only_spec(x):
        return P(None, bs_of(x.shape[1]), *([None] * (x.ndim - 2)))

    def one(st: Any) -> Any:
        f = {}
        f["kv"] = jax.tree.map(kv_cache_spec, st.kv)
        f["sdsa"] = jax.tree.map(status_spec, st.sdsa)
        f["mamba"] = None
        if st.mamba is not None:
            f["mamba"] = type(st.mamba)(
                h=dim2_model_spec(st.mamba.h),
                conv=dim3_model_spec(st.mamba.conv))
        f["mlstm"] = jax.tree.map(batch_only_spec, st.mlstm)
        f["slstm"] = None
        if st.slstm is not None:
            f["slstm"] = jax.tree.map(dim2_model_spec, st.slstm)
        f["cross_kv"] = jax.tree.map(kv_cache_spec, st.cross_kv)
        f["cross_status"] = jax.tree.map(status_spec, st.cross_status)
        return LayerState(**f)

    return [one(st) for st in state]


# ---------------------------------------------------------------- helpers
def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def validate_specs(abstract_tree: Any, spec_tree: Any, mesh: Mesh) -> list:
    """Check every sharded dim is splittable (jax pads uneven shards, so
    only dim < n_shards is fatal); returns list of problems."""
    problems = []
    flat_a = jax.tree_util.tree_flatten_with_path(abstract_tree)[0]
    flat_s = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_a, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if leaf.shape[dim] < size:
                problems.append(
                    (_path_str(path), leaf.shape, dim, ax, size))
    return problems
