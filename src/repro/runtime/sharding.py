"""Sharding rules: param-tree paths / state structures -> PartitionSpecs.

Policy (DESIGN.md §5):
  * tensor-parallel over `model`: vocab, d_ff, flattened head dims, experts
    (EP when n_experts divides the axis, else TP inside experts);
  * batch over (`pod`, `data`) — as many of those axes as divide B;
  * FSDP (cfg.fsdp): the non-TP matrix dim of params & optimizer moments is
    additionally sharded over `data` (ZeRO-3 analogue; GSPMD inserts the
    all-gathers);
  * KV caches: kv-heads over `model` when divisible, else sequence over
    `model`; SDSA statuses: heads over `model`;
  * block params carry a leading layer-group axis (scan stacking) — specs
    get a None prefix.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig


# ------------------------------------------------------------ mesh helpers
def model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def batch_axes(mesh: Mesh, b: int, include_model: bool = False
               ) -> Tuple[str, ...]:
    """Largest prefix of ('pod','data'[,'model']) whose product divides b.

    include_model=True is the pure-FSDP regime: no tensor parallelism, the
    whole mesh is data-parallel (small-model training)."""
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    axes = [a for a in names if a in mesh.shape]
    out, prod = [], 1
    for a in axes:
        if b % (prod * mesh.shape[a]) == 0:
            out.append(a)
            prod *= mesh.shape[a]
    return tuple(out)


def _bspec(mesh: Mesh, b: int):
    ax = batch_axes(mesh, b)
    return ax if ax else None


# ------------------------------------------------------------ param specs
_COL_NAMES = {"w_q", "w_k", "w_v", "w_gate", "w_up", "in_proj", "dt_proj",
              "frontend_proj", "w_i", "w_f", "w_z", "lm_head"}
_ROW_NAMES = {"w_o", "w_down", "out_proj", "x_proj", "w_out"}


def tp_axes(cfg: LMConfig, mesh: Mesh):
    """Tensor-parallel mesh axes: ('model',) normally; (data, model) for
    the tp2d serving regime (weights resident, no per-step FSDP gather)."""
    if getattr(cfg, "tp2d", False):
        return tuple(a for a in ("data", "model") if a in mesh.shape)
    return ("model",)


def _param_rule(path: Tuple[str, ...], shape: Tuple[int, ...],
                cfg: LMConfig, mesh: Mesh) -> P:
    tp = tp_axes(cfg, mesh)
    m = int(np.prod([mesh.shape[a] for a in tp]))
    tp_spec = tp if len(tp) > 1 else tp[0]
    fsdp = "data" if (cfg.fsdp and "data" in mesh.shape
                      and not getattr(cfg, "tp2d", False)) else None
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    in_blocks = "blocks" in path

    def wrap(*spec):
        if in_blocks:
            return P(None, *spec)
        return P(*spec)

    core = shape[1:] if in_blocks else shape
    m1 = model_size(mesh)   # single-axis fallback when 2D doesn't divide

    if getattr(cfg, "pure_fsdp", False):
        # ZeRO-3: no TP — shard ONE (largest divisible) dim of every matrix
        # over the full (data x model) mesh purely for storage; GSPMD
        # gathers weights per layer because activations are batch-sharded
        # over the whole mesh.
        axes_all = tuple(a for a in ("data", "model") if a in mesh.shape)
        import numpy as _np
        n_all = int(_np.prod([mesh.shape[a] for a in axes_all]))
        if len(core) >= 2:
            order = sorted(range(len(core)), key=lambda i: -core[i])
            for nshards, ax in ((n_all, axes_all), (m1, "model")):
                for i in order:
                    if core[i] % nshards == 0:
                        return wrap(*[ax if j == i else None
                                      for j in range(len(core))])
        return wrap(*([None] * len(core)))

    def tp_for(dim: int):
        """Largest of (2D tp axes, model-only, nothing) dividing `dim`."""
        if dim % m == 0:
            return tp_spec
        if dim % m1 == 0:
            return "model"
        return None

    if name == "embed":
        v_ax = tp_for(shape[0])
        if v_ax is not None:
            return P(v_ax, fsdp)                     # vocab-sharded table
        return P(None, tp_for(shape[1]) or fsdp)     # odd vocab (whisper)
    if name == "lm_head":
        v_ax = tp_for(shape[1])
        if v_ax is not None:
            return P(fsdp, v_ax)
        return P(tp_for(shape[0]) or fsdp, None)
    if name in ("r_i", "r_f", "r_z", "r_o"):         # tiny per-head recurrences
        return wrap(*([None] * len(core)))
    if len(core) == 3 and name in ("w_gate", "w_up", "w_down"):
        e = core[0]
        e_ax = tp_for(e)
        # (pjit in_shardings require even splits, so uneven expert counts
        # must be padded at the model level — MoESpec.pad_experts_to.)
        if e_ax is not None:                         # expert parallelism
            return wrap(e_ax, fsdp, None) if name != "w_down" \
                else wrap(e_ax, None, fsdp)
        # TP inside experts (mixtral 8e on 16-way model)
        if name == "w_down":
            return wrap(None, tp_for(core[1]), fsdp)
        return wrap(None, fsdp, tp_for(core[2]))
    if name in ("w_i", "w_f") and len(core) == 2 and core[1] <= 128:
        return wrap(None, None)                      # mLSTM gate vectors
    if name in _COL_NAMES and len(core) == 2:
        ax = tp_for(core[1])
        if ax is None:
            return wrap(fsdp, None)
        return wrap(fsdp, ax)
    if name in _ROW_NAMES and len(core) == 2:
        ax = tp_for(core[0])
        if ax is None:
            return wrap(None, fsdp)
        return wrap(ax, fsdp)
    if name == "conv_w":
        return wrap(None, tp_for(core[1]))
    if name == "a_log":
        return wrap(tp_for(core[0]), None)
    if name == "d_skip":
        return wrap(tp_for(core[0]))
    # norms, router, everything else: replicate (tiny)
    return wrap(*([None] * len(core)))


def _path_str(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def param_specs(cfg: LMConfig, abstract_params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching the param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        rule_path = tuple(x for x in _path_str(path) if not x.isdigit())
        spec = _param_rule(
            rule_path if rule_path else ("param",), leaf.shape, cfg, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------------- batch specs
def batch_specs(cfg: LMConfig, batch: Dict[str, Any], mesh: Mesh) -> Dict:
    out = {}
    include_model = getattr(cfg, "pure_fsdp", False)
    for k, v in batch.items():
        b = v.shape[0]
        bs = batch_axes(mesh, b, include_model=include_model) or None
        out[k] = P(bs, *([None] * (v.ndim - 1)))
    return out


# ------------------------------------------------------------- state specs
def decode_state_specs(cfg: LMConfig, state: Any, mesh: Mesh) -> Any:
    """Specs for the (list of LayerState) decode state, built structurally
    from the LayerState fields (no shape guessing)."""
    from repro.models.lm import LayerState
    m = model_size(mesh)
    tp2d = getattr(cfg, "tp2d", False)
    tp = tp_axes(cfg, mesh)
    m2 = int(np.prod([mesh.shape[a] for a in tp]))

    def kv_cache_spec(x):            # (G, B, S, KV, dh)
        _, b, s_len, kv, _ = x.shape
        if tp2d:
            # weights own the data axis: keep B unsharded, spread the
            # sequence over every TP axis (cache slice stays local)
            if s_len % m2 == 0:
                return P(None, None, tp if len(tp) > 1 else tp[0],
                         None, None)
            return P(None, None, "model" if s_len % m == 0 else None,
                     None, None)
        bs = _bspec(mesh, b)
        if kv % m == 0:
            return P(None, bs, None, "model", None)
        if s_len % m == 0:
            return P(None, bs, "model", None, None)
        return P(None, bs, None, None, None)

    def bs_of(b):
        return None if tp2d else _bspec(mesh, b)

    def status_spec(x):              # (G, B, H, dh)
        _, b, h, _ = x.shape
        return P(None, bs_of(b), "model" if h % m == 0 else None, None)

    def dim2_model_spec(x):          # shard dim 2 over model if divisible
        rest = [None] * (x.ndim - 3)
        d2 = "model" if x.shape[2] % m == 0 else None
        return P(None, bs_of(x.shape[1]), d2, *rest)

    def dim3_model_spec(x):          # shard last dim over model if divisible
        mid = [None] * (x.ndim - 3)
        dl = "model" if x.shape[-1] % m == 0 else None
        return P(None, bs_of(x.shape[1]), *mid, dl)

    def batch_only_spec(x):
        return P(None, bs_of(x.shape[1]), *([None] * (x.ndim - 2)))

    def one(st: Any) -> Any:
        f = {}
        f["kv"] = jax.tree.map(kv_cache_spec, st.kv)
        f["sdsa"] = jax.tree.map(status_spec, st.sdsa)
        f["mamba"] = None
        if st.mamba is not None:
            f["mamba"] = type(st.mamba)(
                h=dim2_model_spec(st.mamba.h),
                conv=dim3_model_spec(st.mamba.conv))
        f["mlstm"] = jax.tree.map(batch_only_spec, st.mlstm)
        f["slstm"] = None
        if st.slstm is not None:
            f["slstm"] = jax.tree.map(dim2_model_spec, st.slstm)
        f["cross_kv"] = jax.tree.map(kv_cache_spec, st.cross_kv)
        f["cross_status"] = jax.tree.map(status_spec, st.cross_status)
        return LayerState(**f)

    return [one(st) for st in state]


# ----------------------------------------------- event ops under the mesh
def event_rows_axes(mesh: Mesh, rows: int) -> Tuple[str, ...]:
    """Mesh axes the event-row axis shards over: the batch-parallel
    ('pod', 'data') prefix that divides the row count. The 'model' axis
    shards features/heads and never event rows."""
    return batch_axes(mesh, rows)


def per_shard_occupied_tiles(s, n_shards: int, block_m: int = 128,
                             block_k: int = 128, *,
                             packed_k: int | None = None) -> list:
    """Occupied-tile count each row shard of `s` owns — the event-load
    signal `runtime.straggler.occupancy_imbalance` summarizes.

    Splits the SPIKE rows (flattened lead axes, contiguous chunks — what
    shard_map actually hands each shard) and runs every shard's own
    padded occupancy pre-pass, exactly what that shard would compute
    locally. Splitting the global occupancy map's tile rows instead would
    misattribute load whenever per-shard rows are not a block_m multiple
    (e.g. 512 rows over 8 shards: 4 tile rows split 8 ways reports half
    the shards empty when all carry equal load).

    `packed_k` marks `s` as uint32 spike words (trailing axis = words):
    per-shard counts come from word popcounts (`packed_tile_occupancy`),
    identical to the dense counts — no unpack."""
    import jax.numpy as jnp
    from repro.kernels import ops
    s2 = np.asarray(s).reshape(-1, s.shape[-1])
    if packed_k is not None:
        from repro.core.spikes import packed_tile_occupancy
        out = []
        for chunk in np.array_split(s2, n_shards, axis=0):
            pad = (-chunk.shape[0]) % block_m
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            out.append(int((np.asarray(packed_tile_occupancy(
                jnp.asarray(chunk), block_m, block_k)) > 0).sum()))
        return out
    return [int((np.asarray(ops.padded_occupancy(
                jnp.asarray(chunk), block_m, block_k)) > 0).sum())
            for chunk in np.array_split(s2, n_shards, axis=0)]


def event_op_sharded(mesh: Mesh, op: str, s, w, *, csr_stack=None,
                     occupancy=None, with_report: bool = False,
                     rebalance: bool = True, **kwargs):
    """Route a matmul-form registry op (`spike_matmul` / `apec_matmul`)
    through `shard_map` on `mesh`, with mesh-aware backend resolution.

    The event rows (leading axis of `s`) shard over the batch-parallel
    mesh axes; `w` is replicated. Resolution runs ONCE, outside the body,
    against the per-shard shapes (`dispatch.resolve(..., mesh=)` — the
    `pallas-csr` family holds while each shard's tile grid divides
    cleanly, else it degrades down its declared fallback chain), and the
    body pins the resolved backend so every shard runs the same kernel.
    Differentiable end to end: the pinned backend carries its registered
    VJP, and shard_map transposes the row sharding.

    `s` may be an `core.events.EventTensor` (or `occupancy=` a carried
    map): the sharded path then REUSES the producer's map instead of
    rebuilding local work lists from the resident spikes — a concrete map
    compacts straight into per-shard trimmed work lists
    (`shard_occupancy_to_csr` on the tiny map, no dense pre-pass and no
    gather), and a traced map shards row-contiguously into the body so
    each shard compacts its own slice. When the per-shard tile grid can't
    split the map evenly (ragged rows), the map is dropped with a warning
    and shards re-derive locally — never silently misgated.

    `csr_stack`: optional stacked per-shard `TileCSR`
    (`core.spikes.shard_occupancy_to_csr` + `stack_shard_csrs`) for
    `spike_matmul` on the CSR family — each shard consumes its own
    pre-built work list (leading shard axis sharded like the rows), so
    the trimmed eager grid survives sharding without gathering any
    global occupancy map.

    A packed `s` (packed-only `EventTensor`, or raw uint32 words with
    `packed_k=` in kwargs) shards its WORDS over the same row axes — the
    per-shard work lists from `shard_occupancy_to_csr` feed the
    packed-csr kernels directly, because the carried (128, 128) map's
    k-tiling coincides with the word tiling (ceil(ceil(K/32)/4) ==
    ceil(K/128)) and the 128-row shard-tile gate counts logical rows
    either way. Resolution routes by payload: packed shards land on the
    `packed-csr` family or degrade through the explicit unpack shim.

    `rebalance` (default on): when a CONCRETE carried map feeds the
    per-shard work lists and the payload is a plain (rows, K) matrix,
    split points are occupancy-weighted instead of row-contiguous
    (`core.spikes.rebalance_shard_plan` — greedy heaviest-row-first plus
    a stolen-tile swap tail): the payload's 128-row tile rows permute so
    every shard still owns one contiguous equal slice, outputs permute
    back, numerics are unchanged, and the most-occupied shard — the one
    a synchronous collective waits for — carries as close to the mean
    occupied-tile count as whole tile rows allow. Never gathers global
    occupancy (the plan reads only the tiny carried map); static maps /
    traced maps / explicit `csr_stack=` are untouched.

    `with_report=True` additionally returns the routing/straggler report:
    resolved backend + attribution, occupancy provenance
    (``occupancy_source``: carried / csr_stack / rederived), and (for
    concrete `s`) the per-shard occupied-tile `OccupancyImbalance`.
    """
    from repro.core.events import EventTensor
    from repro.core.spikes import (TileCSR, rebalance_shard_plan,
                                   shard_occupancy_to_csr,
                                   stack_shard_csrs)
    from repro.kernels import dispatch, ops
    from repro.launch.mesh import shard_map

    if isinstance(s, EventTensor):
        if occupancy is None:
            occupancy = s.occupancy_for(128, 128)
        if s.is_packed:
            kwargs = dict(kwargs)
            kwargs["packed_k"] = s.feature_size
            s = s.packed
        else:
            s = s.spikes
    packed_k = kwargs.get("packed_k")

    axes = event_rows_axes(mesh, s.shape[0])
    n_shards = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    rows = int(np.prod(s.shape[:-1]))
    plan = None          # set iff occupancy-weighted rebalancing engages

    def _per_shard_routes(attribution):
        """Per-shard hybrid route choices ("event"/"dense") for the report:
        which kernel each shard's local occupied-tile count selects under
        the calibrated predicate — recorded only when this resolution went
        through hybrid routing (the traced cond branches per shard; this
        is the same decision, named per shard for the report)."""
        if "hybrid" not in attribution or n_shards <= 1 \
                or isinstance(s, jax.core.Tracer):
            return ()
        from repro.core import costmodel
        mt_l = -(-(rows // n_shards) // 128)
        kt = -(-int(s.shape[-1]) // 128)
        return tuple(
            "event" if costmodel.event_route_wins(
                op, costmodel.bucket_representative(
                    costmodel.pow2_bucket(c), mt_l * kt), mt_l, kt)
            else "dense"
            for c in per_shard_occupied_tiles(s, n_shards))

    def _report(backend, attribution, occupancy_source):
        if not with_report:
            return None
        from repro.runtime.straggler import occupancy_imbalance
        rep = {"op": op, "backend": backend, "attribution": attribution,
               "n_shards": n_shards, "occupancy": None,
               "occupancy_source": occupancy_source}
        if n_shards > 1 and not isinstance(s, jax.core.Tracer):
            if plan is not None:
                # Rebalanced run: per_shard is the executed (rebalanced)
                # assignment; the static-split counts ride as the pre-
                # rebalance column, straight off the plan.
                rep["occupancy"] = occupancy_imbalance(
                    plan.post_per_shard,
                    routes=_per_shard_routes(attribution),
                    pre_per_shard=plan.pre_per_shard)
            else:
                rep["occupancy"] = occupancy_imbalance(
                    per_shard_occupied_tiles(s, n_shards,
                                             packed_k=packed_k),
                    routes=_per_shard_routes(attribution))
        return rep

    if csr_stack is not None and op != "spike_matmul":
        raise ValueError(
            f"csr_stack is a spike_matmul pass-through; op {op!r} builds "
            f"its own (union) pre-pass in-kernel")
    if n_shards > 1 and occupancy is not None and (
            rows % n_shards or (rows // n_shards) % 128
            or occupancy.shape[0] % n_shards):
        # A carried map only splits into congruent per-shard maps when
        # every shard owns whole 128-row tiles (the same condition the
        # CSR mesh gate checks). Say so — the caller believes the carried
        # route is live. Checked BEFORE resolution: hybrid routing keys
        # off the occupancy kwarg, and resolving on a map that is about
        # to be dropped would pin a route the body can't feed.
        warnings.warn(
            f"exspike sharding: carried occupancy dropped for {op!r} — "
            f"{rows} rows over {n_shards} shards do not split into whole "
            f"128-row tiles; shards re-derive locally",
            RuntimeWarning, stacklevel=2)
        occupancy = None
    # Resolve against the shard count we will actually execute with (the
    # dividing axes), not the mesh's full batch capacity — when the rows
    # don't divide, execution stays unsharded and resolution must match.
    # The carried map joins resolution as the occupancy kwarg: hybrid
    # routing (dispatch.use_hybrid) decides dense-vs-event on it.
    res_kwargs = dict(kwargs)
    if occupancy is not None:
        res_kwargs["occupancy"] = occupancy
    be, attribution = dispatch.resolve_with_attribution(
        op, s, w, mesh=n_shards, **res_kwargs)
    if n_shards <= 1:
        if occupancy is not None:
            out = be.fn(s, w, occupancy=occupancy, **kwargs)
            src = "carried"
        else:
            out = be.fn(s, w, **kwargs)
            src = "csr_stack" if csr_stack is not None else "rederived"
        return (out, _report(be.name, attribution, src)) if with_report \
            else out

    lead = tuple(axes) if len(axes) > 1 else axes[0]
    row_spec = P(lead, *([None] * (s.ndim - 1)))
    w_spec = P(*([None] * w.ndim))

    # Which CSR family the resolved backend must belong to for pre-built
    # work lists to feed it (word tiling == dense tiling, so the SAME
    # `shard_occupancy_to_csr` compaction serves both payloads).
    csr_family = "packed-csr" if packed_k is not None else "pallas-csr"
    if occupancy is not None and csr_stack is None \
            and op == "spike_matmul" and be.name.startswith(csr_family) \
            and not isinstance(occupancy, jax.core.Tracer):
        # Concrete carried map -> per-shard TRIMMED work lists, built from
        # the tiny map alone (the whole point: no dense pre-pass, no
        # gather, and the producer's emission is what feeds the mesh).
        # With `rebalance`, the split points are occupancy-weighted
        # (`rebalance_shard_plan` on the same tiny map): the payload's
        # 128-row tile rows are permuted so each shard still owns one
        # contiguous equal slice, and the output is permuted back below —
        # numerics are identical, only who computes which rows moves.
        if rebalance and s.ndim == 2:
            plan = rebalance_shard_plan(occupancy, n_shards)
            if plan.identity or not plan.improves:
                plan = None      # nothing to win — skip the row gathers
        csr_stack = stack_shard_csrs(shard_occupancy_to_csr(
            occupancy, n_shards, tiling=(128, 128), plan=plan))
        occupancy = None
        occupancy_source = "carried"
    elif csr_stack is not None:
        occupancy_source = "csr_stack"
    elif occupancy is not None:
        occupancy_source = "carried"
    else:
        occupancy_source = "rederived"

    if csr_stack is not None and not be.name.startswith(csr_family):
        # Degraded off the CSR family (mesh gate / capability): the
        # pre-built work lists can't feed the resolved kernel. Say so —
        # the caller paid for the eager pre-pass and would otherwise
        # believe the trimmed grids are running.
        warnings.warn(
            f"exspike sharding: csr_stack ignored — {op!r} resolved to "
            f"{be.name!r} ({attribution}), not the CSR family",
            RuntimeWarning, stacklevel=2)
        csr_stack = None
        plan = None      # rebalanced lists died with the stack
        # A carried map passed alongside the stack still feeds the
        # sharded occupancy-operand path below — attribute it honestly.
        occupancy_source = "carried" if occupancy is not None \
            else "rederived"
    if csr_stack is not None:
        csr_arrays = tuple(csr_stack[:5])   # row_ptr/tile_m/tile_k/occ/valid
        csr_specs = tuple(P(lead) for _ in csr_arrays)
        pipelined = "-pipe" in be.name

        def body(sl, wl, *carrs):
            local = TileCSR(*[a[0] for a in carrs],
                            csr_stack.tiling, csr_stack.map_shape)
            if packed_k is not None:
                return ops.spike_matmul_packed(sl, wl, packed_k=packed_k,
                                               csr=local,
                                               pipeline=pipelined)
            return ops.spike_matmul_csr(sl, wl, local,
                                        pipeline=pipelined)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(row_spec, w_spec) + csr_specs,
                       out_specs=row_spec)

        # The raw csr wrapper has no autodiff rule (the registry attaches
        # one per backend); give this pass-through the SAME gradient
        # contract the csr backends declare — the matmul transpose rule
        # on the global operands (packed words get a float0 cotangent;
        # dw replays through the unpacked view).
        bwd_static = {"packed_k": packed_k} if packed_k is not None else {}

        @jax.custom_vjp
        def run(s_, w_):
            return fn(s_, w_, *csr_arrays)

        def run_fwd(s_, w_):
            return fn(s_, w_, *csr_arrays), (s_, w_)

        def run_bwd(res, g):
            return tuple(dispatch._matmul_bwd(res, bwd_static, g))

        run.defvjp(run_fwd, run_bwd)
        if plan is not None:
            # Permute 128-row tile rows so the plan's assignment becomes
            # the contiguous equal split shard_map hands out, run, then
            # permute the output back. Both gathers sit OUTSIDE the
            # custom_vjp boundary: autodiff transposes them as ordinary
            # scatter/gather, and run's matmul-transpose rule sees the
            # permuted operands it actually multiplied. The work-list
            # rows (128 logical rows each) move wholesale, so the
            # per-shard CSR tile indices stay local and trimmed.
            mt_rows = len(plan.perm)
            tile = rows // mt_rows
            perm = jnp.asarray(plan.perm)
            inv = jnp.asarray(plan.inverse())
            k_tail = s.shape[1:]
            s_bal = jnp.take(s.reshape((mt_rows, tile) + k_tail), perm,
                             axis=0).reshape(s.shape)
            out = run(s_bal, w)
            out = jnp.take(out.reshape((mt_rows, tile) + out.shape[1:]),
                           inv, axis=0).reshape(out.shape)
        else:
            out = run(s, w)
    elif occupancy is not None:
        # Carried map, traced (or a non-spike_matmul op): shard the map
        # row-contiguously alongside the spikes — each shard's body
        # consumes its own slice (the CSR family compacts it in-shard;
        # the predicated family gates on it directly). The map rides as
        # a shard_map operand, so no shard re-derives from dense spikes.
        occ_spec = P(lead, None)
        registered = be.name in dispatch.backend_names(op)

        def body(sl, wl, occl):
            if not registered:
                # Synthetic hybrid cond backend (dispatch names it
                # "hybrid[event|dense@bN]" but never registers it): its fn
                # re-derives the bucket threshold from the LOCAL map shape
                # and cond-branches per shard — exactly the per-shard
                # routing the report's occ_routes field records.
                return be.fn(sl, wl, occupancy=occl, **kwargs)
            return dispatch.call_backend(op, be.name, sl, wl,
                                         occupancy=occl, **kwargs)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(row_spec, w_spec, occ_spec),
                       out_specs=row_spec)
        out = fn(s, w, occupancy)
    else:
        registered = be.name in dispatch.backend_names(op)

        def body(sl, wl):
            if not registered:
                # The unpack shim (packed payload degraded off the
                # packed-csr family) is synthesized, never registered —
                # pin its fn directly.
                return be.fn(sl, wl, **kwargs)
            return dispatch.call_backend(op, be.name, sl, wl, **kwargs)

        fn = shard_map(body, mesh=mesh, in_specs=(row_spec, w_spec),
                       out_specs=row_spec)
        out = fn(s, w)
    return (out, _report(be.name, attribution, occupancy_source)) \
        if with_report else out


# ---------------------------------------------------------------- helpers
def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def validate_specs(abstract_tree: Any, spec_tree: Any, mesh: Mesh) -> list:
    """Check every sharded dim is splittable (jax pads uneven shards, so
    only dim < n_shards is fatal); returns list of problems."""
    problems = []
    flat_a = jax.tree_util.tree_flatten_with_path(abstract_tree)[0]
    flat_s = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_a, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if leaf.shape[dim] < size:
                problems.append(
                    (_path_str(path), leaf.shape, dim, ax, size))
    return problems
