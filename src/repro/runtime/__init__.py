"""Distributed runtime: sharding rules, elastic restart, stragglers."""
from . import elastic, sharding, straggler
__all__ = ["elastic", "sharding", "straggler"]
