"""Deterministic, seedable fault injectors for the guarded-execution layer.

Every injector is pure numpy over host copies (never in-place on device
arrays), keyed by an integer seed, and returns the corrupted value plus
the injected coordinates — so a test can assert the guard detected
EXACTLY the fault it planted. The taxonomy mirrors what the stack trusts:

  occupancy_undercount   carried map claims occupied tiles empty — the
                         CSR kernels would silently skip live work
  occupancy_overcount    map claims empty tiles occupied — LEGAL (maps
                         are upper bounds): wasted tile visits, not
                         wrong numerics; the audit must NOT flag it
  packed_bitflip         uint32 spike words gain set bits (0->1 only:
                         a 1->0 flip keeps the map a valid upper bound
                         and is invisible to bound checking — documented
                         detection asymmetry)
  stale_csr              TileCSR with wrong tiling / map-grid tags — the
                         consumers' `check_compatible` rejects it loudly
  nan_params             NaN'd parameter leaves (training/serve poison)
  nan_decode_state       NaN'd per-slot decode state (serve quarantine)
  truncated_checkpoint   a leaf file truncated mid-write (crashed/dropped
                         writer) — restore must detect and walk back
  dropped_shard          a data-shard group disappears mid-training —
                         recovered via `elastic.shrunk_mesh` +
                         `reshard_restore` (exercised by the elastic
                         drill in the multi-device suite)

`FAULT_CLASSES` names the full set; the CI fault-injection smoke iterates
it so a new class can't land without detection coverage.
"""
from __future__ import annotations

import os
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _is_float_leaf(x) -> bool:
    # jnp.issubdtype, not np: ml_dtypes (bfloat16, fp8) are inexact to
    # jax but not np.floating subtypes.
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)

# Detection home of each class (CI smoke asserts coverage by name).
FAULT_CLASSES = (
    "occupancy_undercount",    # kernels: guard audit/repair
    "occupancy_overcount",     # kernels: guard no-flag (upper bound)
    "packed_bitflip",          # kernels: guard audit/repair (popcount)
    "stale_csr",               # kernels: TileCSR.check_compatible
    "nan_params",              # serve: NaN/inf logit quarantine
    "nan_decode_state",        # serve: NaN/inf logit quarantine
    "truncated_checkpoint",    # checkpoint: CRC/size check + walk-back
    "dropped_shard",           # runtime: shrunk_mesh + reshard_restore
)

# Re-export: the guard's violation type lives with the policy.
from repro.kernels.dispatch import GuardViolationError  # noqa: E402,F401


# ------------------------------------------------------------- occupancy
def undercount_occupancy(occ, n_tiles: int = 1, seed: int = 0
                         ) -> Tuple[np.ndarray, List[Tuple[int, int]]]:
    """Zero `n_tiles` occupied entries of a carried map: the classic
    silent-drop fault (kernels skip tiles that hold live events).
    Returns (bad_map, [(mt, kt) coords zeroed])."""
    bad = np.array(occ, copy=True)
    occupied = np.argwhere(bad > 0)
    if occupied.shape[0] == 0:
        raise ValueError("map has no occupied tiles to undercount")
    rng = np.random.default_rng(seed)
    pick = rng.choice(occupied.shape[0],
                      size=min(n_tiles, occupied.shape[0]), replace=False)
    coords = [tuple(int(c) for c in occupied[i]) for i in pick]
    for c in coords:
        bad[c] = 0
    return bad, coords


def overcount_occupancy(occ, n_tiles: int = 1, seed: int = 0,
                        count: int = 7
                        ) -> Tuple[np.ndarray, List[Tuple[int, int]]]:
    """Claim `n_tiles` empty entries occupied (or inflate occupied counts
    when no tile is empty). LEGAL under the upper-bound contract: the
    guard must pass it and the numerics must be unchanged — this is the
    audit's false-positive control."""
    bad = np.array(occ, copy=True)
    empty = np.argwhere(bad == 0)
    rng = np.random.default_rng(seed)
    if empty.shape[0] == 0:
        coords = []
        bad += count                     # inflate: still an upper bound
    else:
        pick = rng.choice(empty.shape[0],
                          size=min(n_tiles, empty.shape[0]), replace=False)
        coords = [tuple(int(c) for c in empty[i]) for i in pick]
        for c in coords:
            bad[c] = count
    return bad, coords


# ---------------------------------------------------------------- packed
def flip_packed_bits(words, n_bits: int = 4, seed: int = 0
                     ) -> Tuple[np.ndarray, List[Tuple[int, ...]]]:
    """SET `n_bits` random zero bits of a uint32 word tensor (0->1 only).
    Sets create payload support the carried map never counted, which the
    guard's popcount audit detects; 1->0 clears keep the map a valid
    upper bound and are deliberately not injected (bound checking cannot
    see them — a paired exact-count map would be needed).
    Returns (corrupted_words, [(word_idx..., bit) flipped])."""
    w = np.array(words, copy=True)
    if w.dtype != np.uint32:
        raise ValueError(f"expected uint32 words, got {w.dtype}")
    bits = (w[..., None] >> np.arange(32, dtype=np.uint32)) & 1
    zero_coords = np.argwhere(bits == 0)
    if zero_coords.shape[0] == 0:
        raise ValueError("no zero bits to flip")
    rng = np.random.default_rng(seed)
    pick = rng.choice(zero_coords.shape[0],
                      size=min(n_bits, zero_coords.shape[0]), replace=False)
    flipped = []
    for i in pick:
        *idx, bit = (int(c) for c in zero_coords[i])
        w[tuple(idx)] |= np.uint32(1) << np.uint32(bit)
        flipped.append(tuple(idx) + (bit,))
    return w, flipped


# ------------------------------------------------------------------- CSR
def stale_csr(csr, tiling: Optional[Tuple[int, int]] = (64, 64),
              map_shape: Optional[Tuple[int, int]] = None):
    """A TileCSR whose compatibility tags no longer match the call site
    (built for another tiling / another map grid). Consumers reject it
    via `TileCSR.check_compatible` — the loud path this injector pins."""
    kw = {}
    if tiling is not None:
        kw["tiling"] = tuple(tiling)
    if map_shape is not None:
        kw["map_shape"] = tuple(map_shape)
    return csr._replace(**kw)


# ------------------------------------------------------------- NaN poison
def nan_params(tree: Any, n_leaves: int = 1, seed: int = 0) -> Any:
    """NaN the first element of `n_leaves` float leaves (deterministic
    leaf choice). Models a poisoned optimizer step / corrupt weight load;
    serve's logit quarantine is the detector."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    float_idx = [i for i, l in enumerate(leaves) if _is_float_leaf(l)]
    if not float_idx:
        raise ValueError("tree has no float leaves")
    rng = np.random.default_rng(seed)
    pick = rng.choice(len(float_idx),
                      size=min(n_leaves, len(float_idx)), replace=False)
    for i in (float_idx[p] for p in pick):
        host = np.array(leaves[i], dtype=np.float32)
        host.reshape(-1)[0] = np.nan
        leaves[i] = jnp.asarray(host).astype(leaves[i].dtype)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def nan_decode_state(state: Any, slot: int, seed: int = 0) -> Any:
    """NaN one slot's decode state (leaves are stacked
    ``(n_groups, n_slots, ...)`` — slot = index on axis 1). Every float
    leaf gets the poison so the next decode step's logits for that slot
    are non-finite, triggering the serve loop's quarantine."""
    del seed   # slot choice is the caller's; the poison is total per slot

    def poison(x):
        if not _is_float_leaf(x) or getattr(x, "ndim", 0) < 2:
            return x
        host = np.array(x, dtype=np.float32)
        host[:, slot] = np.nan
        return jnp.asarray(host).astype(x.dtype)
    return jax.tree_util.tree_map(poison, state)


# ------------------------------------------------------------ checkpoints
def truncate_checkpoint(ckpt_dir: str, keep_bytes: int = 64,
                        seed: int = 0) -> str:
    """Truncate one leaf file of a committed checkpoint to `keep_bytes`
    (a writer that died mid-flush / lost its shard before the data hit
    disk). The manifest still promises the full payload, so restore must
    detect the short read loudly and `restore_latest` walk back.
    Returns the truncated file's path."""
    leaf_files = sorted(f for f in os.listdir(ckpt_dir)
                        if f.startswith("leaf_") and f.endswith(".npy"))
    if not leaf_files:
        raise ValueError(f"no leaf files under {ckpt_dir}")
    rng = np.random.default_rng(seed)
    target = os.path.join(ckpt_dir, leaf_files[int(rng.integers(
        len(leaf_files)))])
    with open(target, "r+b") as f:
        f.truncate(keep_bytes)
    return target


def drop_checkpoint_file(ckpt_dir: str, seed: int = 0) -> str:
    """Delete one leaf file of a committed checkpoint (a lost shard whose
    host never wrote). Returns the removed file's path."""
    leaf_files = sorted(f for f in os.listdir(ckpt_dir)
                        if f.startswith("leaf_") and f.endswith(".npy"))
    if not leaf_files:
        raise ValueError(f"no leaf files under {ckpt_dir}")
    rng = np.random.default_rng(seed)
    target = os.path.join(ckpt_dir, leaf_files[int(rng.integers(
        len(leaf_files)))])
    os.remove(target)
    return target
