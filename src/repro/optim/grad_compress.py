"""Error-feedback int8 gradient compression (distributed-optimization trick).

At 1000-node scale the data-parallel gradient all-reduce dominates the
collective term for dense archs. We quantize per-leaf gradients to int8
with a per-leaf scale and carry the quantization error into the next step
(error feedback, à la 1-bit Adam / EF-SGD), so convergence is preserved.
Wire format is int8-valued numbers carried in bf16 (exact summation for
<= 256 data shards), halving all-reduce bytes vs f32 — the HLO collective
bytes in the dry-run shrink accordingly when enabled.

The transform is pure: state (error buffers) lives alongside the optimizer
state; compress() is applied to the microbatch-mean gradient *before* the
cross-data-shard mean (under GSPMD the subsequent psum happens in the
compressed dtype).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    error: Any   # residual tree, same structure as grads


def init(params: Any) -> EFState:
    return EFState(error=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params))


def compress(grads: Any, ef: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (wire_grads_bf16_int8valued, scales, new_ef)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127)
        err = g32 - q * scale
        return q.astype(jnp.bfloat16), scale, err.astype(jnp.bfloat16)

    out = jax.tree.map(one, grads, ef.error)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    wire = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    scales = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_err = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return wire, scales, EFState(error=new_err)


def decompress(wire: Any, scales: Any) -> Any:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, wire, scales)
