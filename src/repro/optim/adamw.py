"""AdamW (the paper's training optimizer, Sec. IV) — built from scratch.

Features needed at 1000-node scale:
  * configurable moment dtype (bf16 moments halve optimizer HBM — used by
    the 123B/398B configs whose f32 states would not fit v5e),
  * global-norm clipping fused into the update,
  * decoupled weight decay,
  * pytree-native, donation-friendly (state mirrors the param tree).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment (param-tree)
    nu: Any        # second moment (param-tree)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # "float32" | "bfloat16"


def init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads: Any, state: AdamWState, params: Any,
           cfg: AdamWConfig = AdamWConfig(),
           lr_scale: jax.Array | float = 1.0) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state). lr_scale: schedule multiplier."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    dt = jnp.dtype(cfg.state_dtype)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
