"""Sharded checkpointing: async save, checksummed, atomic, reshardable.

Layout of one checkpoint:
    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, checksums
        leaf_00000.npy ... # one file per pytree leaf (host-local values)
        _COMMITTED         # atomic commit marker (written last)

Fault-tolerance contract:
  * save is crash-safe — a checkpoint without _COMMITTED is ignored and
    garbage-collected on the next save;
  * every leaf carries a CRC32 checksum validated on restore;
  * restore takes *target shardings*, so a checkpoint written on one mesh
    loads onto a different mesh (elastic restart) — values are logical,
    layout is per-restore.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_COMMIT = "_COMMITTED"

# Everything a corrupt/truncated/vanished checkpoint can raise out of
# `restore`: short reads surface as IOError (size/CRC checks below), but
# np.load on a mangled header can also throw EOFError / KeyError /
# pickle errors, and a malformed manifest ValueError. `restore_latest`
# catches THIS tuple so any corruption walks back to an older snapshot
# instead of crashing the resume.
CORRUPTION_ERRORS = (OSError, ValueError, KeyError, EOFError)

# numpy can't serialize ml_dtypes (bf16, fp8...) natively: store a same-width
# integer view plus the logical dtype name in the manifest.
_VIEW_FOR = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_FOR:
        return arr.view(_VIEW_FOR[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_FOR:
        return arr.view(getattr(ml_dtypes, name))
    return arr


def _tree_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any,
         wait: bool = True) -> threading.Thread:
    """Write a checkpoint. wait=False returns immediately (async save)."""
    leaves, treedef = _tree_paths(tree)
    host_leaves = [np.asarray(l) for l in leaves]   # fetch before async
    ckpt_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = ckpt_dir + ".tmp"

    def _write():
        os.makedirs(tmp_dir, exist_ok=True)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, arr in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            path = os.path.join(tmp_dir, fname)
            enc, dtype_name = _encode(arr)
            # fsync each leaf before the commit marker exists: a crash
            # between rename and writeback must never leave a COMMITTED
            # checkpoint with half-flushed payload bytes.
            with open(path, "wb") as f:
                np.save(f, enc)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append({
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
                "nbytes": os.path.getsize(path),
                "crc32": zlib.crc32(np.ascontiguousarray(enc).tobytes()),
            })
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp_dir, _COMMIT), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(ckpt_dir):
            shutil.rmtree(ckpt_dir)
        os.rename(tmp_dir, ckpt_dir)
        # Durable rename: fsync the parent directory entry too.
        try:
            dfd = os.open(directory, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if wait:
        t.join()
    return t


def is_committed(ckpt_dir: str) -> bool:
    return os.path.exists(os.path.join(ckpt_dir, _COMMIT))


def restore(ckpt_dir: str, target_tree: Any,
            shardings: Optional[Any] = None) -> Any:
    """Load into the structure of `target_tree`, applying `shardings`
    (a matching tree of jax.sharding.Sharding, or None for host arrays).

    Raises on checksum mismatch, truncation, or structural drift — every
    corruption mode surfaces as one of `CORRUPTION_ERRORS`, never a
    silently short or garbage tree.
    """
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _tree_paths(target_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target has "
            f"{len(leaves)} — structure drift")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (meta, tgt, shd) in enumerate(
            zip(manifest["leaves"], leaves, shard_leaves)):
        path = os.path.join(ckpt_dir, meta["file"])
        expected_bytes = meta.get("nbytes")
        if expected_bytes is not None \
                and os.path.getsize(path) != expected_bytes:
            raise IOError(
                f"leaf {i} is {os.path.getsize(path)} bytes, manifest "
                f"promises {expected_bytes} — truncated checkpoint")
        try:
            arr = np.load(path)
        except Exception as e:
            # np.load on a mangled file raises a zoo of types (EOFError,
            # ValueError, pickle errors...); normalize so callers handle
            # one corruption surface.
            raise IOError(f"leaf {i} unreadable ({type(e).__name__}: {e}) "
                          f"— corrupt checkpoint") from e
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"leaf {i} checksum mismatch — corrupt checkpoint")
        arr = _decode(arr, meta["dtype"])
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(
                f"leaf {i} shape {arr.shape} != target {tgt.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
