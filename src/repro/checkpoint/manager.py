"""Rolling checkpoint manager: retention, auto-resume, corruption skip."""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, List, Optional

from . import checkpointer

_STEP_RE = re.compile(r"^step_(\d{9})$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 save_every: int = 100, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.save_every = save_every
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------- discovery
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and checkpointer.is_committed(os.path.join(self.dir, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # --------------------------------------------------------------- save
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree: Any):
        self.wait()     # never overlap two saves
        # gc BEFORE launching the async write (must not race the new .tmp
        # dir); trim to keep-1 so the incoming checkpoint lands at `keep`.
        self._gc(reserve=1)
        self._pending = checkpointer.save(
            self.dir, step, tree, wait=not self.async_save)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, reserve: int = 0):
        # Remove uncommitted temp dirs and old checkpoints beyond retention.
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if name.endswith(".tmp"):
                shutil.rmtree(full, ignore_errors=True)
        steps = self.steps()
        limit = max(1, self.keep - reserve)
        for s in steps[: max(0, len(steps) - limit)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore
    def restore_latest(self, target_tree: Any, shardings: Any = None,
                       ) -> tuple[Optional[int], Any]:
        """Walk newest-first to the newest VALID snapshot; any corruption
        (truncated/byte-flipped/vanished leaf, mangled manifest — the
        full `checkpointer.CORRUPTION_ERRORS` surface) skips to an older
        checkpoint, logged, never fatal."""
        for step in reversed(self.steps()):
            path = os.path.join(self.dir, f"step_{step:09d}")
            try:
                tree = checkpointer.restore(path, target_tree, shardings)
                return step, tree
            except checkpointer.CORRUPTION_ERRORS as e:  # corrupt -> older
                print(f"[ckpt] skipping step {step}: {e}")
        return None, target_tree
