"""Fault-tolerant checkpointing: async sharded save/restore + manager."""
from . import checkpointer, manager
from .manager import CheckpointManager
__all__ = ["checkpointer", "manager", "CheckpointManager"]
