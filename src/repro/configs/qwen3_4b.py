"""qwen3-4b [hf:Qwen/Qwen3-8B family]: 36L d2560 32H(kv8) d_ff 9728,
qk_norm, head_dim 128 (decoupled from d_model/H)."""
from .base import LMConfig, SpikingConfig

CONFIG = LMConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, d_ff=9728, vocab=151936, d_head=128,
    qk_norm=True, rope_theta=1e6, spiking=SpikingConfig(t_steps=2),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512, d_head=16,
    remat="none", loss_chunk=16)
