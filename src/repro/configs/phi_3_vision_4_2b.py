"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct]: 32L d3072
32H(kv32) d_ff 8192; CLIP frontend stubbed as precomputed patch embeds."""
from .base import LMConfig, SpikingConfig

CONFIG = LMConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
    n_frontend_tokens=1024, rope_theta=1e4,
    spiking=SpikingConfig(t_steps=2),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, vocab=512,
    n_frontend_tokens=8, remat="none", loss_chunk=16)
