"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d2048 16H(kv16)
d_ff 1408/expert, 4 shared + 60 routed top-4."""
from .base import LMConfig, MoESpec, SpikingConfig

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936,
    moe=MoESpec(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4),
    rope_theta=1e6, spiking=SpikingConfig(t_steps=2),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, vocab=512,
    moe=MoESpec(n_experts=8, top_k=4, d_ff_expert=32, n_shared=2),
    remat="none", loss_chunk=16)
