"""mixtral-8x22b [arXiv:2401.04088]: 56L d6144 48H(kv8) d_ff 16384,
8 experts top-2, sliding-window attention."""
from .base import LMConfig, MoESpec, SpikingConfig

CONFIG = LMConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16384),
    sliding_window=4096, rope_theta=1e6,
    spiking=SpikingConfig(t_steps=2), fsdp=True, microbatches=4,
    opt_state_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
    moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=64),
    sliding_window=8, fsdp=False, microbatches=1, remat="none",
    loss_chunk=16)
