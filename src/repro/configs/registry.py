"""Architecture registry: --arch <id> -> (full config, reduced smoke config).

Covers the 10 assigned pool architectures plus the paper's own workloads.
"""
from __future__ import annotations

from typing import Dict, Tuple

from .base import CNNConfig, LMConfig, ShapeSpec, SHAPES
from . import (jamba_1_5_large_398b, internlm2_20b, mistral_large_123b,
               mixtral_8x22b, phi_3_vision_4_2b, qwen2_moe_a2_7b, qwen3_4b,
               tinyllama_1_1b, whisper_medium, xlstm_350m)

_LM_MODULES = {
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "mixtral-8x22b": mixtral_8x22b,
    "whisper-medium": whisper_medium,
    "internlm2-20b": internlm2_20b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "mistral-large-123b": mistral_large_123b,
    "qwen3-4b": qwen3_4b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "xlstm-350m": xlstm_350m,
}

ARCH_IDS = tuple(_LM_MODULES)


def get_config(arch: str) -> LMConfig:
    return _LM_MODULES[arch].CONFIG


def get_reduced(arch: str) -> LMConfig:
    return _LM_MODULES[arch].REDUCED


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """The 40 assigned (arch x shape) dry-run cells."""
    return tuple((a, s.name) for a in ARCH_IDS for s in SHAPES)


# ----------------------------------------------------- paper's own models
def paper_cnn_configs() -> Dict[str, CNNConfig]:
    from repro.models.cnn import SEGNET_LAYERS, VGG11_LAYERS
    return {
        "vgg11": CNNConfig(name="vgg11", layers=VGG11_LAYERS, n_classes=10),
        "resnet18": CNNConfig(name="resnet18", layers=(), n_classes=10),
        "segnet": CNNConfig(name="segnet", layers=SEGNET_LAYERS, img=64,
                            n_classes=2),
    }


PAPER_TRANSFORMERS = {
    "spikingformer-4-256": dict(depth=4, dim=256, n_classes=10),
    "spikingformer-2-512": dict(depth=2, dim=512, n_classes=100),
}
