"""tinyllama-1.1b [arXiv:2401.02385]: 22L d2048 32H(kv4) d_ff 5632."""
from .base import LMConfig, SpikingConfig

CONFIG = LMConfig(
    name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000,
    rope_theta=1e4, spiking=SpikingConfig(t_steps=2),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
    remat="none", loss_chunk=16)
