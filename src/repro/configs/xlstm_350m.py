"""xlstm-350m [arXiv:2405.04517]: 24L d1024 4H, sLSTM+mLSTM 1:7 blocks,
vocab 50304. Attention-free: SDSA inapplicable (DESIGN §Arch-applicability);
the LIF/full-event activation path still applies."""
from .base import LMConfig, SpikingConfig, XLSTMSpec

CONFIG = LMConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    xlstm=XLSTMSpec(period=8, slstm_index=7),
    spiking=SpikingConfig(t_steps=1),
)

# One shortened period still covers both block kinds (mLSTM + sLSTM) at
# a quarter of the distinct-block compile cost of period=8.
REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512,
    xlstm=XLSTMSpec(period=2, slstm_index=1),
    remat="none", loss_chunk=16)
