"""jamba-1.5-large-398b [arXiv:2403.19887]: 72L d8192 64H(kv8) d_ff 24576,
Mamba+attn 1:7 interleave, MoE 16e top-2 on alternate layers."""
from .base import HybridSpec, LMConfig, MoESpec, SpikingConfig

CONFIG = LMConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=24576, moe_every=2),
    hybrid=HybridSpec(period=8, attn_index=3),
    rope_theta=1e6,
    spiking=SpikingConfig(t_steps=1),   # SSM states keep T=1 (DESIGN §4)
    fsdp=True, microbatches=8, opt_state_dtype="bfloat16",
)

# One shortened period still covers every block kind (mamba + attn, and
# moe_every=2 puts a dense ffn on one and MoE on the other) at a quarter
# of the distinct-block compile cost of period=8.
REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
    moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=32, moe_every=2),
    hybrid=HybridSpec(period=2, attn_index=1),
    fsdp=False, microbatches=1, remat="none", loss_chunk=16)
