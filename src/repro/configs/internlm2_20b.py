"""internlm2-20b [arXiv:2403.17297]: 48L d6144 48H(kv8) d_ff 16384 GQA."""
from .base import LMConfig, SpikingConfig

CONFIG = LMConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92544,
    rope_theta=1e6, spiking=SpikingConfig(t_steps=2),
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
    remat="none", loss_chunk=16)
