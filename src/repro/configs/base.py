"""Config schema for all architectures (assigned LM pool + paper models)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SpikingConfig:
    """ExSpike technique knobs (first-class feature, DESIGN.md §4)."""
    enabled: bool = True
    t_steps: int = 2            # micro-timesteps per token (paper CNNs: 4)
    lif_decay: float = 0.5      # paper: tau = 0.5
    lif_vth: float = 1.0
    sdsa_mode: str = "or"       # "or" (paper Fig. 6) | "sum" (trainable)
    apec_group: int = 2         # paper's default G2
    hybrid: bool = False        # density-adaptive dispatch: matmul-form ops
                                # with a carried occupancy map pick dense vs
                                # event per call (kernels.dispatch.use_hybrid)
    packed: bool = False        # uint32 spike words as the canonical
                                # inter-layer payload (inference-only; the
                                # fire stages emit packed EventTensors and
                                # dispatch routes to packed-csr backends)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # always-on shared experts (qwen2-moe)
    moe_every: int = 1          # MoE FFN on layers where l % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    pad_experts_to: int = 0     # pad the expert BANK (not the router) to a
                                # mesh-divisible count: dead experts receive
                                # no tokens; enables even EP for e.g. 60e/16

    @property
    def bank_size(self) -> int:
        return max(self.n_experts, self.pad_experts_to)


@dataclasses.dataclass(frozen=True)
class HybridSpec:
    """jamba: 1 attention per `period` layers, rest Mamba."""
    period: int = 8
    attn_index: int = 3
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    """xLSTM[m:s] interleave: one sLSTM per `period`, rest mLSTM."""
    period: int = 8
    slstm_index: int = 7


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                 # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    moe: Optional[MoESpec] = None
    hybrid: Optional[HybridSpec] = None
    xlstm: Optional[XLSTMSpec] = None
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0        # stub frontend positions feeding the encoder
    n_frontend_tokens: int = 0  # stub embeds prepended to the decoder (vlm)
    rope_theta: float = 1e6
    spiking: SpikingConfig = SpikingConfig()
    # Distribution / memory knobs (per-arch defaults; hillclimb overrides).
    remat: str = "full"         # none|full|dots
    microbatches: int = 1
    opt_state_dtype: str = "float32"
    fsdp: bool = False          # additionally shard params/opt over `data`
    tp2d: bool = False          # TP over (data x model) — serving regime:
                                # weights stay resident, no per-step gather
    moe_dispatch_groups: int = 1  # data-shard-local MoE dispatch groups
    moe_shard_map: bool = False   # manual-EP MoE (collective-optimal)
    decode_masked_update: bool = True  # one-hot cache merge (seq-sharded
                                       # caches); False = dynamic_update_slice
                                       # (kv-sharded caches: in-place, cheaper)
    pure_fsdp: bool = False     # no TP at all: params sharded over all axes,
                                # gathered per layer (small-model training)
    loss_chunk: int = 512       # chunked cross-entropy sequence chunk

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train|prefill|decode|long_decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "long_decode"),
)


@dataclasses.dataclass(frozen=True)
class CNNLayer:
    kind: str                   # conv|tconv|maxpool|avgpool
    out_ch: int = 0
    kernel: int = 3
    stride: int = 1
    pool: int = 2


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Paper's own workloads (VGG11/ResNet18/SegNet)."""
    name: str
    layers: Tuple[CNNLayer, ...]
    in_ch: int = 3
    img: int = 32
    n_classes: int = 10
    fc_pool: int = 2            # avgpool before FC (EAFC target)
    direct_coding_bits: int = 8
    spiking: SpikingConfig = SpikingConfig(t_steps=4)
