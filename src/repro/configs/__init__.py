"""Arch configs: assigned 10-arch pool + paper workloads. See registry."""
from .base import (CNNConfig, HybridSpec, LMConfig, MoESpec, ShapeSpec,
                   SHAPES, SpikingConfig, XLSTMSpec)

__all__ = ["CNNConfig", "HybridSpec", "LMConfig", "MoESpec", "ShapeSpec",
           "SHAPES", "SpikingConfig", "XLSTMSpec"]
