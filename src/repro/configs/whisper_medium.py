"""whisper-medium [arXiv:2212.04356]: enc-dec 24L d1024 16H d_ff 4096,
conv audio frontend stubbed as precomputed frame embeddings (1500 frames)."""
from .base import LMConfig, SpikingConfig

CONFIG = LMConfig(
    name="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    encoder_decoder=True, n_encoder_layers=24, encoder_seq=1500,
    rope_theta=1e4, spiking=SpikingConfig(t_steps=2),
)

REDUCED = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    vocab=512, encoder_seq=24, remat="none", loss_chunk=16)
