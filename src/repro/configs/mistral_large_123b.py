"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]:
88L d12288 96H(kv8) d_ff 28672."""
from .base import LMConfig, SpikingConfig

CONFIG = LMConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=28672, vocab=32768,
    rope_theta=1e6, spiking=SpikingConfig(t_steps=2),
    fsdp=True, microbatches=4, opt_state_dtype="bfloat16",
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
    fsdp=False, microbatches=1, remat="none", loss_chunk=16)
