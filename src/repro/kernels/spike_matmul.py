"""Event-driven (occupancy-skipping) spike matmul — Pallas TPU kernel.

The EPE Core computes only while the AER FIFO is non-empty: no events, no
work. Per-event scatter is hostile to the MXU, so the TPU-native event
granularity is the VMEM tile: a precomputed occupancy map marks which
(bm x bk) spike tiles contain any event, and the kernel skips the MXU dot
(and the weight-tile VMEM read is wasted but the FLOPs are not) for empty
tiles. Under the paper's measured sparsities (60-97%) most K-tiles of a
spike matrix are empty at bk=128 only for highly structured sparsity; the
practical win tracks `core.spikes.occupancy_fraction`, which the cost
model and benchmarks report alongside.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation).
out[i,j] = sum_k S[i,k] @ W[k,j], accumulated in an f32 VMEM scratch.

APEC composes with this kernel: `apec_matmul` rewrites grouped positions
as [overlap, residual...] rows, so residual tiles are strictly sparser and
skip more often (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spike_matmul_kernel(occ_ref, s_ref, w_ref, out_ref, acc_ref, *,
                         k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            s_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spike_matmul_pallas(
    s: jax.Array,
    w: jax.Array,
    occupancy: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Occupancy-skipping matmul. s: (M, K) binary; w: (K, N) -> (M, N).

    `occupancy`: (M/bm, K/bk) int32 per-tile event counts (from
    `core.spikes.tile_occupancy`); computed here if not supplied.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = s.shape
    k2, n = w.shape
    assert k == k2, (s.shape, w.shape)
    if m % block_m or k % block_k or n % block_n:
        raise ValueError(
            f"(M,K,N)=({m},{k},{n}) must tile by ({block_m},{block_k},{block_n})")
    if occupancy is None:
        from repro.core.spikes import tile_occupancy
        occupancy = tile_occupancy(s, block_m, block_k)
    occupancy = occupancy.astype(jnp.int32)

    k_steps = k // block_k
    kernel = functools.partial(_spike_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(occupancy, s, w)
