"""Event-driven (occupancy-skipping) spike matmuls — Pallas TPU kernels.

The EPE Core computes only while the AER FIFO is non-empty: no events, no
work. Per-event scatter is hostile to the MXU, so the TPU-native event
granularity is the VMEM tile. Two realizations live here:

* **Predicated** (`spike_matmul_pallas`): a dense (M/bm, N/bn, K/bk) grid
  where a precomputed occupancy map gates the MXU dot with `pl.when`.
  Empty tiles save FLOPs, but every grid step still runs and every weight
  tile still streams HBM->VMEM — the wasted read the CSR form removes.

* **Event-compacted** (`spike_matmul_csr_pallas`, `apec_matmul_csr_pallas`):
  the occupancy map is drained into a CSR-of-tiles work list
  (`core.spikes.TileCSR`) and the grid — via
  `pltpu.PrefetchScalarGridSpec` — runs over occupied tiles only. The
  scalar-prefetched tile indices feed the block index maps, so empty
  tiles cost zero grid steps (concrete pre-pass) and zero tile DMA (the
  traced pre-pass clamps padding steps onto already-resident tiles).
  This is the TPU analogue of the AER FIFO draining to empty. The APEC
  variant additionally fuses the overlap/residual combine: one pass over
  the weight tiles accumulates both matmuls, and the epilogue broadcasts
  each group's overlap partial sum into its g residual output rows
  in-kernel — no `jnp.repeat` full-tensor pass afterwards.

Under the paper's measured sparsities (60-97%) K-tiles of a spike matrix
empty out only for spatially clustered events (which real feature maps
have); the practical win tracks `core.spikes.occupancy_fraction`, which
the cost model (`core.costmodel.tile_matmul_savings`) and benchmarks
report alongside.

APEC composes with both kernels: `apec_matmul` rewrites grouped positions
as [overlap, residual...] rows, so residual tiles are strictly sparser and
skip more often (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.spikes import (PACK, TileCSR, occupancy_to_csr,
                               packed_tile_occupancy, tile_occupancy)


def _spike_matmul_kernel(occ_ref, s_ref, w_ref, out_ref, acc_ref, *,
                         k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            s_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spike_matmul_pallas(
    s: jax.Array,
    w: jax.Array,
    occupancy: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Occupancy-skipping matmul. s: (M, K) binary; w: (K, N) -> (M, N).

    `occupancy`: (M/bm, K/bk) int32 per-tile event counts (from
    `core.spikes.tile_occupancy`); computed here if not supplied.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = s.shape
    k2, n = w.shape
    assert k == k2, (s.shape, w.shape)
    if m % block_m or k % block_k or n % block_n:
        raise ValueError(
            f"(M,K,N)=({m},{k},{n}) must tile by ({block_m},{block_k},{block_n})")
    if occupancy is None:
        occupancy = tile_occupancy(s, block_m, block_k)
    if occupancy.shape != (m // block_m, k // block_k):
        # A map built for another tiling would silently gate the wrong
        # tiles (Pallas clamps out-of-range block indices) — refuse it.
        raise ValueError(
            f"occupancy shape {occupancy.shape} does not match tiling "
            f"({m // block_m}, {k // block_k})")
    occupancy = occupancy.astype(jnp.int32)

    k_steps = k // block_k
    kernel = functools.partial(_spike_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(occupancy, s, w)


# ---------------------------------------------------------------- CSR grid
def _weight_prefetch(gate, kidx_ref, w_hbm, wbuf, sem, *,
                     block_k: int, block_n: int):
    """Double-buffered weight-tile motion for the CSR grids (the spikehard
    `dma_controller`/`dma_buffer` pattern): while step t's dot runs out of
    rotation slot t%2, the HBM->VMEM copy for step t+1's tile streams into
    slot (t+1)%2, so an occupied step's MXU work hides the next weight
    fetch instead of stalling on its own.

    `gate(u)` must be True exactly when step u performs a dot: every
    `start()` here is paired with exactly one `wait()` (returned closure)
    under the same gate, and dummy / clamp-padding steps (occ=0) issue no
    DMA at all — the serial kernels' "empty tiles cost zero weight DMA"
    contract survives the rewrite. Only the warm-up copy at t==0 is
    exposed; the cost model's `dma_overlap_ledger` counts exactly that.
    """
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    j = pl.program_id(0)

    def copy(slot, step):
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(kidx_ref[step] * block_k, block_k),
                     pl.ds(j * block_n, block_n)],
            wbuf.at[slot], sem.at[slot])

    @pl.when((t == 0) & gate(0))
    def _warmup():
        copy(0, 0).start()

    nxt = jnp.minimum(t + 1, n_t - 1)

    @pl.when((t + 1 < n_t) & gate(nxt))
    def _lookahead():
        copy((t + 1) % 2, nxt).start()

    def wait_resident():
        copy(t % 2, t).wait()
    return wait_resident


def _spike_matmul_csr_kernel(row_ref, kidx_ref, occ_ref,
                             s_ref, w_ref, out_ref, acc_ref):
    """One grid step per occupied (m-tile, k-tile); j (N-tile) is the outer
    grid axis so steps of one output row are consecutive. The accumulator
    resets on row change and flushes on the last step of each row; dummy /
    padding steps (occ=0) contribute nothing but keep empty rows written
    and clamped indices DMA-free (see core.spikes.TileCSR)."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[t] > 0)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            s_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _spike_matmul_csr_pipe_kernel(row_ref, kidx_ref, occ_ref,
                                  s_ref, w_hbm, out_ref,
                                  acc_ref, wbuf, sem, *,
                                  block_k: int, block_n: int):
    """Pipelined twin of `_spike_matmul_csr_kernel`: the weight operand
    stays an HBM ref and occupied steps read their tile from the 2-deep
    VMEM rotation that `_weight_prefetch` keeps one step ahead. Init /
    accumulate / flush row logic is identical to the serial kernel."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]
    wait_resident = _weight_prefetch(
        lambda u: occ_ref[u] > 0, kidx_ref, w_hbm, wbuf, sem,
        block_k=block_k, block_n=block_n)

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[t] > 0)
    def _accumulate():
        wait_resident()
        acc_ref[...] += jnp.dot(
            s_ref[...], wbuf[t % 2], preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spike_matmul_csr_pallas(
    s: jax.Array,
    w: jax.Array,
    csr: TileCSR | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
    pipeline: bool = False,
) -> jax.Array:
    """Event-compacted matmul: grid over occupied tiles only.

    s: (M, K) binary; w: (K, N) -> (M, N). `csr`: a precomputed
    `core.spikes.TileCSR` for this (block_m, block_k) tiling (built here
    if not supplied — suppliers get the pre-pass cost once per layer).
    `pipeline=True` switches to the double-buffered weight-DMA kernel
    (see `_weight_prefetch`); same math, same work list, same outputs.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = s.shape
    k2, n = w.shape
    assert k == k2, (s.shape, w.shape)
    if m % block_m or k % block_k or n % block_n:
        raise ValueError(
            f"(M,K,N)=({m},{k},{n}) must tile by ({block_m},{block_k},{block_n})")
    if csr is None:
        csr = occupancy_to_csr(tile_occupancy(s, block_m, block_k),
                               tiling=(block_m, block_k))
    csr.check_compatible(block_m, block_k, m // block_m, k // block_k)
    if csr.n_rows != m // block_m:
        raise ValueError(
            f"csr has {csr.n_rows} m-tile rows, input needs {m // block_m}")

    if pipeline:
        kernel = functools.partial(_spike_matmul_csr_pipe_kernel,
                                   block_k=block_k, block_n=block_n)
        w_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((2, block_k, block_n), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = _spike_matmul_csr_kernel
        w_spec = pl.BlockSpec((block_k, block_n),
                              lambda j, t, row, kidx, occ: (kidx[t], j))
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n // block_n, csr.n_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda j, t, row, kidx, occ: (row[t], kidx[t])),
            w_spec,
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda j, t, row, kidx, occ: (row[t], j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=interpret,
    )(csr.tile_m_idx, csr.tile_k_idx, csr.occ, s, w)


# ------------------------------------------------------- packed CSR grid
# The `packed-csr` family: the spike operand arrives as uint32 words
# (32 lanes per word — 1/32 the HBM read of the f32 operand) and each
# occupied tile is unpacked VMEM-RESIDENT, inside the grid step that
# already holds it for the dot: a broadcast-compare against the 32 bit
# masks, never an HBM round-trip through f32. Weight traffic, grid
# compaction, accumulate/flush logic are identical to the f32 CSR kernels
# above — only the spike-side DMA shrinks.
def _unpack_tile(words, block_k: int):
    """(bm, bk/32) uint32 -> (bm, bk) f32 {0,1}: broadcast-compare each
    word against the 32 single-bit masks (little-endian lane order,
    matching `core.spikes.pack_spikes`)."""
    bm = words.shape[0]
    masks = jnp.uint32(1) << jnp.arange(PACK, dtype=jnp.uint32)
    bits = (words[:, :, None] & masks[None, None, :]) != 0
    return bits.reshape(bm, block_k).astype(jnp.float32)


def _spike_matmul_packed_csr_kernel(row_ref, kidx_ref, occ_ref,
                                    p_ref, w_ref, out_ref, acc_ref, *,
                                    block_k: int):
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[t] > 0)
    def _accumulate():
        s_tile = _unpack_tile(p_ref[...], block_k)
        acc_ref[...] += jnp.dot(
            s_tile, w_ref[...], preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _spike_matmul_packed_csr_pipe_kernel(row_ref, kidx_ref, occ_ref,
                                         p_ref, w_hbm, out_ref,
                                         acc_ref, wbuf, sem, *,
                                         block_k: int, block_n: int):
    """Pipelined twin of `_spike_matmul_packed_csr_kernel`: the uint32
    word tile unpacks in-VMEM while the next step's weight tile streams
    into the other rotation slot — the two sides of the dot overlap."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]
    wait_resident = _weight_prefetch(
        lambda u: occ_ref[u] > 0, kidx_ref, w_hbm, wbuf, sem,
        block_k=block_k, block_n=block_n)

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[t] > 0)
    def _accumulate():
        wait_resident()
        s_tile = _unpack_tile(p_ref[...], block_k)
        acc_ref[...] += jnp.dot(
            s_tile, wbuf[t % 2], preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spike_matmul_packed_csr_pallas(
    p: jax.Array,
    w: jax.Array,
    csr: TileCSR | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
    pipeline: bool = False,
) -> jax.Array:
    """Event-compacted matmul on a PACKED spike operand.

    p: (M, K/32) uint32 words of a binary (M, K) matrix; w: (K, N) ->
    (M, N). The packed operand's k-tile blocks are (block_m, block_k/32)
    words addressed by the same scalar-prefetched tile indices as the f32
    kernel — the work list is payload-agnostic. `csr` built here from the
    words' popcounts if not supplied (32x cheaper than the dense pre-pass,
    same counts exactly).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, kw = p.shape
    k2, n = w.shape
    if block_k % PACK:
        raise ValueError(f"block_k {block_k} not a multiple of {PACK}")
    bkw = block_k // PACK
    if kw * PACK != k2:
        raise ValueError(
            f"packed operand ({m},{kw}) words does not cover w rows {k2} "
            f"(want {k2 // PACK} words — pad both to the tile boundary)")
    if m % block_m or kw % bkw or n % block_n:
        raise ValueError(
            f"(M,KW,N)=({m},{kw},{n}) must tile by ({block_m},{bkw},{block_n})")
    if csr is None:
        csr = occupancy_to_csr(packed_tile_occupancy(p, block_m, block_k),
                               tiling=(block_m, block_k))
    csr.check_compatible(block_m, block_k, m // block_m, kw // bkw)
    if csr.n_rows != m // block_m:
        raise ValueError(
            f"csr has {csr.n_rows} m-tile rows, input needs {m // block_m}")

    if pipeline:
        kernel = functools.partial(_spike_matmul_packed_csr_pipe_kernel,
                                   block_k=block_k, block_n=block_n)
        w_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((2, block_k, block_n), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_spike_matmul_packed_csr_kernel,
                                   block_k=block_k)
        w_spec = pl.BlockSpec((block_k, block_n),
                              lambda j, t, row, kidx, occ: (kidx[t], j))
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n // block_n, csr.n_steps),
        in_specs=[
            pl.BlockSpec((block_m, bkw),
                         lambda j, t, row, kidx, occ: (row[t], kidx[t])),
            w_spec,
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda j, t, row, kidx, occ: (row[t], j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=interpret,
    )(csr.tile_m_idx, csr.tile_k_idx, csr.occ, p, w)


def _apec_matmul_packed_csr_kernel(row_ref, kidx_ref, occ_res_ref,
                                   occ_ov_ref, res_ref, ov_ref, w_ref,
                                   out_ref, acc_ref, acc_ov_ref, *, g: int,
                                   block_k: int):
    """Packed twin of `_apec_matmul_csr_kernel`: both spike operands
    (residual and overlap) arrive as uint32 words and unpack in-VMEM per
    occupied step; weight DMA, union gating, and the fused group-broadcast
    epilogue are unchanged."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        acc_ov_ref[...] = jnp.zeros_like(acc_ov_ref)

    @pl.when(occ_res_ref[t] > 0)
    def _acc_res():
        acc_ref[...] += jnp.dot(
            _unpack_tile(res_ref[...], block_k), w_ref[...],
            preferred_element_type=jnp.float32)

    @pl.when(occ_ov_ref[t] > 0)
    def _acc_ov():
        acc_ov_ref[...] += jnp.dot(
            _unpack_tile(ov_ref[...], block_k), w_ref[...],
            preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        bmg, bn = acc_ov_ref.shape
        ov_rep = jnp.broadcast_to(acc_ov_ref[...][:, None, :],
                                  (bmg, g, bn)).reshape(bmg * g, bn)
        out_ref[...] = (acc_ref[...] + ov_rep).astype(out_ref.dtype)


def _apec_matmul_packed_csr_pipe_kernel(row_ref, kidx_ref, occ_res_ref,
                                        occ_ov_ref, res_ref, ov_ref, w_hbm,
                                        out_ref, acc_ref, acc_ov_ref, wbuf,
                                        sem, *, g: int, block_k: int,
                                        block_n: int):
    """Pipelined twin of `_apec_matmul_packed_csr_kernel`: one prefetched
    weight tile serves both dots of a union step, so the DMA gate is the
    union occupancy (either operand live)."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]

    def gate(u):
        return (occ_res_ref[u] > 0) | (occ_ov_ref[u] > 0)

    wait_resident = _weight_prefetch(gate, kidx_ref, w_hbm, wbuf, sem,
                                     block_k=block_k, block_n=block_n)

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        acc_ov_ref[...] = jnp.zeros_like(acc_ov_ref)

    @pl.when(gate(t))
    def _land():
        wait_resident()

    @pl.when(occ_res_ref[t] > 0)
    def _acc_res():
        acc_ref[...] += jnp.dot(
            _unpack_tile(res_ref[...], block_k), wbuf[t % 2],
            preferred_element_type=jnp.float32)

    @pl.when(occ_ov_ref[t] > 0)
    def _acc_ov():
        acc_ov_ref[...] += jnp.dot(
            _unpack_tile(ov_ref[...], block_k), wbuf[t % 2],
            preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        bmg, bn = acc_ov_ref.shape
        ov_rep = jnp.broadcast_to(acc_ov_ref[...][:, None, :],
                                  (bmg, g, bn)).reshape(bmg * g, bn)
        out_ref[...] = (acc_ref[...] + ov_rep).astype(out_ref.dtype)


def apec_matmul_packed_csr_pallas(
    res: jax.Array,
    ov: jax.Array,
    w: jax.Array,
    g: int,
    csr: TileCSR,
    occ_res: jax.Array,
    occ_ov: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
    pipeline: bool = False,
) -> jax.Array:
    """Fused APEC matmul over the event-compacted grid, packed operands.

    res: (M, K/32) uint32 residual words; ov: (M/g, K/32) uint32 overlap
    words; w: (K, N). Same union-CSR / per-step gating contract as
    `apec_matmul_csr_pallas` — see there.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, kw = res.shape
    mg, kwg = ov.shape
    k2, n = w.shape
    if block_k % PACK:
        raise ValueError(f"block_k {block_k} not a multiple of {PACK}")
    bkw = block_k // PACK
    assert kw == kwg and kw * PACK == k2 and mg * g == m, \
        (res.shape, ov.shape, w.shape, g)
    if block_m % g:
        raise ValueError(f"block_m {block_m} not divisible by group {g}")
    if m % block_m or kw % bkw or n % block_n:
        raise ValueError(
            f"(M,KW,N)=({m},{kw},{n}) must tile by ({block_m},{bkw},{block_n})")

    if pipeline:
        kernel = functools.partial(_apec_matmul_packed_csr_pipe_kernel, g=g,
                                   block_k=block_k, block_n=block_n)
        w_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m // g, block_n), jnp.float32),
                   pltpu.VMEM((2, block_k, block_n), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_apec_matmul_packed_csr_kernel, g=g,
                                   block_k=block_k)
        w_spec = pl.BlockSpec((block_k, block_n),
                              lambda j, t, row, kidx, o1, o2: (kidx[t], j))
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m // g, block_n), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n // block_n, csr.n_steps),
        in_specs=[
            pl.BlockSpec((block_m, bkw),
                         lambda j, t, row, kidx, o1, o2: (row[t], kidx[t])),
            pl.BlockSpec((block_m // g, bkw),
                         lambda j, t, row, kidx, o1, o2: (row[t], kidx[t])),
            w_spec,
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda j, t, row, kidx, o1, o2: (row[t], j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=interpret,
    )(csr.tile_m_idx, csr.tile_k_idx, occ_res, occ_ov, res, ov, w)


def _apec_matmul_csr_kernel(row_ref, kidx_ref, occ_res_ref, occ_ov_ref,
                            res_ref, ov_ref, w_ref, out_ref,
                            acc_ref, acc_ov_ref, *, g: int):
    """Fused APEC epilogue: the residual and overlap matmuls share one
    pass over the weight tiles (one DMA serves both dots), and the flush
    broadcasts each group's overlap partial sum into its g member rows
    in-kernel — the `psum_res + jnp.repeat(psum_ov, g)` full-tensor pass
    is gone from the `pallas-csr` path."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        acc_ov_ref[...] = jnp.zeros_like(acc_ov_ref)

    @pl.when(occ_res_ref[t] > 0)
    def _acc_res():
        acc_ref[...] += jnp.dot(
            res_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(occ_ov_ref[t] > 0)
    def _acc_ov():
        acc_ov_ref[...] += jnp.dot(
            ov_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        bmg, bn = acc_ov_ref.shape
        ov_rep = jnp.broadcast_to(acc_ov_ref[...][:, None, :],
                                  (bmg, g, bn)).reshape(bmg * g, bn)
        out_ref[...] = (acc_ref[...] + ov_rep).astype(out_ref.dtype)


def _apec_matmul_csr_pipe_kernel(row_ref, kidx_ref, occ_res_ref, occ_ov_ref,
                                 res_ref, ov_ref, w_hbm, out_ref,
                                 acc_ref, acc_ov_ref, wbuf, sem, *, g: int,
                                 block_k: int, block_n: int):
    """Pipelined twin of `_apec_matmul_csr_kernel`: the shared weight tile
    is prefetched one union step ahead (DMA gate = either operand live),
    and both dots read it from the same rotation slot."""
    t = pl.program_id(1)
    n_t = pl.num_programs(1)
    row = row_ref[t]

    def gate(u):
        return (occ_res_ref[u] > 0) | (occ_ov_ref[u] > 0)

    wait_resident = _weight_prefetch(gate, kidx_ref, w_hbm, wbuf, sem,
                                     block_k=block_k, block_n=block_n)

    @pl.when((t == 0) | (row != row_ref[jnp.maximum(t - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        acc_ov_ref[...] = jnp.zeros_like(acc_ov_ref)

    @pl.when(gate(t))
    def _land():
        wait_resident()

    @pl.when(occ_res_ref[t] > 0)
    def _acc_res():
        acc_ref[...] += jnp.dot(
            res_ref[...], wbuf[t % 2], preferred_element_type=jnp.float32)

    @pl.when(occ_ov_ref[t] > 0)
    def _acc_ov():
        acc_ov_ref[...] += jnp.dot(
            ov_ref[...], wbuf[t % 2], preferred_element_type=jnp.float32)

    @pl.when((t == n_t - 1) | (row_ref[jnp.minimum(t + 1, n_t - 1)] != row))
    def _flush():
        bmg, bn = acc_ov_ref.shape
        ov_rep = jnp.broadcast_to(acc_ov_ref[...][:, None, :],
                                  (bmg, g, bn)).reshape(bmg * g, bn)
        out_ref[...] = (acc_ref[...] + ov_rep).astype(out_ref.dtype)


def apec_matmul_csr_pallas(
    res: jax.Array,
    ov: jax.Array,
    w: jax.Array,
    g: int,
    csr: TileCSR,
    occ_res: jax.Array,
    occ_ov: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
    pipeline: bool = False,
) -> jax.Array:
    """Fused APEC matmul over the event-compacted grid.

    res: (M, K) residual spikes (M = padded positions, group members
    adjacent); ov: (M/g, K) overlap spikes; w: (K, N). Output (M, N) =
    res @ w + repeat(ov @ w, g) — computed in one kernel. `csr` must be
    built from the *union* occupancy (a k-tile is visited when either
    operand's tile holds events) and `occ_res`/`occ_ov` are the per-step
    counts of each operand (0 on the other operand's exclusive steps and
    on dummy/padding steps) — see `ops.apec_matmul_csr` for the pre-pass.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = res.shape
    mg, kg = ov.shape
    k2, n = w.shape
    assert k == k2 == kg and mg * g == m, (res.shape, ov.shape, w.shape, g)
    if block_m % g:
        raise ValueError(f"block_m {block_m} not divisible by group {g}")
    if m % block_m or k % block_k or n % block_n:
        raise ValueError(
            f"(M,K,N)=({m},{k},{n}) must tile by ({block_m},{block_k},{block_n})")

    if pipeline:
        kernel = functools.partial(_apec_matmul_csr_pipe_kernel, g=g,
                                   block_k=block_k, block_n=block_n)
        w_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m // g, block_n), jnp.float32),
                   pltpu.VMEM((2, block_k, block_n), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_apec_matmul_csr_kernel, g=g)
        w_spec = pl.BlockSpec((block_k, block_n),
                              lambda j, t, row, kidx, o1, o2: (kidx[t], j))
        scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
                   pltpu.VMEM((block_m // g, block_n), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n // block_n, csr.n_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda j, t, row, kidx, o1, o2: (row[t], kidx[t])),
            pl.BlockSpec((block_m // g, block_k),
                         lambda j, t, row, kidx, o1, o2: (row[t], kidx[t])),
            w_spec,
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda j, t, row, kidx, o1, o2: (row[t], j)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=interpret,
    )(csr.tile_m_idx, csr.tile_k_idx, occ_res, occ_ov, res, ov, w)
