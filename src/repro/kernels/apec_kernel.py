"""APEC overlap/residual extraction — Pallas TPU kernel on packed spikes.

Fig. 5's compression step in hardware form: for each group of g adjacent
positions, overlap = AND of the packed spike words, residual_i =
s_i AND NOT overlap. Pure VPU bitwise ops on uint32 lanes — one pass over
HBM, 32 channels per lane. The event-driven matmul then processes
[overlap | residuals], whose residual tiles are strictly sparser
(higher tile-skip rate in spike_matmul).

Grid: (P/(g*bm), dw/bn); each program handles bm groups x bn words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apec_kernel(s_ref, ov_ref, res_ref, *, g: int):
    s = s_ref[...]                       # (g*bm, bn) uint32
    bm = s.shape[0] // g
    grp = s.reshape(bm, g, s.shape[1])
    ov = grp[:, 0, :]
    for i in range(1, g):
        ov = ov & grp[:, i, :]           # Eq. 1: AND across the group
    res = grp & ~ov[:, None, :]          # s_i AND NOT overlap
    ov_ref[...] = ov
    res_ref[...] = res.reshape(s.shape)


def apec_decompose_packed(
    s_packed: jax.Array, g: int = 2, *, block_m: int = 8,
    block_n: int = 128, interpret: bool | None = None,
):
    """(P, dw) packed spikes -> (overlap (P/g, dw), residual (P, dw)).

    P must be divisible by g*block_m and dw by block_n (pad upstream; the
    ops.py wrapper handles it).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    p, dw = s_packed.shape
    block_n = min(block_n, dw)
    if p % (g * block_m) or dw % block_n:
        raise ValueError(f"({p},{dw}) not tileable by (g*{block_m},{block_n})")
    kernel = functools.partial(_apec_kernel, g=g)
    return pl.pallas_call(
        kernel,
        grid=(p // (g * block_m), dw // block_n),
        in_specs=[pl.BlockSpec((g * block_m, block_n),
                               lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((g * block_m, block_n), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((p // g, dw), jnp.uint32),
            jax.ShapeDtypeStruct((p, dw), jnp.uint32),
        ),
        interpret=interpret,
    )(s_packed)
