"""Backend dispatch registry for the ExSpike hot-path ops.

One event-driven dataflow (LIF -> spike encoding -> APEC -> occupancy-
skipping matmul / SDSA) serves every workload in this repo, but each op
has several numerically-equivalent realizations: a pure-jnp oracle, an
alternative vectorized jnp form, and the Pallas TPU kernels (compiled on
TPU, interpret mode on CPU). This module is the single switchboard:

  op          backends                         notes
  ----------  -------------------------------  ---------------------------
  lif_scan    ref | pallas-interpret | pallas  pallas: fused fwd + reversed-
                                               scan surrogate bwd kernels
  spike_matmul ref | jnp | pallas[-interpret]        pallas-csr: event-
              | pallas-csr[-interpret]              compacted grid (TPU
  apec_matmul ref | jnp | pallas[-interpret]         default; degrades to
              | pallas-csr[-interpret]              pallas, see `fallback`)
  sdsa        ref | jnp | pallas-interpret | pallas   packed paths: mode=or
  causal_sdsa ref | jnp | pallas-interpret | pallas   packed paths: mode=or
  econv       ref | jnp | pallas[-interpret]        jnp = event scatter;
              | pallas-csr[-interpret]              csr = im2col + CSR grid
  tconv       ref | jnp | pallas-interpret | pallas   transposed conv
                                               (decoder upsampling)

Every backend above is *differentiable*: `jax.grad` through `dispatch(...)`
produces the same surrogate-gradient cotangents as the `ref` oracle on any
resolved backend, so training never needs a backend pin. The registration
contract (see `register`) is one of:

  * ``differentiable=True`` — the fn is natively differentiable with
    ref-matching gradients (jnp oracles, custom_vjp'd kernels like the
    fused LIF);
  * ``vjp="ref"`` — the fn is wrapped in a `jax.custom_vjp` whose backward
    replays the ref oracle's VJP on the saved inputs (grad parity by
    construction; used for bit-packed / scatter paths whose natural
    gradients would be zero or tie-broken differently);
  * ``vjp=<callable>`` — an explicit backward rule
    ``(saved_args, kwargs, cotangent) -> grads`` (used for the matmul-form
    ops, where the transpose rule is cheaper than a ref replay).

Selection order per call:
  1. explicit override — `use_backend(...)` context or the
     ``EXSPIKE_BACKEND`` env var (``ref`` for all ops, or a comma list of
     ``op=backend`` entries, e.g. ``EXSPIKE_BACKEND=sdsa=pallas,ref``);
  2. otherwise the highest-priority backend registered for the current
     platform whose capability check (`supports`) passes;
  3. the `ref` oracle as the universal fallback — if an override or a
     chosen kernel can't handle the inputs (shape divisibility, dtype,
     unsupported mode), the call falls back to `ref` with a warning
     instead of erroring.

Resolution happens at trace time (shapes/dtypes are static under jit), so
dispatch adds zero runtime cost to compiled code.

Distributed execution resolves through the SAME registry: under
`resolve(..., mesh=)` or an ambient `use_mesh(...)` context (what
`launch.steps` pushes around sharded step tracing and
`runtime.sharding.event_op_sharded` uses inside shard_map), candidates
are filtered to backends declaring the `mesh_aware` capability and every
capability check runs on the PER-SHARD shapes, so "distributed" can never
silently mean "dense jnp math": the `pallas-csr` family stays selected
while each shard's tile grid divides cleanly and degrades down its
declared fallback chain (with `resolved_backends()` attribution) when it
doesn't.

Registering a new kernel is one `register(...)` call; the parity harness
(`tests/test_dispatch_parity.py`) enumerates every registered
(op x backend) pair against `ref` automatically, and
``benchmarks/run.py --backend`` sweeps it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import warnings
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

ENV_VAR = "EXSPIKE_BACKEND"
REF = "ref"
# Override value selecting density-adaptive hybrid resolution instead of a
# concrete backend: matmul-form calls carrying an occupancy map route
# per call between the predicated-dense and event-compacted kernel
# families on the cost model's calibrated crossover (see use_hybrid).
HYBRID = "hybrid"
# Ops hybrid resolution applies to: matmul-form consumers of a carried
# (MT, KT) occupancy map with a registered dense/event kernel pair.
HYBRID_OPS = ("spike_matmul", "apec_matmul", "econv")
ALL_PLATFORMS = ("cpu", "gpu", "tpu")


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered implementation of an op.

    `supports(*args, **kwargs) -> str | None` returns a reason string when
    the backend CANNOT handle the call (None means supported). `auto`
    backends participate in automatic selection; non-auto ones run only
    under an explicit override (and in the parity harness).
    """
    name: str
    fn: Callable[..., Any]
    platforms: Tuple[str, ...] = ALL_PLATFORMS
    priority: int = 0
    auto: bool = True
    supports: Optional[Callable[..., Optional[str]]] = None
    differentiable: bool = False
    # Name of the backend an explicit override degrades to when THIS
    # backend can't take the inputs (e.g. pallas-csr -> pallas keeps a
    # degraded sweep comparable: still the kernel family, not the ref
    # oracle). None falls straight to ref, the universal fallback.
    fallback: Optional[str] = None
    # Mesh capability: may this backend be picked when resolution runs
    # under a device mesh (`resolve(..., mesh=)` / `use_mesh(...)`, i.e.
    # the op will execute per data shard inside shard_map / sharded jit)?
    #   False     — never (the safe default for new registrations: a
    #               backend must declare shard-locality explicitly);
    #   True      — per-shard execution is safe whenever plain `supports`
    #               passes on the per-shard shapes;
    #   callable  — an extra per-shard gate with the `supports` signature,
    #               run on the per-shard (local) shapes; returns a reason
    #               string when the sharded execution should degrade (the
    #               CSR family uses this to require that each shard's row
    #               count fills whole 128-row tiles, keeping every shard's
    #               compacted tile grid congruent).
    mesh_aware: Union[bool, Callable[..., Optional[str]]] = False
    # Payload capability: which spike-payload representations this
    # backend may be AUTO-selected (or hybrid-routed) for. A call whose
    # spike operand is uint32 words (marked by the static ``packed_k=``
    # kwarg threaded from a packed `EventTensor`) resolves only among
    # backends declaring "packed"; every other call resolves only among
    # backends declaring "dense". When resolution must leave the packed
    # family (degrade chain, no packed backend on this platform), the
    # chosen dense backend is wrapped in an EXPLICIT unpack shim
    # (`_unpack_shim`, warn-once + ``+unpack`` attribution) — a packed
    # payload is never silently reinterpreted or densified. Explicit
    # overrides / `call_backend` bypass the filter: the packed-csr family
    # also accepts dense operands (packs internally), which is how the
    # parity harness covers it with dense example inputs.
    payload: Tuple[str, ...] = ("dense",)

    def unsupported_reason(self, *args, **kwargs) -> Optional[str]:
        platform = jax.default_backend()
        if platform not in self.platforms:
            return f"platform {platform} not in {self.platforms}"
        if self.supports is not None:
            return self.supports(*args, **kwargs)
        return None

    def mesh_unsupported_reason(self, *args, **kwargs) -> Optional[str]:
        """Like `unsupported_reason`, evaluated on PER-SHARD shapes, with
        the mesh-awareness capability folded in."""
        if self.mesh_aware is False:
            return "backend not declared mesh-aware"
        reason = self.unsupported_reason(*args, **kwargs)
        if reason is not None:
            return reason
        if callable(self.mesh_aware):
            return self.mesh_aware(*args, **kwargs)
        return None


@dataclasses.dataclass
class OpSpec:
    name: str
    make_example: Callable[[jax.Array], Tuple[tuple, dict]]
    backends: Dict[str, Backend] = dataclasses.field(default_factory=dict)


_REGISTRY: Dict[str, OpSpec] = {}
_OVERRIDES: list = []   # stack of {op_or_None: backend_name} dicts


# ----------------------------------------------------------- registration
def register_op(name: str, make_example) -> None:
    if name not in _REGISTRY:
        _REGISTRY[name] = OpSpec(name=name, make_example=make_example)


def _is_arrayish(v) -> bool:
    return isinstance(v, (jax.Array, jax.core.Tracer, np.ndarray))


def _zero_cotangent(x):
    """Symbolic-zero stand-in for a non-differentiated aux operand:
    float0 for integer/bool primals (what custom_vjp requires), zeros
    otherwise."""
    aval = jax.core.get_aval(x)
    if jnp.issubdtype(aval.dtype, jnp.inexact):
        return jnp.zeros(aval.shape, aval.dtype)
    return np.zeros(aval.shape, jax.dtypes.float0)


def _wrap_vjp(op: str, fn, rule):
    """Make `fn` differentiable under a custom backward rule.

    rule="ref": backward replays the ref oracle's VJP on the saved primal
    inputs — gradient parity with ref by construction, at the cost of one
    ref forward inside backward (cheap for the logic-form ops this is used
    on). rule=callable: explicit ``(saved_args, kwargs, g) -> grads``.
    Static kwargs (mode, g, stride) are closed over. Array-valued kwargs
    (the carried `occupancy` map, a `csr` work list) are NON-DIFFERENTIATED
    AUX OPERANDS: they thread through the custom_vjp as primal inputs (a
    tracer must not be closed over) but their cotangent is a symbolic zero
    — occupancy is metadata, gradients flow only through spikes/weights,
    exactly the stop_gradient contract the EventTensor pipeline declares.

    Packed payloads (static ``packed_k`` kwarg, spike operand = uint32
    words): pack is forward-only aux — the backward unpacks the saved
    words and the cotangents flow through the UNPACKED values (ref replay
    on the dense view; explicit rules receive `packed_k` and handle it),
    while the word operand itself gets the float0 cotangent its integer
    dtype mandates.
    """
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        aux_keys = tuple(sorted(
            k for k, v in kwargs.items()
            if any(_is_arrayish(l) for l in jax.tree_util.tree_leaves(v))))
        static = {k: v for k, v in kwargs.items() if k not in aux_keys}
        aux = {k: kwargs[k] for k in aux_keys}

        @jax.custom_vjp
        def inner(aux, *a):
            return fn(*a, **static, **aux)

        def inner_fwd(aux, *a):
            return fn(*a, **static, **aux), (aux, a)

        if rule == "ref":
            def inner_bwd(res, g):
                aux_r, a = res
                ref_fn = _REGISTRY[op].backends[REF].fn
                pk = static.get("packed_k")
                if pk is not None:
                    # Replay ref on the unpacked dense view; the word
                    # operand is non-differentiated (float0 by dtype).
                    from repro.core.spikes import unpack_spikes
                    ref_static = {k: v for k, v in static.items()
                                  if k != "packed_k"}
                    s0 = unpack_spikes(a[0], axis=-1,
                                       dtype=jnp.float32)[..., :pk]
                    _, pull = jax.vjp(
                        lambda *ar: ref_fn(s0, *ar, **ref_static, **aux_r),
                        *a[1:])
                    return (jax.tree.map(_zero_cotangent, aux_r),
                            _zero_cotangent(a[0])) + tuple(pull(g))
                _, pull = jax.vjp(
                    lambda *ar: ref_fn(*ar, **static, **aux_r), *a)
                return (jax.tree.map(_zero_cotangent, aux_r),) \
                    + tuple(pull(g))
        else:
            def inner_bwd(res, g):
                aux_r, a = res
                return (jax.tree.map(_zero_cotangent, aux_r),) \
                    + tuple(rule(a, static, g))

        inner.defvjp(inner_fwd, inner_bwd)
        return inner(aux, *args)
    return wrapper


def _matmul_bwd(res, kwargs, g):
    """Transpose rule for ops whose math is `out = s @ w` with optional
    leading batch axes on s (spike_matmul, apec_matmul): ds = g @ w.T,
    dw = sum over rows of s^T g — the ref oracle's exact cotangents.

    A packed spike operand (static ``packed_k`` present) contributes dw
    through its UNPACKED values and receives the float0 cotangent its
    integer dtype mandates — pack is forward-only aux."""
    s, w = res
    gf = g.astype(jnp.float32)
    pk = kwargs.get("packed_k")
    if pk is not None:
        from repro.core.spikes import unpack_spikes
        sf = unpack_spikes(s, axis=-1, dtype=jnp.float32)[..., :pk]
        dw = jnp.einsum("...mk,...mn->kn", sf, gf).astype(w.dtype)
        return _zero_cotangent(s), dw
    ds = jnp.matmul(gf, w.astype(jnp.float32).T).astype(s.dtype)
    dw = jnp.einsum("...mk,...mn->kn", s.astype(jnp.float32), gf).astype(w.dtype)
    return ds, dw


def register(op: str, name: str, *, platforms=ALL_PLATFORMS, priority=0,
             auto=True, supports=None, differentiable=False, vjp=None,
             fallback=None, mesh_aware=False, payload=("dense",)):
    """Decorator: register `fn` as backend `name` for `op`.

    Gradient contract: pass ``differentiable=True`` when `jax.grad`
    through `fn` natively matches the ref oracle's (surrogate) gradients,
    or ``vjp="ref"`` / ``vjp=<callable>`` to wrap `fn` in a custom_vjp
    (see `_wrap_vjp`) — wrapped backends are differentiable by definition.
    Declared pairs are grad-parity-tested against ref by
    tests/test_dispatch_parity.py automatically.

    ``fallback``: backend name an explicit override degrades to when this
    backend's capability check fails (chains until a supported backend;
    `ref` remains the terminal fallback). Auto-selection already falls
    through by priority and ignores this.

    ``mesh_aware``: mesh capability (see `Backend.mesh_aware`) — False
    (default) keeps the backend off every sharded path; True admits it
    whenever `supports` passes per shard; a callable is an extra
    per-shard gate run on local shapes.

    ``payload``: payload capability (see `Backend.payload`) — the default
    ``("dense",)`` keeps the backend off packed-payload calls; declare
    ``("packed",)`` for backends consuming uint32 spike words natively.
    """
    def deco(fn):
        if op not in _REGISTRY:
            raise KeyError(f"unknown op {op!r}; register_op it first")
        wrapped = _wrap_vjp(op, fn, vjp) if vjp is not None else fn
        _REGISTRY[op].backends[name] = Backend(
            name=name, fn=wrapped, platforms=tuple(platforms),
            priority=priority, auto=auto, supports=supports,
            differentiable=differentiable or vjp is not None,
            fallback=fallback, mesh_aware=mesh_aware,
            payload=tuple(payload))
        return fn
    return deco


def op_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def backend_names(op: str) -> Tuple[str, ...]:
    return tuple(_REGISTRY[op].backends)


def get_backend(op: str, name: str) -> Backend:
    try:
        return _REGISTRY[op].backends[name]
    except KeyError:
        raise KeyError(
            f"op {op!r} has no backend {name!r}; "
            f"registered: {backend_names(op)}") from None


def example_inputs(op: str, key: jax.Array) -> Tuple[tuple, dict]:
    """Small CPU-friendly (args, kwargs) for the parity harness."""
    return _REGISTRY[op].make_example(key)


def differentiable_backend_names(op: str) -> Tuple[str, ...]:
    """Backends of `op` declaring the gradient contract (grad-parity set)."""
    return tuple(n for n, b in _REGISTRY[op].backends.items()
                 if b.differentiable)


# -------------------------------------------------------------- overrides
@functools.lru_cache(maxsize=8)
def _parse_env(value: str) -> Tuple[Tuple[Optional[str], str], ...]:
    """'ref' -> ((None,'ref'),); 'sdsa=pallas,ref' -> per-op + global."""
    out = []
    for part in value.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            op, be = part.split("=", 1)
            out.append((op.strip(), be.strip()))
        else:
            out.append((None, part))
    return tuple(out)


def _override_for(op: str) -> Optional[str]:
    for frame in reversed(_OVERRIDES):
        if op in frame:
            return frame[op]
        if None in frame:
            return frame[None]
    env = os.environ.get(ENV_VAR, "")
    if env:
        glob = None
        for o, be in _parse_env(env):
            if o == op:
                return be
            if o is None:
                glob = be
        return glob
    return None


@contextlib.contextmanager
def use_backend(name: str, op: Optional[str] = None):
    """Force backend `name` for one op (or all ops when op=None)."""
    _OVERRIDES.append({op: name})
    try:
        yield
    finally:
        _OVERRIDES.pop()


@contextlib.contextmanager
def use_hybrid(op: Optional[str] = None):
    """Density-adaptive hybrid resolution (``EXSPIKE_BACKEND=hybrid`` is
    the env-var spelling): while active, matmul-form calls (HYBRID_OPS)
    that carry an occupancy map pick between the predicated-dense and
    event-compacted kernel routes PER CALL, on the cost model's
    calibrated dense/event crossover evaluated at the map's occupied-tile
    count — bucketed into pow2 bands so jit compiles at most
    O(log tiles) routes per map shape. Concrete maps resolve in Python
    (attribution ``<route><-hybrid[b<bucket>]``); traced maps resolve to
    a `lax.cond` on the bucketed count (attribution
    ``hybrid[<event>|<dense>@b<threshold>]``). Calls hybrid cannot route
    (no carried map, op outside HYBRID_OPS, no registered route pair)
    fall through to normal auto selection, tagged ``<-hybrid``."""
    with use_backend(HYBRID, op=op):
        yield


# ------------------------------------------------------------ mesh context
_MESH: list = []   # stack of ambient meshes for trace-time resolution


@contextlib.contextmanager
def use_mesh(mesh):
    """Ambient mesh for resolution: while active, `resolve`/`dispatch`
    treat every call as executing per data shard (capability checks run on
    per-shard shapes, non-mesh-aware backends are skipped). Push it around
    jit tracing of sharded step functions — resolution is trace-time, so
    the context must be live when the jit cache misses, not per step.
    `mesh` may be a jax Mesh/AbstractMesh or a plain int shard count."""
    _MESH.append(mesh)
    try:
        yield
    finally:
        _MESH.pop()


def ambient_mesh():
    return _MESH[-1] if _MESH else None


def data_shard_count(mesh) -> int:
    """Number of data shards the row axis splits over: the product of the
    batch-parallel ('pod', 'data') mesh axes — the 'model' axis shards
    features/heads, not event rows. Ints pass through; no mesh -> 1."""
    if mesh is None:
        return 1
    if isinstance(mesh, int):
        return max(1, mesh)
    shape = getattr(mesh, "shape", None)
    if hasattr(shape, "get"):        # Mesh / AbstractMesh shape mapping
        n = 1
        for ax in ("pod", "data"):
            n *= int(shape.get(ax, 1))
        return max(1, n)
    return max(1, int(getattr(mesh, "size", 1)))


def _shard_view(args, n_shards: int):
    """Per-shard stand-ins for capability checks: the first positional
    (the event/activation operand — every registered op takes it first)
    has its leading axis divided by the shard count; weights and the rest
    are replicated. Uses ShapeDtypeStructs, which is all `supports` /
    `mesh_aware` gates may inspect (shapes/dtypes/static kwargs only).
    A non-dividing leading axis models GSPMD's padded shards (ceil)."""
    if not args:
        return args
    x = args[0]
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if not shape or dtype is None:
        return args
    lead = -(-int(shape[0]) // n_shards)
    local = jax.ShapeDtypeStruct((lead,) + tuple(shape[1:]), dtype)
    return (local,) + tuple(args[1:])


# -------------------------------------------------------------- resolution
# Degrade/fallback warnings fire once per (op, from-backend, to-backend,
# route) per process: resolution runs at trace time, and a retrace storm
# repeating the same RuntimeWarning hundreds of times buries the one
# occurrence that matters. The `route` component keeps hybrid routing's
# edges distinct — a dense-route degrade and an event-route degrade of
# the same op are different events, and muting the second because the
# first fired would hide that BOTH halves of the hybrid pair moved.
# `reset_fallback_warnings()` re-arms every key, route-qualified or not.
_WARNED: set = set()


def reset_fallback_warnings() -> None:
    _WARNED.clear()


def _warn_once(op: str, src: str, dst: str, msg: str,
               stacklevel: int = 3, route: Optional[str] = None) -> None:
    key = (op, src, dst, route)
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, RuntimeWarning, stacklevel=stacklevel + 1)


# Observers appended by `watch_resolutions`: every resolve records
# {"op", "backend", "attribution"} — how benchmarks and the CI smoke
# assert which route hybrid actually chose, call by call.
_RESOLUTION_WATCHERS: list = []


@contextlib.contextmanager
def watch_resolutions():
    """Context manager yielding a list that receives one
    ``{"op", "backend", "attribution"}`` record per resolution (trace-time
    under jit, so one record per compiled route, per call when eager)."""
    rec: list = []
    _RESOLUTION_WATCHERS.append(rec)
    try:
        yield rec
    finally:
        _RESOLUTION_WATCHERS.remove(rec)


# ------------------------------------------------------------ guard policy
# The event stack rides on trusted metadata: carried occupancy maps gate
# which tiles the CSR kernels visit, and packed uint32 words ARE the
# payload. An under-counting or stale map silently drops spike
# contributions — wrong numerics with no exception. EXSPIKE_GUARD (or the
# `use_guard` context) threads a trust policy through every matmul-form
# dispatch that carries a map:
#
#   off    — (default) trust the metadata, zero added work, attribution
#            strings unchanged;
#   audit  — verify the carried map is a TRUE UPPER BOUND of the payload
#            support before running the backend. Packed payloads: a
#            per-word popcount against the map (~1/32 of the dense
#            bytes). Dense payloads: an exact per-tile any-nonzero check.
#            A concrete violation raises GuardViolationError; a traced
#            one (under jit) NaN-poisons the float outputs — a loud
#            sentinel downstream NaN guards catch (data-dependent raises
#            can't cross the jit boundary, and host callbacks are too
#            expensive for the hot path; traces built under an active
#            `watch_guard_events` additionally record the violation);
#   repair — a violated invariant stops trusting the metadata: the call
#            recomputes on the trusted-payload route (words unpacked, map
#            dropped, ref oracle) with warn-once `<be>+repaired`
#            attribution — never a silent wrong answer.
#
# Upper bound, not equality: propagated maps (conv windows, pooling)
# legitimately over-count, so only "support where the map claims empty"
# is a violation — over-counts are a performance fault, not a
# correctness fault, and never flag. See "Guarded execution" in
# kernels/README.md for the per-op audit-cost contract.
GUARD_ENV_VAR = "EXSPIKE_GUARD"
GUARD_MODES = ("off", "audit", "repair")
# Ops the guard wraps (the matmul-form consumers of a carried map). The
# payload-support audit runs where the first operand IS the matrix the
# map tiles; econv's map covers the im2col patch matrix (different
# rows/K from the raw input), so its audit is the static grid check —
# materializing patches just to audit would cost kh*kw payload reads.
GUARDED_OPS = HYBRID_OPS
_SUPPORT_AUDITED_OPS = ("spike_matmul", "apec_matmul")
_GUARD: list = []            # stack pushed by use_guard()


class GuardViolationError(ValueError):
    """A carried occupancy map failed the upper-bound invariant (payload
    support in a tile the map claims empty) or arrived on the wrong tile
    grid for its payload (stale / wrong tiling)."""


def guard_mode() -> str:
    """Active guard policy: innermost `use_guard` frame, else the
    EXSPIKE_GUARD env var, else "off". Consulted at RESOLUTION time
    (trace time under jit) — like EXSPIKE_BACKEND, flipping it does not
    re-trace already-compiled functions."""
    if _GUARD:
        return _GUARD[-1]
    env = os.environ.get(GUARD_ENV_VAR, "").strip().lower()
    if not env:
        return "off"
    if env not in GUARD_MODES:
        raise ValueError(
            f"{GUARD_ENV_VAR}={env!r}: expected one of {GUARD_MODES}")
    return env


@contextlib.contextmanager
def use_guard(mode: str):
    """Scoped guard policy (see the "guard policy" block above)."""
    if mode not in GUARD_MODES:
        raise ValueError(
            f"guard mode {mode!r}: expected one of {GUARD_MODES}")
    _GUARD.append(mode)
    try:
        yield
    finally:
        _GUARD.pop()


# Observers appended by `watch_guard_events`: one record per detected
# violation — {"op", "backend", "kind", "mode", "action", "attribution",
# "detail"}. Concrete violations append at call time; traced ones append
# at RUN time through `jax.debug.callback` (block on the result before
# asserting on the list).
_GUARD_WATCHERS: list = []


@contextlib.contextmanager
def watch_guard_events():
    rec: list = []
    _GUARD_WATCHERS.append(rec)
    try:
        yield rec
    finally:
        _GUARD_WATCHERS.remove(rec)


def _guard_record(event: dict) -> None:
    for rec in _GUARD_WATCHERS:
        rec.append(dict(event))


def _guard_grid(op: str, args: tuple, packed_k,
                kwargs: dict) -> Optional[Tuple[int, int]]:
    """Expected (MT, KT) 128x128 tile grid of the carried map for this
    payload — the same flattening `ops.padded_occupancy` and the fused
    emission use (rows = prod(leading dims), K = logical features). For
    econv the map tiles the im2col patch matrix, so the grid comes from
    the conv geometry. None: geometry unknown, skip the static check."""
    s = args[0]
    if op == "econv":
        if len(args) < 2 or getattr(s, "ndim", 0) < 4:
            return None
        kh, kw_, ci, _ = (int(d) for d in args[1].shape)
        h, w_ = int(s.shape[-3]), int(s.shape[-2])
        stride = int(kwargs.get("stride", 1))
        padding = kwargs.get("padding", "SAME")
        if padding == "SAME":
            ho, wo = -(-h // stride), -(-w_ // stride)
        elif padding == "VALID":
            ho, wo = (h - kh) // stride + 1, (w_ - kw_) // stride + 1
        else:
            return None
        rows = int(np.prod(s.shape[:-3])) * ho * wo
        k = ci * kh * kw_
    else:
        rows = int(np.prod(s.shape[:-1]))
        k = int(packed_k) if packed_k is not None else int(s.shape[-1])
    return (-(-rows // 128), -(-k // 128))


def _support_violation(s, occupancy, packed_k):
    """Scalar bool: the payload has support in a tile the carried map
    claims empty. Exact, not sampled — detection must be total for the
    guard's contract; the packed form reads ~1/32 of the dense bytes
    (popcount per word), the dense form one comparison pass."""
    mt, kt = (int(d) for d in occupancy.shape)
    empty = occupancy == 0
    if packed_k is not None:
        from repro.core.spikes import PACK, popcount
        words = s.reshape(-1, s.shape[-1])
        r, nw = (int(d) for d in words.shape)
        wpt = 128 // PACK               # uint32 words per 128-col k-tile
        words = jnp.pad(words, ((0, mt * 128 - r), (0, kt * wpt - nw)))
        counts = popcount(words).astype(jnp.int32) \
            .reshape(mt, 128, kt, wpt).sum(axis=(1, 3))
        support = counts > 0
    else:
        x = s.reshape(-1, s.shape[-1])
        r, k = (int(d) for d in x.shape)
        nz = jnp.pad(x != 0, ((0, mt * 128 - r), (0, kt * 128 - k)))
        support = jnp.any(nz.reshape(mt, 128, kt, 128), axis=(1, 3))
    return jnp.any(support & empty)


def _repair_route(op: str, args: tuple, kwargs: dict):
    """The guard's safe route: trust only the payload — unpack words,
    drop the map / work list, run the ref oracle (dense math, the
    gradient oracle — a repaired call keeps the op's grad contract)."""
    kw = {k: v for k, v in kwargs.items()
          if k not in ("occupancy", "packed_k", "csr")}
    s = args[0]
    pk = kwargs.get("packed_k")
    if pk is not None:
        from repro.core.spikes import unpack_spikes
        s = unpack_spikes(s, axis=-1, dtype=jnp.float32)[..., :pk]
    return _REGISTRY[op].backends[REF].fn(s, *args[1:], **kw)


def _guard_shim(be: Backend, op: str, mode: str) -> Backend:
    """Wrap a resolved backend in the active guard policy. The backend
    name/attribution are unchanged (the guard is policy, not routing);
    detections surface through GuardViolationError / `watch_guard_events`
    records / the warn-once `<be>+repaired` repair attribution."""
    inner = be.fn
    repaired = f"{be.name}+repaired"

    @functools.wraps(inner)
    def fn(*args, **kwargs):
        occ = kwargs.get("occupancy")
        pk = kwargs.get("packed_k")
        if occ is None or getattr(occ, "ndim", 0) != 2:
            return inner(*args, **kwargs)
        expected = _guard_grid(op, args, pk, kwargs)
        if expected is not None and tuple(occ.shape) != expected:
            # Shapes are static: this check is free and may raise even
            # under jit.
            detail = (f"carried map grid {tuple(occ.shape)} != expected "
                      f"{expected} for the payload (stale/wrong tiling)")
            if mode == "audit":
                _guard_record({"op": op, "backend": be.name, "kind": "grid",
                               "mode": mode, "action": "raise",
                               "attribution": be.name, "detail": detail})
                raise GuardViolationError(f"guard[{op}/{be.name}]: {detail}")
            _guard_record({"op": op, "backend": be.name, "kind": "grid",
                           "mode": mode, "action": "repair",
                           "attribution": repaired, "detail": detail})
            _warn_once(op, be.name, repaired,
                       f"exspike guard: {detail}; repairing op {op!r} on "
                       f"the trusted-payload route ({repaired!r})",
                       route="guard")
            return _repair_route(op, args, kwargs)
        if op not in _SUPPORT_AUDITED_OPS:
            return inner(*args, **kwargs)
        violated = _support_violation(args[0], occ, pk)
        detail = ("carried map claims empty tiles that hold payload "
                  "support (occupancy undercount / corrupted payload)")
        event = {"op": op, "backend": be.name, "kind": "undercount",
                 "mode": mode, "detail": detail}
        if not isinstance(violated, jax.core.Tracer):
            if not bool(violated):
                return inner(*args, **kwargs)
            if mode == "audit":
                _guard_record({**event, "action": "raise",
                               "attribution": be.name})
                raise GuardViolationError(f"guard[{op}/{be.name}]: {detail}")
            _guard_record({**event, "action": "repair",
                           "attribution": repaired})
            _warn_once(op, be.name, repaired,
                       f"exspike guard: {detail}; repairing op {op!r} on "
                       f"the trusted-payload route ({repaired!r})",
                       route="guard")
            return _repair_route(op, args, kwargs)
        # Traced map/payload: a data-dependent raise can't cross the jit
        # boundary, and a host callback can't ride in the hot path — the
        # mere PRESENCE of the callback effect in the jitted program
        # costs ~700us/call on CPU (measured: it serializes dispatch),
        # voiding the audit-cost contract even when the branch never
        # fires. So the traced path stays effect-free:
        #   audit  — NaN-poison the (float) outputs when violated. The
        #            wrong answer the undercount would cause becomes a
        #            loud sentinel the downstream NaN guards catch (the
        #            serve loop quarantines non-finite logits; loss
        #            checks trip) instead of a plausible wrong number.
        #   repair — lax.cond branches to the trusted-payload route
        #            on-device; the answer is correct either way.
        # The watcher record (attribution for tests/CI) is attached only
        # when `watch_guard_events` is active AT TRACE TIME — a cached
        # trace keeps whatever observability it was built with.
        action = "record" if mode == "audit" else "repair"
        attribution = be.name if mode == "audit" else repaired

        def _on_violation():
            _guard_record({**event, "action": action, "traced": True,
                           "attribution": attribution})
            _warn_once(op, be.name, attribution,
                       f"exspike guard: {detail} (op {op!r}, detected "
                       f"at run time under jit"
                       + ("; repaired on the trusted-payload route"
                          if mode == "repair" else "") + ")",
                       route="guard")
        if _GUARD_WATCHERS:          # trace-time binding, see above
            jax.lax.cond(violated,
                         lambda: jax.debug.callback(_on_violation),
                         lambda: None)
        if mode == "audit":
            out = inner(*args, **kwargs)
            poison = jnp.where(violated, jnp.nan, 1.0)  # *1.0 is exact,
            return jax.tree.map(                        # fuses into the
                lambda x: x * poison.astype(x.dtype)    # matmul epilogue
                if jnp.issubdtype(x.dtype, jnp.inexact) else x, out)
        return jax.lax.cond(
            violated,
            lambda: _repair_route(op, args, kwargs),
            lambda: inner(*args, **kwargs))
    return dataclasses.replace(be, fn=fn)


def _fallback(op: str, wanted: str, reason: str) -> Backend:
    _warn_once(
        op, wanted, REF,
        f"exspike dispatch: backend {wanted!r} for op {op!r} unavailable "
        f"({reason}); falling back to {REF!r}", stacklevel=3)
    return _REGISTRY[op].backends[REF]


def _walk_fallback_chain(op: str, spec: OpSpec, be: Backend,
                         reason: Optional[str],
                         reason_of) -> Tuple[Backend, Optional[str]]:
    """Degrade along the declared fallback chain while `reason_of`
    refuses, warning once per edge. Returns the last backend reached and
    its reason (None iff some link accepted the call)."""
    seen = {be.name}
    while reason is not None and be.fallback is not None \
            and be.fallback not in seen:
        nxt = spec.backends.get(be.fallback)
        if nxt is None:
            break
        _warn_once(
            op, be.name, nxt.name,
            f"exspike dispatch: backend {be.name!r} for op {op!r} "
            f"unavailable ({reason}); degrading to {nxt.name!r}",
            stacklevel=5)
        seen.add(nxt.name)
        be, reason = nxt, reason_of(nxt)
    return be, reason


# ---------------------------------------------------- hybrid resolution
def _hybrid_route_pair(spec: OpSpec) -> Optional[Tuple[Backend, Backend]]:
    """(event_route, dense_route) for this platform: the highest-priority
    event-compacted (csr-family) backend and its declared dense fallback —
    the same pair the override fallback chain walks, so hybrid's routes
    are exactly the two kernels the BENCH trajectory has been comparing.
    None when either half is missing (hybrid then disengages)."""
    platform = jax.default_backend()

    def _dense_fallback(b):
        # The pair's dense half is the event backend's DECLARED fallback.
        # Pipelined csr variants declare their *serial* csr kernel as
        # fallback (degrade stays inside the event family), so they are
        # structurally not pair candidates — the documented contract is
        # "carries csr in its name, declares a dense fallback".
        fb = spec.backends.get(b.fallback) if b.fallback else None
        return fb is not None and "csr" not in fb.name

    event = max(
        (b for b in spec.backends.values()
         if "csr" in b.name and platform in b.platforms
         and "dense" in b.payload    # hybrid routes dense payloads only
         and _dense_fallback(b)),
        key=lambda b: b.priority, default=None)
    if event is None:
        return None
    dense = spec.backends.get(event.fallback)
    if dense is None or platform not in dense.platforms:
        return None
    return event, dense


def _hybrid_cond_fn(op: str, event_be: Backend, dense_be: Backend,
                    threshold: int):
    """Traced-occupancy hybrid body: branch between the two routes with
    `lax.cond` on the pow2-bucketed occupied-tile count. The bucket
    threshold is re-derived from the occupancy actually received (static
    shape at trace time), so inside shard_map each shard branches on ITS
    OWN local map — per-shard routing can differ, by design. Both routes
    are custom_vjp-wrapped already, so the cond stays differentiable."""
    del threshold   # attribution-time value; the fn recomputes per shape

    def fn(*args, occupancy=None, **kw):
        from repro.core import costmodel
        mt, kt = occupancy.shape
        thresh = costmodel.hybrid_event_bucket_threshold(op, mt, kt)
        n_buckets = costmodel.num_buckets(mt * kt)
        if thresh < 0:
            return dense_be.fn(*args, occupancy=occupancy, **kw)
        if thresh >= n_buckets - 1:
            return event_be.fn(*args, occupancy=occupancy, **kw)
        count = jnp.sum((occupancy > 0).astype(jnp.int32))
        bucket = costmodel.pow2_bucket_traced(count, (mt * kt).bit_length())
        return jax.lax.cond(
            bucket <= thresh,
            lambda: event_be.fn(*args, occupancy=occupancy, **kw),
            lambda: dense_be.fn(*args, occupancy=occupancy, **kw))
    return fn


def _hybrid_resolution(spec: OpSpec, op: str, kwargs, reason_of,
                       n_shards: int) -> Optional[Tuple[Backend, str]]:
    """Resolve under the HYBRID override. Returns (backend, attribution)
    or None to disengage (no carried map / no route pair / op outside
    HYBRID_OPS) — the caller then falls through to auto selection."""
    occ = kwargs.get("occupancy")
    if op not in HYBRID_OPS or occ is None or getattr(occ, "ndim", 0) != 2:
        return None
    if kwargs.get("packed_k") is not None:
        # Packed payloads route by the `payload` capability, not by
        # density: the packed-csr family's bytes-moved advantage holds at
        # every occupancy, so hybrid disengages (auto selection, tagged).
        return None
    pair = _hybrid_route_pair(spec)
    if pair is None:
        return None
    event_be, dense_be = pair
    event_reason = reason_of(event_be)
    dense_reason = reason_of(dense_be)
    if event_reason is not None and dense_reason is not None:
        return None          # both routes refuse: normal chain takes over
    if event_reason is not None:
        _warn_once(op, event_be.name, dense_be.name,
                   f"exspike dispatch: hybrid event route {event_be.name!r} "
                   f"for op {op!r} unavailable ({event_reason}); pinning "
                   f"dense route {dense_be.name!r}",
                   stacklevel=5, route="event")
        return dense_be, f"{dense_be.name}<-{HYBRID}"
    if dense_reason is not None:
        _warn_once(op, dense_be.name, event_be.name,
                   f"exspike dispatch: hybrid dense route {dense_be.name!r} "
                   f"for op {op!r} unavailable ({dense_reason}); pinning "
                   f"event route {event_be.name!r}",
                   stacklevel=5, route="dense")
        return event_be, f"{event_be.name}<-{HYBRID}"
    from repro.core import costmodel
    mt, kt = occ.shape
    mt_local = mt // n_shards if n_shards > 1 and mt % n_shards == 0 else mt
    if not isinstance(occ, jax.core.Tracer):
        # Concrete map (eager pre-pass): pick in Python on the band's
        # representative count — same decision jit would bake in, zero
        # runtime cost, and the bucket lands in the attribution.
        count = int(np.count_nonzero(np.asarray(occ) > 0))
        bucket = costmodel.pow2_bucket(-(-count // n_shards)
                                       if n_shards > 1 else count)
        rep = costmodel.bucket_representative(bucket, mt_local * kt)
        event = costmodel.event_route_wins(op, rep, mt_local, kt)
        be = event_be if event else dense_be
        return be, f"{be.name}<-{HYBRID}[b{bucket}]"
    threshold = costmodel.hybrid_event_bucket_threshold(op, mt_local, kt)
    cond = Backend(
        name=f"{HYBRID}[{event_be.name}|{dense_be.name}@b{threshold}]",
        fn=_hybrid_cond_fn(op, event_be, dense_be, threshold),
        platforms=event_be.platforms, priority=0, auto=False,
        differentiable=event_be.differentiable and dense_be.differentiable,
        mesh_aware=event_be.mesh_aware)
    return cond, cond.name


def resolve_with_attribution(op: str, *args, mesh=None,
                             **kwargs) -> Tuple[Backend, str]:
    """Pick the backend `dispatch` would run, plus an attribution string:
    the backend name, suffixed ``<-requested`` when resolution degraded
    from a higher-preference backend (override fallback chain or a
    mesh/capability gate) — `resolved_backends()` surfaces this so sweeps
    and serve logs show what *actually* ran and why it moved. Under
    `use_hybrid` the attribution carries the chosen route and its
    occupancy bucket (see `use_hybrid` for the formats). `resolve` /
    `resolve_attribution` are the single-value projections."""
    be, attribution = _resolve_impl(op, *args, mesh=mesh, **kwargs)
    for rec in _RESOLUTION_WATCHERS:
        rec.append({"op": op, "backend": be.name,
                    "attribution": attribution})
    return be, attribution


def _unpack_shim(be: Backend, packed_k: int) -> Backend:
    """Wrap a dense-payload backend so a packed call can reach it
    EXPLICITLY: the uint32 words are unpacked to the logical dense spikes
    at entry (f32 — the consumers' compute dtype) and the ``packed_k``
    marker is consumed. The ``+unpack`` attribution suffix plus the
    warn-once at the wrap site keep the densify visible — a packed
    payload never silently reinterprets as dense math."""
    from repro.core.spikes import unpack_spikes

    @functools.wraps(be.fn)
    def fn(s, *rest, packed_k=None, **kw):
        dense = unpack_spikes(s, axis=-1, dtype=jnp.float32)
        return be.fn(dense[..., :packed_k], *rest, **kw)
    return dataclasses.replace(be, fn=fn, name=f"{be.name}+unpack")


def _resolve_impl(op: str, *args, mesh=None,
                  **kwargs) -> Tuple[Backend, str]:
    be, attribution = _resolve_payload_blind(op, *args, mesh=mesh, **kwargs)
    packed_k = kwargs.get("packed_k")
    if packed_k is not None and "packed" not in be.payload:
        _warn_once(
            op, "packed", be.name,
            f"exspike dispatch: packed payload for op {op!r} leaving the "
            f"packed-csr family; unpacking to dense for {be.name!r} "
            f"(explicit unpack shim)", stacklevel=5, route="payload")
        shim = _unpack_shim(be, packed_k)
        attribution = shim.name + attribution[len(be.name):]
        be = shim
    # Guard policy (audit/repair) wraps OUTERMOST so the audit sees the
    # payload exactly as carried (packed words before any unpack shim).
    # Off (the default) adds nothing — attributions stay byte-identical.
    mode = guard_mode()
    if mode != "off" and op in GUARDED_OPS \
            and kwargs.get("occupancy") is not None:
        be = _guard_shim(be, op, mode)
    return be, attribution


def _resolve_payload_blind(op: str, *args, mesh=None,
                           **kwargs) -> Tuple[Backend, str]:
    spec = _REGISTRY[op]
    mesh = mesh if mesh is not None else ambient_mesh()
    n_shards = data_shard_count(mesh)
    if n_shards > 1:
        check_args = _shard_view(args, n_shards)

        def reason_of(be: Backend) -> Optional[str]:
            return be.mesh_unsupported_reason(*check_args, **kwargs)
    else:
        def reason_of(be: Backend) -> Optional[str]:
            return be.unsupported_reason(*args, **kwargs)

    def attributed(be: Backend, requested: Optional[str]) -> Tuple[Backend, str]:
        if requested is None or requested == be.name:
            if hybrid_requested:
                # hybrid disengaged (no carried map / no route pair):
                # normal selection ran, but the tag keeps visible that
                # hybrid was asked for and stepped aside.
                return be, f"{be.name}<-{HYBRID}"
            return be, be.name
        return be, f"{be.name}<-{requested}"

    override = _override_for(op)
    # Hybrid only means anything for the matmul-form ops with a dense/
    # event pair; on every other op a blanket use_hybrid() is a plain
    # no-op (auto selection, untagged) — not a disengage.
    hybrid_requested = override == HYBRID and op in HYBRID_OPS
    if override == HYBRID and not hybrid_requested:
        override = None
    if hybrid_requested:
        routed = _hybrid_resolution(spec, op, kwargs, reason_of, n_shards)
        if routed is not None:
            return routed
        override = None      # disengage -> auto selection, tagged above
    if override is not None:
        be = spec.backends.get(override)
        if be is None:
            return attributed(_fallback(op, override, "not registered"),
                              override)
        reason = reason_of(be)
        # Walk the declared fallback chain (packed-csr -> pallas-csr ->
        # pallas -> ...) before surrendering to ref, so a constraint
        # failure degrades to the nearest comparable kernel, not all the
        # way to the oracle.
        be, reason = _walk_fallback_chain(op, spec, be, reason, reason_of)
        if reason is not None:
            return attributed(_fallback(op, be.name, reason), override)
        return attributed(be, override)
    platform = jax.default_backend()
    # Payload filtering is silent, like platform filtering: a dense call
    # never auto-selects a packed-only backend and vice versa (the shim
    # wrap in `_resolve_impl` covers a packed call that finds no packed
    # candidate at all — including the terminal ref fallback).
    want_payload = "packed" if kwargs.get("packed_k") is not None else "dense"
    candidates = sorted(
        (b for b in spec.backends.values()
         if b.auto and platform in b.platforms
         and (want_payload in b.payload or b.name == REF)),
        key=lambda b: -b.priority)
    cap_failure = None
    for be in candidates:
        if be.name == REF:
            break
        reason = reason_of(be)
        if reason is None:
            return attributed(be, cap_failure[0] if cap_failure else None)
        if cap_failure is None:
            cap_failure = (be.name, reason)
    if cap_failure is not None:
        if want_payload == "packed":
            # No other packed candidate: degrade along the refused
            # backend's DECLARED chain (packed-csr -> pallas-csr) so the
            # call stays on the nearest comparable kernel — the caller's
            # shim wrap makes the densify explicit.
            be, reason = _walk_fallback_chain(
                op, spec, spec.backends[cap_failure[0]], cap_failure[1],
                reason_of)
            if reason is None:
                return attributed(be, cap_failure[0])
        # A capability failure (shape/dtype/mode/mesh gate) silently
        # degrading to the oracle would hide lost compression/kernel
        # coverage — warn. (Platform filtering stays silent.)
        return attributed(_fallback(op, *cap_failure), cap_failure[0])
    return attributed(spec.backends[REF], None)


def resolve(op: str, *args, mesh=None, **kwargs) -> Backend:
    """Pick the backend that `dispatch` would run for these inputs.

    `mesh`: resolve as if executing per data shard of that mesh (or the
    ambient `use_mesh` one) — mesh-aware filtering + per-shard capability
    checks. None with no ambient mesh is the plain single-device path.
    """
    return resolve_with_attribution(op, *args, mesh=mesh, **kwargs)[0]


def resolve_name(op: str, *args, mesh=None, **kwargs) -> str:
    return resolve(op, *args, mesh=mesh, **kwargs).name


def resolve_attribution(op: str, *args, mesh=None, **kwargs) -> str:
    """Attribution string for this resolution: ``name`` normally,
    ``name<-requested`` when a fallback chain / mesh gate degraded it."""
    return resolve_with_attribution(op, *args, mesh=mesh, **kwargs)[1]


def dispatch(op: str, *args, mesh=None, **kwargs):
    """Run `op` on the resolved backend (`mesh` steers resolution only —
    it is never forwarded to the backend fn)."""
    return resolve(op, *args, mesh=mesh, **kwargs).fn(*args, **kwargs)


def call_backend(op: str, name: str, *args, **kwargs):
    """Run a specific backend, erroring (not falling back) if unsupported.

    The parity harness uses this so an unsupported pair is an explicit
    skip, never a silent ref-vs-ref comparison.
    """
    be = get_backend(op, name)
    if be.supports is not None:
        reason = be.supports(*args, **kwargs)
        if reason is not None:
            raise ValueError(f"{op}/{name} unsupported: {reason}")
    return be.fn(*args, **kwargs)


def resolved_backends(mesh=None) -> Dict[str, str]:
    """op -> backend that would run on this platform/override for each
    op's canonical example shapes (serve startup log). With `mesh` (or an
    ambient `use_mesh`), resolution is mesh-aware and values carry degrade
    attribution: ``name`` when the preferred backend held,
    ``name<-requested`` when a fallback chain or per-shard gate moved it.
    """
    out = {}
    # This is a read-only snapshot: suppress the degrade warnings AND
    # restore the warn-once ledger afterwards, so a startup log call
    # doesn't consume an (op, from, to) edge and mute the one warning a
    # later real-model degrade on that same edge would have fired.
    saved_warned = set(_WARNED)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            for op in op_names():
                ex_args, ex_kwargs = example_inputs(op, jax.random.PRNGKey(0))
                out[op] = resolve_attribution(op, *ex_args, mesh=mesh,
                                              **ex_kwargs)
    finally:
        _WARNED.clear()
        _WARNED.update(saved_warned)
    return out


def table() -> str:
    """Human-readable registry dump with the grad-capability column
    (debugging / REPL aid; printed by the CI `dispatch table` check)."""
    lines = []
    for op, spec in _REGISTRY.items():
        bes = ", ".join(
            f"{b.name}(p{b.priority}{'' if b.auto else ',manual'}"
            f"{',grad' if b.differentiable else ''}"
            f"{',mesh' if b.mesh_aware is not False else ''}"
            f"{',packed' if 'packed' in b.payload else ''})"
            for b in sorted(spec.backends.values(), key=lambda b: -b.priority))
        lines.append(f"{op:14s} -> {bes}")
        pair = _hybrid_route_pair(spec) if op in HYBRID_OPS else None
        if pair is not None:
            from repro.core import costmodel
            r, h = costmodel.calibrated_route_params(op)
            lines.append(
                f"{'':14s}    hybrid: event={pair[0].name} | "
                f"dense={pair[1].name} (calibrated r={r:.2f}, h={h:.2f})")
    return "\n".join(lines)


# ======================================================================
# Op definitions + backend implementations
# ======================================================================
def _csr_shard_gate(s, *rest, block_m: int = 128, **kwargs) -> Optional[str]:
    """Per-shard gate for the `pallas-csr` family (`Backend.mesh_aware`):
    the compacted grid is worth building per shard only when the shard's
    flattened row count fills whole `block_m`-row tiles — then every
    shard's tile grid is congruent (one compiled grid shape serves all
    shards) and no shard pays a ragged padding tile per step. Called on
    the per-shard local view; rows = prod(shape[:-1]) matches how the ops
    wrappers flatten leading axes into the row axis (for strided econv
    the output-row count shrinks, which only makes the gate conservative).
    """
    del kwargs
    rows = int(np.prod(s.shape[:-1]))
    if rows % block_m:
        return (f"per-shard rows {rows} do not fill {block_m}-row tiles "
                f"(ragged per-shard tile grid)")
    return None


# ------------------------------------------------------------- lif_scan
def _lif_example(key):
    x = jax.random.normal(key, (4, 3, 40)) * 2.0
    return (x,), {"decay": 0.5, "v_th": 1.0, "soft_reset": True}


register_op("lif_scan", _lif_example)


@register("lif_scan", REF, priority=0, differentiable=True, mesh_aware=True)
def _lif_ref(x, *, decay=0.5, v_th=1.0, soft_reset=True,
             surrogate_alpha=2.0):
    from repro.core.lif import LIFConfig, lif_scan
    cfg = LIFConfig(decay=decay, v_th=v_th, soft_reset=soft_reset,
                    surrogate_alpha=surrogate_alpha)
    return lif_scan(x.astype(jnp.float32), cfg).astype(x.dtype)


def _lif_pallas(x, *, decay=0.5, v_th=1.0, soft_reset=True,
                surrogate_alpha=2.0):
    # Fused kernel pair: forward-exact vs ref, and `jax.grad` runs the
    # reversed-scan Pallas kernel with the ATan surrogate (kernels/lif_scan
    # custom_vjp) — TPU training no longer pins lif_scan=ref.
    from repro.kernels import ops
    return ops.lif(x, decay=decay, v_th=v_th, soft_reset=soft_reset,
                   surrogate_alpha=surrogate_alpha)


# NOTE: lif's leading axis is TIME, which no mesh axis shards (batch is
# axis 1) — the scan is elementwise over trailing dims, so the per-shard
# view's divided leading axis is still a valid shape for it.
register("lif_scan", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, differentiable=True, mesh_aware=True)(_lif_pallas)
register("lif_scan", "pallas", platforms=("tpu",), priority=20,
         differentiable=True, mesh_aware=True)(_lif_pallas)


# --------------------------------------------------------- lif_scan_occ
# The full-event producer: fire AND emit the spike tensor's (128, 128)
# per-tile occupancy map (plus its 8-row chunk refinement, which window
# propagation dilates) in the same pass, so downstream event consumers
# never re-derive it from the dense activation. Returns (spikes, map,
# chunks); the maps are non-differentiated aux (int32 — zero-tangent by
# dtype on the jnp paths, cotangent-discarded by the Pallas custom_vjp),
# which is the gradient contract models rely on when they wrap the
# triple in an `EventTensor`.
def _lif_occ_example(key):
    x = jax.random.normal(key, (3, 8, 40)) * 2.0
    return (x,), {"decay": 0.5, "v_th": 1.0, "soft_reset": True}


register_op("lif_scan_occ", _lif_occ_example)


@register("lif_scan_occ", REF, priority=0, differentiable=True,
          mesh_aware=True)
def _lif_occ_ref(x, *, decay=0.5, v_th=1.0, soft_reset=True,
                 surrogate_alpha=2.0, packed=False):
    s = _lif_ref(x, decay=decay, v_th=v_th, soft_reset=soft_reset,
                 surrogate_alpha=surrogate_alpha)
    # One chunk-granular pre-pass; the tile map is its 16:1 aggregation
    # (identical to the fused kernel's emission, counts and all).
    chunks = jax.lax.stop_gradient(_ref_chunk_occupancy(s))
    occ = jnp.sum(chunks.reshape(-1, 16, chunks.shape[1]), axis=1)
    if packed:
        # Forward-only packed emission (oracle form: fire dense, then
        # pack — value-identical to the fused kernel's in-VMEM packing).
        from repro.core.spikes import pack_spikes_padded
        return jax.lax.stop_gradient(pack_spikes_padded(s)), occ, chunks
    return s, occ, chunks


def _ref_chunk_occupancy(s):
    from repro.core.spikes import tile_occupancy
    k = s.shape[-1]
    s2 = s.reshape(-1, k)
    s2 = jnp.pad(s2, ((0, (-s2.shape[0]) % 128), (0, (-k) % 128)))
    return tile_occupancy(s2, 8, 128)


def _lif_occ_supports(x, **kwargs) -> Optional[str]:
    del kwargs
    r = int(np.prod(x.shape[1:-1])) if x.ndim > 2 else 1
    if r % 8:
        return (f"fused occupancy emission needs the middle axes to fill "
                f"8-row chunks, got R={r}")
    return None


def _lif_occ_pallas(x, *, decay=0.5, v_th=1.0, soft_reset=True,
                    surrogate_alpha=2.0, packed=False):
    from repro.kernels import ops
    return ops.lif_occ(x, decay=decay, v_th=v_th, soft_reset=soft_reset,
                       surrogate_alpha=surrogate_alpha, packed=packed)


register("lif_scan_occ", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_lif_occ_supports, differentiable=True,
         fallback=REF, mesh_aware=True)(_lif_occ_pallas)
register("lif_scan_occ", "pallas", platforms=("tpu",), priority=20,
         supports=_lif_occ_supports, differentiable=True, fallback=REF,
         mesh_aware=True)(_lif_occ_pallas)


# --------------------------------------------------------- spike_matmul
def _spike_matmul_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 48, 96)) < 0.3).astype(jnp.float32)
    w = jax.random.normal(k2, (96, 56), jnp.float32)
    return (s, w), {}


register_op("spike_matmul", _spike_matmul_example)


@register("spike_matmul", REF, priority=0, differentiable=True,
          mesh_aware=True)
def _spike_matmul_ref(s, w, occupancy=None):
    del occupancy    # metadata for the event kernels; the oracle is dense
    return jnp.dot(s, w, preferred_element_type=jnp.float32).astype(w.dtype)


@register("spike_matmul", "jnp", priority=5, auto=False, vjp=_matmul_bwd,
          mesh_aware=True)
def _spike_matmul_jnp(s, w, block_m: int = 8, block_k: int = 32,
                      occupancy=None):
    """Tile-masked jnp emulation of the occupancy-skipping kernel: per-tile
    partial products are gated by the same occupancy map the Pallas kernel
    consumes (numerically identical to dense — empty tiles contribute 0).
    Its (8, 32) emulation tiling never matches the carried (128, 128)
    maps, so a supplied `occupancy` is ignored (manual backend)."""
    del occupancy
    lead = s.shape[:-2]
    m, k = s.shape[-2:]
    s2 = s.reshape((-1, k)).astype(jnp.float32)
    rows = s2.shape[0]
    pad_m, pad_k = (-rows) % block_m, (-k) % block_k
    s2 = jnp.pad(s2, ((0, pad_m), (0, pad_k)))
    w2 = jnp.pad(w.astype(jnp.float32), ((0, pad_k), (0, 0)))
    mt, kt = s2.shape[0] // block_m, s2.shape[1] // block_k
    st = s2.reshape(mt, block_m, kt, block_k)
    wt = w2.reshape(kt, block_k, w.shape[1])
    occ = (jnp.sum(st, axis=(1, 3)) > 0).astype(jnp.float32)  # (mt, kt)
    part = jnp.einsum("aibk,bkn->abin", st, wt)               # per-tile dots
    out = jnp.sum(part * occ[:, :, None, None], axis=1)
    out = out.reshape(mt * block_m, -1)[:rows]
    return out.reshape(lead + (m, w.shape[1])).astype(w.dtype)


def _spike_matmul_pallas(s, w, occupancy=None):
    from repro.kernels import ops
    return ops.spike_matmul(s, w, occupancy=occupancy)


register("spike_matmul", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, vjp=_matmul_bwd, mesh_aware=True)(_spike_matmul_pallas)
register("spike_matmul", "pallas", platforms=("tpu",),
         priority=20, vjp=_matmul_bwd, mesh_aware=True)(_spike_matmul_pallas)


def _spike_matmul_csr(s, w, occupancy=None):
    # Event-compacted grid (scalar-prefetch CSR dispatch): occupied tiles
    # only; see kernels/spike_matmul.py. Wrapper pads arbitrary shapes;
    # a carried `occupancy` replaces the dense pre-pass (the work list
    # compacts from the tiny map).
    from repro.kernels import ops
    return ops.spike_matmul_csr(s, w, occupancy=occupancy)


register("spike_matmul", "pallas-csr-interpret", platforms=("cpu",),
         priority=2, auto=False, fallback="pallas-interpret",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate)(_spike_matmul_csr)
register("spike_matmul", "pallas-csr", platforms=("tpu",), priority=25,
         fallback="pallas", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate)(_spike_matmul_csr)


def _spike_matmul_packed(s, w, occupancy=None, packed_k=None):
    # packed-csr: the spike operand stays uint32 words end to end; each
    # occupied tile unpacks VMEM-resident inside the CSR grid step (see
    # kernels/spike_matmul.spike_matmul_packed_csr_pallas). Dense input
    # (packed_k=None) is packed at entry — parity-harness coverage.
    from repro.kernels import ops
    return ops.spike_matmul_packed(s, w, packed_k=packed_k,
                                   occupancy=occupancy)


register("spike_matmul", "packed-csr-interpret", platforms=("cpu",),
         priority=3, auto=False, fallback="pallas-csr-interpret",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate,
         payload=("packed",))(_spike_matmul_packed)
register("spike_matmul", "packed-csr", platforms=("tpu",), priority=30,
         fallback="pallas-csr", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate,
         payload=("packed",))(_spike_matmul_packed)


def _spike_matmul_csr_pipe(s, w, occupancy=None):
    # Double-buffered weight-tile DMA variant of the CSR walk
    # (pipeline=True selects the 2-slot rotation kernel): same work list,
    # same math, occupied step t's dot overlaps step t+1's weight fetch.
    # The fallback chain points at the serial CSR kernel, so parity /
    # grad / mesh coverage and the degrade story are inherited unchanged.
    from repro.kernels import ops
    return ops.spike_matmul_csr(s, w, occupancy=occupancy, pipeline=True)


register("spike_matmul", "pallas-csr-pipe-interpret", platforms=("cpu",),
         priority=4, auto=False, fallback="pallas-csr-interpret",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate)(_spike_matmul_csr_pipe)
register("spike_matmul", "pallas-csr-pipe", platforms=("tpu",), priority=26,
         fallback="pallas-csr", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate)(_spike_matmul_csr_pipe)


def _spike_matmul_packed_pipe(s, w, occupancy=None, packed_k=None):
    # Pipelined packed-csr: word unpack and MXU dot overlap the next
    # step's weight fetch; the spike-side read stays 1/32 of f32.
    from repro.kernels import ops
    return ops.spike_matmul_packed(s, w, packed_k=packed_k,
                                   occupancy=occupancy, pipeline=True)


register("spike_matmul", "packed-csr-pipe-interpret", platforms=("cpu",),
         priority=6, auto=False, fallback="packed-csr-interpret",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate,
         payload=("packed",))(_spike_matmul_packed_pipe)
register("spike_matmul", "packed-csr-pipe", platforms=("tpu",), priority=31,
         fallback="packed-csr", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate,
         payload=("packed",))(_spike_matmul_packed_pipe)


# ---------------------------------------------------------- apec_matmul
def _apec_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 16, 48)) < 0.4).astype(jnp.float32)
    w = jax.random.normal(k2, (48, 24), jnp.float32)
    return (s, w), {"g": 2}


register_op("apec_matmul", _apec_example)


def _apec_divisibility(s, w, *, g=2, **kwargs) -> Optional[str]:
    del w, kwargs
    if s.shape[-2] % g:
        return f"positions {s.shape[-2]} not divisible by group {g}"
    return None


@register("apec_matmul", REF, priority=0, differentiable=True,
          mesh_aware=True)
def _apec_matmul_ref(s, w, *, g=2, occupancy=None):
    del g, occupancy    # the oracle is the plain dense accumulation s @ w
    return jnp.dot(s.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(w.dtype)


# The overlap/residual decomposition equals s @ w in value but not under
# autodiff (min() tie-breaking would split cotangents across group
# members), so the explicit transpose rule supplies the exact gradients.
@register("apec_matmul", "jnp", priority=10, supports=_apec_divisibility,
          vjp=_matmul_bwd, mesh_aware=True)
def _apec_matmul_jnp(s, w, *, g=2, occupancy=None):
    del occupancy       # its own packed form re-derives what it gates on
    from repro.core.apec import apec_matmul_jnp
    return apec_matmul_jnp(s, w, g)


def _apec_matmul_pallas(s, w, *, g=2, occupancy=None):
    from repro.kernels import ops
    return ops.apec_matmul(s, w, g=g, occupancy=occupancy)


register("apec_matmul", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_apec_divisibility,
         vjp=_matmul_bwd, mesh_aware=True)(_apec_matmul_pallas)
register("apec_matmul", "pallas", platforms=("tpu",), priority=20,
         supports=_apec_divisibility, vjp=_matmul_bwd,
         mesh_aware=True)(_apec_matmul_pallas)


def _apec_csr_supports(s, w, *, g=2, **kwargs) -> Optional[str]:
    # The fused kernel maps each output row tile onto a (block_m/g)-row
    # overlap tile, so the group size must divide the 128-row block.
    del kwargs
    reason = _apec_divisibility(s, w, g=g)
    if reason is not None:
        return reason
    if 128 % g:
        return f"group {g} does not divide the 128-row tile"
    return None


def _apec_matmul_csr(s, w, *, g=2, occupancy=None):
    # Fused event-compacted APEC: union-CSR grid, overlap partial sums
    # accumulated into the g member rows in-kernel (no repeat pass). A
    # carried map IS the union gate (s-tile occupied iff res or ov is).
    from repro.kernels import ops
    return ops.apec_matmul_csr(s, w, g=g, occupancy=occupancy)


register("apec_matmul", "pallas-csr-interpret", platforms=("cpu",),
         priority=2, auto=False, supports=_apec_csr_supports,
         fallback="pallas-interpret", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate)(_apec_matmul_csr)
register("apec_matmul", "pallas-csr", platforms=("tpu",), priority=25,
         supports=_apec_csr_supports, fallback="pallas",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate)(_apec_matmul_csr)


def _apec_matmul_packed(s, w, *, g=2, occupancy=None, packed_k=None):
    # packed-csr APEC: decomposition is already bitwise on uint32 words
    # (apec_decompose_packed), so the payload never round-trips through
    # f32 — union-CSR grid with in-VMEM unpack of both operands' tiles.
    from repro.kernels import ops
    return ops.apec_matmul_packed(s, w, g=g, packed_k=packed_k,
                                  occupancy=occupancy)


register("apec_matmul", "packed-csr-interpret", platforms=("cpu",),
         priority=3, auto=False, supports=_apec_csr_supports,
         fallback="pallas-csr-interpret", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate,
         payload=("packed",))(_apec_matmul_packed)
register("apec_matmul", "packed-csr", platforms=("tpu",), priority=30,
         supports=_apec_csr_supports, fallback="pallas-csr",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate,
         payload=("packed",))(_apec_matmul_packed)


def _apec_matmul_csr_pipe(s, w, *, g=2, occupancy=None):
    # Pipelined fused APEC: one prefetched weight tile serves both dots
    # of a union step (DMA gate = either operand live).
    from repro.kernels import ops
    return ops.apec_matmul_csr(s, w, g=g, occupancy=occupancy,
                               pipeline=True)


register("apec_matmul", "pallas-csr-pipe-interpret", platforms=("cpu",),
         priority=4, auto=False, supports=_apec_csr_supports,
         fallback="pallas-csr-interpret", vjp=_matmul_bwd,
         mesh_aware=_csr_shard_gate)(_apec_matmul_csr_pipe)
register("apec_matmul", "pallas-csr-pipe", platforms=("tpu",), priority=26,
         supports=_apec_csr_supports, fallback="pallas-csr",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate)(_apec_matmul_csr_pipe)


def _apec_matmul_packed_pipe(s, w, *, g=2, occupancy=None, packed_k=None):
    from repro.kernels import ops
    return ops.apec_matmul_packed(s, w, g=g, packed_k=packed_k,
                                  occupancy=occupancy, pipeline=True)


# TPU-only: the packed-apec pipe kernel is the packed-spike pipe kernel
# plus the (CPU-covered) fused-APEC pipe epilogue; a cpu-interpret twin
# would re-test that composition at real wall-clock cost in the tier-1
# gate for no new coverage.
register("apec_matmul", "packed-csr-pipe", platforms=("tpu",), priority=31,
         supports=_apec_csr_supports, fallback="packed-csr",
         vjp=_matmul_bwd, mesh_aware=_csr_shard_gate,
         payload=("packed",))(_apec_matmul_packed_pipe)


# ------------------------------------------------------------------ sdsa
def _sdsa_example(key):
    ks = jax.random.split(key, 3)
    q, k, v = ((jax.random.uniform(kk, (2, 3, 24, 40)) < 0.4)
               .astype(jnp.float32) for kk in ks)
    return (q, k, v), {"mode": "or"}


register_op("sdsa", _sdsa_example)


def _sdsa_or_only(q, k, v, *, mode="or") -> Optional[str]:
    del q, k, v
    if mode != "or":
        return f"packed bitwise path supports mode='or' only, got {mode!r}"
    return None


@register("sdsa", REF, priority=0, differentiable=True, mesh_aware=True)
def _sdsa_ref(q, k, v, *, mode="or"):
    from repro.core.sdsa import sdsa_jnp
    return sdsa_jnp(q, k, v, mode=mode)


# Bitwise paths have no gradient at all (uint32 words); vjp="ref" replays
# the oracle's VJP, preserving its max-tie cotangent splitting.
@register("sdsa", "jnp", priority=5, auto=False, supports=_sdsa_or_only,
          vjp="ref", mesh_aware=True)
def _sdsa_packed_jnp(q, k, v, *, mode="or"):
    """Bit-packed pure-jnp path (the kernels' uint32 semantics without
    Pallas): pack -> AND / column-OR / AND -> unpack."""
    del mode
    from repro.core.spikes import PACK, pack_spikes, unpack_spikes
    from repro.kernels.ref import sdsa_packed_ref
    lead, (n, d) = q.shape[:-2], q.shape[-2:]
    pad = (-d) % PACK

    def prep(x):
        x = x.reshape((-1, n, d))
        return pack_spikes(jnp.pad(x, ((0, 0), (0, 0), (0, pad))), axis=-1)

    out_p = sdsa_packed_ref(prep(q), prep(k), prep(v))
    out = unpack_spikes(out_p, axis=-1, dtype=q.dtype)[..., :d]
    return out.reshape(lead + (n, d))


def _sdsa_pallas(q, k, v, *, mode="or"):
    del mode
    from repro.kernels import ops
    return ops.sdsa_or(q, k, v)


# Attention is token-local over the batch/head axes the mesh shards (the
# token axis N stays shard-resident), so the packed paths are mesh-aware.
register("sdsa", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_sdsa_or_only, vjp="ref",
         mesh_aware=True)(_sdsa_pallas)
register("sdsa", "pallas", platforms=("tpu",), priority=20,
         supports=_sdsa_or_only, vjp="ref", mesh_aware=True)(_sdsa_pallas)


# ----------------------------------------------------------- causal_sdsa
def _causal_sdsa_example(key):
    ks = jax.random.split(key, 3)
    q, k, v = ((jax.random.uniform(kk, (2, 2, 2, 12, 40)) < 0.4)
               .astype(jnp.float32) for kk in ks)
    return (q, k, v), {"mode": "or"}


register_op("causal_sdsa", _causal_sdsa_example)


def _causal_or_only(q, k, v, *, mode="or") -> Optional[str]:
    del q, k, v
    if mode != "or":
        return f"packed causal path supports mode='or' only, got {mode!r}"
    return None


@register("causal_sdsa", REF, priority=0, differentiable=True,
          mesh_aware=True)
def _causal_sdsa_ref(q, k, v, *, mode="or"):
    from repro.core.sdsa import causal_sdsa_jnp
    return causal_sdsa_jnp(q, k, v, mode=mode)


@register("causal_sdsa", "jnp", priority=5, auto=False,
          supports=_causal_or_only, vjp="ref", mesh_aware=True)
def _causal_sdsa_packed(q, k, v, *, mode="or"):
    from repro.core.sdsa import causal_sdsa_packed_jnp
    return causal_sdsa_packed_jnp(q, k, v, mode=mode)


def _causal_sdsa_pallas(q, k, v, *, mode="or"):
    del mode
    from repro.kernels import ops
    return ops.causal_sdsa_or(q, k, v)


register("causal_sdsa", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_causal_or_only, vjp="ref",
         mesh_aware=True)(_causal_sdsa_pallas)
register("causal_sdsa", "pallas", platforms=("tpu",), priority=20,
         supports=_causal_or_only, vjp="ref",
         mesh_aware=True)(_causal_sdsa_pallas)


# ----------------------------------------------------------------- econv
def _econv_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 8, 8, 6)) < 0.25).astype(jnp.float32)
    w = jax.random.normal(k2, (3, 3, 6, 10), jnp.float32)
    return (s, w), {"stride": 1, "padding": "SAME"}


register_op("econv", _econv_example)


def _econv_scatter_supports(s, w, *, stride=1, padding="SAME", **kwargs):
    del s, kwargs
    kh, kw = w.shape[:2]
    if kh % 2 == 0 or kw % 2 == 0:
        return f"event scatter needs odd kernels, got {(kh, kw)}"
    if stride != 1 or padding != "SAME":
        return f"event scatter is stride-1/SAME only, got {stride}/{padding}"
    return None


@register("econv", REF, priority=0, differentiable=True, mesh_aware=True)
def _econv_ref(s, w, *, stride=1, padding="SAME", occupancy=None):
    del occupancy    # dense lax conv: no event metadata consumed
    from repro.core.econv import tconv
    return tconv(s, w, stride=stride, padding=padding)


# Event extraction (nonzero) + fori scatter has no reverse-mode path;
# vjp="ref" replays the dense conv's VJP instead. Deliberately NOT
# mesh-aware: the serialized event scan's step count is sized from the
# global event budget, and per-shard it degenerates (each shard walks the
# full budget over a fraction of the events) — the mesh path degrades it
# to the tiled kernels instead.
@register("econv", "jnp", priority=5, auto=False,
          supports=_econv_scatter_supports, vjp="ref")
def _econv_scatter(s, w, *, stride=1, padding="SAME", occupancy=None):
    del stride, padding, occupancy
    from repro.core.econv import econv_scatter
    return econv_scatter(s, w)


def _econv_im2col(s, w, stride, padding, matmul, occupancy=None):
    """im2col + an occupancy-skipping spike matmul: binary patches of a
    binary map stay binary, so the event matmul kernel is the conv's MXU
    form. `matmul` picks the realization (predicated ops.spike_matmul or
    event-compacted ops.spike_matmul_csr). `occupancy` is a map for the
    PATCH matrix — the input map propagated through the im2col window
    (`core.events.conv_patch_occupancy`), never a re-scan of the
    (kh*kw-times larger) patch tensor."""
    kh, kw, ci, co = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        s, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    n, ho, wo, _ = patches.shape
    # patch features are ordered (Ci, kh, kw): transpose weights to match
    # (the carried map is order-agnostic: its k-tiles bound whole rows)
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(ci * kh * kw, co)
    out = matmul(patches.reshape(n * ho * wo, -1), w2.astype(jnp.float32),
                 occupancy=occupancy)
    return out.reshape(n, ho, wo, co)


def _econv_pallas(s, w, *, stride=1, padding="SAME", occupancy=None):
    from repro.kernels import ops
    return _econv_im2col(s, w, stride, padding, ops.spike_matmul,
                         occupancy)


register("econv", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, vjp="ref", mesh_aware=True)(_econv_pallas)
register("econv", "pallas", platforms=("tpu",), priority=20,
         vjp="ref", mesh_aware=True)(_econv_pallas)


def _econv_csr(s, w, *, stride=1, padding="SAME", occupancy=None):
    """Same im2col form, but patch-row tiles with no events cost no grid
    steps/DMA on the event-compacted kernel."""
    from repro.kernels import ops
    return _econv_im2col(s, w, stride, padding, ops.spike_matmul_csr,
                         occupancy)


register("econv", "pallas-csr-interpret", platforms=("cpu",), priority=2,
         auto=False, fallback="pallas-interpret", vjp="ref",
         mesh_aware=_csr_shard_gate)(_econv_csr)
register("econv", "pallas-csr", platforms=("tpu",), priority=25,
         fallback="pallas", vjp="ref", mesh_aware=_csr_shard_gate)(_econv_csr)


def _econv_packed_supports(s, w, *, stride=1, padding="SAME", **kwargs):
    del s, w, kwargs
    if padding not in ("SAME", "VALID"):
        return (f"packed im2col computes its own halos and supports "
                f"SAME/VALID only, got {padding!r}")
    if stride < 1:
        return f"stride must be >= 1, got {stride}"
    return None


def _econv_packed_csr(s, w, *, stride=1, padding="SAME", occupancy=None,
                      packed_k=None):
    # packed-csr conv: im2col runs in the WORD domain (strided shifted
    # slices of the padded word array — bit patterns are per-channel, so
    # window extraction never repacks), then the packed CSR matmul. See
    # ops.econv_packed for the weight relayout matching the word-aligned
    # patch feature order.
    from repro.kernels import ops
    return ops.econv_packed(s, w, stride=stride, padding=padding,
                            packed_k=packed_k, occupancy=occupancy)


register("econv", "packed-csr-interpret", platforms=("cpu",), priority=3,
         auto=False, supports=_econv_packed_supports,
         fallback="pallas-csr-interpret", vjp="ref",
         mesh_aware=_csr_shard_gate, payload=("packed",))(_econv_packed_csr)
register("econv", "packed-csr", platforms=("tpu",), priority=30,
         supports=_econv_packed_supports, fallback="pallas-csr", vjp="ref",
         mesh_aware=_csr_shard_gate, payload=("packed",))(_econv_packed_csr)


def _econv_csr_pipe(s, w, *, stride=1, padding="SAME", occupancy=None):
    # im2col feeding the pipelined CSR matmul: patch-row weight tiles
    # stream one occupied step ahead of the dot.
    from repro.kernels import ops
    return _econv_im2col(s, w, stride, padding,
                         functools.partial(ops.spike_matmul_csr,
                                           pipeline=True), occupancy)


register("econv", "pallas-csr-pipe-interpret", platforms=("cpu",),
         priority=4, auto=False, fallback="pallas-csr-interpret",
         vjp="ref", mesh_aware=_csr_shard_gate)(_econv_csr_pipe)
register("econv", "pallas-csr-pipe", platforms=("tpu",), priority=26,
         fallback="pallas-csr", vjp="ref",
         mesh_aware=_csr_shard_gate)(_econv_csr_pipe)


def _econv_packed_csr_pipe(s, w, *, stride=1, padding="SAME",
                           occupancy=None, packed_k=None):
    from repro.kernels import ops
    return ops.econv_packed(s, w, stride=stride, padding=padding,
                            packed_k=packed_k, occupancy=occupancy,
                            pipeline=True)


# TPU-only for the same reason as apec's packed pipe twin (word-domain
# im2col is CPU-covered by packed-csr-interpret; the pipelined matmul
# underneath is CPU-covered by packed-csr-pipe-interpret).
register("econv", "packed-csr-pipe", platforms=("tpu",), priority=31,
         supports=_econv_packed_supports, fallback="packed-csr", vjp="ref",
         mesh_aware=_csr_shard_gate,
         payload=("packed",))(_econv_packed_csr_pipe)


# ----------------------------------------------------------------- tconv
# NOTE on naming: in this repo "TConv" (econv's ref backend) is the
# traditional *forward* conv baseline of paper Fig. 1; the `tconv` op here
# is the *transposed* conv — the segmentation decoder's upsampling layers
# (SegNet 16TC3/2TC3) — promoted from inline lax.conv_transpose calls in
# models/cnn.py into a registry op.
def _tconv_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 6, 6, 5)) < 0.3).astype(jnp.float32)
    w = jax.random.normal(k2, (3, 3, 5, 4), jnp.float32)
    return (s, w), {"stride": 2, "padding": "SAME"}


register_op("tconv", _tconv_example)


def _tconv_pad_supports(s, w, *, stride=2, padding="SAME") -> Optional[str]:
    del s, w
    if padding not in ("SAME", "VALID"):
        return f"upsample form supports SAME/VALID, got {padding!r}"
    if stride < 1:
        return f"stride must be >= 1, got {stride}"
    return None


@register("tconv", REF, priority=0, differentiable=True, mesh_aware=True)
def _tconv_ref(s, w, *, stride=2, padding="SAME"):
    from repro.core.econv import conv_transpose_ref
    return conv_transpose_ref(s, w, stride=stride, padding=padding)


# Zero-insertion + stride-1 conv: same linear map as the oracle, so its
# native autodiff cotangents coincide with ref's.
@register("tconv", "jnp", priority=5, auto=False,
          supports=_tconv_pad_supports, differentiable=True, mesh_aware=True)
def _tconv_upsampled(s, w, *, stride=2, padding="SAME"):
    from repro.core.econv import conv_transpose_upsampled
    return conv_transpose_upsampled(s, w, stride=stride, padding=padding)


def _tconv_pallas(s, w, *, stride=2, padding="SAME"):
    """Zero-insert (events keep binarity, addresses dilate), then im2col +
    the occupancy-skipping spike matmul — the MXU form of the decoder's
    upsampling conv, mirroring `_econv_pallas`."""
    from repro.core.econv import upsample_events
    from repro.kernels import ops
    kh, kw, ci, co = w.shape
    up = upsample_events(s, stride, kh, kw, padding)
    patches = jax.lax.conv_general_dilated_patches(
        up, (kh, kw), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    n, ho, wo, _ = patches.shape
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(ci * kh * kw, co)
    out = ops.spike_matmul(patches.reshape(n * ho * wo, -1),
                           w2.astype(jnp.float32))
    return out.reshape(n, ho, wo, co)


register("tconv", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_tconv_pad_supports, vjp="ref",
         mesh_aware=True)(_tconv_pallas)
register("tconv", "pallas", platforms=("tpu",), priority=20,
         supports=_tconv_pad_supports, vjp="ref",
         mesh_aware=True)(_tconv_pallas)


# --------------------------------------------------- dispatch entry points
# The typed entries accept an `EventTensor` in place of dense spikes and
# unpack it into (spikes, occupancy-kwarg) for the registered backends:
# event backends consume the carried map, oracles ignore it, and either
# way the values are identical — occupancy only gates what is provably
# zero. A map carried for the wrong tiling raises before resolution.
def _event_args(s, kw=None):
    from repro.core.events import EventTensor
    kw = dict(kw or {})
    if isinstance(s, EventTensor):
        occ = s.occupancy_for(128, 128)
        if occ is not None:
            kw["occupancy"] = occ
        if s.is_packed:
            # Packed payload: the words become the positional operand and
            # the static packed_k marker routes resolution to backends
            # declaring payload="packed" (non-declaring fallbacks get the
            # explicit unpack shim, never a silent densify).
            kw["packed_k"] = s.feature_size
            s = s.packed
        else:
            s = s.spikes
    return s, kw


def lif_scan(x, *, decay=0.5, v_th=1.0, soft_reset=True, surrogate_alpha=2.0):
    return dispatch("lif_scan", x, decay=decay, v_th=v_th,
                    soft_reset=soft_reset, surrogate_alpha=surrogate_alpha)


def lif_scan_occ(x, *, decay=0.5, v_th=1.0, soft_reset=True,
                 surrogate_alpha=2.0, packed=False):
    """Fire + emit the occupancy maps: returns (spikes, (128,128) tile
    map, 8-row chunk map) — wrap in an EventTensor via
    `models.layers.lif_fire_events`. With ``packed=True`` the first
    element is the uint32 word tensor instead (forward-only; the fused
    kernel packs in-VMEM and takes the counts from word popcounts, so no
    f32 spike tensor reaches HBM)."""
    return dispatch("lif_scan_occ", x, decay=decay, v_th=v_th,
                    soft_reset=soft_reset, surrogate_alpha=surrogate_alpha,
                    packed=packed)


def spike_matmul(s, w):
    s, kw = _event_args(s)
    return dispatch("spike_matmul", s, w, **kw)


def apec_matmul(s, w, *, g=2):
    s, kw = _event_args(s, {"g": g})
    return dispatch("apec_matmul", s, w, **kw)


def sdsa(q, k, v, *, mode="or"):
    from repro.core.events import as_spikes
    return dispatch("sdsa", as_spikes(q), as_spikes(k), as_spikes(v),
                    mode=mode)


def causal_sdsa(q, k, v, *, mode="or"):
    from repro.core.events import as_spikes
    return dispatch("causal_sdsa", as_spikes(q), as_spikes(k), as_spikes(v),
                    mode=mode)


def econv(s, w, *, stride=1, padding="SAME"):
    from repro.core.events import EventTensor, conv_patch_occupancy
    kw = {"stride": stride, "padding": padding}
    if isinstance(s, EventTensor):
        # The carried map is for the INPUT flattening — the im2col patch
        # matrix has different rows/K, so the map is propagated through
        # the window (tile-granular dilation), not passed through as-is.
        occ = conv_patch_occupancy(s, w.shape, stride, padding)
        if occ is not None:
            kw["occupancy"] = occ
        if s.is_packed:
            kw["packed_k"] = s.feature_size
            s = s.packed
        else:
            s = s.spikes
    return dispatch("econv", s, w, **kw)


def tconv(s, w, *, stride=2, padding="SAME"):
    # Transposed conv dilates event addresses (zero-insertion): a carried
    # map does not survive — dense view only (documented invalidation).
    from repro.core.events import as_spikes
    return dispatch("tconv", as_spikes(s), w, stride=stride, padding=padding)
