"""Backend dispatch registry for the ExSpike hot-path ops.

One event-driven dataflow (LIF -> spike encoding -> APEC -> occupancy-
skipping matmul / SDSA) serves every workload in this repo, but each op
has several numerically-equivalent realizations: a pure-jnp oracle, an
alternative vectorized jnp form, and the Pallas TPU kernels (compiled on
TPU, interpret mode on CPU). This module is the single switchboard:

  op          backends                         notes
  ----------  -------------------------------  ---------------------------
  lif_scan    ref | pallas-interpret | pallas  ref keeps surrogate grads
  spike_matmul ref | jnp | pallas-interpret | pallas
  apec_matmul ref | jnp | pallas-interpret | pallas   jnp is the default
  sdsa        ref | jnp | pallas-interpret | pallas   packed paths: mode=or
  econv       ref | jnp | pallas-interpret | pallas   jnp = event scatter

Selection order per call:
  1. explicit override — `use_backend(...)` context or the
     ``EXSPIKE_BACKEND`` env var (``ref`` for all ops, or a comma list of
     ``op=backend`` entries, e.g. ``EXSPIKE_BACKEND=sdsa=pallas,ref``);
  2. otherwise the highest-priority backend registered for the current
     platform whose capability check (`supports`) passes;
  3. the `ref` oracle as the universal fallback — if an override or a
     chosen kernel can't handle the inputs (shape divisibility, dtype,
     unsupported mode), the call falls back to `ref` with a warning
     instead of erroring.

Resolution happens at trace time (shapes/dtypes are static under jit), so
dispatch adds zero runtime cost to compiled code.

Registering a new kernel is one `register(...)` call; the parity harness
(`tests/test_dispatch_parity.py`) enumerates every registered
(op x backend) pair against `ref` automatically, and
``benchmarks/run.py --backend`` sweeps it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

ENV_VAR = "EXSPIKE_BACKEND"
REF = "ref"
ALL_PLATFORMS = ("cpu", "gpu", "tpu")


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered implementation of an op.

    `supports(*args, **kwargs) -> str | None` returns a reason string when
    the backend CANNOT handle the call (None means supported). `auto`
    backends participate in automatic selection; non-auto ones run only
    under an explicit override (and in the parity harness).
    """
    name: str
    fn: Callable[..., Any]
    platforms: Tuple[str, ...] = ALL_PLATFORMS
    priority: int = 0
    auto: bool = True
    supports: Optional[Callable[..., Optional[str]]] = None

    def unsupported_reason(self, *args, **kwargs) -> Optional[str]:
        platform = jax.default_backend()
        if platform not in self.platforms:
            return f"platform {platform} not in {self.platforms}"
        if self.supports is not None:
            return self.supports(*args, **kwargs)
        return None


@dataclasses.dataclass
class OpSpec:
    name: str
    make_example: Callable[[jax.Array], Tuple[tuple, dict]]
    backends: Dict[str, Backend] = dataclasses.field(default_factory=dict)


_REGISTRY: Dict[str, OpSpec] = {}
_OVERRIDES: list = []   # stack of {op_or_None: backend_name} dicts


# ----------------------------------------------------------- registration
def register_op(name: str, make_example) -> None:
    if name not in _REGISTRY:
        _REGISTRY[name] = OpSpec(name=name, make_example=make_example)


def register(op: str, name: str, *, platforms=ALL_PLATFORMS, priority=0,
             auto=True, supports=None):
    """Decorator: register `fn` as backend `name` for `op`."""
    def deco(fn):
        if op not in _REGISTRY:
            raise KeyError(f"unknown op {op!r}; register_op it first")
        _REGISTRY[op].backends[name] = Backend(
            name=name, fn=fn, platforms=tuple(platforms), priority=priority,
            auto=auto, supports=supports)
        return fn
    return deco


def op_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def backend_names(op: str) -> Tuple[str, ...]:
    return tuple(_REGISTRY[op].backends)


def get_backend(op: str, name: str) -> Backend:
    try:
        return _REGISTRY[op].backends[name]
    except KeyError:
        raise KeyError(
            f"op {op!r} has no backend {name!r}; "
            f"registered: {backend_names(op)}") from None


def example_inputs(op: str, key: jax.Array) -> Tuple[tuple, dict]:
    """Small CPU-friendly (args, kwargs) for the parity harness."""
    return _REGISTRY[op].make_example(key)


# -------------------------------------------------------------- overrides
@functools.lru_cache(maxsize=8)
def _parse_env(value: str) -> Tuple[Tuple[Optional[str], str], ...]:
    """'ref' -> ((None,'ref'),); 'sdsa=pallas,ref' -> per-op + global."""
    out = []
    for part in value.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            op, be = part.split("=", 1)
            out.append((op.strip(), be.strip()))
        else:
            out.append((None, part))
    return tuple(out)


def _override_for(op: str) -> Optional[str]:
    for frame in reversed(_OVERRIDES):
        if op in frame:
            return frame[op]
        if None in frame:
            return frame[None]
    env = os.environ.get(ENV_VAR, "")
    if env:
        glob = None
        for o, be in _parse_env(env):
            if o == op:
                return be
            if o is None:
                glob = be
        return glob
    return None


@contextlib.contextmanager
def use_backend(name: str, op: Optional[str] = None):
    """Force backend `name` for one op (or all ops when op=None)."""
    _OVERRIDES.append({op: name})
    try:
        yield
    finally:
        _OVERRIDES.pop()


# -------------------------------------------------------------- resolution
def _fallback(op: str, wanted: str, reason: str) -> Backend:
    warnings.warn(
        f"exspike dispatch: backend {wanted!r} for op {op!r} unavailable "
        f"({reason}); falling back to {REF!r}", RuntimeWarning, stacklevel=3)
    return _REGISTRY[op].backends[REF]


def resolve(op: str, *args, **kwargs) -> Backend:
    """Pick the backend that `dispatch` would run for these inputs."""
    spec = _REGISTRY[op]
    override = _override_for(op)
    if override is not None:
        be = spec.backends.get(override)
        if be is None:
            return _fallback(op, override, "not registered")
        reason = be.unsupported_reason(*args, **kwargs)
        if reason is not None:
            return _fallback(op, override, reason)
        return be
    platform = jax.default_backend()
    candidates = sorted(
        (b for b in spec.backends.values()
         if b.auto and platform in b.platforms),
        key=lambda b: -b.priority)
    cap_failure = None
    for be in candidates:
        if be.name == REF:
            break
        reason = be.supports(*args, **kwargs) if be.supports else None
        if reason is None:
            return be
        if cap_failure is None:
            cap_failure = (be.name, reason)
    if cap_failure is not None:
        # A capability failure (shape/dtype/mode) silently degrading to
        # the oracle would hide lost compression/kernel coverage — warn.
        # (Platform filtering above is expected and stays silent.)
        return _fallback(op, *cap_failure)
    return spec.backends[REF]


def resolve_name(op: str, *args, **kwargs) -> str:
    return resolve(op, *args, **kwargs).name


def dispatch(op: str, *args, **kwargs):
    """Run `op` on the resolved backend."""
    return resolve(op, *args, **kwargs).fn(*args, **kwargs)


def call_backend(op: str, name: str, *args, **kwargs):
    """Run a specific backend, erroring (not falling back) if unsupported.

    The parity harness uses this so an unsupported pair is an explicit
    skip, never a silent ref-vs-ref comparison.
    """
    be = get_backend(op, name)
    if be.supports is not None:
        reason = be.supports(*args, **kwargs)
        if reason is not None:
            raise ValueError(f"{op}/{name} unsupported: {reason}")
    return be.fn(*args, **kwargs)


def resolved_backends() -> Dict[str, str]:
    """op -> backend that would run on this platform/override for each
    op's canonical example shapes (serve startup log)."""
    out = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for op in op_names():
            ex_args, ex_kwargs = example_inputs(op, jax.random.PRNGKey(0))
            out[op] = resolve_name(op, *ex_args, **ex_kwargs)
    return out


def table() -> str:
    """Human-readable registry dump (debugging / REPL aid)."""
    lines = []
    for op, spec in _REGISTRY.items():
        bes = ", ".join(
            f"{b.name}(p{b.priority}{'' if b.auto else ',manual'})"
            for b in sorted(spec.backends.values(), key=lambda b: -b.priority))
        lines.append(f"{op:14s} -> {bes}")
    return "\n".join(lines)


# ======================================================================
# Op definitions + backend implementations
# ======================================================================
# ------------------------------------------------------------- lif_scan
def _lif_example(key):
    x = jax.random.normal(key, (4, 3, 40)) * 2.0
    return (x,), {"decay": 0.5, "v_th": 1.0, "soft_reset": True}


register_op("lif_scan", _lif_example)


@register("lif_scan", REF, priority=0)
def _lif_ref(x, *, decay=0.5, v_th=1.0, soft_reset=True,
             surrogate_alpha=2.0):
    from repro.core.lif import LIFConfig, lif_scan
    cfg = LIFConfig(decay=decay, v_th=v_th, soft_reset=soft_reset,
                    surrogate_alpha=surrogate_alpha)
    return lif_scan(x.astype(jnp.float32), cfg).astype(x.dtype)


def _lif_pallas(x, *, decay=0.5, v_th=1.0, soft_reset=True,
                surrogate_alpha=2.0):
    # Hard-Heaviside kernel: forward-exact vs ref; no surrogate gradient.
    del surrogate_alpha
    from repro.kernels import ops
    return ops.lif(x, decay=decay, v_th=v_th, soft_reset=soft_reset)


register("lif_scan", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False)(_lif_pallas)
register("lif_scan", "pallas", platforms=("tpu",), priority=20)(_lif_pallas)


# --------------------------------------------------------- spike_matmul
def _spike_matmul_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 48, 96)) < 0.3).astype(jnp.float32)
    w = jax.random.normal(k2, (96, 56), jnp.float32)
    return (s, w), {}


register_op("spike_matmul", _spike_matmul_example)


@register("spike_matmul", REF, priority=0)
def _spike_matmul_ref(s, w):
    return jnp.dot(s, w, preferred_element_type=jnp.float32).astype(w.dtype)


@register("spike_matmul", "jnp", priority=5, auto=False)
def _spike_matmul_jnp(s, w, block_m: int = 8, block_k: int = 32):
    """Tile-masked jnp emulation of the occupancy-skipping kernel: per-tile
    partial products are gated by the same occupancy map the Pallas kernel
    consumes (numerically identical to dense — empty tiles contribute 0)."""
    lead = s.shape[:-2]
    m, k = s.shape[-2:]
    s2 = s.reshape((-1, k)).astype(jnp.float32)
    rows = s2.shape[0]
    pad_m, pad_k = (-rows) % block_m, (-k) % block_k
    s2 = jnp.pad(s2, ((0, pad_m), (0, pad_k)))
    w2 = jnp.pad(w.astype(jnp.float32), ((0, pad_k), (0, 0)))
    mt, kt = s2.shape[0] // block_m, s2.shape[1] // block_k
    st = s2.reshape(mt, block_m, kt, block_k)
    wt = w2.reshape(kt, block_k, w.shape[1])
    occ = (jnp.sum(st, axis=(1, 3)) > 0).astype(jnp.float32)  # (mt, kt)
    part = jnp.einsum("aibk,bkn->abin", st, wt)               # per-tile dots
    out = jnp.sum(part * occ[:, :, None, None], axis=1)
    out = out.reshape(mt * block_m, -1)[:rows]
    return out.reshape(lead + (m, w.shape[1])).astype(w.dtype)


def _spike_matmul_pallas(s, w):
    from repro.kernels import ops
    return ops.spike_matmul(s, w)


register("spike_matmul", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False)(_spike_matmul_pallas)
register("spike_matmul", "pallas", platforms=("tpu",),
         priority=20)(_spike_matmul_pallas)


# ---------------------------------------------------------- apec_matmul
def _apec_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 16, 48)) < 0.4).astype(jnp.float32)
    w = jax.random.normal(k2, (48, 24), jnp.float32)
    return (s, w), {"g": 2}


register_op("apec_matmul", _apec_example)


def _apec_divisibility(s, w, *, g=2) -> Optional[str]:
    del w
    if s.shape[-2] % g:
        return f"positions {s.shape[-2]} not divisible by group {g}"
    return None


@register("apec_matmul", REF, priority=0)
def _apec_matmul_ref(s, w, *, g=2):
    del g    # the oracle is the plain dense accumulation s @ w
    return jnp.dot(s.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(w.dtype)


@register("apec_matmul", "jnp", priority=10, supports=_apec_divisibility)
def _apec_matmul_jnp(s, w, *, g=2):
    from repro.core.apec import apec_matmul_jnp
    return apec_matmul_jnp(s, w, g)


def _apec_matmul_pallas(s, w, *, g=2):
    from repro.kernels import ops
    return ops.apec_matmul(s, w, g=g)


register("apec_matmul", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_apec_divisibility)(_apec_matmul_pallas)
register("apec_matmul", "pallas", platforms=("tpu",), priority=20,
         supports=_apec_divisibility)(_apec_matmul_pallas)


# ------------------------------------------------------------------ sdsa
def _sdsa_example(key):
    ks = jax.random.split(key, 3)
    q, k, v = ((jax.random.uniform(kk, (2, 3, 24, 40)) < 0.4)
               .astype(jnp.float32) for kk in ks)
    return (q, k, v), {"mode": "or"}


register_op("sdsa", _sdsa_example)


def _sdsa_or_only(q, k, v, *, mode="or") -> Optional[str]:
    del q, k, v
    if mode != "or":
        return f"packed bitwise path supports mode='or' only, got {mode!r}"
    return None


@register("sdsa", REF, priority=0)
def _sdsa_ref(q, k, v, *, mode="or"):
    from repro.core.sdsa import sdsa_jnp
    return sdsa_jnp(q, k, v, mode=mode)


@register("sdsa", "jnp", priority=5, auto=False, supports=_sdsa_or_only)
def _sdsa_packed_jnp(q, k, v, *, mode="or"):
    """Bit-packed pure-jnp path (the kernels' uint32 semantics without
    Pallas): pack -> AND / column-OR / AND -> unpack."""
    del mode
    from repro.core.spikes import PACK, pack_spikes, unpack_spikes
    from repro.kernels.ref import sdsa_packed_ref
    lead, (n, d) = q.shape[:-2], q.shape[-2:]
    pad = (-d) % PACK

    def prep(x):
        x = x.reshape((-1, n, d))
        return pack_spikes(jnp.pad(x, ((0, 0), (0, 0), (0, pad))), axis=-1)

    out_p = sdsa_packed_ref(prep(q), prep(k), prep(v))
    out = unpack_spikes(out_p, axis=-1, dtype=q.dtype)[..., :d]
    return out.reshape(lead + (n, d))


def _sdsa_pallas(q, k, v, *, mode="or"):
    del mode
    from repro.kernels import ops
    return ops.sdsa_or(q, k, v)


register("sdsa", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False, supports=_sdsa_or_only)(_sdsa_pallas)
register("sdsa", "pallas", platforms=("tpu",), priority=20,
         supports=_sdsa_or_only)(_sdsa_pallas)


# ----------------------------------------------------------------- econv
def _econv_example(key):
    k1, k2 = jax.random.split(key)
    s = (jax.random.uniform(k1, (2, 8, 8, 6)) < 0.25).astype(jnp.float32)
    w = jax.random.normal(k2, (3, 3, 6, 10), jnp.float32)
    return (s, w), {"stride": 1, "padding": "SAME"}


register_op("econv", _econv_example)


def _econv_scatter_supports(s, w, *, stride=1, padding="SAME"):
    del s
    kh, kw = w.shape[:2]
    if kh % 2 == 0 or kw % 2 == 0:
        return f"event scatter needs odd kernels, got {(kh, kw)}"
    if stride != 1 or padding != "SAME":
        return f"event scatter is stride-1/SAME only, got {stride}/{padding}"
    return None


@register("econv", REF, priority=0)
def _econv_ref(s, w, *, stride=1, padding="SAME"):
    from repro.core.econv import tconv
    return tconv(s, w, stride=stride, padding=padding)


@register("econv", "jnp", priority=5, auto=False,
          supports=_econv_scatter_supports)
def _econv_scatter(s, w, *, stride=1, padding="SAME"):
    del stride, padding
    from repro.core.econv import econv_scatter
    return econv_scatter(s, w)


def _econv_pallas(s, w, *, stride=1, padding="SAME"):
    """im2col + occupancy-skipping spike matmul: binary patches of a binary
    map stay binary, so the event matmul kernel is the conv's MXU form."""
    from repro.kernels import ops
    kh, kw, ci, co = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        s, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    n, ho, wo, _ = patches.shape
    # patch features are ordered (Ci, kh, kw): transpose weights to match
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(ci * kh * kw, co)
    out = ops.spike_matmul(patches.reshape(n * ho * wo, -1),
                           w2.astype(jnp.float32))
    return out.reshape(n, ho, wo, co)


register("econv", "pallas-interpret", platforms=("cpu",), priority=1,
         auto=False)(_econv_pallas)
register("econv", "pallas", platforms=("tpu",), priority=20)(_econv_pallas)


# --------------------------------------------------- dispatch entry points
def lif_scan(x, *, decay=0.5, v_th=1.0, soft_reset=True, surrogate_alpha=2.0):
    return dispatch("lif_scan", x, decay=decay, v_th=v_th,
                    soft_reset=soft_reset, surrogate_alpha=surrogate_alpha)


def spike_matmul(s, w):
    return dispatch("spike_matmul", s, w)


def apec_matmul(s, w, *, g=2):
    return dispatch("apec_matmul", s, w, g=g)


def sdsa(q, k, v, *, mode="or"):
    return dispatch("sdsa", q, k, v, mode=mode)


def econv(s, w, *, stride=1, padding="SAME"):
    return dispatch("econv", s, w, stride=stride, padding=padding)
