"""Pallas TPU kernels for the ExSpike hot spots + the backend registry.

Kernels (each with a pure-jnp oracle in ref.py and a jit'd shape-agnostic
wrapper in ops.py; interpret=True on CPU, compiled on TPU):

  lif_scan      — fused temporal LIF (membrane resident in VMEM), with a
                  reversed-scan surrogate-gradient backward kernel
  sdsa_kernel   — bit-packed Attention Core stages (AND / column-OR / AND)
                  + the causal prefix-OR status kernel (LM form)
  spike_matmul  — occupancy-skipping event matmuls (AER-FIFO tile analog):
                  the predicated dense-grid kernel AND the event-compacted
                  scalar-prefetch CSR kernel (grid over occupied tiles
                  only), incl. the fused-APEC CSR variant
  apec_kernel   — packed overlap/residual extraction (Fig. 5)

Backend registry (`dispatch.py`) — every hot-path op routes through one
switchboard so kernels are drop-in registrations, parity-tested (forward
AND gradient) the moment they register (tests/test_dispatch_parity.py):

  op            backends (default first)           constraints
  ------------  ---------------------------------  --------------------------
  lif_scan      cpu: ref · tpu: pallas             pallas bwd = reversed-scan
                (+ pallas-interpret, manual)         ATan surrogate kernel
  lif_scan_occ  cpu: ref · tpu: pallas             fused occupancy emission:
                (+ pallas-interpret, manual)         (spikes, tile map, chunk
                                                     map); R % 8 == 0 -> ref
  spike_matmul  cpu: ref · tpu: pallas-csr         pallas-csr: TPU (interpret
                (+ pallas, jnp tile-masked,          variant on CPU, manual);
                   pallas-csr-interpret, manual)     degrades to pallas
  apec_matmul   jnp (overlap-reuse) · tpu:         P % g == 0, else -> ref;
                pallas-csr (fused combine)         csr also needs g | 128
                (+ ref = dense s @ w, pallas)        (row tile), else pallas
  sdsa          cpu: ref · tpu: pallas             packed paths: mode="or"
                (+ jnp bit-packed, manual)           only, else -> ref
  causal_sdsa   cpu: ref (cummax) · tpu: pallas    packed paths: mode="or"
                (+ jnp packed prefix-OR, manual)     only, else -> ref
  econv         cpu: ref (TConv) · tpu:            jnp scatter: odd kernel,
                pallas-csr (im2col + CSR grid)       stride 1, SAME
                (+ jnp event scatter, pallas)
  tconv         cpu: ref (conv_transpose)          transposed conv (decoder
                · tpu: pallas (dilate+im2col)        upsampling); SAME/VALID
                (+ jnp zero-insertion, manual)

The `pallas-csr` family is the event-compacted grid: a CSR-of-tiles
pre-pass (`core.spikes.TileCSR`) drains the occupancy map into a work
list and `pltpu.PrefetchScalarGridSpec` walks occupied tiles only — empty
tiles cost zero grid steps (concrete pre-pass) and zero tile DMA, where
the predicated `pallas` kernel only saves the MXU FLOPs
(`core.costmodel.tile_matmul_savings` keeps the two ledgers apart). Its
`fallback` declaration makes explicit overrides degrade to the predicated
kernel, never silently to `ref`. Measured on the clustered-event sweep
(`benchmarks/sparsity_sweep.py`, committed as BENCH_PR3.json): CSR
crosses over at 60-80% sparsity and wins ~1.3-1.8x at 90-97%.

Every registered backend is differentiable with ref-matching surrogate
gradients (see dispatch.register's ``differentiable``/``vjp`` contract and
src/repro/kernels/README.md), so the train loop resolves backends exactly
like inference — the old ``EXSPIKE_BACKEND=lif_scan=ref`` training pin is
gone.

Override with the ``EXSPIKE_BACKEND`` env var — a single backend name
applies to all ops (``EXSPIKE_BACKEND=ref``), and ``op=backend`` entries
pin single ops (``EXSPIKE_BACKEND=sdsa=pallas,ref``) — or programmatically
with ``dispatch.use_backend(name, op=...)``. Fallback rule: whenever the
selected backend is unregistered or its capability check fails (platform,
mode, shape divisibility), the call runs the `ref` oracle and emits a
RuntimeWarning instead of erroring. ``benchmarks/run.py --backend``
sweeps backends so speedups are measured, not asserted.
"""
from . import dispatch, ops, ref
from .lif_scan import (lif_scan_occ_pallas_sg, lif_scan_pallas,
                       lif_scan_pallas_sg)
from .sdsa_kernel import (sdsa_apply_pallas, sdsa_causal_status_pallas,
                          sdsa_packed, sdsa_status_pallas)
from .spike_matmul import (apec_matmul_csr_pallas, spike_matmul_csr_pallas,
                           spike_matmul_pallas)

__all__ = [
    "dispatch", "ops", "ref", "lif_scan_pallas", "lif_scan_pallas_sg",
    "lif_scan_occ_pallas_sg",
    "sdsa_apply_pallas", "sdsa_causal_status_pallas", "sdsa_packed",
    "sdsa_status_pallas", "spike_matmul_pallas", "spike_matmul_csr_pallas",
    "apec_matmul_csr_pallas",
]
