"""Pallas TPU kernels for the ExSpike hot spots.

  lif_scan      — fused temporal LIF (membrane resident in VMEM)
  sdsa_kernel   — bit-packed Attention Core stages (AND / column-OR / AND)
  spike_matmul  — occupancy-skipping event matmul (AER-FIFO tile analog)

Each has a pure-jnp oracle in ref.py and a jit'd shape-agnostic wrapper in
ops.py. Kernels validate in interpret=True on CPU; TPU is the target.
"""
from . import ops, ref
from .lif_scan import lif_scan_pallas
from .sdsa_kernel import sdsa_apply_pallas, sdsa_packed, sdsa_status_pallas
from .spike_matmul import spike_matmul_pallas

__all__ = [
    "ops", "ref", "lif_scan_pallas", "sdsa_apply_pallas", "sdsa_packed",
    "sdsa_status_pallas", "spike_matmul_pallas",
]
