"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lif import LIFConfig, lif_scan as _lif_scan_core
from repro.core.sdsa import kv_status_or


def lif_scan_ref(x: jax.Array, *, decay: float = 0.5, v_th: float = 1.0,
                 soft_reset: bool = True) -> jax.Array:
    """Oracle for kernels.lif_scan: the core lax.scan implementation."""
    cfg = LIFConfig(decay=decay, v_th=v_th, soft_reset=soft_reset)
    return _lif_scan_core(x.astype(jnp.float32), cfg).astype(x.dtype)


def sdsa_status_ref(k_packed: jax.Array, v_packed: jax.Array) -> jax.Array:
    """Oracle for sdsa_status_pallas: OR-reduce of AND, on packed words."""
    kv = k_packed & v_packed
    return jax.lax.reduce(kv, jnp.uint32(0), jax.lax.bitwise_or, (1,))


def sdsa_apply_ref(q_packed: jax.Array, status: jax.Array) -> jax.Array:
    """Oracle for sdsa_apply_pallas."""
    return q_packed & status[:, None, :]


def sdsa_packed_ref(q_packed, k_packed, v_packed):
    return sdsa_apply_ref(q_packed, sdsa_status_ref(k_packed, v_packed))


def sdsa_unpacked_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Cross-check against the dense core implementation (OR form)."""
    return q * kv_status_or(k, v)[..., None, :]


def spike_matmul_ref(s: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for spike_matmul_pallas: plain dense matmul."""
    return jnp.dot(s, w, preferred_element_type=jnp.float32).astype(w.dtype)


def apec_decompose_packed_ref(s_packed: jax.Array, g: int):
    """Oracle for apec_decompose_packed: jnp bitwise reduce."""
    p, dw = s_packed.shape
    grp = s_packed.reshape(p // g, g, dw)
    ov = grp[:, 0, :]
    for i in range(1, g):
        ov = ov & grp[:, i, :]
    res = (grp & ~ov[:, None, :]).reshape(p, dw)
    return ov, res
