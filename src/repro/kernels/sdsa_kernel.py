"""Spike-driven self-attention — Pallas TPU kernels on bit-packed spikes.

The Attention Core (Fig. 6) is pure logic: kv = K AND V, status = column-
OR(kv), out = Q AND status. On TPU this is a VPU workload; we run it on
uint32-packed spike words (32 channels per lane), which cuts HBM traffic
32x vs bf16 0/1 tensors and turns AND/OR into single vector ops — the
closest TPU analogue to the paper's bit-parallel logic lanes.

Two kernels (stage 1 is a reduction, stage 2 elementwise, matching the
paper's two hardware stages):

  status:  grid (BH, N/bn); each program ORs a (bn, dw) K AND V block into
           a (1, dw) status row. The N-axis is the innermost (sequential)
           grid dim, so revisiting the same output block accumulates.
  apply:   grid (BH, N/bn); out = Q AND broadcast(status).

dw = d/32 packed words; bn a multiple of 8 (sublane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _status_kernel(k_ref, v_ref, status_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        status_ref[...] = jnp.zeros_like(status_ref)

    kv = k_ref[0] & v_ref[0]                       # (bn, dw) AND
    folded = jax.lax.reduce(kv, jnp.uint32(0), jax.lax.bitwise_or, (0,))
    status_ref[...] |= folded[None, :]


def _apply_kernel(q_ref, status_ref, out_ref):
    out_ref[...] = q_ref[...] & status_ref[...]    # broadcast over bn rows


def sdsa_status_pallas(
    k_packed: jax.Array, v_packed: jax.Array, *, block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(BH, N, dw) uint32 -> (BH, dw) packed status vectors."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bh, n, dw = k_packed.shape
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError(f"N={n} must tile by block_n={block_n}")
    out = pl.pallas_call(
        _status_kernel,
        grid=(bh, n // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n, dw), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_n, dw), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, dw), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, dw), jnp.uint32),
        interpret=interpret,
    )(k_packed, v_packed)
    return out


def sdsa_apply_pallas(
    q_packed: jax.Array, status: jax.Array, *, block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(BH, N, dw), (BH, dw) -> (BH, N, dw): out = Q AND status."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bh, n, dw = q_packed.shape
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError(f"N={n} must tile by block_n={block_n}")
    return pl.pallas_call(
        _apply_kernel,
        grid=(bh, n // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n, dw), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, dw), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, dw), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dw), jnp.uint32),
        interpret=interpret,
    )(q_packed, status[:, None, :])


def sdsa_packed(
    q_packed: jax.Array, k_packed: jax.Array, v_packed: jax.Array,
    *, block_n: int = 256, interpret: bool | None = None,
) -> jax.Array:
    """Full packed SDSA (OR form): both stages."""
    status = sdsa_status_pallas(k_packed, v_packed, block_n=block_n,
                                interpret=interpret)
    return sdsa_apply_pallas(q_packed, status, block_n=block_n,
                             interpret=interpret)


# ----------------------------------------------------------- causal (LM) form
def _causal_status_kernel(kv_ref, out_ref, carry_ref, *, block_n: int):
    """Prefix-OR over the token axis: out[i] = OR_{j<=i} kv[j].

    Within a (bn, dw) block, a Hillis-Steele doubling scan (log2(bn) vector
    OR + static shifts — no dynamic sublane indexing); across blocks, a
    (1, dw) VMEM carry holds the running status, the streaming form of the
    paper's on-the-fly OR during V write-back (Sec. III-C).
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = kv_ref[0]                                  # (bn, dw)
    shift = 1
    while shift < block_n:
        pad = jnp.zeros((shift,) + x.shape[1:], x.dtype)
        x = x | jnp.concatenate([pad, x[:-shift]], axis=0)
        shift *= 2
    x = x | carry_ref[...]                         # fold previous blocks
    out_ref[0] = x
    carry_ref[...] = x[block_n - 1:block_n]


def sdsa_causal_status_pallas(
    kv_packed: jax.Array, *, block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(BH, N, dw) uint32 kv mask -> (BH, N, dw) causal (prefix-OR) status.

    The N-axis is the innermost (sequential) grid dim so the carry scratch
    accumulates across blocks of the same (b, h) row.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bh, n, dw = kv_packed.shape
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError(f"N={n} must tile by block_n={block_n}")
    return pl.pallas_call(
        functools.partial(_causal_status_kernel, block_n=block_n),
        grid=(bh, n // block_n),
        in_specs=[pl.BlockSpec((1, block_n, dw), lambda b, i: (b, i, 0))],
        out_specs=pl.BlockSpec((1, block_n, dw), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dw), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((1, dw), jnp.uint32)],
        interpret=interpret,
    )(kv_packed)
