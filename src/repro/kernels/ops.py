"""Jit'd public wrappers around the Pallas kernels.

These handle padding to block multiples, dtype plumbing, head/batch axis
flattening, and CPU-interpret fallback, so model code can call them on
arbitrary shapes. Each wrapper is shape-polymorphic under jit and safe to
use inside pjit/shard_map (pure, no host callbacks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.events import EventTensor
from repro.core.spikes import (PACK, TileCSR, build_csr, pack_spikes,
                               pack_spikes_padded, packed_tile_occupancy,
                               packed_width, tile_occupancy, unpack_spikes)
from .lif_scan import (lif_scan_occ_packed_pallas, lif_scan_occ_pallas_sg,
                       lif_scan_pallas_sg)
from .sdsa_kernel import (sdsa_causal_status_pallas, sdsa_packed,
                          sdsa_status_pallas)
from .spike_matmul import (apec_matmul_csr_pallas,
                           apec_matmul_packed_csr_pallas,
                           spike_matmul_csr_pallas,
                           spike_matmul_packed_csr_pallas,
                           spike_matmul_pallas)


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("decay", "v_th", "soft_reset",
                                              "surrogate_alpha"))
def lif(x: jax.Array, decay: float = 0.5, v_th: float = 1.0,
        soft_reset: bool = True, surrogate_alpha: float = 2.0) -> jax.Array:
    """Fused LIF over leading time axis, any trailing shape.

    Differentiable: routes through `lif_scan_pallas_sg`, whose backward is
    the reversed-scan Pallas kernel with the ATan surrogate. Padding /
    reshape around the kernel are native jax ops, so `jax.grad` composes.
    """
    t = x.shape[0]
    rest = x.shape[1:]
    flat = x.reshape(t, -1)
    # Fold into (T, M, N) with N a lane multiple.
    n = 128
    flat, orig = _pad_to(flat, 1, n * 8)
    m = flat.shape[1] // n
    out = lif_scan_pallas_sg(flat.reshape(t, m, n), decay, v_th, soft_reset,
                             surrogate_alpha)
    return out.reshape(t, -1)[:, :orig].reshape((t,) + rest)


@functools.partial(jax.jit, static_argnames=("decay", "v_th", "soft_reset",
                                              "surrogate_alpha", "packed"))
def lif_occ(x: jax.Array, decay: float = 0.5, v_th: float = 1.0,
            soft_reset: bool = True, surrogate_alpha: float = 2.0,
            packed: bool = False):
    """Fused LIF that also emits the (128, 128)-tiled occupancy map of its
    own spike output — the full-event producer.

    x: (T, ..., K) drive -> (spikes (T, ..., K),
    occupancy (ceil(T*R/128), ceil(K/128)) int32,
    chunks (ceil(T*R/128)*16, ceil(K/128)) int32) where R = prod of the
    middle axes. `occupancy` is exactly `padded_occupancy(spikes)` —
    valid for every matmul-form consumer that flattens lead axes into
    rows; `chunks` is the kernel's native per-(8-row, 128-lane) popcount
    map (what window propagation dilates at fine granularity). Both come
    from the scan kernel's in-VMEM popcounts plus a reduction over the
    tiny count map, never a dense re-read of the spikes. Requires
    R % 8 == 0 (the kernel's row-chunk size; the dispatch `supports`
    gate falls back to ref otherwise).

    ``packed=True`` switches to the FORWARD-ONLY fused pack emission:
    the first return value is the uint32 word tensor
    (T, ..., ceil(K/32)) instead of dense spikes — packed in-VMEM by the
    same kernel pass that fires, with the counts taken from the words'
    popcounts, so no f32 spike tensor ever reaches HBM. The K padding to
    the lane tile never fires (zero drive keeps v below threshold), so
    slicing the word axis to `packed_width(K)` leaves the exact words
    `pack_spikes_padded` would produce, tail bits zero.
    """
    t = x.shape[0]
    k = x.shape[-1]
    mid = x.shape[1:-1]
    r = 1
    for d in mid:
        r *= d
    if r % 8:
        raise ValueError(f"middle axes {mid} (R={r}) must divide by 8")
    xr = x.reshape(t, r, k)
    xr, k_orig = _pad_to(xr, 2, 128)   # zero drive never fires: counts 0
    if packed:
        p, cnt = lif_scan_occ_packed_pallas(xr, decay=decay, v_th=v_th,
                                            soft_reset=soft_reset)
        pw = packed_width(k_orig)
        payload = p[..., :pw].reshape(x.shape[:-1] + (pw,))
    else:
        s, cnt = lif_scan_occ_pallas_sg(xr, decay, v_th, soft_reset,
                                        surrogate_alpha)
        payload = s[..., :k_orig].reshape(x.shape)
    # (T, R/8, KT) per-chunk counts -> (ceil(T*R/128), KT) matmul tiles:
    # flattened row chunk (t, a) sits at index t*(R/8)+a, so groups of 16
    # consecutive chunks are exactly the 128-row tiles (zero-padded tail
    # chunks match the consumers' zero-padded rows).
    kt = cnt.shape[-1]
    cnt2 = cnt.reshape(t * (r // 8), kt)
    cnt2, _ = _pad_to(cnt2, 0, 16)
    occ = jnp.sum(cnt2.reshape(-1, 16, kt), axis=1)
    return (payload, jax.lax.stop_gradient(occ),
            jax.lax.stop_gradient(cnt2))


@jax.jit
def sdsa_or(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Paper-faithful OR-form SDSA on dense binary tensors of shape
    (..., N, d); internally bit-packed and run through the Pallas kernels.
    """
    lead = q.shape[:-2]
    n, d = q.shape[-2:]
    dt = q.dtype

    def prep(x):
        x = x.reshape(-1, n, d)
        x, _ = _pad_to(x, 2, PACK)
        return pack_spikes(x, axis=-1)

    qp, kp, vp = prep(q), prep(k), prep(v)
    # Pad N to a block_n multiple (the kernel grid divides N exactly);
    # zero K/V rows are OR no-ops, zero Q rows are sliced off below.
    block_n = min(256, n + (-n) % 8)
    qp, n_orig = _pad_to(qp, 1, block_n)
    kp, _ = _pad_to(kp, 1, block_n)
    vp, _ = _pad_to(vp, 1, block_n)
    out_p = sdsa_packed(qp, kp, vp, block_n=block_n)
    out = unpack_spikes(out_p, axis=-1, dtype=dt)[:, :n_orig, :d]
    return out.reshape(lead + (n, d))


@jax.jit
def causal_sdsa_or(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal (LM) OR-form SDSA on dense binary tensors.

    q, k, v: (T, ..., N, d) with T the micro-timestep axis and N the token
    axis. status[i] = OR over micro-steps and tokens j <= i of K AND V;
    out[t, i] = Q[t, i] AND status[i]. Internally bit-packed: the kv mask
    is OR-folded over T elementwise, the prefix-OR over tokens runs in the
    Pallas causal-status kernel, and the Q AND is a packed vector op.
    """
    t = q.shape[0]
    lead = q.shape[1:-2]
    n, d = q.shape[-2:]
    dt = q.dtype

    def prep(x):
        x = x.reshape(t, -1, n, d)
        x, _ = _pad_to(x, 3, PACK)
        return pack_spikes(x, axis=-1)

    qp, kp, vp = prep(q), prep(k), prep(v)
    # kv mask per micro-step, then OR over T (elementwise on packed words).
    kv = jax.lax.reduce(kp & vp, jnp.uint32(0), jax.lax.bitwise_or, (0,))
    # Token-axis padding must reach a block_n multiple (the kernel grid
    # divides N exactly); trailing zero rows are prefix-OR no-ops and the
    # padded outputs are sliced off.
    block_n = min(256, n + (-n) % 8)
    kv, n_orig = _pad_to(kv, 1, block_n)
    status = sdsa_causal_status_pallas(kv, block_n=block_n)
    out_p = qp & status[None, :, :n_orig, :]
    out = unpack_spikes(out_p, axis=-1, dtype=dt)[..., :d]
    return out.reshape((t,) + lead + (n, d))


@jax.jit
def sdsa_status(k: jax.Array, v: jax.Array) -> jax.Array:
    """Status vector only (decode prefill path). (..., N, d) -> (..., d)."""
    lead = k.shape[:-2]
    n, d = k.shape[-2:]

    block_n = min(256, n + (-n) % 8)

    def prep(x):
        x = x.reshape(-1, n, d)
        x, _ = _pad_to(x, 2, PACK)
        x, _ = _pad_to(x, 1, block_n)
        return pack_spikes(x, axis=-1)

    kp, vp = prep(k), prep(v)
    st = sdsa_status_pallas(kp, vp, block_n=block_n)
    return unpack_spikes(st, axis=-1, dtype=k.dtype)[:, :d].reshape(lead + (d,))


@functools.partial(jax.jit, static_argnames=("g",))
def apec_decompose(s: jax.Array, g: int = 2):
    """Dense binary (P, C) spikes -> (overlap (P/g, C), residual (P, C))
    via the packed bitwise kernel. P must divide by g."""
    from .apec_kernel import apec_decompose_packed
    p, c = s.shape
    sp, _ = _pad_to(s, 1, PACK)
    packed = pack_spikes(sp, axis=-1)
    packed, p_orig = _pad_to(packed, 0, g * 8)
    ov_p, res_p = apec_decompose_packed(packed, g,
                                        block_n=min(128, packed.shape[1]))
    ov = unpack_spikes(ov_p, axis=-1, dtype=s.dtype)[: p_orig // g, :c]
    res = unpack_spikes(res_p, axis=-1, dtype=s.dtype)[:p_orig, :c]
    return ov, res


def _pad_operands(s2, w, block_m, block_n, block_k):
    """Pad a flattened (R, K) spike matrix and (K, N) weights to block
    multiples — padding adds zeros, so it can never mark a tile occupied."""
    s2, m_orig = _pad_to(s2, 0, block_m)
    s2, _ = _pad_to(s2, 1, block_k)
    w2, _ = _pad_to(w, 0, block_k)
    w2, n_orig = _pad_to(w2, 1, block_n)
    return s2, w2, m_orig, n_orig


def padded_occupancy(s: jax.Array, block_m: int = 128,
                     block_k: int = 128) -> jax.Array:
    """The occupancy pre-pass exactly as `spike_matmul` computes it: lead
    axes flattened into rows, then padded-tiling per-tile event counts.
    Callers running several matmuls over the *same* spike tensor (e.g. one
    encoding against several weight matrices, or stat collection alongside
    the matmul) run this once and pass the result through
    `spike_matmul(..., occupancy=)` or `occupancy_to_csr` ->
    `spike_matmul_csr(..., csr=)`. The kernels validate the map's shape
    against their tiling — a map for another tiling would silently gate
    the wrong tiles.
    """
    k = s.shape[-1]
    s2 = s.reshape(-1, k)
    s2, _ = _pad_to(s2, 0, block_m)
    s2, _ = _pad_to(s2, 1, block_k)
    return tile_occupancy(s2, block_m, block_k)


def _carried_occupancy(s, occupancy, block_m: int, block_k: int,
                       want_csr: bool = False):
    """Unpack an EventTensor operand into (dense spikes, validated carried
    occupancy, cached TileCSR). Explicit `occupancy=` wins over the
    carried map; a map built for another tiling raises (loudly) inside
    `EventTensor.occupancy_for`."""
    if isinstance(s, EventTensor):
        csr = None
        if occupancy is None:
            occupancy = s.occupancy_for(block_m, block_k)
            if want_csr and occupancy is not None:
                csr = s.csr(block_m, block_k)
        return s.spikes, occupancy, csr
    return s, occupancy, None


def _group_occupancy(occ, g: int, rows: int, block_m: int = 128):
    """Conservative overlap-operand map derived from the carried map of
    the undecomposed spikes: the overlap tile at row-tile i unions group
    members living in s row-tiles [g*i, g*i+g) (AND-of-group is a subset
    of each member, so a zero s-tile group guarantees a zero overlap
    tile). Only derivable when the row tiling regroups exactly
    (rows % (block_m*g) == 0); otherwise None (caller re-derives)."""
    if occ is None or rows % (block_m * g):
        return None
    mt = occ.shape[0]
    return jnp.sum(occ.reshape(mt // g, g, occ.shape[1]), axis=1)


@functools.partial(jax.jit, static_argnames=("g",))
def _apec_matmul_jit(w, g, ov, res, occ_res, occ_ov):
    wf = w.astype(jnp.float32)
    psum_ov = spike_matmul(ov, wf, occupancy=occ_ov)   # (R/g, F) cached sums
    psum_res = spike_matmul(res, wf, occupancy=occ_res)  # (R, F) residuals
    return psum_res + jnp.repeat(psum_ov, g, axis=0)   # reuse across members


def apec_matmul(s, w: jax.Array, g: int = 2, *, decomposed=None,
                occ_res: jax.Array | None = None,
                occ_ov: jax.Array | None = None,
                occupancy: jax.Array | None = None) -> jax.Array:
    """APEC matmul on the packed kernels: bitwise overlap/residual
    decomposition, then two occupancy-skipping matmuls with the overlap
    partial sums reused across each group's members.

    s: (..., P, C) binary (or an `EventTensor`) with P % g == 0;
    w: (C, F) -> (..., P, F). Leading axes are flattened into the
    position axis — safe because each row contributes whole groups when P
    divides by g.

    Callers that already decomposed pass ``decomposed=(residual,
    overlap)`` (flattened (R, C) / (R/g, C)) plus their per-operand maps
    ``occ_res`` / ``occ_ov`` — aligning this path with the CSR kernel's
    single-pre-pass behavior instead of paying two fresh dense passes
    here. A carried ``occupancy`` (of the undecomposed s) gates both
    matmuls conservatively: residual tiles are a subset of s tiles, and
    the overlap map folds g s-row-tiles (`_group_occupancy`).
    """
    s, occupancy, _ = _carried_occupancy(s, occupancy, 128, 128)
    lead = s.shape[:-2]
    p, c = s.shape[-2:]
    if p % g:
        raise ValueError(f"positions {p} not divisible by group {g}")
    s2 = s.reshape(-1, c)
    if decomposed is None:
        ov, res = apec_decompose(s2, g)              # packed bitwise kernel
    else:
        res, ov = decomposed
    if occupancy is not None and occ_res is None:
        occ_res = occupancy                          # res tiles ⊆ s tiles
        if occ_ov is None:
            occ_ov = _group_occupancy(occupancy, g, s2.shape[0])
    out = _apec_matmul_jit(w, g, ov, res, occ_res, occ_ov)
    return out.reshape(lead + (p, w.shape[-1])).astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def spike_matmul(s, w: jax.Array, block_m: int = 128,
                 block_n: int = 128, block_k: int = 128,
                 occupancy: jax.Array | None = None) -> jax.Array:
    """Occupancy-skipping spike matmul for (..., M, K) x (K, N).

    `s` may be an `EventTensor` — its carried map replaces the pre-pass.
    `occupancy`: optional precomputed per-tile event counts from
    `padded_occupancy(s, block_m, block_k)` (or the fused LIF emission) —
    callers that already hold the map skip recomputing it here. A map for
    the wrong tiling/tile grid is rejected, never silently consumed.

    This is the PREDICATED-DENSE route of the hybrid pair: the grid walks
    every tile and the map gates compute per step. Density-adaptive
    dispatch (`kernels.dispatch.use_hybrid`) picks between this and the
    event-compacted `spike_matmul_csr` per call from the carried map's
    occupied-tile count — direct callers pick a route statically instead.
    """
    s, occupancy, _ = _carried_occupancy(s, occupancy, block_m, block_k)
    lead = s.shape[:-2]
    m, k = s.shape[-2:]
    n = w.shape[-1]
    s2 = s.reshape(-1, k) if lead else s.reshape(m, k)
    s2, w2, m_orig, n_orig = _pad_operands(s2, w, block_m, block_n, block_k)
    if occupancy is None:
        occupancy = tile_occupancy(s2, block_m, block_k)
    out = spike_matmul_pallas(s2, w2, occupancy, block_m=block_m,
                              block_n=block_n, block_k=block_k)
    out = out[:m_orig, :n_orig]
    return out.reshape(lead + (m, n)) if lead else out


# ------------------------------------------------- event-compacted (CSR)
# The pow2-bucketed CSR builder lives in core.spikes.build_csr (shared
# with the per-shard pre-pass and EventTensor.csr).
_build_csr = build_csr


def _check_map(occupancy, s2, block_m, block_k):
    if occupancy.shape != (s2.shape[0] // block_m, s2.shape[1] // block_k):
        raise ValueError(
            f"occupancy map {occupancy.shape} does not match the padded "
            f"({s2.shape[0] // block_m}, {s2.shape[1] // block_k}) tile "
            f"grid — built for a different flattening or tiling")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                              "block_k", "pipeline"))
def _spike_matmul_csr_core(s2, w2, csr, *, block_m, block_n, block_k,
                           pipeline=False):
    return spike_matmul_csr_pallas(s2, w2, csr, block_m=block_m,
                                   block_n=block_n, block_k=block_k,
                                   pipeline=pipeline)


def spike_matmul_csr(s, w: jax.Array,
                     csr: TileCSR | None = None, *, block_m: int = 128,
                     block_n: int = 128, block_k: int = 128,
                     occupancy: jax.Array | None = None,
                     pipeline: bool = False) -> jax.Array:
    """Event-compacted spike matmul for (..., M, K) x (K, N).

    The CSR pre-pass (occupancy -> `TileCSR` work list) runs *outside* the
    jitted kernel call: with concrete inputs (serve/benchmark paths) the
    compaction trims the grid to occupied tiles only, so empty tiles cost
    zero grid steps; under jit tracing the step count is the dense bound
    but clamped padding steps still cost zero tile DMA and zero FLOPs.
    `s` may be an `EventTensor` (carried map + cached work list).
    `csr`: optional precomputed `TileCSR` for this padded tiling — the
    layer-level pass-through. `occupancy`: optional precomputed map for
    callers holding occupancy but no work list yet — the compaction runs
    on the tiny map; the dense `tile_occupancy` pass is skipped.

    This is the EVENT route of the hybrid pair (see `spike_matmul`): it
    wins when few tiles are occupied (the compacted grid skips empty
    steps outright) and loses to predicated-dense near-full occupancy
    (per-step compaction overhead with nothing left to skip) — the
    calibrated crossover lives in `core.costmodel`.
    """
    if csr is None:
        s, occupancy, csr = _carried_occupancy(s, occupancy, block_m,
                                               block_k, want_csr=True)
    else:
        s, occupancy, _ = _carried_occupancy(s, occupancy, block_m, block_k)
    lead = s.shape[:-2]
    m, k = s.shape[-2:]
    n = w.shape[-1]
    s2 = s.reshape(-1, k) if lead else s.reshape(m, k)
    s2, w2, m_orig, n_orig = _pad_operands(s2, w, block_m, block_n, block_k)
    if csr is None:
        if occupancy is None:
            occupancy = tile_occupancy(s2, block_m, block_k)
        else:
            _check_map(occupancy, s2, block_m, block_k)
        csr = _build_csr(occupancy, block_m, block_k)
    # The jit core can't see the static tags — validate before entering.
    csr.check_compatible(block_m, block_k,
                         s2.shape[0] // block_m, s2.shape[1] // block_k)
    out = _spike_matmul_csr_core(s2, w2, csr, block_m=block_m,
                                 block_n=block_n, block_k=block_k,
                                 pipeline=pipeline)
    out = out[:m_orig, :n_orig]
    return out.reshape(lead + (m, n)) if lead else out


@functools.partial(jax.jit,
                   static_argnames=("g", "block_m", "block_n", "block_k",
                                    "pipeline"))
def _apec_matmul_csr_core(res2, ov2, w2, csr, occ_res, occ_ov, *, g,
                          block_m, block_n, block_k, pipeline=False):
    return apec_matmul_csr_pallas(res2, ov2, w2, g, csr, occ_res, occ_ov,
                                  block_m=block_m, block_n=block_n,
                                  block_k=block_k, pipeline=pipeline)


def apec_matmul_csr(s, w: jax.Array, g: int = 2, *,
                    block_m: int = 128, block_n: int = 128,
                    block_k: int = 128,
                    occupancy: jax.Array | None = None,
                    pipeline: bool = False) -> jax.Array:
    """APEC matmul fused into one event-compacted kernel pass.

    Overlap/residual decomposition (packed bitwise kernel), then a single
    CSR-grid kernel computes both matmuls — each weight k-tile is DMA'd
    once and feeds the residual AND overlap dots — and accumulates the
    overlap partial sum directly into its group's g residual output rows
    in the epilogue. The union CSR pre-pass runs once and is shared
    between the two operands (no per-matmul occupancy recompute, no
    `jnp.repeat` combine pass).

    `s` may be an `EventTensor`, and `occupancy` a precomputed map of the
    UNDECOMPOSED spikes: an s-tile holds events iff its residual or
    (broadcast) overlap tile does, so the carried map IS the union gate —
    the work list compacts from it directly and both in-kernel dots are
    gated conservatively on it (an exclusive-operand step runs one empty
    dot instead of paying two dense pre-passes on the decomposed pair).
    """
    s, occupancy, _ = _carried_occupancy(s, occupancy, block_m, block_k)
    lead = s.shape[:-2]
    p, c = s.shape[-2:]
    if p % g:
        raise ValueError(f"positions {p} not divisible by group {g}")
    if block_m % g:
        raise ValueError(f"block_m {block_m} not divisible by group {g}")
    s2 = s.reshape(-1, c)
    ov, res = apec_decompose(s2, g)                  # packed bitwise kernel
    res2, w2, p_orig, n_orig = _pad_operands(
        res, w.astype(jnp.float32), block_m, block_n, block_k)
    ov2, _ = _pad_to(ov, 0, block_m // g)            # rows stay group-aligned
    ov2, _ = _pad_to(ov2, 1, block_k)
    # One union pre-pass serves both operands: a k-tile enters the work
    # list when either the residual or the overlap tile holds events, and
    # per-step counts gate each dot separately in-kernel. A carried map
    # replaces the pre-pass outright (union == s-tile occupancy).
    if occupancy is not None:
        _check_map(occupancy, res2, block_m, block_k)
        csr = _build_csr(occupancy, block_m, block_k)
        steps = (csr.tile_m_idx, csr.tile_k_idx)
        gate = (occupancy[steps] * csr.valid).astype(jnp.int32)
        occ_res_steps = occ_ov_steps = gate
    else:
        occ_res = tile_occupancy(res2, block_m, block_k)
        occ_ov = tile_occupancy(ov2, block_m // g, block_k)
        csr = _build_csr(occ_res + occ_ov, block_m, block_k)
        steps = (csr.tile_m_idx, csr.tile_k_idx)
        occ_res_steps = (occ_res[steps] * csr.valid).astype(jnp.int32)
        occ_ov_steps = (occ_ov[steps] * csr.valid).astype(jnp.int32)
    out = _apec_matmul_csr_core(res2, ov2, w2, csr, occ_res_steps,
                                occ_ov_steps, g=g, block_m=block_m,
                                block_n=block_n, block_k=block_k,
                                pipeline=pipeline)
    out = out[:p_orig, :n_orig]
    return out.reshape(lead + (p, w.shape[-1])).astype(w.dtype)


# -------------------------------------------------- packed-payload (PR 7)
# The packed wrappers are the `packed-csr` backend family's entry points.
# They accept EITHER a dense binary operand (packed_k=None — packed
# internally, which is how the registry-enumerated parity harness covers
# them with its dense f32 example inputs) OR pre-packed uint32 words with
# `packed_k=` the logical channel count (how dispatch threads a packed
# EventTensor's payload). Forward-only: gradients come from the dispatch
# layer's ref-replay / `_matmul_bwd` contract, which unpacks first —
# cotangents flow through the unpacked values, never through the words.


def _packed_rows(s, packed_k, occupancy, block_m, block_k):
    """Normalize the spike operand to flattened (R, KW) uint32 words.

    Returns (words, logical_k, lead_shape, logical_rows, occupancy). The
    dense entry stops gradients before packing (pack is forward-only
    aux); pre-packed words are validated against `packed_width(packed_k)`
    so a wrong-width payload is rejected loudly, never reinterpreted.
    """
    if isinstance(s, EventTensor):
        if occupancy is None:
            occupancy = s.occupancy_for(block_m, block_k)
        if s.is_packed:
            packed_k, s = s.feature_size, s.packed
        else:
            packed_k, s = None, s.spikes
    lead = s.shape[:-2]
    m = s.shape[-2]
    if packed_k is None:
        k = s.shape[-1]
        p2 = pack_spikes_padded(jax.lax.stop_gradient(s).reshape(-1, k))
        return p2, k, lead, m, occupancy
    kw = s.shape[-1]
    if kw != packed_width(packed_k):
        raise ValueError(
            f"packed operand {s.shape} carries {kw} words which does not "
            f"cover packed_k={packed_k} (want {packed_width(packed_k)})")
    return s.reshape(-1, kw), int(packed_k), lead, m, occupancy


def _pad_packed_operands(p2, w, packed_k, block_m, block_n, block_k):
    """Pad (R, KW) words and (K, N) weights to the packed tile grid.

    Zero words never mark a tile occupied; weight rows pad to KW*32 so
    the in-kernel unpack's phantom channels (always-zero bits) multiply
    zero weights.
    """
    if w.shape[0] != packed_k:
        raise ValueError(
            f"weights have {w.shape[0]} rows, packed operand covers "
            f"packed_k={packed_k} channels")
    bkw = block_k // PACK
    p2, m_orig = _pad_to(p2, 0, block_m)
    p2, _ = _pad_to(p2, 1, bkw)
    w2, _ = _pad_to(w, 0, p2.shape[1] * PACK)
    w2, n_orig = _pad_to(w2, 1, block_n)
    return p2, w2, m_orig, n_orig


def _check_packed_map(occupancy, p2, block_m, bkw):
    if occupancy.shape != (p2.shape[0] // block_m, p2.shape[1] // bkw):
        raise ValueError(
            f"occupancy map {occupancy.shape} does not match the padded "
            f"({p2.shape[0] // block_m}, {p2.shape[1] // bkw}) packed tile "
            f"grid — built for a different flattening or tiling")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                              "block_k", "pipeline"))
def _spike_matmul_packed_core(p2, w2, csr, *, block_m, block_n, block_k,
                              pipeline=False):
    return spike_matmul_packed_csr_pallas(p2, w2, csr, block_m=block_m,
                                          block_n=block_n, block_k=block_k,
                                          pipeline=pipeline)


def spike_matmul_packed(s, w: jax.Array, *, packed_k: int | None = None,
                        csr: TileCSR | None = None,
                        occupancy: jax.Array | None = None,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128,
                        pipeline: bool = False) -> jax.Array:
    """Event-compacted spike matmul on the uint32-packed payload.

    `s`: packed words (..., M, ceil(K/32)) with ``packed_k=K``, a packed
    `EventTensor`, or a dense binary (..., M, K) operand (packed here).
    Same CSR grid and work list as `spike_matmul_csr` — the tile indices
    are payload-agnostic — but the spike-side HBM read is 1/32 the f32
    route's, and each occupied tile unpacks VMEM-resident in-kernel.
    A carried/explicit `occupancy` map skips the popcount pre-pass (its
    (rows/128, ceil(K/128)) grid matches the packed word tiling exactly).
    """
    p2, packed_k, lead, m, occupancy = _packed_rows(
        s, packed_k, occupancy, block_m, block_k)
    n = w.shape[-1]
    p2, w2, m_orig, n_orig = _pad_packed_operands(
        p2, w, packed_k, block_m, block_n, block_k)
    bkw = block_k // PACK
    if csr is None:
        if occupancy is None:
            occupancy = packed_tile_occupancy(p2, block_m, block_k)
        else:
            _check_packed_map(occupancy, p2, block_m, bkw)
        csr = _build_csr(occupancy, block_m, block_k)
    csr.check_compatible(block_m, block_k,
                         p2.shape[0] // block_m, p2.shape[1] // bkw)
    out = _spike_matmul_packed_core(p2, w2, csr, block_m=block_m,
                                    block_n=block_n, block_k=block_k,
                                    pipeline=pipeline)
    out = out[:m_orig, :n_orig]
    return out.reshape(lead + (m, n)) if lead else out


@functools.partial(jax.jit, static_argnames=("g", "block_m", "block_n"))
def _apec_decompose_packed_jit(p2, *, g, block_m, block_n):
    from .apec_kernel import apec_decompose_packed
    return apec_decompose_packed(p2, g, block_m=block_m, block_n=block_n)


@functools.partial(jax.jit,
                   static_argnames=("g", "block_m", "block_n", "block_k",
                                    "pipeline"))
def _apec_matmul_packed_core(res2, ov2, w2, csr, occ_res, occ_ov, *, g,
                             block_m, block_n, block_k, pipeline=False):
    return apec_matmul_packed_csr_pallas(res2, ov2, w2, g, csr, occ_res,
                                         occ_ov, block_m=block_m,
                                         block_n=block_n, block_k=block_k,
                                         pipeline=pipeline)


def apec_matmul_packed(s, w: jax.Array, g: int = 2, *,
                       packed_k: int | None = None,
                       occupancy: jax.Array | None = None,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128,
                       pipeline: bool = False) -> jax.Array:
    """Fused APEC matmul staying in the packed domain end to end.

    The overlap/residual decomposition is already bitwise on uint32 words
    (`apec_decompose_packed`), so a packed operand never round-trips
    through f32: decompose packed -> popcount maps from the words ->
    union-CSR kernel unpacking each occupied residual/overlap tile
    in-VMEM. Contracts (union gate, carried-map semantics) mirror
    `apec_matmul_csr`.
    """
    from .apec_kernel import apec_decompose_packed
    p2, packed_k, lead, p_pos, occupancy = _packed_rows(
        s, packed_k, occupancy, block_m, block_k)
    if p2.shape[0] % g:
        raise ValueError(f"positions {p2.shape[0]} not divisible by "
                         f"group {g}")
    if block_m % g:
        raise ValueError(f"block_m {block_m} not divisible by group {g}")
    wf = w.astype(jnp.float32)
    p2, w2, p_orig, n_orig = _pad_packed_operands(
        p2, wf, packed_k, block_m, block_n, block_k)
    kw = p2.shape[1]
    bkw = block_k // PACK
    bn_dec = min(128, kw)
    if kw % bn_dec:
        bn_dec = bkw                      # bkw always divides the padding
    # Largest tileable row block: the decompose grid shrinks accordingly,
    # which is what keeps the per-step interpret overhead off the CPU
    # wall clock (rows are padded to block_m, and g divides block_m, so
    # the fallback chain always terminates). The jit wrapper caches the
    # pallas trace — an eager interpret-mode pallas_call re-traces every
    # call, which would put ~100ms of pure tracing on each APEC call.
    bm_dec = next(b for b in (128, 64, 32, 16, 8)
                  if p2.shape[0] % (g * b) == 0)
    ov_p, res_p = _apec_decompose_packed_jit(p2, g=g, block_m=bm_dec,
                                             block_n=bn_dec)
    if occupancy is not None:
        _check_packed_map(occupancy, p2, block_m, bkw)
        csr = _build_csr(occupancy, block_m, block_k)
        steps = (csr.tile_m_idx, csr.tile_k_idx)
        gate = (occupancy[steps] * csr.valid).astype(jnp.int32)
        occ_res_steps = occ_ov_steps = gate
    else:
        occ_res = packed_tile_occupancy(res_p, block_m, block_k)
        occ_ov = packed_tile_occupancy(ov_p, block_m // g, block_k)
        csr = _build_csr(occ_res + occ_ov, block_m, block_k)
        steps = (csr.tile_m_idx, csr.tile_k_idx)
        occ_res_steps = (occ_res[steps] * csr.valid).astype(jnp.int32)
        occ_ov_steps = (occ_ov[steps] * csr.valid).astype(jnp.int32)
    out = _apec_matmul_packed_core(res_p, ov_p, w2, csr, occ_res_steps,
                                   occ_ov_steps, g=g, block_m=block_m,
                                   block_n=block_n, block_k=block_k,
                                   pipeline=pipeline)
    out = out[:p_orig, :n_orig]
    return out.reshape(lead + (p_pos, w.shape[-1])).astype(w.dtype)


def _conv_pads(size: int, k: int, stride: int, padding: str):
    """(out_size, pad_lo, pad_hi) matching lax's SAME/VALID conventions."""
    if padding == "SAME":
        out = -(-size // stride)
        total = max((out - 1) * stride + k - size, 0)
        return out, total // 2, total - total // 2
    out = (size - k) // stride + 1
    return out, 0, 0


def econv_packed(s, w: jax.Array, *, stride: int = 1,
                 padding: str = "SAME", packed_k: int | None = None,
                 occupancy: jax.Array | None = None,
                 pipeline: bool = False) -> jax.Array:
    """Event conv with the payload packed end to end.

    im2col runs in the WORD domain: channels are the packed axis, so a
    spatial window of the word array IS the packed patch — kh*kw strided
    shifted slices of the zero-padded words concatenate into
    (N*Ho*Wo, kh*kw*ciw) patch rows with feature order (kh, kw,
    ci-words), and the weights are relaid to match: ci zero-padded to
    ciw*32 (the phantom channels multiply zero weights), transposed to
    (kh, kw, ci_pad, co). The packed CSR matmul consumes the patch words
    directly.

    A carried `occupancy` (the conv_patch_occupancy map of the DENSE
    patch matrix) is honored only when ci % 32 == 0 — then the packed
    patch k-tiling coincides with the dense one (the map is row-granular
    across k-tiles, so feature order doesn't matter); otherwise the word
    popcount pre-pass re-derives the map (32x cheaper than a dense scan).
    """
    if isinstance(s, EventTensor):
        if s.is_packed:
            packed_k, s = s.feature_size, s.packed
        else:
            s = s.spikes
    if packed_k is None:
        ci = s.shape[-1]
        p = pack_spikes_padded(jax.lax.stop_gradient(s))
    else:
        ci = int(packed_k)
        p = s
        if p.shape[-1] != packed_width(ci):
            raise ValueError(
                f"packed conv input {p.shape} carries {p.shape[-1]} words "
                f"which does not cover packed_k={ci}")
    kh, kw_, ci_w, co = w.shape
    if ci_w != ci:
        raise ValueError(f"weights expect {ci_w} input channels, packed "
                         f"operand covers {ci}")
    n, h, wdt, ciw = p.shape
    ho, pt, pb = _conv_pads(h, kh, stride, padding)
    wo, pl_, pr = _conv_pads(wdt, kw_, stride, padding)
    pp = jnp.pad(p, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    slices = [
        pp[:, dy:dy + (ho - 1) * stride + 1:stride,
           dx:dx + (wo - 1) * stride + 1:stride, :]
        for dy in range(kh) for dx in range(kw_)
    ]
    patches = jnp.concatenate(slices, axis=-1)      # (n, ho, wo, kh*kw*ciw)
    k_eff = kh * kw_ * ciw * PACK
    ci_pad = ciw * PACK
    w2 = jnp.pad(w, ((0, 0), (0, 0), (0, ci_pad - ci), (0, 0)))
    w2 = w2.reshape(kh * kw_ * ci_pad, co)
    if occupancy is not None and ci % PACK:
        occupancy = None               # dense-patch tiling doesn't align
    out = spike_matmul_packed(patches.reshape(n * ho * wo, kh * kw_ * ciw),
                              w2, packed_k=k_eff, occupancy=occupancy,
                              pipeline=pipeline)
    return out.reshape(n, ho, wo, co)
