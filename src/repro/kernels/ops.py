"""Jit'd public wrappers around the Pallas kernels.

These handle padding to block multiples, dtype plumbing, head/batch axis
flattening, and CPU-interpret fallback, so model code can call them on
arbitrary shapes. Each wrapper is shape-polymorphic under jit and safe to
use inside pjit/shard_map (pure, no host callbacks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.spikes import (PACK, TileCSR, occupancy_to_csr, pack_spikes,
                               pow2_step_cap, tile_occupancy, unpack_spikes)
from .lif_scan import lif_scan_pallas_sg
from .sdsa_kernel import (sdsa_causal_status_pallas, sdsa_packed,
                          sdsa_status_pallas)
from .spike_matmul import (apec_matmul_csr_pallas, spike_matmul_csr_pallas,
                           spike_matmul_pallas)


def _pad_to(x: jax.Array, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("decay", "v_th", "soft_reset",
                                              "surrogate_alpha"))
def lif(x: jax.Array, decay: float = 0.5, v_th: float = 1.0,
        soft_reset: bool = True, surrogate_alpha: float = 2.0) -> jax.Array:
    """Fused LIF over leading time axis, any trailing shape.

    Differentiable: routes through `lif_scan_pallas_sg`, whose backward is
    the reversed-scan Pallas kernel with the ATan surrogate. Padding /
    reshape around the kernel are native jax ops, so `jax.grad` composes.
    """
    t = x.shape[0]
    rest = x.shape[1:]
    flat = x.reshape(t, -1)
    # Fold into (T, M, N) with N a lane multiple.
    n = 128
    flat, orig = _pad_to(flat, 1, n * 8)
    m = flat.shape[1] // n
    out = lif_scan_pallas_sg(flat.reshape(t, m, n), decay, v_th, soft_reset,
                             surrogate_alpha)
    return out.reshape(t, -1)[:, :orig].reshape((t,) + rest)


@jax.jit
def sdsa_or(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Paper-faithful OR-form SDSA on dense binary tensors of shape
    (..., N, d); internally bit-packed and run through the Pallas kernels.
    """
    lead = q.shape[:-2]
    n, d = q.shape[-2:]
    dt = q.dtype

    def prep(x):
        x = x.reshape(-1, n, d)
        x, _ = _pad_to(x, 2, PACK)
        return pack_spikes(x, axis=-1)

    qp, kp, vp = prep(q), prep(k), prep(v)
    # Pad N to a block_n multiple (the kernel grid divides N exactly);
    # zero K/V rows are OR no-ops, zero Q rows are sliced off below.
    block_n = min(256, n + (-n) % 8)
    qp, n_orig = _pad_to(qp, 1, block_n)
    kp, _ = _pad_to(kp, 1, block_n)
    vp, _ = _pad_to(vp, 1, block_n)
    out_p = sdsa_packed(qp, kp, vp, block_n=block_n)
    out = unpack_spikes(out_p, axis=-1, dtype=dt)[:, :n_orig, :d]
    return out.reshape(lead + (n, d))


@jax.jit
def causal_sdsa_or(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal (LM) OR-form SDSA on dense binary tensors.

    q, k, v: (T, ..., N, d) with T the micro-timestep axis and N the token
    axis. status[i] = OR over micro-steps and tokens j <= i of K AND V;
    out[t, i] = Q[t, i] AND status[i]. Internally bit-packed: the kv mask
    is OR-folded over T elementwise, the prefix-OR over tokens runs in the
    Pallas causal-status kernel, and the Q AND is a packed vector op.
    """
    t = q.shape[0]
    lead = q.shape[1:-2]
    n, d = q.shape[-2:]
    dt = q.dtype

    def prep(x):
        x = x.reshape(t, -1, n, d)
        x, _ = _pad_to(x, 3, PACK)
        return pack_spikes(x, axis=-1)

    qp, kp, vp = prep(q), prep(k), prep(v)
    # kv mask per micro-step, then OR over T (elementwise on packed words).
    kv = jax.lax.reduce(kp & vp, jnp.uint32(0), jax.lax.bitwise_or, (0,))
    # Token-axis padding must reach a block_n multiple (the kernel grid
    # divides N exactly); trailing zero rows are prefix-OR no-ops and the
    # padded outputs are sliced off.
    block_n = min(256, n + (-n) % 8)
    kv, n_orig = _pad_to(kv, 1, block_n)
    status = sdsa_causal_status_pallas(kv, block_n=block_n)
    out_p = qp & status[None, :, :n_orig, :]
    out = unpack_spikes(out_p, axis=-1, dtype=dt)[..., :d]
    return out.reshape((t,) + lead + (n, d))


@jax.jit
def sdsa_status(k: jax.Array, v: jax.Array) -> jax.Array:
    """Status vector only (decode prefill path). (..., N, d) -> (..., d)."""
    lead = k.shape[:-2]
    n, d = k.shape[-2:]

    block_n = min(256, n + (-n) % 8)

    def prep(x):
        x = x.reshape(-1, n, d)
        x, _ = _pad_to(x, 2, PACK)
        x, _ = _pad_to(x, 1, block_n)
        return pack_spikes(x, axis=-1)

    kp, vp = prep(k), prep(v)
    st = sdsa_status_pallas(kp, vp, block_n=block_n)
    return unpack_spikes(st, axis=-1, dtype=k.dtype)[:, :d].reshape(lead + (d,))


@functools.partial(jax.jit, static_argnames=("g",))
def apec_decompose(s: jax.Array, g: int = 2):
    """Dense binary (P, C) spikes -> (overlap (P/g, C), residual (P, C))
    via the packed bitwise kernel. P must divide by g."""
    from .apec_kernel import apec_decompose_packed
    p, c = s.shape
    sp, _ = _pad_to(s, 1, PACK)
    packed = pack_spikes(sp, axis=-1)
    packed, p_orig = _pad_to(packed, 0, g * 8)
    ov_p, res_p = apec_decompose_packed(packed, g,
                                        block_n=min(128, packed.shape[1]))
    ov = unpack_spikes(ov_p, axis=-1, dtype=s.dtype)[: p_orig // g, :c]
    res = unpack_spikes(res_p, axis=-1, dtype=s.dtype)[:p_orig, :c]
    return ov, res


def _pad_operands(s2, w, block_m, block_n, block_k):
    """Pad a flattened (R, K) spike matrix and (K, N) weights to block
    multiples — padding adds zeros, so it can never mark a tile occupied."""
    s2, m_orig = _pad_to(s2, 0, block_m)
    s2, _ = _pad_to(s2, 1, block_k)
    w2, _ = _pad_to(w, 0, block_k)
    w2, n_orig = _pad_to(w2, 1, block_n)
    return s2, w2, m_orig, n_orig


def padded_occupancy(s: jax.Array, block_m: int = 128,
                     block_k: int = 128) -> jax.Array:
    """The occupancy pre-pass exactly as `spike_matmul` computes it: lead
    axes flattened into rows, then padded-tiling per-tile event counts.
    Callers running several matmuls over the *same* spike tensor (e.g. one
    encoding against several weight matrices, or stat collection alongside
    the matmul) run this once and pass the result through
    `spike_matmul(..., occupancy=)` or `occupancy_to_csr` ->
    `spike_matmul_csr(..., csr=)`. The kernels validate the map's shape
    against their tiling — a map for another tiling would silently gate
    the wrong tiles.
    """
    k = s.shape[-1]
    s2 = s.reshape(-1, k)
    s2, _ = _pad_to(s2, 0, block_m)
    s2, _ = _pad_to(s2, 1, block_k)
    return tile_occupancy(s2, block_m, block_k)


@functools.partial(jax.jit, static_argnames=("g",))
def apec_matmul(s: jax.Array, w: jax.Array, g: int = 2) -> jax.Array:
    """APEC matmul on the packed kernels: bitwise overlap/residual
    decomposition, then two occupancy-skipping matmuls with the overlap
    partial sums reused across each group's members.

    s: (..., P, C) binary with P % g == 0; w: (C, F) -> (..., P, F).
    Leading axes are flattened into the position axis — safe because each
    row contributes whole groups when P divides by g. (Each matmul runs
    its own occupancy pre-pass — overlap and residual are distinct
    operands, so there is nothing to share on this path; the fused
    `apec_matmul_csr` is the one that builds a single union pre-pass.)
    """
    lead = s.shape[:-2]
    p, c = s.shape[-2:]
    if p % g:
        raise ValueError(f"positions {p} not divisible by group {g}")
    s2 = s.reshape(-1, c)
    ov, res = apec_decompose(s2, g)                  # packed bitwise kernel
    wf = w.astype(jnp.float32)
    psum_ov = spike_matmul(ov, wf)                   # (R/g, F) cached sums
    psum_res = spike_matmul(res, wf)                 # (R, F) residuals
    out = psum_res + jnp.repeat(psum_ov, g, axis=0)  # reuse across members
    return out.reshape(lead + (p, w.shape[-1])).astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def spike_matmul(s: jax.Array, w: jax.Array, block_m: int = 128,
                 block_n: int = 128, block_k: int = 128,
                 occupancy: jax.Array | None = None) -> jax.Array:
    """Occupancy-skipping spike matmul for (..., M, K) x (K, N).

    `occupancy`: optional precomputed per-tile event counts from
    `padded_occupancy(s, block_m, block_k)` — callers that already ran the
    pre-pass (APEC, stat-collecting layers) skip recomputing it here.
    """
    lead = s.shape[:-2]
    m, k = s.shape[-2:]
    n = w.shape[-1]
    s2 = s.reshape(-1, k) if lead else s.reshape(m, k)
    s2, w2, m_orig, n_orig = _pad_operands(s2, w, block_m, block_n, block_k)
    if occupancy is None:
        occupancy = tile_occupancy(s2, block_m, block_k)
    out = spike_matmul_pallas(s2, w2, occupancy, block_m=block_m,
                              block_n=block_n, block_k=block_k)
    out = out[:m_orig, :n_orig]
    return out.reshape(lead + (m, n)) if lead else out


# ------------------------------------------------- event-compacted (CSR)
def _build_csr(occ, block_m, block_k):
    """CSR work list with a power-of-two step-count bucket (dense-capped,
    `core.spikes.pow2_step_cap` — shared with the per-shard pre-pass so
    single-device and sharded grids bucket identically). The traced path
    keeps the dense cap (one compile)."""
    tiling = (block_m, block_k)
    if isinstance(occ, jax.core.Tracer):
        return occupancy_to_csr(occ, tiling=tiling)
    exact = occupancy_to_csr(occ, tiling=tiling)
    mt, kt = occ.shape
    cap = pow2_step_cap(exact.n_steps, mt * kt)
    if cap == exact.n_steps:
        return exact
    return occupancy_to_csr(occ, cap=cap, tiling=tiling)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def _spike_matmul_csr_core(s2, w2, csr, *, block_m, block_n, block_k):
    return spike_matmul_csr_pallas(s2, w2, csr, block_m=block_m,
                                   block_n=block_n, block_k=block_k)


def spike_matmul_csr(s: jax.Array, w: jax.Array,
                     csr: TileCSR | None = None, *, block_m: int = 128,
                     block_n: int = 128, block_k: int = 128) -> jax.Array:
    """Event-compacted spike matmul for (..., M, K) x (K, N).

    The CSR pre-pass (occupancy -> `TileCSR` work list) runs *outside* the
    jitted kernel call: with concrete inputs (serve/benchmark paths) the
    compaction trims the grid to occupied tiles only, so empty tiles cost
    zero grid steps; under jit tracing the step count is the dense bound
    but clamped padding steps still cost zero tile DMA and zero FLOPs.
    `csr`: optional precomputed `TileCSR` for this padded tiling (from
    `padded_occupancy` + `occupancy_to_csr`) — the layer-level pass-through.
    """
    lead = s.shape[:-2]
    m, k = s.shape[-2:]
    n = w.shape[-1]
    s2 = s.reshape(-1, k) if lead else s.reshape(m, k)
    s2, w2, m_orig, n_orig = _pad_operands(s2, w, block_m, block_n, block_k)
    if csr is None:
        csr = _build_csr(tile_occupancy(s2, block_m, block_k),
                         block_m, block_k)
    # The jit core can't see the static tags — validate before entering.
    csr.check_compatible(block_m, block_k,
                         s2.shape[0] // block_m, s2.shape[1] // block_k)
    out = _spike_matmul_csr_core(s2, w2, csr, block_m=block_m,
                                 block_n=block_n, block_k=block_k)
    out = out[:m_orig, :n_orig]
    return out.reshape(lead + (m, n)) if lead else out


@functools.partial(jax.jit,
                   static_argnames=("g", "block_m", "block_n", "block_k"))
def _apec_matmul_csr_core(res2, ov2, w2, csr, occ_res, occ_ov, *, g,
                          block_m, block_n, block_k):
    return apec_matmul_csr_pallas(res2, ov2, w2, g, csr, occ_res, occ_ov,
                                  block_m=block_m, block_n=block_n,
                                  block_k=block_k)


def apec_matmul_csr(s: jax.Array, w: jax.Array, g: int = 2, *,
                    block_m: int = 128, block_n: int = 128,
                    block_k: int = 128) -> jax.Array:
    """APEC matmul fused into one event-compacted kernel pass.

    Overlap/residual decomposition (packed bitwise kernel), then a single
    CSR-grid kernel computes both matmuls — each weight k-tile is DMA'd
    once and feeds the residual AND overlap dots — and accumulates the
    overlap partial sum directly into its group's g residual output rows
    in the epilogue. The union CSR pre-pass runs once and is shared
    between the two operands (no per-matmul occupancy recompute, no
    `jnp.repeat` combine pass).
    """
    lead = s.shape[:-2]
    p, c = s.shape[-2:]
    if p % g:
        raise ValueError(f"positions {p} not divisible by group {g}")
    if block_m % g:
        raise ValueError(f"block_m {block_m} not divisible by group {g}")
    s2 = s.reshape(-1, c)
    ov, res = apec_decompose(s2, g)                  # packed bitwise kernel
    res2, w2, p_orig, n_orig = _pad_operands(
        res, w.astype(jnp.float32), block_m, block_n, block_k)
    ov2, _ = _pad_to(ov, 0, block_m // g)            # rows stay group-aligned
    ov2, _ = _pad_to(ov2, 1, block_k)
    # One union pre-pass serves both operands: a k-tile enters the work
    # list when either the residual or the overlap tile holds events, and
    # per-step counts gate each dot separately in-kernel.
    occ_res = tile_occupancy(res2, block_m, block_k)
    occ_ov = tile_occupancy(ov2, block_m // g, block_k)
    csr = _build_csr(occ_res + occ_ov, block_m, block_k)
    steps = (csr.tile_m_idx, csr.tile_k_idx)
    occ_res_steps = (occ_res[steps] * csr.valid).astype(jnp.int32)
    occ_ov_steps = (occ_ov[steps] * csr.valid).astype(jnp.int32)
    out = _apec_matmul_csr_core(res2, ov2, w2, csr, occ_res_steps,
                                occ_ov_steps, g=g, block_m=block_m,
                                block_n=block_n, block_k=block_k)
    out = out[:p_orig, :n_orig]
    return out.reshape(lead + (p, w.shape[-1])).astype(w.dtype)
