"""Fused temporal LIF scan — Pallas TPU kernel.

The EPE Core's MPE stage keeps membrane potentials on-chip between eFIFO
pushes; the TPU analogue is keeping the membrane tensor resident in VMEM
across the T-step temporal loop instead of round-tripping it through HBM
per timestep (what a naive `lax.scan` of elementwise ops compiles to when
the tensor exceeds registers).

Grid: (M/bm, N/bn) over the flattened neuron axes; each program owns a
(T, bm, bn) input/output block and a (bm, bn) f32 VMEM scratch for the
membrane potential. VPU-aligned blocks: bm multiple of 8, bn multiple of
128. HBM traffic: read T*bm*bn once, write T*bm*bn once — the membrane
state never leaves VMEM.

Training: `lif_scan_pallas_sg` is the differentiable form. Its forward
kernel additionally emits the pre-threshold membrane residuals V (the
values the surrogate derivative is evaluated at), and its backward is a
second Pallas kernel running the temporal scan in REVERSE with the ATan
surrogate of `core/surrogate.py` — the cotangent of the carried membrane
stays resident in VMEM exactly like the membrane does in forward. The
gradient matches `jax.grad` through `core.lif.lif_scan` (the ref oracle)
to float32 round-off, so TPU training no longer needs to pin
``EXSPIKE_BACKEND=lif_scan=ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lif_kernel(x_ref, out_ref, v_ref, *, t_steps: int, decay: float,
                v_th: float, soft_reset: bool):
    v_ref[...] = jnp.zeros_like(v_ref)

    def body(t, _):
        v = v_ref[...] * decay + x_ref[t].astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        if soft_reset:
            v_ref[...] = v - s * v_th
        else:
            v_ref[...] = v * (1.0 - s)
        out_ref[t] = s.astype(out_ref.dtype)
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def lif_scan_pallas(
    x: jax.Array,
    *,
    decay: float = 0.5,
    v_th: float = 1.0,
    soft_reset: bool = True,
    block_m: int = 8,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """LIF over leading time axis. x: (T, M, N) -> binary spikes (T, M, N)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t_steps, m, n = x.shape
    if m % block_m or n % block_n:
        raise ValueError(f"(M,N)=({m},{n}) must tile by ({block_m},{block_n})")

    kernel = functools.partial(
        _lif_kernel, t_steps=t_steps, decay=decay, v_th=v_th,
        soft_reset=soft_reset)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[pl.BlockSpec((t_steps, block_m, block_n),
                               lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((t_steps, block_m, block_n),
                               lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x)


# ---------------------------------------------------- differentiable form
def _lif_fwd_kernel(x_ref, s_ref, vres_ref, v_ref, *, t_steps: int,
                    decay: float, v_th: float, soft_reset: bool):
    """Forward scan that also emits the pre-reset membrane V[t] (the value
    the Heaviside — and hence the surrogate derivative — is evaluated at)."""
    v_ref[...] = jnp.zeros_like(v_ref)

    def body(t, _):
        v = v_ref[...] * decay + x_ref[t].astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        vres_ref[t] = v
        if soft_reset:
            v_ref[...] = v - s * v_th
        else:
            v_ref[...] = v * (1.0 - s)
        s_ref[t] = s.astype(s_ref.dtype)
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def _lif_bwd_kernel(vres_ref, g_ref, dx_ref, u_ref, *, t_steps: int,
                    decay: float, v_th: float, soft_reset: bool,
                    surrogate_alpha: float):
    """Reversed temporal scan: u_ref carries the cotangent of the membrane
    state (the VMEM-resident mirror of forward's v_ref).

    Per step, with sg = ATan'(V[t] - v_th) and gs = cotangent of S[t]:
      dL/dV[t]  = gs * sg + u * d(reset)/dV
      d(reset)/dV = 1 - v_th*sg          (soft: v' = V - S*v_th)
                  = (1 - S) - V*sg       (hard: v' = V * (1 - S))
      dX[t]     = dL/dV[t];   u <- decay * dL/dV[t]
    matching jax.grad through core.lif.lif_scan term by term.
    """
    u_ref[...] = jnp.zeros_like(u_ref)
    half_pi_alpha = 0.5 * math.pi * surrogate_alpha

    def body(i, _):
        t = t_steps - 1 - i
        v = vres_ref[t]
        sg = surrogate_alpha / 2.0 / (1.0 + (half_pi_alpha * (v - v_th)) ** 2)
        gs = g_ref[t].astype(jnp.float32)
        if soft_reset:
            dreset = 1.0 - v_th * sg
        else:
            s = (v >= v_th).astype(jnp.float32)
            dreset = (1.0 - s) - v * sg
        dv = gs * sg + u_ref[...] * dreset
        dx_ref[t] = dv.astype(dx_ref.dtype)
        u_ref[...] = decay * dv
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def _lif_fwd_pallas(x, *, decay, v_th, soft_reset, block_m, block_n):
    interpret = jax.default_backend() == "cpu"
    t_steps, m, n = x.shape
    if m % block_m or n % block_n:
        raise ValueError(f"(M,N)=({m},{n}) must tile by ({block_m},{block_n})")
    kernel = functools.partial(
        _lif_fwd_kernel, t_steps=t_steps, decay=decay, v_th=v_th,
        soft_reset=soft_reset)
    spec = pl.BlockSpec((t_steps, block_m, block_n), lambda i, j: (0, i, j))
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(x.shape, jnp.float32)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x)


def _lif_bwd_pallas(vres, g, *, decay, v_th, soft_reset, surrogate_alpha,
                    block_m, block_n):
    interpret = jax.default_backend() == "cpu"
    t_steps, m, n = vres.shape
    kernel = functools.partial(
        _lif_bwd_kernel, t_steps=t_steps, decay=decay, v_th=v_th,
        soft_reset=soft_reset, surrogate_alpha=surrogate_alpha)
    spec = pl.BlockSpec((t_steps, block_m, block_n), lambda i, j: (0, i, j))
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(vres, g)


# ------------------------------------------- fused occupancy emission
# The full-event pipeline's producer side: while the forward scan holds
# each spike tile in VMEM it also popcounts it, so the per-tile event
# counts leave the kernel as a second (scalar-memory) output with zero
# extra HBM traffic over the spikes themselves — occupancy becomes a
# byproduct of spike production instead of a dense re-read downstream.
# Counts are emitted per (timestep, block_m-row chunk, block_n-lane tile)
# and aggregated to the consumers' (128, 128) matmul tiling outside the
# kernel by `kernels.ops.lif_occ` (a reduction over the tiny count map,
# not the spike tensor).
def _lif_occ_kernel(x_ref, s_ref, cnt_ref, v_ref, *, t_steps: int,
                    decay: float, v_th: float, soft_reset: bool):
    v_ref[...] = jnp.zeros_like(v_ref)

    def body(t, _):
        v = v_ref[...] * decay + x_ref[t].astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        if soft_reset:
            v_ref[...] = v - s * v_th
        else:
            v_ref[...] = v * (1.0 - s)
        s_ref[t] = s.astype(s_ref.dtype)
        cnt_ref[t, 0, 0] = jnp.sum(s.astype(jnp.int32))   # tile popcount
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def _lif_occ_fwd_kernel(x_ref, s_ref, cnt_ref, vres_ref, v_ref, *,
                        t_steps: int, decay: float, v_th: float,
                        soft_reset: bool):
    """Autodiff forward: spikes + per-tile counts + pre-reset membrane
    residuals (what the surrogate backward consumes)."""
    v_ref[...] = jnp.zeros_like(v_ref)

    def body(t, _):
        v = v_ref[...] * decay + x_ref[t].astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        vres_ref[t] = v
        if soft_reset:
            v_ref[...] = v - s * v_th
        else:
            v_ref[...] = v * (1.0 - s)
        s_ref[t] = s.astype(s_ref.dtype)
        cnt_ref[t, 0, 0] = jnp.sum(s.astype(jnp.int32))
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def _lif_occ_pallas(x, *, decay, v_th, soft_reset, block_m, block_n,
                    emit_vres: bool):
    """x: (T, M, N) -> (spikes (T, M, N), counts (T, M/bm, N/bn) int32
    [, vres (T, M, N) f32]). Counts live in SMEM: one scalar per
    (t, row-chunk, lane-tile), written while the spike tile is resident."""
    interpret = jax.default_backend() == "cpu"
    t_steps, m, n = x.shape
    if m % block_m or n % block_n:
        raise ValueError(f"(M,N)=({m},{n}) must tile by ({block_m},{block_n})")
    kernel = functools.partial(
        _lif_occ_fwd_kernel if emit_vres else _lif_occ_kernel,
        t_steps=t_steps, decay=decay, v_th=v_th, soft_reset=soft_reset)
    spec = pl.BlockSpec((t_steps, block_m, block_n), lambda i, j: (0, i, j))
    cnt_spec = pl.BlockSpec((t_steps, 1, 1), lambda i, j: (0, i, j),
                            memory_space=pltpu.SMEM)
    cnt_shape = jax.ShapeDtypeStruct(
        (t_steps, m // block_m, n // block_n), jnp.int32)
    out_specs = (spec, cnt_spec) + ((spec,) if emit_vres else ())
    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype), cnt_shape) \
        + ((jax.ShapeDtypeStruct(x.shape, jnp.float32),) if emit_vres else ())
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[spec],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x)


def _lif_occ_packed_kernel(x_ref, p_ref, cnt_ref, v_ref, *, t_steps: int,
                           decay: float, v_th: float, soft_reset: bool):
    """Fire + PACK: while the spike tile is VMEM-resident for the scan,
    emit it as uint32 words (bit i of word w = lane w*32+i, the
    `core.spikes.pack_spikes` layout) and derive the per-tile event count
    from the words' popcounts — occupancy becomes a free byproduct of
    packing, and the f32 spike tile never reaches HBM at all (32x less
    spike traffic out of the producer).

    TPU layout note: the packed store's lane dim is block_n/32 (=4 at the
    default 128); on real hardware a sublane-transposed store or an
    8-word-wide block (block_n=256+) may lay out better — interpret mode
    (all CI here) is layout-agnostic, so this keeps the canonical form.
    """
    v_ref[...] = jnp.zeros_like(v_ref)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)

    def body(t, _):
        v = v_ref[...] * decay + x_ref[t].astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        if soft_reset:
            v_ref[...] = v - s * v_th
        else:
            v_ref[...] = v * (1.0 - s)
        bm, bn = s.shape
        bits = s.reshape(bm, bn // 32, 32).astype(jnp.uint32)
        words = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)
        p_ref[t] = words
        cnt_ref[t, 0, 0] = jnp.sum(
            jax.lax.population_count(words).astype(jnp.int32))
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def lif_scan_occ_packed_pallas(x, *, decay: float = 0.5, v_th: float = 1.0,
                               soft_reset: bool = True, block_m: int = 8,
                               block_n: int = 128):
    """Fused packed emission: x (T, M, N) -> (packed words
    (T, M, N/32) uint32, counts (T, M/bm, N/bn) int32).

    FORWARD-ONLY by contract (the packed payload is inference-mode event
    transport; both outputs are integer-typed and the drive is
    stop_gradient'ed — training paths run the differentiable dense
    emission and pack nothing). N must tile by block_n (>= and a multiple
    of 32), which the `ops.lif_occ` wrapper's 128-lane padding guarantees.
    """
    interpret = jax.default_backend() == "cpu"
    x = jax.lax.stop_gradient(x)
    t_steps, m, n = x.shape
    if m % block_m or n % block_n or block_n % 32:
        raise ValueError(f"(M,N)=({m},{n}) must tile by ({block_m},{block_n})"
                         f" with block_n a multiple of 32")
    kernel = functools.partial(
        _lif_occ_packed_kernel, t_steps=t_steps, decay=decay, v_th=v_th,
        soft_reset=soft_reset)
    spec = pl.BlockSpec((t_steps, block_m, block_n), lambda i, j: (0, i, j))
    p_spec = pl.BlockSpec((t_steps, block_m, block_n // 32),
                          lambda i, j: (0, i, j))
    cnt_spec = pl.BlockSpec((t_steps, 1, 1), lambda i, j: (0, i, j),
                            memory_space=pltpu.SMEM)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[spec],
        out_specs=(p_spec, cnt_spec),
        out_shape=(jax.ShapeDtypeStruct((t_steps, m, n // 32), jnp.uint32),
                   jax.ShapeDtypeStruct(
                       (t_steps, m // block_m, n // block_n), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def lif_scan_occ_pallas_sg(x, decay: float = 0.5, v_th: float = 1.0,
                           soft_reset: bool = True,
                           surrogate_alpha: float = 2.0,
                           block_m: int = 8, block_n: int = 128):
    """Differentiable fused LIF with occupancy emission.

    x: (T, M, N) drive -> (spikes (T, M, N), counts (T, M/bm, N/bn)).
    Spikes are bit-identical to `lif_scan_pallas`; counts are the
    non-differentiated aux (their cotangent is discarded — occupancy is
    metadata, not signal). `jax.grad` runs the same reversed-scan
    surrogate kernel as `lif_scan_pallas_sg`.
    """
    return _lif_occ_pallas(x, decay=decay, v_th=v_th, soft_reset=soft_reset,
                           block_m=block_m, block_n=block_n, emit_vres=False)


def _occ_sg_fwd(x, decay, v_th, soft_reset, surrogate_alpha, block_m,
                block_n):
    s, cnt, vres = _lif_occ_pallas(
        x, decay=decay, v_th=v_th, soft_reset=soft_reset, block_m=block_m,
        block_n=block_n, emit_vres=True)
    return (s, cnt), vres


def _occ_sg_bwd(decay, v_th, soft_reset, surrogate_alpha, block_m, block_n,
                vres, g):
    gs, _g_cnt = g          # occupancy aux carries no gradient
    dx = _lif_bwd_pallas(vres, gs, decay=decay, v_th=v_th,
                         soft_reset=soft_reset,
                         surrogate_alpha=surrogate_alpha,
                         block_m=block_m, block_n=block_n)
    return (dx,)


lif_scan_occ_pallas_sg.defvjp(_occ_sg_fwd, _occ_sg_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def lif_scan_pallas_sg(x, decay: float = 0.5, v_th: float = 1.0,
                       soft_reset: bool = True, surrogate_alpha: float = 2.0,
                       block_m: int = 8, block_n: int = 128):
    """Differentiable fused LIF: Pallas forward, Pallas surrogate backward.

    x: (T, M, N) membrane drive -> binary spikes (T, M, N). Forward output
    is bit-identical to `lif_scan_pallas`; `jax.grad` runs the reversed-
    scan kernel with the ATan surrogate (SpikingJelly convention), matching
    the ref oracle `core.lif.lif_scan`. The primal runs the plain forward
    kernel — the f32 membrane-residual write only happens under autodiff
    (custom_vjp fwd), so inference pays nothing for differentiability.
    """
    return lif_scan_pallas(x, decay=decay, v_th=v_th, soft_reset=soft_reset,
                           block_m=block_m, block_n=block_n)


def _sg_fwd(x, decay, v_th, soft_reset, surrogate_alpha, block_m, block_n):
    s, vres = _lif_fwd_pallas(x, decay=decay, v_th=v_th,
                              soft_reset=soft_reset, block_m=block_m,
                              block_n=block_n)
    return s, vres


def _sg_bwd(decay, v_th, soft_reset, surrogate_alpha, block_m, block_n,
            vres, g):
    dx = _lif_bwd_pallas(vres, g, decay=decay, v_th=v_th,
                         soft_reset=soft_reset,
                         surrogate_alpha=surrogate_alpha,
                         block_m=block_m, block_n=block_n)
    return (dx,)


lif_scan_pallas_sg.defvjp(_sg_fwd, _sg_bwd)
