"""Fused temporal LIF scan — Pallas TPU kernel.

The EPE Core's MPE stage keeps membrane potentials on-chip between eFIFO
pushes; the TPU analogue is keeping the membrane tensor resident in VMEM
across the T-step temporal loop instead of round-tripping it through HBM
per timestep (what a naive `lax.scan` of elementwise ops compiles to when
the tensor exceeds registers).

Grid: (M/bm, N/bn) over the flattened neuron axes; each program owns a
(T, bm, bn) input/output block and a (bm, bn) f32 VMEM scratch for the
membrane potential. VPU-aligned blocks: bm multiple of 8, bn multiple of
128. HBM traffic: read T*bm*bn once, write T*bm*bn once — the membrane
state never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lif_kernel(x_ref, out_ref, v_ref, *, t_steps: int, decay: float,
                v_th: float, soft_reset: bool):
    v_ref[...] = jnp.zeros_like(v_ref)

    def body(t, _):
        v = v_ref[...] * decay + x_ref[t].astype(jnp.float32)
        s = (v >= v_th).astype(jnp.float32)
        if soft_reset:
            v_ref[...] = v - s * v_th
        else:
            v_ref[...] = v * (1.0 - s)
        out_ref[t] = s.astype(out_ref.dtype)
        return ()

    jax.lax.fori_loop(0, t_steps, body, ())


def lif_scan_pallas(
    x: jax.Array,
    *,
    decay: float = 0.5,
    v_th: float = 1.0,
    soft_reset: bool = True,
    block_m: int = 8,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """LIF over leading time axis. x: (T, M, N) -> binary spikes (T, M, N)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t_steps, m, n = x.shape
    if m % block_m or n % block_n:
        raise ValueError(f"(M,N)=({m},{n}) must tile by ({block_m},{block_n})")

    kernel = functools.partial(
        _lif_kernel, t_steps=t_steps, decay=decay, v_th=v_th,
        soft_reset=soft_reset)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[pl.BlockSpec((t_steps, block_m, block_n),
                               lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((t_steps, block_m, block_n),
                               lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x)
