"""Predicated vs event-compacted spike matmuls across the paper's sparsities.

Rows: ``sparsity/<op>/<pallas|pallas-csr>/s<pct>,us_per_call,...`` timing
the same op under the predicated dense-grid kernel (``pallas`` family) and
the scalar-prefetch CSR kernel (``pallas-csr`` family) at the paper's
measured sparsity levels (50/60/80/90/97%), plus one
``sparsity/<op>/crossover`` row reporting the first sparsity where the
compacted grid wins — the measured "when CSR beats predication" point the
kernel README cites.

Event layout: tile-skipping saves nothing on i.i.d. sparsity (a 128x128
tile at 97% uniform sparsity still holds ~490 events), and real spike maps
are not i.i.d. — events cluster in active regions (PAPER.md's irregular
sparsity; see `core.spikes.occupancy_fraction`). The generator therefore
draws *clustered* events: each (block_m x block_k) tile is live with
probability (1 - sparsity)/IN_TILE_DENSITY and live tiles fire at
IN_TILE_DENSITY, so overall sparsity matches the sweep level while tile
occupancy spans 1.0 -> ~0.06 across it. Each row's ``derived`` records the
realized occupancy fraction plus the cost model's FLOPs-saved and
DMA-saved fractions (`core.costmodel.tile_matmul_savings`) — the two
ledgers the backends differ on.

The suite times fixed formulations against each other, so (like fig2) its
numbers do not respond to ``--backend`` overrides, by design.

``--mesh`` (or the ``sparsity_mesh`` suite in benchmarks.run) adds the
sharded columns: the same CSR op at the same sparsity points, single
device vs row-sharded over an 8-way ('data') host mesh through
`runtime.sharding.event_op_sharded` — mesh-aware registry resolution,
per-shard `TileCSR` work lists (`core.spikes.shard_occupancy_to_csr`, no
global-occupancy gather), and per-shard occupancy columns
(`runtime.straggler.occupancy_imbalance`: ``occ_per_shard``/``occ_max``/
``occ_mean``/``occ_imbalance``) since event-load skew is what makes
sharded event execution straggle. Committed as BENCH_PR4.json.

``--pipelined`` adds the DMA-pipelining half of BENCH_PR10: the ``-pipe``
kernels (manual double-buffered weight-tile DMA, `kernels/README.md`
"DMA pipelining & load balance") paired against their serial CSR
baselines under the interleaved clone-pair protocol, with the modeled
prefetched-vs-stalled weight-byte split (`costmodel.dma_overlap_ledger`)
per row. ``--mesh --rebalance`` adds the load-balance half: static
row-contiguous vs occupancy-weighted shard splits on hotspot-clustered
maps at a taller M (M_MESH has one tile row per shard, so whole-tile-row
rebalancing has no freedom there — the `rebalanced=` column on the
ordinary mesh rows records exactly that). ``--pr10`` runs both halves
and writes the combined BENCH_PR10.json.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.core.spikes import occupancy_fraction
from repro.kernels import ops
from .common import csv_row, noise_band, not_slower, time_fn, \
    time_interleaved

SPARSITIES = (0.50, 0.60, 0.80, 0.90, 0.97)
IN_TILE_DENSITY = 0.5
BLOCK = 128
# (M, K, N) for the matmul-form ops; positions grouped g=2 for APEC.
M, K, N = 512, 512, 256
APEC_G = 2


def clustered_spikes(key, m: int, k: int, sparsity: float,
                     block_m: int = BLOCK, block_k: int = BLOCK) -> jax.Array:
    """Binary (m, k) spikes at `sparsity` with tile-clustered events.

    Exactly max(1, round(live_frac * n_tiles)) tiles are live: an iid
    Bernoulli draw can zero out the whole map at the sparse end of the
    sweep, which would silently time the degenerate all-empty edge case
    instead of a representative sparse workload.
    """
    k_live, k_fire = jax.random.split(key)
    live_frac = min(1.0, (1.0 - sparsity) / IN_TILE_DENSITY)
    density = (1.0 - sparsity) / live_frac
    mt, kt = m // block_m, k // block_k
    n_live = max(1, round(live_frac * mt * kt))
    live = (jax.random.permutation(k_live, mt * kt) < n_live
            ).reshape(mt, 1, kt, 1)
    fire = jax.random.uniform(k_fire, (mt, block_m, kt, block_k)) < density
    return (live & fire).astype(jnp.float32).reshape(m, k)


def _savings_fields(s2: jax.Array, n: int) -> str:
    occ_map = ops.padded_occupancy(s2, BLOCK, BLOCK)
    occ_frac = float(occupancy_fraction(s2, BLOCK, BLOCK))
    pred = costmodel.tile_matmul_savings(occ_map, n, backend="pallas")
    csr = costmodel.tile_matmul_savings(occ_map, n, backend="pallas-csr")
    return (f"occupancy={occ_frac:.3f};"
            f"flops_saved={pred.flops_fraction_saved:.3f};"
            f"dma_saved_pallas={pred.dma_fraction_saved:.3f};"
            f"dma_saved_csr={csr.dma_fraction_saved:.3f}")


def _prepass_time(s: jax.Array, be: str) -> float:
    """Wall seconds of the standalone occupancy pre-pass the backend pays
    per call when no carried map is supplied: the dense `tile_occupancy`
    read (both kernel families) plus the eager CSR compaction (`pallas-csr`
    only). This is the share an EventTensor-carried forward deletes — the
    per-row `prepass_us`/`prepass_share` columns make visible how much of
    the 'CSR win' the pre-pass was eating."""
    from repro.core.spikes import build_csr

    if be.startswith("pallas-csr"):
        def fn(x):
            return build_csr(ops.padded_occupancy(x, BLOCK, BLOCK),
                             BLOCK, BLOCK)
    else:
        def fn(x):
            return ops.padded_occupancy(x, BLOCK, BLOCK)
    return time_fn(fn, s)


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    crossover: dict[str, float | None] = {}
    variants = {
        "spike_matmul": {
            "pallas": jax.jit(ops.spike_matmul),
            # eager pre-pass (trimmed CSR grid) + jitted kernel core
            "pallas-csr": ops.spike_matmul_csr,
        },
        "apec_matmul": {
            "pallas": jax.jit(functools.partial(ops.apec_matmul, g=APEC_G)),
            "pallas-csr": functools.partial(ops.apec_matmul_csr, g=APEC_G),
        },
    }
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    for op, impls in variants.items():
        crossover[op] = None
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            s = clustered_spikes(key, M, K, sparsity)
            stats = _savings_fields(s, N)
            t_by = {}
            for be, fn in impls.items():
                t_by[be] = time_fn(fn, s, w) * 1e6
                prepass = _prepass_time(s, be) * 1e6
                rows.append(csv_row(
                    f"sparsity/{op}/{be}/s{int(sparsity * 100)}", t_by[be],
                    f"platform={platform};prepass_us={prepass:.1f};"
                    f"prepass_share={prepass / max(t_by[be], 1e-9):.3f};"
                    f"{stats}"))
            if crossover[op] is None and t_by["pallas-csr"] < t_by["pallas"]:
                crossover[op] = sparsity
        rows.append(csv_row(
            f"sparsity/{op}/crossover", 0.0,
            f"csr_wins_from_sparsity="
            f"{'none' if crossover[op] is None else crossover[op]};"
            f"platform={platform}"))
    return rows


# ------------------------------------------------------- packed payload
def _bytes_fields(occ, n: int) -> str:
    """Absolute modeled HBM traffic of the two CSR payloads on this map
    (`costmodel.matmul_bytes_moved`): the event-payload stream responds
    32x to packing, the weight/output streams are route-invariant (same
    trimmed grid) and reported alongside."""
    mb = 1.0 / 2**20
    f32 = costmodel.matmul_bytes_moved(occ, n, backend="pallas-csr")
    pk = costmodel.matmul_bytes_moved(occ, n, backend="packed-csr")
    return (f"spike_mb_csr={f32.spike_hbm * mb:.3f};"
            f"spike_mb_packed={pk.spike_hbm * mb:.3f};"
            f"spike_reduction={f32.spike_hbm / pk.spike_hbm:.1f};"
            f"weight_mb={f32.weight_hbm * mb:.3f};"
            f"out_mb={f32.out_hbm * mb:.3f};"
            f"total_reduction={f32.total / pk.total:.2f}")


def run_packed() -> list[str]:
    """uint32-packed CSR vs f32 CSR, single ops at the sweep points.

    Rows ``sparsity/<op>/packed-csr/s<pct>`` time the packed kernel on
    pre-packed words (packing is the producer's job — fused into emission
    in the pipeline — so the consumer-side comparison starts from each
    route's canonical payload; both routes re-derive their occupancy +
    work list per call). Fields carry the paired packed-vs-csr ratio
    against the self-measured clone noise band (`common.time_interleaved`
    protocol) plus the absolute bytes-moved ledger.
    """
    import functools

    from repro.core.spikes import pack_spikes

    rows = []
    platform = jax.default_backend()
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    variants = {
        "spike_matmul": (ops.spike_matmul_csr,
                         functools.partial(ops.spike_matmul_packed,
                                           packed_k=K)),
        "apec_matmul": (functools.partial(ops.apec_matmul_csr, g=APEC_G),
                        functools.partial(ops.apec_matmul_packed, g=APEC_G,
                                          packed_k=K)),
    }
    for op, (csr_fn, packed_fn) in variants.items():
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            s = clustered_spikes(key, M, K, sparsity)
            p = pack_spikes(s)
            ref = csr_fn(s, w)
            import numpy as np
            np.testing.assert_allclose(np.asarray(packed_fn(p, w)),
                                       np.asarray(ref), atol=1e-4)
            fns = {"csr": (lambda: csr_fn(s, w)),
                   "packed": (lambda: packed_fn(p, w)),
                   "csr2": (lambda: csr_fn(s, w)),
                   "packed2": (lambda: packed_fn(p, w))}
            best, samples = time_interleaved(fns, iters=24)
            ratio = best["packed"] / best["csr"]
            band = noise_band(samples, (("csr2", "csr"),
                                        ("packed2", "packed")))
            occ = ops.padded_occupancy(s, BLOCK, BLOCK)
            pct = int(sparsity * 100)
            rows.append(csv_row(
                f"sparsity/{op}/packed-csr/s{pct}", best["packed"] * 1e6,
                f"platform={platform};csr_us={best['csr'] * 1e6:.1f};"
                f"packed_vs_csr={ratio:.3f};noise_band={band:.3f};"
                f"not_slower={not_slower(ratio, band)};"
                f"{_bytes_fields(occ, N)};{_savings_fields(s, N)}"))
    return rows


# ------------------------------------------------------ pipelined kernels
def _dma_fields(occ, n: int, ledger_backend: str) -> str:
    """Modeled weight-stream DMA split for the serial-vs-pipe pair
    (`costmodel.dma_overlap_ledger`): total weight bytes the pipe variant
    fetches, how many land behind compute, how many stay exposed (one
    warm-up per N-tile iteration), and the serial baseline's all-exposed
    bytes for the same map."""
    mb = 1.0 / 2**20
    ser = costmodel.dma_overlap_ledger(occ, n, backend=ledger_backend)
    pipe = costmodel.dma_overlap_ledger(occ, n, backend=ledger_backend,
                                        pipelined=True)
    return (f"dma_w_mb={pipe.bytes_total * mb:.3f};"
            f"dma_prefetched_mb={pipe.bytes_prefetched * mb:.3f};"
            f"dma_stalled_mb={pipe.bytes_stalled * mb:.3f};"
            f"dma_stalled_serial_mb={ser.bytes_stalled * mb:.3f};"
            f"dma_overlap={pipe.overlap_fraction:.3f}")


def run_pipelined() -> list[str]:
    """Double-buffered weight-DMA (`-pipe`) kernels vs their serial CSR
    baselines at the sweep points.

    Rows ``sparsity/<op>/<family>-pipe/s<pct>`` time each registered
    pipelined matmul-form variant against the serial kernel it falls back
    to, under the paired interleaved clone protocol (`time_interleaved` /
    `noise_band` / `not_slower` — the same contract the packed rows use),
    after asserting forward parity at 1e-4. Fields carry the DMA-overlap
    ledger (`_dma_fields`): the weight bytes the pipe variant hides
    behind compute are the perf mechanism, so the modeled split rides
    next to the measured ratio.
    """
    import numpy as np

    from repro.core.spikes import pack_spikes

    rows = []
    platform = jax.default_backend()
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    for sparsity in SPARSITIES:
        key = jax.random.PRNGKey(int(sparsity * 1000))
        s = clustered_spikes(key, M, K, sparsity)
        p = pack_spikes(s)
        occ = ops.padded_occupancy(s, BLOCK, BLOCK)
        stats = _savings_fields(s, N)
        variants = (
            ("spike_matmul", "pallas-csr-pipe", "pallas-csr",
             lambda: ops.spike_matmul_csr(s, w),
             lambda: ops.spike_matmul_csr(s, w, pipeline=True)),
            ("spike_matmul", "packed-csr-pipe", "packed-csr",
             lambda: ops.spike_matmul_packed(p, w, packed_k=K),
             lambda: ops.spike_matmul_packed(p, w, packed_k=K,
                                             pipeline=True)),
            ("apec_matmul", "pallas-csr-pipe", "pallas-csr",
             lambda: ops.apec_matmul_csr(s, w, g=APEC_G),
             lambda: ops.apec_matmul_csr(s, w, g=APEC_G, pipeline=True)),
        )
        for op, pipe_name, ledger_be, ser_fn, pipe_fn in variants:
            np.testing.assert_allclose(np.asarray(pipe_fn()),
                                       np.asarray(ser_fn()), atol=1e-4)
            best, samples = time_interleaved(
                {"serial": ser_fn, "pipe": pipe_fn,
                 "serial2": ser_fn, "pipe2": pipe_fn}, iters=12)
            ratio = best["pipe"] / best["serial"]
            band = noise_band(samples, (("serial2", "serial"),
                                        ("pipe2", "pipe")))
            rows.append(csv_row(
                f"sparsity/{op}/{pipe_name}/s{int(sparsity * 100)}",
                best["pipe"] * 1e6,
                f"platform={platform};serial_us={best['serial'] * 1e6:.1f};"
                f"pipe_vs_serial={ratio:.3f};noise_band={band:.3f};"
                f"not_slower={not_slower(ratio, band)};"
                f"{_dma_fields(occ, N, ledger_be)};{stats}"))
    return rows


# ------------------------------------------------------------- mesh sweep
MESH_SHARDS = 8
# 128 rows per shard at 8 shards: every shard's tile grid divides cleanly,
# so the csr family passes its per-shard gate (the point of the sweep).
M_MESH = 1024
# Taller geometry for the rebalance rows: at M_MESH each shard owns ONE
# 128-row tile row, so whole-tile-row rebalancing has zero freedom; at
# M_REBAL each shard owns four and the occupancy-weighted split can move
# load (`core.spikes.rebalance_shard_plan`).
M_REBAL = 4096
REBAL_SPARSITIES = (0.90, 0.97)


def hotspot_spikes(key, m: int, k: int, sparsity: float,
                   block_m: int = BLOCK, block_k: int = BLOCK) -> jax.Array:
    """`clustered_spikes` live-tile count, but the live tiles form ONE
    contiguous row-major band at a key-dependent offset — the spatial
    hotspot (events concentrated in an active region) that motivates
    occupancy-weighted sharding: a static row-contiguous split lands the
    whole band on one or two shards, which the synchronous collective
    then waits for."""
    k_off, k_fire = jax.random.split(key)
    live_frac = min(1.0, (1.0 - sparsity) / IN_TILE_DENSITY)
    density = (1.0 - sparsity) / live_frac
    mt, kt = m // block_m, k // block_k
    n_live = max(1, round(live_frac * mt * kt))
    off = jax.random.randint(k_off, (), 0, mt * kt - n_live + 1)
    flat = jnp.arange(mt * kt)
    live = ((flat >= off) & (flat < off + n_live)).reshape(mt, 1, kt, 1)
    fire = jax.random.uniform(k_fire, (mt, block_m, kt, block_k)) < density
    return (live & fire).astype(jnp.float32).reshape(m, k)


def _shard_step_fields(occ_np, n_shards: int, plan=None) -> str:
    """Per-shard grid-step columns for the sharded CSR rows: every shard
    pads to ONE shared pow2 cap (`steps_cap` — what the synchronous grid
    actually runs), and `steps_per_shard` counts each shard's real steps
    (occupied tiles + one dummy per all-empty tile row) under the given
    split — the pre-padding work the cap is quantizing."""
    import numpy as np

    from repro.core.spikes import shard_occupancy_to_csr
    locals_ = shard_occupancy_to_csr(occ_np, n_shards,
                                     tiling=(BLOCK, BLOCK), plan=plan)
    steps = [int(np.asarray(c.valid).sum()) for c in locals_]
    return (f"steps_cap={int(locals_[0].n_steps)};"
            "steps_per_shard=" + ":".join(str(x) for x in steps))


def run_mesh(n_shards: int = MESH_SHARDS) -> list[str]:
    """Sharded vs single-device CSR at the same sparsity points.

    Both variants pin the CSR family; the sharded rows go through
    `event_op_sharded` (mesh-aware resolution + per-shard work lists) and
    carry the resolved attribution plus the per-shard occupancy columns.
    On one physical CPU the 8 host devices are threads, so sharded wall
    time mixes real thread parallelism with partitioning overhead — the
    columns that transfer to real meshes are the per-shard occupancy /
    imbalance ones.

    Grid formulation per row (the ``grid=`` field): spike_matmul shards
    consume eager per-shard trimmed work lists (`csr_stack`); apec has no
    CSR pass-through (its union pre-pass is built in-kernel), so its
    sharded variant traces the pre-pass and runs the dense-capped clamped
    grid while its single row runs the eager trimmed grid — an asymmetry
    the field makes explicit rather than hides.
    """
    import numpy as np

    from repro.core.spikes import rebalance_shard_plan
    from repro.kernels import dispatch
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding

    platform = jax.default_backend()
    if len(jax.devices()) < n_shards:
        raise RuntimeError(
            f"mesh sweep needs {n_shards} devices, have {len(jax.devices())}"
            " (run via --mesh, which re-launches with host devices forced)")
    mesh = make_mesh((n_shards, 1), ("data", "model"))
    csr = "pallas-csr" if platform == "tpu" else "pallas-csr-interpret"
    rows = []
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    for op, single_fn, kwargs in (
            ("spike_matmul", ops.spike_matmul_csr, {}),
            ("apec_matmul",
             functools.partial(ops.apec_matmul_csr, g=APEC_G),
             {"g": APEC_G})):
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            s = clustered_spikes(key, M_MESH, K, sparsity)
            stats = _savings_fields(s, N)
            with dispatch.use_backend(csr, op=op):
                t_single = time_fn(single_fn, s, w) * 1e6
                if op == "spike_matmul":
                    # carried concrete map -> per-shard trimmed work
                    # lists inside event_op_sharded (one shared pow2 cap,
                    # no global-map gather), occupancy-weighted when the
                    # plan can move load — at M_MESH's one tile row per
                    # shard it cannot, which `rebalanced=` records.
                    occ_np = np.asarray(
                        ops.padded_occupancy(s, BLOCK, BLOCK))
                    plan = rebalance_shard_plan(occ_np, n_shards)
                    if plan.identity or not plan.improves:
                        plan = None
                    sharded = jax.jit(functools.partial(
                        sharding.event_op_sharded, mesh, op,
                        occupancy=occ_np))
                    grid = "trimmed"
                    extra = (f"rebalanced={int(plan is not None)};"
                             f"{_shard_step_fields(occ_np, n_shards, plan)}")
                    _, rep = sharding.event_op_sharded(
                        mesh, op, s, w, with_report=True,
                        occupancy=occ_np, **kwargs)
                else:
                    sharded = jax.jit(functools.partial(
                        sharding.event_op_sharded, mesh, op, **kwargs))
                    grid = "dense-capped"    # traced in-shard pre-pass
                    # every shard runs the same clamped dense-capped
                    # union grid — the step columns say so explicitly
                    cap = (M_MESH // n_shards // BLOCK) * (K // BLOCK)
                    extra = (f"rebalanced=0;steps_cap={cap};"
                             "steps_per_shard="
                             + ":".join([str(cap)] * n_shards))
                    _, rep = sharding.event_op_sharded(
                        mesh, op, s, w, with_report=True, **kwargs)
                t_shard = time_fn(sharded, s, w) * 1e6
            pct = int(sparsity * 100)
            rows.append(csv_row(
                f"sparsity/mesh/{op}/single/s{pct}", t_single,
                f"platform={platform};shards=1;backend={csr};"
                f"grid=trimmed;{stats}"))
            rows.append(csv_row(
                f"sparsity/mesh/{op}/sharded/s{pct}", t_shard,
                f"platform={platform};shards={n_shards};"
                f"backend={rep['backend']};resolved={rep['attribution']};"
                f"grid={grid};{extra};{rep['occupancy'].as_fields()};"
                f"{stats}"))
    return rows


def run_mesh_rebalance(n_shards: int = MESH_SHARDS) -> list[str]:
    """Static row-contiguous vs occupancy-weighted shard split on hotspot
    maps — the load-balance half of BENCH_PR10.

    Rows ``sparsity/mesh/rebalance/spike_matmul/{static,rebalanced}/s<pct>``
    run the SAME carried map through `event_op_sharded` with rebalancing
    off and on, at `M_REBAL` (four tile rows per shard — room to move)
    on `hotspot_spikes` maps (one contiguous active band — the split a
    static partition concentrates on few shards). Forward outputs are
    asserted equal at 1e-5 (the plan only permutes who computes which
    tile rows), and the rebalanced row carries the pre/post imbalance
    pair (`occ_pre_*` columns from `OccupancyImbalance.as_fields`) plus
    the per-shard step columns under both splits.
    """
    import numpy as np

    from repro.core.spikes import rebalance_shard_plan
    from repro.kernels import dispatch
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding

    platform = jax.default_backend()
    if len(jax.devices()) < n_shards:
        raise RuntimeError(
            f"rebalance sweep needs {n_shards} devices, have "
            f"{len(jax.devices())} (run via --mesh --rebalance)")
    mesh = make_mesh((n_shards, 1), ("data", "model"))
    csr = "pallas-csr" if platform == "tpu" else "pallas-csr-interpret"
    rows = []
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    for sparsity in REBAL_SPARSITIES:
        key = jax.random.PRNGKey(int(sparsity * 1000))
        s = hotspot_spikes(key, M_REBAL, K, sparsity)
        occ_np = np.asarray(ops.padded_occupancy(s, BLOCK, BLOCK))
        plan = rebalance_shard_plan(occ_np, n_shards)
        if plan.identity or not plan.improves:
            plan = None
        with dispatch.use_backend(csr, op="spike_matmul"):
            out_st, rep_st = sharding.event_op_sharded(
                mesh, "spike_matmul", s, w, occupancy=occ_np,
                rebalance=False, with_report=True)
            out_rb, rep_rb = sharding.event_op_sharded(
                mesh, "spike_matmul", s, w, occupancy=occ_np,
                with_report=True)
            np.testing.assert_allclose(np.asarray(out_rb),
                                       np.asarray(out_st), atol=1e-5)
            t_st = time_fn(jax.jit(functools.partial(
                sharding.event_op_sharded, mesh, "spike_matmul",
                occupancy=occ_np, rebalance=False)), s, w) * 1e6
            t_rb = time_fn(jax.jit(functools.partial(
                sharding.event_op_sharded, mesh, "spike_matmul",
                occupancy=occ_np)), s, w) * 1e6
        pct = int(sparsity * 100)
        imb_st = rep_st["occupancy"].imbalance
        imb_rb = rep_rb["occupancy"].imbalance
        rows.append(csv_row(
            f"sparsity/mesh/rebalance/spike_matmul/static/s{pct}", t_st,
            f"platform={platform};shards={n_shards};"
            f"backend={rep_st['backend']};generator=hotspot;rows={M_REBAL};"
            f"rebalanced=0;{_shard_step_fields(occ_np, n_shards)};"
            f"{rep_st['occupancy'].as_fields()}"))
        rows.append(csv_row(
            f"sparsity/mesh/rebalance/spike_matmul/rebalanced/s{pct}", t_rb,
            f"platform={platform};shards={n_shards};"
            f"backend={rep_rb['backend']};generator=hotspot;rows={M_REBAL};"
            f"rebalanced={int(plan is not None)};parity_vs_static=1e-5;"
            f"imbalance_vs_static={imb_rb / imb_st:.3f};"
            f"{_shard_step_fields(occ_np, n_shards, plan)};"
            f"{rep_rb['occupancy'].as_fields()}"))
    return rows


def _mesh_subprocess_rows(n_shards: int = MESH_SHARDS,
                          rebalance: bool = False) -> list[str]:
    """Re-launch this module with `n_shards` forced host devices (the XLA
    device-count flag is process-global and must precede the jax import)
    and collect its CSV rows. `rebalance` adds the static-vs-rebalanced
    hotspot rows (`run_mesh_rebalance`)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_shards} "
                        "--xla_backend_optimization_level=0")
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sparsity_sweep", "--mesh",
         "--shards", str(n_shards)]
        + (["--rebalance"] if rebalance else []),
        capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh sweep subprocess failed:\n{proc.stderr}")
    return [ln for ln in proc.stdout.splitlines() if ln.strip()]


def run_mesh_rows() -> list[str]:
    """Suite entry for benchmarks.run: in-process when the host already
    exposes enough devices, else via the forced-device subprocess."""
    if len(jax.devices()) >= MESH_SHARDS:
        return run_mesh()
    return _mesh_subprocess_rows()


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", action="store_true",
                    help="sharded-vs-single CSR columns on an "
                         f"{MESH_SHARDS}-way host mesh")
    ap.add_argument("--shards", type=int, default=MESH_SHARDS)
    ap.add_argument("--pipelined", action="store_true",
                    help="paired pipelined-vs-serial CSR rows with the "
                         "DMA-overlap ledger (single device)")
    ap.add_argument("--rebalance", action="store_true",
                    help="(with --mesh) static-vs-rebalanced shard-split "
                         f"rows on hotspot maps at M={M_REBAL}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="(with --mesh) also write BENCH_PR4-schema JSON: "
                         "mesh shape, mesh-aware resolved backends "
                         "(attribution), and the rows")
    ap.add_argument("--pr10", default=None, metavar="PATH",
                    help="write BENCH_PR10 JSON: pipelined paired rows "
                         "(in-process) plus mesh + rebalance rows (forced-"
                         "device subprocess when needed)")
    args = ap.parse_args()
    if args.pr10:
        pipe_rows = run_pipelined()
        if len(jax.devices()) >= args.shards:
            mesh_rows = run_mesh(args.shards) + run_mesh_rebalance(
                args.shards)
        else:
            mesh_rows = _mesh_subprocess_rows(args.shards, rebalance=True)
        rows = pipe_rows + mesh_rows
        print("\n".join(rows))
        with open(args.pr10, "w") as f:
            json.dump({"mesh": {"shards": args.shards,
                                "axes": ["data", "model"],
                                "platform": jax.default_backend()},
                       "pipelined_geometry": {"M": M, "K": K, "N": N,
                                              "apec_g": APEC_G},
                       "rebalance_geometry": {"M": M_REBAL, "K": K,
                                              "generator": "hotspot",
                                              "sparsities":
                                              list(REBAL_SPARSITIES)},
                       "bench_rows_per_shard": M_MESH // args.shards,
                       "rows": rows}, f, indent=2)
        return
    if args.pipelined:
        print("\n".join(run_pipelined()))
        return
    if not args.mesh:
        print("\n".join(run()))
        return
    if len(jax.devices()) < args.shards:
        rows = _mesh_subprocess_rows(args.shards, rebalance=args.rebalance)
    else:
        rows = run_mesh(args.shards)
        if args.rebalance:
            rows += run_mesh_rebalance(args.shards)
    print("\n".join(rows))
    if args.json:
        from repro.kernels import dispatch
        csr = ("pallas-csr" if jax.default_backend() == "tpu"
               else "pallas-csr-interpret")
        # Two resolution snapshots: the canonical example shapes are too
        # small to fill per-shard 128-row tiles, so their attribution
        # shows the degrade chain ("pallas<-pallas-csr"); the bench
        # shapes (M_MESH rows) divide cleanly, so the csr family holds —
        # per-row `resolved=` fields record it. Committing both pins the
        # two sides of the mesh gate.
        with dispatch.use_backend(csr, op="spike_matmul"), \
                dispatch.use_backend(csr, op="apec_matmul"), \
                dispatch.use_backend(csr, op="econv"):
            resolved_small = dispatch.resolved_backends(mesh=args.shards)
        with open(args.json, "w") as f:
            json.dump({"mesh": {"shards": args.shards,
                                "axes": ["data", "model"],
                                "platform": jax.default_backend()},
                       "requested_csr_family": csr,
                       "bench_rows_per_shard": M_MESH // args.shards,
                       "resolved_mesh_aware_example_shapes": resolved_small,
                       "rows": rows}, f, indent=2)


if __name__ == "__main__":
    main()
