"""Predicated vs event-compacted spike matmuls across the paper's sparsities.

Rows: ``sparsity/<op>/<pallas|pallas-csr>/s<pct>,us_per_call,...`` timing
the same op under the predicated dense-grid kernel (``pallas`` family) and
the scalar-prefetch CSR kernel (``pallas-csr`` family) at the paper's
measured sparsity levels (50/60/80/90/97%), plus one
``sparsity/<op>/crossover`` row reporting the first sparsity where the
compacted grid wins — the measured "when CSR beats predication" point the
kernel README cites.

Event layout: tile-skipping saves nothing on i.i.d. sparsity (a 128x128
tile at 97% uniform sparsity still holds ~490 events), and real spike maps
are not i.i.d. — events cluster in active regions (PAPER.md's irregular
sparsity; see `core.spikes.occupancy_fraction`). The generator therefore
draws *clustered* events: each (block_m x block_k) tile is live with
probability (1 - sparsity)/IN_TILE_DENSITY and live tiles fire at
IN_TILE_DENSITY, so overall sparsity matches the sweep level while tile
occupancy spans 1.0 -> ~0.06 across it. Each row's ``derived`` records the
realized occupancy fraction plus the cost model's FLOPs-saved and
DMA-saved fractions (`core.costmodel.tile_matmul_savings`) — the two
ledgers the backends differ on.

The suite times fixed formulations against each other, so (like fig2) its
numbers do not respond to ``--backend`` overrides, by design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.core.spikes import occupancy_fraction
from repro.kernels import ops
from .common import csv_row, time_fn

SPARSITIES = (0.50, 0.60, 0.80, 0.90, 0.97)
IN_TILE_DENSITY = 0.5
BLOCK = 128
# (M, K, N) for the matmul-form ops; positions grouped g=2 for APEC.
M, K, N = 512, 512, 256
APEC_G = 2


def clustered_spikes(key, m: int, k: int, sparsity: float,
                     block_m: int = BLOCK, block_k: int = BLOCK) -> jax.Array:
    """Binary (m, k) spikes at `sparsity` with tile-clustered events.

    Exactly max(1, round(live_frac * n_tiles)) tiles are live: an iid
    Bernoulli draw can zero out the whole map at the sparse end of the
    sweep, which would silently time the degenerate all-empty edge case
    instead of a representative sparse workload.
    """
    k_live, k_fire = jax.random.split(key)
    live_frac = min(1.0, (1.0 - sparsity) / IN_TILE_DENSITY)
    density = (1.0 - sparsity) / live_frac
    mt, kt = m // block_m, k // block_k
    n_live = max(1, round(live_frac * mt * kt))
    live = (jax.random.permutation(k_live, mt * kt) < n_live
            ).reshape(mt, 1, kt, 1)
    fire = jax.random.uniform(k_fire, (mt, block_m, kt, block_k)) < density
    return (live & fire).astype(jnp.float32).reshape(m, k)


def _savings_fields(s2: jax.Array, n: int) -> str:
    occ_map = ops.padded_occupancy(s2, BLOCK, BLOCK)
    occ_frac = float(occupancy_fraction(s2, BLOCK, BLOCK))
    pred = costmodel.tile_matmul_savings(occ_map, n, backend="pallas")
    csr = costmodel.tile_matmul_savings(occ_map, n, backend="pallas-csr")
    return (f"occupancy={occ_frac:.3f};"
            f"flops_saved={pred.flops_fraction_saved:.3f};"
            f"dma_saved_pallas={pred.dma_fraction_saved:.3f};"
            f"dma_saved_csr={csr.dma_fraction_saved:.3f}")


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    crossover: dict[str, float | None] = {}
    variants = {
        "spike_matmul": {
            "pallas": jax.jit(ops.spike_matmul),
            # eager pre-pass (trimmed CSR grid) + jitted kernel core
            "pallas-csr": ops.spike_matmul_csr,
        },
        "apec_matmul": {
            "pallas": jax.jit(functools.partial(ops.apec_matmul, g=APEC_G)),
            "pallas-csr": functools.partial(ops.apec_matmul_csr, g=APEC_G),
        },
    }
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    for op, impls in variants.items():
        crossover[op] = None
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            s = clustered_spikes(key, M, K, sparsity)
            stats = _savings_fields(s, N)
            t_by = {}
            for be, fn in impls.items():
                t_by[be] = time_fn(fn, s, w) * 1e6
                rows.append(csv_row(
                    f"sparsity/{op}/{be}/s{int(sparsity * 100)}", t_by[be],
                    f"platform={platform};{stats}"))
            if crossover[op] is None and t_by["pallas-csr"] < t_by["pallas"]:
                crossover[op] = sparsity
        rows.append(csv_row(
            f"sparsity/{op}/crossover", 0.0,
            f"csr_wins_from_sparsity="
            f"{'none' if crossover[op] is None else crossover[op]};"
            f"platform={platform}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
