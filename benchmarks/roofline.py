"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts in results/dryrun/.

  compute term    = analytic FLOPs / (chips * 197 TFLOP/s)
  memory term     = analytic HBM bytes / (chips * 819 GB/s)
  collective term = wire-factored collective bytes / (chips * 50 GB/s)

Collective bytes come from the trip-count-scaled HLO parse; they are
per-device result-shape bytes, so per-chip wire time = bytes * factor /
link_bw (ring all-reduce moves ~2x its payload; all-gather result already
equals the gathered bytes). Analytic FLOPs/bytes are used as numerators
because XLA's cost_analysis counts while-loop bodies once (see
launch/flops.py); the raw cost_analysis numbers are carried alongside.

Emits a markdown table + per-cell JSON summary for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def analyse_record(r: Dict) -> Dict:
    chips = r["chips"]
    a = r["analytic"]
    compute_s = a["flops"] / (chips * PEAK_FLOPS_BF16)
    memory_s = a["hbm_bytes"] / (chips * HBM_BW)
    coll = r.get("collective_bytes", {})
    coll_s = sum(v * WIRE_FACTOR.get(k, 1.0)
                 for k, v in coll.items() if k != "total") / ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_time = a["model_flops_6nd"] / (chips * PEAK_FLOPS_BF16)
    mfu_bound = model_time / step_s if step_s > 0 else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "mode": r.get("mode", "?"),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": a["model_flops_6nd"],
        "hlo_flops": a["flops"],
        "useful_ratio": a["useful_ratio"],
        "mfu_bound": mfu_bound,
        "suggestion": _suggest(dominant, r),
    }


def _suggest(dominant: str, r: Dict) -> str:
    arch, shape = r["arch"], r["shape"]
    if dominant == "collective":
        if "decode" in shape:
            return ("reshard the KV cache so the per-token append stays "
                    "local (avoid the involuntary all-gather)")
        return ("cut all-reduce payloads: fewer microbatches, bf16 grads / "
                "EF-int8 compression, or overlap via async collectives")
    if dominant == "memory":
        if "decode" in shape:
            return ("batch more requests per step or bit-pack spike "
                    "activations (32x) to amortize the param/cache sweep")
        return ("raise arithmetic intensity: larger microbatch, fuse LIF "
                "into matmul epilogue, drop remat on cheap layers")
    return ("compute-bound: good — push MXU utilization (128-aligned tiles,"
            " bf16 spikes, skip empty tiles via occupancy maps)")


def load_all(dryrun_dir: str = "results/dryrun") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("ok"):
            out.append(analyse_record(r))
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | mode | compute (s) | memory (s) | "
           "collective (s) | dominant | useful | MFU-bound |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} |")
    return "\n".join(lines)


def run() -> List[str]:
    rows = load_all()
    if not rows:
        return ["roofline/no_dryrun_results,0.0,run dryrun first"]
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(markdown_table(rows) + "\n")
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    out = []
    for r in rows:
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.1f},"
            f"dominant={r['dominant']};mfu_bound={r['mfu_bound']:.3f};"
            f"useful={r['useful_ratio']:.2f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
