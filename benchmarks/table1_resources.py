"""Table I analog: per-core resource/footprint breakdown.

LUT/FF counts don't transfer off-FPGA; the transferable quantities are the
on-chip storage budgets of each core (spike SRAM words, weight SRAM,
partial-sum buffers) and the APEC-2 overhead (Eq. 4), plus the paper's
published power figures used by the efficiency model.
"""
from __future__ import annotations

from repro.core import apec, costmodel
from .common import csv_row


def run() -> list[str]:
    hw = costmodel.ExSpikeHW()
    rows = []
    # Sparse Core: spike SRAM stores all input channels per address.
    max_hw_c = 512
    spike_sram_bits = 32 * 32 * max_hw_c          # 32x32 map, 512ch, 1b
    rows.append(csv_row("table1/sparse_core/spike_sram_bits", 0.0,
                        f"bits={spike_sram_bits}"))
    # EPE Core: weight SRAM for 32 output channels x 3x3 x 8b + MP 16b.
    weight_sram_bits = hw.n_clusters * 9 * max_hw_c * 8
    mp_bits = hw.n_clusters * 32 * 32 * 16
    rows.append(csv_row("table1/epe_core/weight_sram_bits", 0.0,
                        f"bits={weight_sram_bits}"))
    rows.append(csv_row("table1/epe_core/membrane_bits", 0.0,
                        f"bits={mp_bits}"))
    # APEC-2 overhead: overlap partial sums, Eq. 4 (the LUT/FF growth
    # 19k->25k / 21k->26k in the paper comes from these buffers).
    ov_bits = apec.apec_overhead_bits(co=hw.n_clusters, k=3, w_acc=16)
    rows.append(csv_row("table1/epe_core/apec2_overhead_bits", 0.0,
                        f"bits={ov_bits};eq4=co*k2*w_acc"))
    # Attention Core: KV status vector in registers, C_o bits (Sec. III-C).
    rows.append(csv_row("table1/attention_core/kv_status_bits", 0.0,
                        f"bits={max_hw_c};storage=registers-not-BRAM"))
    # Power model (paper-published, drives Table II efficiency).
    rows.append(csv_row("table1/power_w", 0.0,
                        f"baseline={hw.power_w_baseline};"
                        f"apec2={hw.power_w_apec2};ratio="
                        f"{hw.power_w_apec2 / hw.power_w_baseline:.3f}"))
    rows.append(csv_row("table1/pe_size", 0.0,
                        f"clusters={hw.n_clusters};wpe={hw.wpe_per_cluster};"
                        f"total_pe={hw.n_pe}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
