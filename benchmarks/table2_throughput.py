"""Table II reproduction: throughput / energy efficiency across the five
evaluated workloads (VGG11, ResNet18, SpikingFormer-4-256/-2-512, SegNet).

Per workload we measure real spike statistics on synthetic data, run the
ExSpike cycle model (200 MHz, 352 PE, paper power figures), and report
FPS / GOPS / GOPS/W / GOPS/W/PE next to the paper's published ExSpike row.
GOPS counts dense-equivalent synaptic ops (paper convention), so sparsity
and APEC raise it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.core import apec, costmodel
from repro.models import cnn
from .common import (csv_row, resnet18_spike_maps, spikingformer_spike_maps,
                     vgg11_spike_maps)

PAPER_ROWS = {
    "vgg11": dict(fps=148, gops=479.15, gops_w=281.85, gops_w_pe=0.80),
    "resnet18": dict(fps=85, gops=463.90, gops_w=267.53, gops_w_pe=0.76),
    "spikingformer-4-256": dict(fps=197, gops=123.25, gops_w=82.78,
                                gops_w_pe=0.24),
    "spikingformer-2-512": dict(fps=51, gops=696.64, gops_w=None,
                                gops_w_pe=None),
    "segnet": dict(fps=1633, gops=762.87, gops_w=None, gops_w_pe=None),
}


def _cnn_layers_cycles(stats, conv_specs, img, batch, apec2: bool):
    layers = []
    for i, (layer, s) in enumerate(zip(conv_specs, stats)):
        t_, b, h, w, c = s.shape
        s_in = stats[i - 1] if i > 0 else s
        hi, wi, ci = (s_in.shape[2], s_in.shape[3], s_in.shape[4]) \
            if i > 0 else (img, img, 3)
        n_events = float(jnp.sum(s_in)) / batch if i > 0 \
            else hi * wi * ci * t_      # first layer: direct-coded dense
        elim = ov_pos = 0.0
        if apec2 and i > 0:
            flat = s_in.reshape(-1, s_in.shape[-1])
            p = flat.shape[0] - flat.shape[0] % 2
            st = apec.apec_stats(flat[:p], 2)
            elim = float(st.eliminated) / batch
            ov_pos = float(st.groups_with_overlap) / batch
        layers.append(costmodel.conv_layer_cycles(
            f"l{i}", n_events, hi * wi * t_, hi, wi, ci,
            layer.out_ch if hasattr(layer, "out_ch") else c, 3,
            apec_group=2 if apec2 else 1, apec_eliminated=elim,
            apec_overlap_positions=ov_pos))
    return layers


def run() -> list[str]:
    rows = []
    batch = 4

    # --- VGG11 / ResNet18
    for name, maps_fn, spec_source in (
            ("vgg11", vgg11_spike_maps,
             [l for l in cnn.VGG11_LAYERS if l.kind == "conv"]),
            ("resnet18", resnet18_spike_maps, None)):
        cfg, params, stats = maps_fn(batch=batch)
        conv_specs = spec_source or [
            type("L", (), {"out_ch": s.shape[-1]})() for s in stats]
        for apec2 in (False, True):
            layers = _cnn_layers_cycles(stats, conv_specs, cfg.img, batch,
                                        apec2)
            summ = costmodel.summarize(layers, apec=apec2)
            tag = "apec2" if apec2 else "baseline"
            paper = PAPER_ROWS[name]
            rows.append(csv_row(
                f"table2/{name}/{tag}", summ["latency_ms"] * 1e3,
                f"fps={summ['fps']:.0f};gops={summ['gops']:.1f};"
                f"gops_w={summ['gops_per_w']:.1f};"
                f"gops_w_pe={summ['gops_per_w_per_pe']:.2f};"
                f"paper_fps={paper['fps']};paper_gops={paper['gops']}"))

    # --- SpikingFormers (token blocks + SDSA linear attention)
    for name, depth, dim in (("spikingformer-4-256", 4, 256),
                             ("spikingformer-2-512", 2, 512)):
        _, maps = spikingformer_spike_maps(depth, dim, batch=batch)
        layers = []
        for i, s in enumerate(maps):
            c = s.shape[-1]
            flat = s.reshape(-1, c)
            n_events = float(jnp.sum(s)) / batch
            n_pos = flat.shape[0] / batch
            layers.append(costmodel.fc_layer_cycles(
                f"b{i}", n_events, c, dim))
        layers.append(costmodel.sdsa_cycles("sdsa", 64 * depth, dim))
        summ = costmodel.summarize(layers)
        paper = PAPER_ROWS[name]
        rows.append(csv_row(
            f"table2/{name}/baseline", summ["latency_ms"] * 1e3,
            f"fps={summ['fps']:.0f};gops={summ['gops']:.1f};"
            f"gops_w={summ['gops_per_w']:.1f};"
            f"gops_w_pe={summ['gops_per_w_per_pe']:.2f};"
            f"paper_fps={paper['fps']};paper_gops={paper['gops']}"))

    # --- SegNet
    from repro.data.synthetic import seg_batch
    seg_cfg = CNNConfig(name="segnet", layers=cnn.SEGNET_LAYERS, img=64,
                        n_classes=2)
    p = cnn.segnet_init(seg_cfg, jax.random.PRNGKey(0))
    imgs = jnp.asarray(seg_batch(0, 0, 0, batch)["image"])
    _, stats = cnn.segnet_apply(seg_cfg, p, imgs, collect_stats=True)
    layers = []
    for i, s in enumerate(stats):
        t_, b, h, w, c = s.shape
        n_events = float(jnp.sum(s)) / batch
        layers.append(costmodel.conv_layer_cycles(
            f"seg{i}", n_events, h * w * t_, h, w, c,
            cnn.SEGNET_LAYERS[min(i + 1, len(cnn.SEGNET_LAYERS) - 1)].out_ch,
            3))
    summ = costmodel.summarize(layers)
    paper = PAPER_ROWS["segnet"]
    rows.append(csv_row(
        f"table2/segnet/baseline", summ["latency_ms"] * 1e3,
        f"fps={summ['fps']:.0f};gops={summ['gops']:.1f};"
        f"paper_fps={paper['fps']};paper_gops={paper['gops']}"))
    return rows


import jax  # noqa: E402  (used in segnet init)

if __name__ == "__main__":
    print("\n".join(run()))
