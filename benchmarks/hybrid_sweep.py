"""Density-adaptive hybrid dispatch vs the two static pins.

PR 3 committed the dense/event crossover per op; PR 5 made the occupancy
map flow to every consumer. This suite times what hybrid resolution buys:
the same whole-network forwards as the e2e suite (both model families'
event-hot stacks, carried `EventTensor` metadata) under THREE dispatch
modes — `dense` (predicated kernels pinned), `event` (csr family pinned),
and `hybrid` (per-call routing on the carried map via the calibrated cost
model). The claim the committed BENCH_PR6.json pins: hybrid is never
slower than the better static pin at any sparsity point, because it IS
the better pin at every point (plus a per-call resolution overhead orders
of magnitude below the kernels), picked from the map instead of by hand.

Rows:
  ``hybrid/<family>/<mode>/s<pct>``   stack-total CONSUME us — the sum
      over layers of the per-(layer, mode) reproducible-best sample,
      modes interleaved per layer (same drift/cache conditions; the
      mode-independent fire stage is excluded). Hybrid rows carry per-op
      route attribution (``routes=``) from `dispatch.watch_resolutions`
      and the jit recompile count across the whole sparsity sweep
      (``traces=``: bounded by the bucketed route set, NOT by occupancy
      values).
  ``hybrid/<family>/margin/s<pct>``   hybrid_vs_best = median PAIRED
      hybrid/winner ratio, judged against a self-measured
      ``noise_band`` (the deviation identical-program clone pairs show
      in the same rounds — see _margin), plus ``hybrid_is_winner_route`` attributing tie points
      to identical kernels rather than a lucky clock.
  ``hybrid-mesh/spike_matmul/<mode>/s<pct>``   8-way `event_op_sharded`
      rows with the report's attribution + per-shard ``occ_routes``.

``--json PATH`` writes the BENCH_PR6 schema: one sweep per mode with the
resolved per-op backends and all rows (single-device + mesh).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch, ops
from .common import (NOISE_BAND_FLOOR, csv_row, noise_band, not_slower,
                     paired_median_ratio, time_interleaved)
from .e2e_event import (FAMILIES, _consume, _forward, _produce_carried,
                        _stage_drive)
from .sparsity_sweep import SPARSITIES, clustered_spikes

_ = NOISE_BAND_FLOOR    # re-exported: the band floor rides every margin row

ITERS = 24   # min-of-N; interleaved samples, see common.time_interleaved
             # (the e2e suite's sample count — fewer rounds leave the
             # per-mode minimums of IDENTICAL programs a few % apart on a
             # cgroup-throttled host)
MESH_SHARDS = 8
M_MESH, K_MESH, N_MESH = 1024, 512, 256


def _pin_names() -> dict:
    """Platform-correct backend names for the two static pins."""
    tpu = jax.default_backend() == "tpu"
    return {"dense": "pallas" if tpu else "pallas-interpret",
            "event": "pallas-csr" if tpu else "pallas-csr-interpret"}


def _mode_scope(mode: str):
    """Dispatch context for one sweep mode (platform-correct pin names)."""
    if mode == "hybrid":
        return dispatch.use_hybrid()
    name = _pin_names()[mode]
    ctx = contextlib.ExitStack()
    for op in dispatch.HYBRID_OPS:
        ctx.enter_context(dispatch.use_backend(name, op=op))
    return ctx


def _time_trio(fns: dict, iters: int = ITERS,
               warmup: int = 2) -> tuple[dict, dict]:
    """Per-mode (min, all samples) via the shared interleaved rotating-
    order protocol (`common.time_interleaved` — one implementation for
    this sweep and the e2e pair timer)."""
    return time_interleaved(fns, iters=iters, warmup=warmup)


def _margin(samples: dict) -> tuple[float, float, str]:
    """(hybrid_vs_best, noise_band, winner).

    hybrid_vs_best: MEDIAN of per-round paired t_hybrid/t_winner ratios —
    within a round the modes run back-to-back, so host drift is
    common-mode and cancels; the median kills one-sided stall outliers
    (a min-of-ratios would credit hybrid whenever the WINNER caught the
    stall).

    noise_band: the largest deviation-from-1 the same statistic shows
    for the two IDENTICAL-program pairings in the same rounds — the
    ``dense2``/``event2`` clones against their pins. This is what "not
    slower" has to mean on this host: two separately-jitted executables
    of the IDENTICAL mesh HLO measure 1-2% apart in paired medians
    (instance layout, cgroup quota phase), so a hybrid margin within
    the band is indistinguishable from re-running the winner itself.
    One clone alone underestimates the band half the time (its own
    deviation can land BELOW 1). The margin rows pair the numbers with
    structural attribution (hybrid_is_winner_route / hybrid_picked_best
    / same_hlo) so tie points rest on program identity, not a lucky
    clock."""
    med = {m: sorted(v)[len(v) // 2] for m, v in samples.items()}
    winner = "dense" if med["dense"] <= med["event"] else "event"
    band = noise_band(samples, (("dense2", "dense"), ("event2", "event")))
    return paired_median_ratio(samples, "hybrid", winner), band, winner


# "Not slower" (common.not_slower) allows the measured identical-program
# noise band, floored at common.NOISE_BAND_FLOOR; `identical` is
# structural proof (hybrid_is_winner_route / same_hlo) that hybrid's
# program IS the winner's, which settles ties regardless of the clock.
_not_slower = not_slower


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    for family, spec in FAMILIES.items():
        stages = [(n, kind, shape,
                   jax.random.normal(jax.random.PRNGKey(i + 1),
                                     wshape, jnp.float32) * 0.05)
                  for i, (n, kind, shape, wshape) in enumerate(spec)]

        # The timed consume ops run EAGER with concrete carried maps — the
        # serve-path regime the crossover was calibrated in, where the
        # event route gets its trimmed CSR grid (a traced map pays the
        # pow2 step cap instead and shifts the crossover). Hybrid's
        # measured resolution overhead is ~13us/call vs a plain pin,
        # noise at these stack totals. One jitted hybrid stack reused
        # across every sparsity point is the recompile-boundedness probe:
        # under tracing the route flip rides the compiled lax.cond on the
        # bucketed count, so its trace count stays 1 for the whole sweep.
        @jax.jit
        def _hybrid_stack(drives, stages=stages):
            with dispatch.use_hybrid():
                return _forward(drives, stages, True)

        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            drives = [
                _stage_drive(jax.random.fold_in(key, i), kind, shape,
                             sparsity)
                for i, (_, kind, shape, _w) in enumerate(stages)]

            def fwd(mode):
                with _mode_scope(mode):
                    return _forward(drives, stages, True)

            # parity guard: all modes (and the traced-route hybrid stack)
            # run the same math
            outs = {m: fwd(m) for m in ("dense", "event", "hybrid")}
            outs["hybrid-jit"] = _hybrid_stack(drives)
            for m in ("event", "hybrid", "hybrid-jit"):
                for a, b in zip(outs["dense"], outs[m]):
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               atol=1e-4)
            # per-point route attribution: hybrid resolves on the
            # CONCRETE map, naming pick + bucket per call
            with dispatch.watch_resolutions() as recs:
                fwd("hybrid")
            picked = [r["attribution"].split("<-")[0] for r in recs
                      if r["op"] in dispatch.HYBRID_OPS]
            routes = ":".join(
                r["attribution"] for r in recs
                if r["op"] in dispatch.HYBRID_OPS)

            # Per-LAYER timing, per-mode minimums summed into the stack
            # total. Whole-stack samples (~70ms x 5 modes per round) span
            # several of this host's cgroup quota periods, so stack-level
            # drift is NOT common-mode and neither mins nor paired
            # medians converge (clones of the same eager path measured
            # up to 4% apart). Layer calls are 3-20ms — inside a quota
            # burst — and the per-(layer, mode) minimum is the
            # reproducible unthrottled cost (the e2e suite's protocol);
            # sums of minimums are stable. The fire stage is the same
            # compiled scan in every mode and is excluded: the routed
            # consume ops are all that differs. dense2/event2 re-run the
            # pins through the same eager path — their sum against the
            # winner's is the measured noise floor.
            modes = ("dense", "event", "hybrid", "dense2", "event2")
            ets = [jax.block_until_ready(_produce_carried(d))
                   for d in drives]
            sums = {m: 0.0 for m in modes}
            for (_n, kind, _shape, w), et in zip(stages, ets):
                def consume(m, kind=kind, et=et, w=w):
                    with _mode_scope(m.rstrip("2")):
                        return _consume(kind, et, w)
                layer_best, _ = _time_trio(
                    {m: (lambda m=m: consume(m)) for m in modes})
                for m in modes:
                    sums[m] += layer_best[m]
            best = sums
            winner = "dense" if sums["dense"] <= sums["event"] else "event"
            ratio = sums["hybrid"] / sums[winner]
            band = max(abs(sums["dense2"] / sums["dense"] - 1.0),
                       abs(sums["event2"] / sums["event"] - 1.0))
            # When hybrid resolves every layer to the winning pin's
            # backend, the two runs execute the SAME kernels — any
            # residual margin is resolution overhead (~13us/call) plus
            # timing noise, not a routing loss.
            same_route = int(all(p == _pin_names()[winner]
                                 for p in picked))
            pct = int(sparsity * 100)
            common = f"platform={platform};layers={len(stages)}"
            for mode in ("dense", "event"):
                rows.append(csv_row(f"hybrid/{family}/{mode}/s{pct}",
                                    best[mode] * 1e6, common))
            rows.append(csv_row(
                f"hybrid/{family}/hybrid/s{pct}", best["hybrid"] * 1e6,
                f"{common};routes={routes};"
                f"traces={_hybrid_stack._cache_size()}"))
            rows.append(csv_row(
                f"hybrid/{family}/margin/s{pct}", 0.0,
                f"hybrid_vs_best={ratio:.3f};noise_band={band:.3f};"
                f"not_slower={_not_slower(ratio, band, same_route)};"
                f"best_static={winner};"
                f"hybrid_is_winner_route={same_route};{common}"))
        rows.append(csv_row(
            f"hybrid/{family}/traces", 0.0,
            f"jit_traces_across_sweep={_hybrid_stack._cache_size()};"
            f"sparsity_points={len(SPARSITIES)};platform={platform}"))
    return rows


# --------------------------------------------------------------- 8-way mesh
def run_mesh(n_shards: int = MESH_SHARDS) -> list[str]:
    """Hybrid vs static pins through `event_op_sharded`: mesh-aware
    resolution on the carried map, per-shard route attribution in the
    report's ``occ_routes`` field."""
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding

    platform = jax.default_backend()
    if len(jax.devices()) < n_shards:
        raise RuntimeError(
            f"mesh sweep needs {n_shards} devices, have {len(jax.devices())}"
            " (run via the suite entry, which re-launches with host"
            " devices forced)")
    mesh = make_mesh((n_shards, 1), ("data", "model"))
    w = jax.random.normal(jax.random.PRNGKey(0), (K_MESH, N_MESH),
                          jnp.float32) * 0.05
    rows = []
    for sparsity in SPARSITIES:
        key = jax.random.PRNGKey(int(sparsity * 1000) + 7)
        s = clustered_spikes(key, M_MESH, K_MESH, sparsity)
        occ = jax.block_until_ready(ops.padded_occupancy(s))
        ref = np.asarray(s @ w)

        # One jitted sharded call per mode, the carried CONCRETE map
        # closed over (the serve convention): resolution runs at trace
        # time on the concrete map, so hybrid's pick — and, on the csr
        # route, the per-shard TRIMMED work lists — bake into the
        # compiled program as constants instead of re-deriving per call.
        # dense2/event2 are fresh jits of the SAME pin: the paired
        # clone-vs-pin ratio measures the executable-instance noise
        # floor the hybrid margin is judged against (see _margin).
        fns, reports = {}, {}
        for mode in ("dense", "event", "hybrid", "dense2", "event2"):
            with _mode_scope(mode.rstrip("2")):
                f = jax.jit(lambda s_, w_: sharding.event_op_sharded(
                    mesh, "spike_matmul", s_, w_, occupancy=occ))
                jax.block_until_ready(f(s, w))       # trace inside scope
                if not mode.endswith("2"):
                    _, reports[mode] = sharding.event_op_sharded(
                        mesh, "spike_matmul", s, w, occupancy=occ,
                        with_report=True)
            fns[mode] = f
        for m in ("dense", "event", "hybrid"):
            np.testing.assert_allclose(np.asarray(fns[m](s, w)), ref,
                                       atol=1e-4)
        best, samples = _time_trio({m: (lambda m=m: fns[m](s, w))
                                    for m in fns},
                                   iters=max(ITERS, 16))
        ratio, band, winner = _margin(samples)
        pct = int(sparsity * 100)
        for mode in ("dense", "event", "hybrid"):
            rep = reports[mode]
            occ_fields = rep["occupancy"].as_fields() \
                if rep["occupancy"] is not None else ""
            rows.append(csv_row(
                f"hybrid-mesh/spike_matmul/{mode}/s{pct}",
                best[mode] * 1e6,
                f"platform={platform};shards={n_shards};"
                f"resolved={rep['attribution']};{occ_fields}"))
        # hybrid_picked_best: hybrid resolved to the backend the faster
        # pin ran. same_hlo makes the tie structural: with a concrete
        # carried map the global pick compiles to the PIN'S OWN program
        # (trimmed csr stack or occupancy-gated dense), so when it is 1
        # any residual hybrid_vs_best is executable-instance noise, not
        # a routing cost.
        same_hlo = int(fns["hybrid"].lower(s, w).as_text()
                       == fns[winner].lower(s, w).as_text())
        rows.append(csv_row(
            f"hybrid-mesh/spike_matmul/margin/s{pct}", 0.0,
            f"hybrid_vs_best={ratio:.3f};noise_band={band:.3f};"
            f"not_slower={_not_slower(ratio, band, same_hlo)};"
            f"hybrid_picked_best="
            f"{int(reports[winner]['backend'] in reports['hybrid']['attribution'])};"
            f"same_hlo={same_hlo};"
            f"platform={platform};shards={n_shards}"))
    return rows


def _mesh_subprocess_rows(n_shards: int = MESH_SHARDS) -> list[str]:
    """Re-launch with forced host devices (the XLA device-count flag is
    process-global and must precede the jax import)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_shards} "
                        "--xla_backend_optimization_level=0")
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.hybrid_sweep", "--mesh",
         "--shards", str(n_shards)],
        capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"hybrid mesh subprocess failed:\n{proc.stderr}")
    return [ln for ln in proc.stdout.splitlines() if ln.strip()]


def run_mesh_rows() -> list[str]:
    if len(jax.devices()) >= MESH_SHARDS:
        return run_mesh()
    return _mesh_subprocess_rows()


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", action="store_true",
                    help="mesh rows only (expects forced host devices)")
    ap.add_argument("--shards", type=int, default=MESH_SHARDS)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_PR6-schema JSON (single-device + "
                         "mesh rows, hybrid route attributions)")
    args = ap.parse_args()
    if args.mesh:
        print("\n".join(run_mesh(args.shards)))
        return
    rows = run()
    mesh_rows = run_mesh_rows()
    print("\n".join(rows + mesh_rows))
    if args.json:
        with dispatch.use_hybrid():
            resolved = dispatch.resolved_backends()
        with open(args.json, "w") as f:
            json.dump({"sweeps": [{
                "requested": dispatch.HYBRID,
                "resolved": resolved,
                "rows": rows + mesh_rows,
            }]}, f, indent=2)


if __name__ == "__main__":
    main()
