"""Synthetic arrival traces for the serve scheduler.

Two canonical shapes, both fully deterministic for a given seed:

- ``poisson``: memoryless arrivals (exponential inter-arrival gaps at a
  target rate) — the steady-traffic baseline every queueing result is
  stated against.
- ``bursty``: arrivals grouped into bursts with long quiet gaps between
  them — the staggered-admission stressor. A burst lands while earlier
  requests are mid-generation, so slots join a busy pool at non-aligned
  positions; this is the trace shape that exposed the shared
  ``pos.max()`` decode bug.

Trace format (the scheduler contract, see kernels README "Serving"):
each entry is a `TraceRequest(rid, arrival_s, prompt, max_new)` with
`arrival_s` relative to replay epoch and monotonically non-decreasing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    rid: int
    arrival_s: float
    prompt: Tuple[int, ...]
    max_new: int


def _prompts(rng: np.random.Generator, n: int, vocab: int,
             prompt_len: Tuple[int, int],
             max_new: Tuple[int, int]) -> List[Tuple[Tuple[int, ...], int]]:
    lens = rng.integers(prompt_len[0], prompt_len[1] + 1, n)
    news = rng.integers(max_new[0], max_new[1] + 1, n)
    return [(tuple(int(t) for t in rng.integers(0, vocab, int(L))), int(m))
            for L, m in zip(lens, news)]


def poisson_trace(seed: int = 0, n_requests: int = 16, rate_hz: float = 50.0,
                  vocab: int = 64, prompt_len: Tuple[int, int] = (4, 12),
                  max_new: Tuple[int, int] = (4, 12)) -> List[TraceRequest]:
    """Memoryless arrivals: exponential gaps at `rate_hz` requests/sec."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, n_requests)
    arrivals = np.cumsum(gaps)
    arrivals -= arrivals[0]                       # first request at t=0
    bodies = _prompts(rng, n_requests, vocab, prompt_len, max_new)
    return [TraceRequest(rid=i, arrival_s=float(t), prompt=p, max_new=m)
            for i, (t, (p, m)) in enumerate(zip(arrivals, bodies))]


def bursty_trace(seed: int = 0, n_requests: int = 16, burst_size: int = 4,
                 burst_gap_s: float = 0.05, intra_gap_s: float = 0.001,
                 vocab: int = 64, prompt_len: Tuple[int, int] = (4, 12),
                 max_new: Tuple[int, int] = (4, 12)) -> List[TraceRequest]:
    """Bursts of `burst_size` near-simultaneous arrivals separated by
    `burst_gap_s` quiet gaps — later bursts land mid-generation, forcing
    non-aligned slot admission."""
    rng = np.random.default_rng(seed)
    arrivals = []
    t = 0.0
    for i in range(n_requests):
        if i and i % burst_size == 0:
            t += burst_gap_s
        arrivals.append(t)
        t += intra_gap_s
    bodies = _prompts(rng, n_requests, vocab, prompt_len, max_new)
    return [TraceRequest(rid=i, arrival_s=float(t), prompt=p, max_new=m)
            for i, (t, (p, m)) in enumerate(zip(arrivals, bodies))]


TRACES = {"poisson": poisson_trace, "bursty": bursty_trace}


def make_trace(name: str, **kw) -> List[TraceRequest]:
    try:
        return TRACES[name](**kw)
    except KeyError:
        raise ValueError(
            f"unknown trace {name!r}; known: {sorted(TRACES)}") from None
