"""Fig. 9 analog: ExSpike (cycle model) vs this host CPU running the same
SpikingFormer-4-256 inference in JAX.

The paper reports 30x lower latency and 7046x higher energy efficiency vs
a Xeon 8470Q. We measure the real JAX-CPU latency here, put it against
the accelerator cycle model, and derive the same ratio structure
(latency ratio, energy ratio assuming 350 W CPU package vs 1.59 W).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.models import spikingformer
from .common import csv_row, time_fn

CPU_POWER_W = 350.0     # Xeon-class package power (paper's comparison)


def run() -> list[str]:
    rows = []
    params = spikingformer.spikingformer_init(jax.random.PRNGKey(0), 4, 256)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 32, 32, 3))
    fn = jax.jit(lambda p, xx: spikingformer.spikingformer_apply(p, xx))
    t_cpu = time_fn(fn, params, x)

    # Accelerator model for the same workload (event stats from this input)
    _, stats = spikingformer.spikingformer_apply(params, x,
                                                 collect_stats=True)
    layers = []
    for i, s in enumerate(stats):
        c = s.shape[-1]
        layers.append(costmodel.fc_layer_cycles(
            f"b{i}", float(jnp.sum(s)), c, 256))
    layers.append(costmodel.sdsa_cycles("sdsa", 64 * 4, 256))
    summ = costmodel.summarize(layers)
    t_acc = summ["latency_ms"] / 1e3

    lat_ratio = t_cpu / max(t_acc, 1e-9)
    energy_ratio = (t_cpu * CPU_POWER_W) / (t_acc * 1.593)
    rows.append(csv_row("fig9/cpu_latency", t_cpu * 1e6,
                        "device=this-host-jax-cpu;batch=1"))
    rows.append(csv_row("fig9/exspike_model_latency", t_acc * 1e6,
                        f"fps={summ['fps']:.0f}"))
    rows.append(csv_row("fig9/latency_ratio", 0.0,
                        f"cpu_over_exspike={lat_ratio:.1f};paper=30.0"))
    rows.append(csv_row("fig9/energy_ratio", 0.0,
                        f"cpu_over_exspike={energy_ratio:.0f};paper=7046"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
