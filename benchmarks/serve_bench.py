"""Serve-scheduler latency/throughput bench — the first serving rows on
the perf ledger (BENCH_PR9.json).

Replays the synthetic traces from `serve_traces` through the
continuous-batching scheduler on the REAL clock and reports per-request
latency (arrival -> terminal) and aggregate tokens/sec:

- traces: poisson (steady) and bursty (staggered admission — the shape
  that exercises non-aligned per-slot positions);
- spiking vs dense (O(d) SDSA slot state vs KV cache);
- single replica vs a 2-replica pool with kernels resolved mesh-aware
  against the host mesh and admission steered by the occupancy load
  signal.

Rows: serve/<trace>/<spiking|dense>/<single|mesh2>, value = p50 latency
in us, derived fields carry p99/tok_s/request count. Latency on CPU is
dominated by the decode-step wall time, so absolute numbers are only
comparable within one platform — the ledger point is the RATIOS
(spiking vs dense, pooled vs single) and the regression baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row
from benchmarks.serve_traces import make_trace
from repro.configs.base import LMConfig, SpikingConfig
from repro.launch.serve import ReplicaPool, Request, Server

# Small but real config: 2-layer GQA transformer, both spiking (SDSA
# status decode) and dense (KV cache) paths exercised.
BENCH_CFG = LMConfig(
    name="serve-bench", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=128, spiking=SpikingConfig(t_steps=1),
    remat="none", loss_chunk=16)

TRACE_KW = dict(n_requests=12, vocab=BENCH_CFG.vocab,
                prompt_len=(4, 12), max_new=(4, 8))
TRACES = ("poisson", "bursty")
N_SLOTS = 4
MAX_SEQ = 64


def _build(topo: str, spiking: bool, mesh):
    kw = dict(n_slots=N_SLOTS, max_seq=MAX_SEQ, spiking=spiking)
    if topo == "single":
        return Server(BENCH_CFG, **kw)
    return ReplicaPool(BENCH_CFG, n_replicas=2, mesh=mesh, **kw)


def _replay(server, trace):
    reqs = []
    for t in trace:
        r = Request(rid=t.rid, prompt=list(t.prompt), max_new=t.max_new)
        server.submit_at(r, t.arrival_s)
        reqs.append(r)
    t0 = time.monotonic()
    server.run_until_drained()
    wall = time.monotonic() - t0
    epoch = server.epoch
    lat = np.array([r.finished_at - (epoch + r.arrival_s) for r in reqs])
    toks = sum(len(r.generated) for r in reqs)
    bad = [r.rid for r in reqs if r.state != "done"]
    return lat, toks, wall, bad


def run() -> list:
    from repro.launch.mesh import make_host_mesh
    platform = jax.default_backend()
    mesh = make_host_mesh()
    rows = []
    for trace_name in TRACES:
        trace = make_trace(trace_name, seed=0, **TRACE_KW)
        for spiking in (True, False):
            mode = "spiking" if spiking else "dense"
            for topo in ("single", "mesh2"):
                # Warmup replay populates the shared jit caches (decode
                # step + per-bucket prefills) so the timed replay
                # measures steady-state serving, not compiles.
                _replay(_build(topo, spiking, mesh), trace)
                lat, toks, wall, bad = _replay(
                    _build(topo, spiking, mesh), trace)
                p50, p99 = np.percentile(lat, [50, 99])
                fields = (f"p99_ms={p99 * 1e3:.2f};"
                          f"tok_s={toks / wall:.1f};"
                          f"requests={len(lat)};failed={len(bad)};"
                          f"platform={platform}")
                rows.append(csv_row(
                    f"serve/{trace_name}/{mode}/{topo}",
                    p50 * 1e6, fields))
    return rows


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_PR9-schema JSON: traces, "
                         "modes, topologies, and the serve rows")
    args = ap.parse_args()
    rows = run()
    print("\n".join(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"platform": jax.default_backend(),
                       "traces": list(TRACES),
                       "modes": ["spiking", "dense"],
                       "topologies": ["single", "mesh2"],
                       "trace_kw": {k: list(v) if isinstance(v, tuple)
                                    else v for k, v in TRACE_KW.items()},
                       "n_slots": N_SLOTS,
                       "metric": "p50 latency us (arrival->terminal); "
                                 "derived: p99_ms, tok_s",
                       "rows": rows}, f, indent=2)


if __name__ == "__main__":
    main()
