"""Guard-policy overhead: audit/repair vs off across the paper sparsities.

Rows ``guard/<payload>/<mode>/s<pct>`` time the same jitted
``spike_matmul`` dispatch (carried occupancy map) traced under each
EXSPIKE_GUARD mode, dense-f32 and uint32-packed payloads, at the
sparsity_sweep levels on its clustered generator. Each row's fields
carry the mode-vs-off ratio judged against the self-measured clone
noise band (`common.time_interleaved` protocol — separately-jitted
clones of the OFF program time 2-7% apart on this host, which is what
"within x%" has to mean here).

The audit-cost contract this pins (kernels/README.md "Guarded
execution"): on the packed path the audit is a per-word popcount
against the map (~1/32 of the dense payload bytes) plus a scalar-gated
NaN-poison epilogue, and must stay within 5% of guard-off at the
paper's 90% sparsity point — the ``headline`` row records that verdict
(``contract=0.05``). Dense-payload audit reads the full payload once
(any-nonzero per tile) and is reported, not bounded. Traces here are
UNWATCHED: no `watch_guard_events` at trace time, so the jitted
programs are effect-free — exactly the production configuration (an
attached host callback would cost ~2x per call; see the guard-policy
notes in kernels/dispatch.py).

Committed as BENCH_PR8.json by the CI guard job.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.spikes import pack_spikes
from repro.kernels import dispatch, ops
from .common import NOISE_BAND_FLOOR, csv_row, noise_band, time_interleaved
from .sparsity_sweep import K, M, N, SPARSITIES, clustered_spikes

HEADLINE_SPARSITY = 0.90
CONTRACT = 0.05              # packed-path audit overhead bound at headline


def _traced(mode: str, packed: bool, x, occ, w):
    """One jitted dispatch traced under `mode` (the guard binds at
    resolution = trace time), warmed on the given operands."""
    kw = {"packed_k": K} if packed else {}

    def f(x_, o_, w_):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            return dispatch.dispatch("spike_matmul", x_, w_,
                                     occupancy=o_, **kw)
    fn = jax.jit(f)
    with dispatch.use_guard(mode):
        jax.block_until_ready(fn(x, occ, w))
    return fn


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    headline: dict[str, str] = {}
    for payload in ("dense", "packed"):
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            s = clustered_spikes(key, M, K, sparsity)
            x = pack_spikes(s) if payload == "packed" else s
            occ = ops.padded_occupancy(s)
            fns = {
                name: (lambda fn=_traced(mode, payload == "packed",
                                         x, occ, w): fn(x, occ, w))
                for name, mode in (("off", "off"), ("audit", "audit"),
                                   ("repair", "repair"), ("off2", "off"),
                                   ("audit2", "audit"))
            }
            best, samples = time_interleaved(fns, iters=24)
            band = noise_band(samples, (("off2", "off"),
                                        ("audit2", "audit")))
            pct = int(sparsity * 100)
            for mode in ("audit", "repair"):
                ratio = best[mode] / best["off"]
                fields = (f"platform={platform};"
                          f"off_us={best['off'] * 1e6:.1f};"
                          f"{mode}_vs_off={ratio:.3f};"
                          f"overhead={ratio - 1.0:+.3f};"
                          f"noise_band={band:.3f}")
                if payload == "packed" and mode == "audit" \
                        and sparsity == HEADLINE_SPARSITY:
                    met = int(ratio - 1.0
                              <= CONTRACT + max(band, NOISE_BAND_FLOOR))
                    fields += f";contract={CONTRACT};contract_met={met}"
                    headline = {"ratio": f"{ratio:.3f}",
                                "band": f"{band:.3f}", "met": str(met)}
                rows.append(csv_row(f"guard/{payload}/{mode}/s{pct}",
                                    best[mode] * 1e6, fields))
    rows.append(csv_row(
        "guard/headline/packed_audit_s90", 0.0,
        f"audit_vs_off={headline.get('ratio', 'nan')};"
        f"noise_band={headline.get('band', 'nan')};contract={CONTRACT};"
        f"contract_met={headline.get('met', '0')};platform={platform}"))
    return rows


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_PR8-schema JSON: the guard "
                         "modes, audited ops, contract verdict, and rows")
    args = ap.parse_args()
    rows = run()
    print("\n".join(rows))
    if args.json:
        head = next(r for r in rows if r.startswith("guard/headline"))
        with open(args.json, "w") as f:
            json.dump({"platform": jax.default_backend(),
                       "guard_modes": list(dispatch.GUARD_MODES),
                       "guarded_ops": list(dispatch.GUARDED_OPS),
                       "support_audited_ops":
                           list(dispatch._SUPPORT_AUDITED_OPS),
                       "contract":
                           {"packed_audit_max_overhead": CONTRACT,
                            "at_sparsity": HEADLINE_SPARSITY,
                            "headline_row": head},
                       "rows": rows}, f, indent=2)


if __name__ == "__main__":
    main()
