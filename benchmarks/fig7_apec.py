"""Fig. 7 reproduction: APEC group-size sweep (G2/G4/G8) on VGG11,
ResNet18, SpikingFormer-4-256, SpikingFormer-2-512 spike maps.

Paper claims: G2 wins everywhere (10.9-14.5% average throughput gain,
1.35-1.62x event reduction); mean |O_G| decays fast with group size
(e.g. 19.08 -> 6.82 -> 2.92 on SpikingFormer-4-256).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import apec, costmodel
from .common import (csv_row, resnet18_spike_maps, spikingformer_spike_maps,
                     vgg11_spike_maps)

GROUPS = (2, 4, 8)


def _flatten_positions(s: jnp.ndarray) -> jnp.ndarray:
    """(T,B,H,W,C)/(T,B,N,C) -> (P, C) position-major spike matrix."""
    c = s.shape[-1]
    return s.reshape(-1, c)


def _bench_model(name: str, spike_maps, co_k=(64, 3)) -> list[str]:
    rows = []
    co, k = co_k
    for g in GROUPS:
        tot_before = tot_after = tot_overlap = 0.0
        n_groups_total = 0.0
        speedups = []
        for s in spike_maps:
            flat = _flatten_positions(s)
            p = flat.shape[0] - flat.shape[0] % g
            st = apec.apec_stats(flat[:p], g)
            tot_before += float(st.events_before)
            tot_after += float(st.events_after)
            tot_overlap += float(st.eliminated) / (g - 1)
            n_groups_total += p / g
            base = costmodel.conv_layer_cycles(
                "l", float(st.events_before), p, 32, 32, flat.shape[1],
                co, k)
            compressed = costmodel.conv_layer_cycles(
                "l", float(st.events_before), p, 32, 32, flat.shape[1],
                co, k, apec_group=g,
                apec_eliminated=float(st.eliminated),
                apec_overlap_positions=float(st.groups_with_overlap))
            speedups.append(base.total / max(compressed.total, 1.0))
        red = tot_before / max(tot_after, 1.0)
        mean_og = tot_overlap / max(n_groups_total, 1.0)
        mean_speedup = sum(speedups) / len(speedups)
        rows.append(csv_row(
            f"fig7/{name}/G{g}", 0.0,
            f"event_reduction={red:.2f}x;mean_overlap={mean_og:.2f};"
            f"throughput_speedup={mean_speedup:.3f}"))
    return rows


def run() -> list[str]:
    rows = []
    _, _, vgg_maps = vgg11_spike_maps()
    rows += _bench_model("vgg11", vgg_maps)
    _, _, res_maps = resnet18_spike_maps()
    rows += _bench_model("resnet18", res_maps)
    _, sf4 = spikingformer_spike_maps(4, 256)
    rows += _bench_model("spikingformer-4-256", sf4, co_k=(256, 1))
    _, sf2 = spikingformer_spike_maps(2, 512)
    rows += _bench_model("spikingformer-2-512", sf2, co_k=(512, 1))
    # Verdict row: does G2 dominate (the paper's conclusion)?
    rows.append(csv_row("fig7/verdict", 0.0,
                        "expected=G2-best-overlap-decays-with-g"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
