"""Shared benchmark utilities: timers, spike-stat collection, CSV rows."""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.models import cnn, spikingformer


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ------------------------------------------------- spike map collection
def vgg11_spike_maps(batch: int = 4, seed: int = 0):
    """(cfg, params, per-conv-layer spike tensors) on synthetic images."""
    from repro.data.synthetic import class_images
    cfg = CNNConfig(name="vgg11", layers=cnn.VGG11_LAYERS)
    params = cnn.vgg11_init(cfg, jax.random.PRNGKey(seed))
    imgs = jnp.asarray(class_images(seed, 0, 0, batch)["image"])
    _, stats = cnn.vgg11_apply(cfg, params, imgs, collect_stats=True)
    return cfg, params, stats


def resnet18_spike_maps(batch: int = 4, seed: int = 0):
    from repro.data.synthetic import class_images
    cfg = CNNConfig(name="resnet18", layers=())
    params = cnn.resnet18_init(cfg, jax.random.PRNGKey(seed))
    imgs = jnp.asarray(class_images(seed, 0, 0, batch)["image"])
    _, stats = cnn.resnet18_apply(cfg, params, imgs, collect_stats=True)
    return cfg, params, stats


def spikingformer_spike_maps(depth: int, dim: int, batch: int = 4,
                             seed: int = 0):
    from repro.configs.base import SpikingConfig
    from repro.data.synthetic import class_images
    params = spikingformer.spikingformer_init(
        jax.random.PRNGKey(seed), depth, dim)
    imgs = jnp.asarray(class_images(seed, 0, 0, batch)["image"])
    # v_th=0.5: untrained weights under-drive deep encoder blocks; the
    # lower threshold yields trained-network-like activity levels for the
    # event statistics (the paper measures trained models).
    _, stats = spikingformer.spikingformer_apply(
        params, imgs, collect_stats=True,
        spiking_cfg=SpikingConfig(t_steps=4, lif_vth=0.5))
    return params, stats
