"""Shared benchmark utilities: timers, spike-stat collection, CSV rows."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.models import cnn, spikingformer


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ----------------------------------------- paired interleaved min-of-N
# The one noise-band timing protocol every paired sweep uses (hybrid,
# e2e, packed): candidate routes whose difference is an order of
# magnitude below their totals can only be compared under identical
# load, so samples are INTERLEAVED with the order ROTATED per round (no
# route keeps the first-in-round cache advantage, host drift is
# common-mode), and each route reports its MINIMUM — this host's cgroup
# scheduling inserts multi-ms stalls that corrupt means and medians,
# while the per-route minimum is the reproducible unthrottled cost.
# "Not slower" is then judged against a SELF-MEASURED noise band: the
# paired-median deviation identical-program clone pairs show in the same
# rounds (separately-jitted copies of the same HLO land 0.2-7% apart on
# this host depending on instance placement and quota phase).

NOISE_BAND_FLOOR = 0.02   # clone pairs never resolve tighter than ~2%


def time_interleaved(fns: dict, *args, iters: int = 24,
                     warmup: int = 2) -> tuple[dict, dict]:
    """Per-name (min seconds, all samples) for a dict of callables, each
    invoked as fn(*args), interleaved with rotating order per round."""
    names = list(fns)
    for _ in range(warmup):
        for n in names:
            jax.block_until_ready(fns[n](*args))
    samples: dict = {n: [] for n in names}
    for i in range(iters):
        order = names[i % len(names):] + names[:i % len(names)]
        for n in order:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[n](*args))
            samples[n].append(time.perf_counter() - t0)
    return {n: min(v) for n, v in samples.items()}, samples


def time_pair(fn_a: Callable, fn_b: Callable, *args, iters: int = 24,
              warmup: int = 2) -> tuple[float, float, float]:
    """Two-route special case: (min_a, min_b, min_b/min_a). Rotation over
    two names IS per-round order alternation."""
    mins, _ = time_interleaved({"a": fn_a, "b": fn_b}, *args, iters=iters,
                               warmup=warmup)
    return mins["a"], mins["b"], mins["b"] / mins["a"]


def paired_median_ratio(samples: dict, a: str, b: str) -> float:
    """Median of per-round t_a/t_b ratios — within a round the routes run
    back-to-back, so drift cancels; the median kills one-sided stall
    outliers (a min-of-ratios would credit `a` whenever `b` caught the
    stall)."""
    r = sorted(x / y for x, y in zip(samples[a], samples[b]))
    return r[len(r) // 2]


def noise_band(samples: dict, clone_pairs) -> float:
    """Largest paired-median deviation-from-1 the identical-program clone
    pairings show in the same rounds — what "not slower" has to mean on
    this host. `clone_pairs`: (clone_name, pinned_name) tuples; one clone
    alone underestimates the band half the time (its own deviation can
    land BELOW 1)."""
    return max(abs(paired_median_ratio(samples, c, p) - 1.0)
               for c, p in clone_pairs)


def not_slower(ratio: float, band: float, identical: int = 0) -> int:
    """1 when `ratio` is within the measured band (floored at
    NOISE_BAND_FLOOR) of 1.0, or when `identical` gives structural proof
    the two programs are the same executable (route/HLO identity) — two
    instances of one program can still measure a few % apart from
    placement luck, which is not a routing loss."""
    return int(ratio <= 1.0 + max(band, NOISE_BAND_FLOOR) or identical)


# ------------------------------------------------- spike map collection
def vgg11_spike_maps(batch: int = 4, seed: int = 0):
    """(cfg, params, per-conv-layer spike tensors) on synthetic images."""
    from repro.data.synthetic import class_images
    cfg = CNNConfig(name="vgg11", layers=cnn.VGG11_LAYERS)
    params = cnn.vgg11_init(cfg, jax.random.PRNGKey(seed))
    imgs = jnp.asarray(class_images(seed, 0, 0, batch)["image"])
    _, stats = cnn.vgg11_apply(cfg, params, imgs, collect_stats=True)
    return cfg, params, stats


def resnet18_spike_maps(batch: int = 4, seed: int = 0):
    from repro.data.synthetic import class_images
    cfg = CNNConfig(name="resnet18", layers=())
    params = cnn.resnet18_init(cfg, jax.random.PRNGKey(seed))
    imgs = jnp.asarray(class_images(seed, 0, 0, batch)["image"])
    _, stats = cnn.resnet18_apply(cfg, params, imgs, collect_stats=True)
    return cfg, params, stats


def spikingformer_spike_maps(depth: int, dim: int, batch: int = 4,
                             seed: int = 0):
    from repro.configs.base import SpikingConfig
    from repro.data.synthetic import class_images
    params = spikingformer.spikingformer_init(
        jax.random.PRNGKey(seed), depth, dim)
    imgs = jnp.asarray(class_images(seed, 0, 0, batch)["image"])
    # v_th=0.5: untrained weights under-drive deep encoder blocks; the
    # lower threshold yields trained-network-like activity levels for the
    # event statistics (the paper measures trained models).
    _, stats = spikingformer.spikingformer_apply(
        params, imgs, collect_stats=True,
        spiking_cfg=SpikingConfig(t_steps=4, lif_vth=0.5))
    return params, stats
