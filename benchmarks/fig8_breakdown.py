"""Fig. 8 reproduction: cycle-level latency breakdown (Weight / Buffer /
Calc) for SpikingFormer-2-512 blocks, Baseline vs APEC-2.

Paper observation: APEC-2 cuts Calc cycles but inflates Weight cycles
(overlap stream re-reads weights), so event reduction does not always
translate into end-to-end gains — APEC pays off for computation-bound
blocks with strong adjacent overlap.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import apec, costmodel
from .common import csv_row, spikingformer_spike_maps


def run() -> list[str]:
    rows = []
    _, maps = spikingformer_spike_maps(2, 512)
    block_names = ["sps0", "sps1", "sps2", "sps3",
                   "enc0.ssa", "enc0.ffn", "enc1.ssa", "enc1.ffn"]
    for name, s in zip(block_names, maps):
        c = s.shape[-1]
        flat = s.reshape(-1, c)
        p = flat.shape[0] - flat.shape[0] % 2
        st = apec.apec_stats(flat[:p], 2)
        base = costmodel.conv_layer_cycles(
            name, float(st.events_before), p, 32, 32, c, 512, 1)
        comp = costmodel.conv_layer_cycles(
            name, float(st.events_before), p, 32, 32, c, 512, 1,
            apec_group=2, apec_eliminated=float(st.eliminated),
            apec_overlap_positions=float(st.groups_with_overlap))
        rows.append(csv_row(
            f"fig8/{name}/baseline", base.total,
            f"weight={base.weight:.0f};buffer={base.buffer:.0f};"
            f"calc={base.calc:.0f}"))
        rows.append(csv_row(
            f"fig8/{name}/apec2", comp.total,
            f"weight={comp.weight:.0f};buffer={comp.buffer:.0f};"
            f"calc={comp.calc:.0f};"
            f"calc_saved={base.calc - comp.calc:.0f};"
            f"weight_added={comp.weight - base.weight:.0f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
