"""Registry sweep: wall time of every (op x backend) pair on this host.

Rows: ``backend/<op>/<backend>,us_per_call,...`` — the measured (not
asserted) side of the dispatch registry. New kernels show up here the
moment they register, exactly like they show up in the parity harness.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from .common import csv_row, time_fn

# Larger-than-parity shapes so per-call time is signal, not overhead.
_BENCH_SHAPES = {
    "lif_scan": lambda key: ((jax.random.normal(key, (8, 64, 256)) * 2,), {}),
    "spike_matmul": lambda key: (
        ((jax.random.uniform(key, (256, 512)) < 0.1).astype("float32"),
         jax.random.normal(jax.random.PRNGKey(1), (512, 256), "float32")), {}),
    "apec_matmul": lambda key: (
        ((jax.random.uniform(key, (256, 256)) < 0.3).astype("float32"),
         jax.random.normal(jax.random.PRNGKey(1), (256, 128), "float32")),
        {"g": 2}),
    "sdsa": lambda key: (
        tuple((jax.random.uniform(k, (8, 128, 64)) < 0.3).astype("float32")
              for k in jax.random.split(key, 3)), {"mode": "or"}),
    "causal_sdsa": lambda key: (
        tuple((jax.random.uniform(k, (4, 2, 4, 128, 64)) < 0.3)
              .astype("float32") for k in jax.random.split(key, 3)),
        {"mode": "or"}),
    "econv": lambda key: (
        ((jax.random.uniform(key, (4, 32, 32, 16)) < 0.15).astype("float32"),
         jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 32), "float32")),
        {}),
    "tconv": lambda key: (
        ((jax.random.uniform(key, (4, 16, 16, 32)) < 0.15).astype("float32"),
         jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 16), "float32")),
        {"stride": 2}),
}


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    for op in dispatch.op_names():
        make = _BENCH_SHAPES.get(op)
        if make is None:
            args, kwargs = dispatch.example_inputs(op, jax.random.PRNGKey(0))
        else:
            args, kwargs = make(jax.random.PRNGKey(0))
        for be in dispatch.backend_names(op):
            backend = dispatch.get_backend(op, be)
            if platform not in backend.platforms:
                continue
            if backend.supports is not None \
                    and backend.supports(*args, **kwargs) is not None:
                continue
            # kwargs (g, mode, ...) are Python-level statics: close over them
            fn = jax.jit(functools.partial(backend.fn, **kwargs))
            t = time_fn(fn, *args)
            rows.append(csv_row(
                f"backend/{op}/{be}", t * 1e6,
                f"platform={platform};"
                f"default={dispatch.resolve_name(op, *args, **kwargs)}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
