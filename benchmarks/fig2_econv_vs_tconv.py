"""Fig. 2 reproduction: layer-wise TConv vs EConv cost + input sparsity on
VGG11 (direct-coded, synthetic CIFAR-shaped inputs).

Paper claims: EConv beats TConv in every layer, up to 97% latency
reduction, 88% average; higher sparsity -> larger speedup. We report the
cost-model cycle counts for both dataflows (the FPGA economics) plus
measured CPU wall time of the two JAX formulations on one layer as a
sanity anchor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import costmodel, econv
from repro.models.cnn import VGG11_LAYERS
from .common import csv_row, time_fn, vgg11_spike_maps


def run() -> list[str]:
    rows = []
    cfg, params, stats = vgg11_spike_maps(batch=4)
    conv_specs = [l for l in VGG11_LAYERS if l.kind == "conv"]
    t = cfg.spiking.t_steps
    avg_reductions = []
    for i, (layer, s) in enumerate(zip(conv_specs, stats)):
        # s: (T, B, H, W, C_out) spikes of this layer == input of next;
        # layer i's INPUT spikes are stats[i-1] (first layer: direct-coded)
        if i == 0:
            continue  # input is multi-bit (OPT1 handles it) — skip ratio
        s_in = stats[i - 1]
        t_, b, h, w, ci = s_in.shape
        co = layer.out_ch
        sparsity = 1.0 - float(jnp.mean(s_in))
        n_events = float(jnp.sum(s_in)) / b          # per image, all T
        tcycles = costmodel.conv_layer_cycles(
            f"conv{i}", n_events=h * w * ci * t_,    # dense: every site
            n_unique_positions=h * w * t_, h=h, w=w, ci=ci, co=co, k=3)
        ecycles = costmodel.conv_layer_cycles(
            f"conv{i}", n_events=n_events,
            n_unique_positions=min(n_events, h * w * t_),
            h=h, w=w, ci=ci, co=co, k=3)
        reduction = 1.0 - ecycles.total / max(tcycles.total, 1)
        avg_reductions.append(reduction)
        rows.append(csv_row(
            f"fig2/conv{i}", ecycles.total,
            f"sparsity={sparsity:.3f};tconv_cycles={tcycles.total:.0f};"
            f"econv_cycles={ecycles.total:.0f};latency_reduction={reduction:.3f}"))

    # Measured wall-time anchor on one mid layer (tconv vs event scatter).
    s_small = (jax.random.uniform(jax.random.PRNGKey(0), (1, 16, 16, 32))
               < 0.15).astype(jnp.float32)
    w_small = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 32, 64))
    t_t = time_fn(jax.jit(econv.tconv), s_small, w_small)
    n_ev = int(jnp.sum(s_small))
    t_e = time_fn(jax.jit(lambda s, w: econv.econv_scatter(
        s, w, max_events=1024)), s_small, w_small)
    rows.append(csv_row("fig2/measured_tconv", t_t * 1e6,
                        f"events={n_ev};formulation=dense"))
    rows.append(csv_row("fig2/measured_econv_scatter", t_e * 1e6,
                        "note=event-list form; CPU anchor, not TPU perf"))
    mean_red = sum(avg_reductions) / max(len(avg_reductions), 1)
    rows.append(csv_row("fig2/avg_latency_reduction", 0.0,
                        f"mean={mean_red:.3f};paper=0.88"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
