"""Whole-network forward: carried occupancy (EventTensor) vs re-derive.

The PR 3/4 sweeps timed single ops; this suite times the thing the
full-event pipeline actually changes — a whole multi-layer forward where
every spiking layer's metadata either (a) is re-derived by each consumer
from the dense activation it was just handed (`rederive`: the pre-PR 5
model behavior) or (b) flows from the producer as an `EventTensor`
(`carried`: the fused LIF emits the map, convs propagate it through
im2col on tile granularity, matmuls consume it directly).

Layer stacks mirror the two model families' event-hot shapes (the paper's
SCNN convs and the SpikingFormer SPS + FFN); each layer's drive is
clustered-event spikes pinned at the sweep sparsity (the
`sparsity_sweep.clustered_spikes` generator — LIF with v_th=1 fires a
{0,1}*v_th drive back out exactly, so per-layer sparsity is controlled at
the PR 3 points instead of drifting with untrained weights). Both
variants run the same kernels (`pallas-csr` family) on identical spike
values — the measured delta is purely the metadata plumbing: the
consumer-side dense `tile_occupancy` passes (kh*kw-fold on im2col
patches) the carried route deletes, minus the producer-side emission it
adds.

Rows: ``e2e_event/<family>/<carried|rederive>/s<pct>`` with the network
total, per-layer pre-pass share columns (``prepass_share_<layer>``: the
fraction of the re-derive total each layer's standalone pre-pass eats,
measured on that layer's actual consumer operand), and a
``e2e_event/<family>/speedup/s<pct>`` row (rederive/carried). Committed
as BENCH_PR5.json by CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.events import EventTensor
from repro.core.lif import LIFConfig
from repro.core.spikes import build_csr
from repro.kernels import dispatch, ops
from repro.models.layers import lif_fire_events
from .common import (csv_row, noise_band, not_slower, time_fn,
                     time_interleaved, time_pair)
from .sparsity_sweep import SPARSITIES, clustered_spikes

LIF = LIFConfig()        # v_th=1.0: a {0,1} drive fires itself back out

# (name, kind, drive shape (T, B, ...), weight shape). Conv layers are the
# event-hot part of both families: their re-derive pre-pass reads the
# kh*kw-times-larger im2col patch tensor (K = 9*C at 3x3).
FAMILIES = {
    "cnn": (           # VGG event-hot tail (8x8x128 convs) + EAFC-style
                       # fused fc head, T=2
        ("conv1", "conv", (2, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("conv2", "conv", (2, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("conv3", "conv", (2, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("fc_head", "matmul", (2, 2, 64, 512), (512, 128)),
    ),
    "spikingformer": (                        # SPS tail + encoder FFN, T=4
        ("sps_conv", "conv", (4, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("fc1", "matmul", (4, 2, 64, 512), (512, 128)),
        ("fc2", "matmul", (4, 2, 64, 512), (512, 128)),
    ),
}
ITERS = 24   # CPU wall-clock needs more samples than the op sweeps


def _time_min(fn, *args, iters=ITERS, warmup=2):
    """Best-of-N wall seconds (stable for the small pre-pass probes)."""
    mins, _ = time_interleaved({"fn": fn}, *args, iters=iters, warmup=warmup)
    return mins["fn"]


def _time_pair(fn_a, fn_b, *args, iters=ITERS, warmup=2):
    """Paired interleaved min-of-N via the shared protocol
    (`common.time_pair` — one implementation for this sweep and the
    hybrid trio timer). Returns (min_a, min_b, min_b/min_a)."""
    return time_pair(fn_a, fn_b, *args, iters=iters, warmup=warmup)


def _stage_drive(key, kind, shape, sparsity):
    t = shape[0]
    k = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    pattern = clustered_spikes(key, rows, k, sparsity, block_m=128,
                               block_k=min(128, k))
    return (pattern * LIF.v_th).reshape(shape)


def _consume(kind, s, w):
    """The layer's event op on spikes-or-EventTensor (csr family pinned
    by the caller): conv folds (T, B) into the batch like models/cnn."""
    if kind == "conv":
        from repro.core.econv import econv
        flat = s.reshape((-1,) + s.shape[2:])
        return econv(flat, w)
    return dispatch.spike_matmul(s, w)


# Jitted producers (one compile per drive shape): the fire stage is the
# same compiled scan in both variants — `carried` additionally emits the
# map inside the same jit, `rederive` leaves the consumer to re-derive it
# eagerly from the dense spikes (the serve-path calling convention, where
# concrete maps buy the trimmed eager CSR grid).
@jax.jit
def _produce_carried(drive):
    return lif_fire_events(drive, LIF)


@jax.jit
def _produce_dense(drive):
    return dispatch.lif_scan(drive)


@jax.jit
def _produce_packed(drive):
    # uint32 words as the canonical payload: packing fused into the same
    # emission pass that popcounts the occupancy map (no f32 spike tensor
    # leaves the fire stage)
    return lif_fire_events(drive, LIF, packed=True)


def _forward(drives, stages, carried: bool):
    outs = []
    for (name, kind, _, w), drive in zip(stages, drives):
        s = _produce_carried(drive) if carried else _produce_dense(drive)
        outs.append(_consume(kind, s, w))
    return outs


def _forward_packed(drives, stages):
    """The packed pipeline: every fire stage emits a packed-only
    EventTensor and every consumer unpacks VMEM-resident in-kernel."""
    outs = []
    for (name, kind, _, w), drive in zip(stages, drives):
        outs.append(_consume(kind, _produce_packed(drive), w))
    return outs


def _layer_prepass_seconds(kind, drive, w):
    """What the re-derive route pays per call for THIS layer: the dense
    `tile_occupancy` read of the consumer operand (im2col patches for
    convs) plus the eager CSR compaction."""
    s = _produce_dense(drive)
    if kind == "conv":
        flat = s.reshape((-1,) + s.shape[2:])
        kh, kw = w.shape[:2]
        operand = jax.lax.conv_general_dilated_patches(
            flat, (kh, kw), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        operand = operand.reshape(-1, operand.shape[-1])
    else:
        operand = s.reshape(-1, s.shape[-1])

    def prepass(x):
        return build_csr(ops.padded_occupancy(x), 128, 128)

    return _time_min(prepass, operand)


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    csr = "pallas-csr" if platform == "tpu" else "pallas-csr-interpret"
    for family, spec in FAMILIES.items():
        stages = [(n, kind, shape,
                   jax.random.normal(jax.random.PRNGKey(i + 1),
                                     wshape, jnp.float32) * 0.05)
                  for i, (n, kind, shape, wshape) in enumerate(spec)]
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            drives = [
                _stage_drive(jax.random.fold_in(key, i), kind, shape,
                             sparsity)
                for i, (_, kind, shape, _w) in enumerate(stages)]
            with dispatch.use_backend(csr, op="spike_matmul"), \
                    dispatch.use_backend(csr, op="econv"):
                # value parity guard: same spikes, same kernels — the two
                # routes must agree before their timings mean anything
                for oc, od in zip(_forward(drives, stages, True),
                                  _forward(drives, stages, False)):
                    np.testing.assert_allclose(np.asarray(oc),
                                               np.asarray(od), atol=1e-4)
                # Per-layer paired timing, summed to the network total:
                # each layer's two routes are measured interleaved under
                # identical cache/scheduler conditions (a monolithic
                # whole-pipeline call lets allocator/cache interactions
                # between unrelated layers leak into the few-ms metadata
                # delta being measured).
                t_carried = t_rederive = 0.0
                fields = []
                for stage, d in zip(stages, drives):
                    a, b, _ = _time_pair(
                        lambda dd, st=stage: _forward([dd], [st], True),
                        lambda dd, st=stage: _forward([dd], [st], False), d)
                    t_carried += a * 1e6
                    t_rederive += b * 1e6
                    name, kind, _, w = stage
                    pre = _layer_prepass_seconds(kind, d, w) * 1e6
                    fields.append((name, a * 1e6, b * 1e6, pre))
                shares = ";".join(
                    f"prepass_share_{name}="
                    f"{pre / max(t_rederive, 1e-9):.3f}"
                    for name, _, _, pre in fields)
                layer_cols = ";".join(
                    f"us_{name}={ca:.0f}/{re:.0f}"
                    for name, ca, re, _ in fields)
            pct = int(sparsity * 100)
            common = f"platform={platform};backend={csr};layers={len(stages)}"
            rows.append(csv_row(f"e2e_event/{family}/carried/s{pct}",
                                t_carried, f"{common};occupancy=carried"))
            rows.append(csv_row(f"e2e_event/{family}/rederive/s{pct}",
                                t_rederive,
                                f"{common};occupancy=rederived;{shares};"
                                f"{layer_cols}"))
            rows.append(csv_row(
                f"e2e_event/{family}/speedup/s{pct}", 0.0,
                f"carried_speedup="
                f"{t_rederive / max(t_carried, 1e-9):.3f};{common}"))
    return rows


# ----------------------------------------------- packed payload (PR 7)
def _consumer_operand(kind, s_dense, w):
    """The (R, K) matrix the layer's matmul-form kernel actually tiles:
    im2col patches for convs (K = kh*kw*C), the flattened spikes for
    matmuls — the operand whose occupancy map prices the bytes ledger."""
    if kind == "conv":
        flat = s_dense.reshape((-1,) + s_dense.shape[2:])
        kh, kw = w.shape[:2]
        operand = jax.lax.conv_general_dilated_patches(
            flat, (kh, kw), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return operand.reshape(-1, operand.shape[-1]), w.shape[-1]
    return s_dense.reshape(-1, s_dense.shape[-1]), w.shape[-1]


def _stack_bytes(stages, drives):
    """Modeled HBM bytes over the stack, f32-csr vs packed-csr: emission
    writes (`costmodel.spike_payload_bytes`) + consumer spike-tile reads
    (`costmodel.matmul_bytes_moved`), with the payload-invariant weight/
    output traffic kept separate — both routes run the SAME trimmed grid,
    so only the event-payload stream responds to packing."""
    spike = {"f32": 0.0, "packed": 0.0}
    weight = out = 0.0
    for (name, kind, shape, w), drive in zip(stages, drives):
        s = _produce_dense(drive)
        operand, n = _consumer_operand(kind, s, w)
        occ = np.asarray(ops.padded_occupancy(operand, 128, 128))
        rows_emit = int(np.prod(shape[:-1]))
        for payload, backend in (("f32", "pallas-csr"),
                                 ("packed", "packed-csr")):
            bm = costmodel.matmul_bytes_moved(occ, n, backend=backend)
            spike[payload] += bm.spike_hbm + costmodel.spike_payload_bytes(
                rows_emit, shape[-1],
                "dense" if payload == "f32" else "packed")
        weight += bm.weight_hbm
        out += bm.out_hbm
    return spike, weight, out


def run_packed() -> list[str]:
    """Packed uint32 pipeline vs the f32 CSR pipeline, same stacks.

    Rows per (family, sparsity):
      ``e2e_event/<family>/f32csr/s<pct>``   stack produce+consume us,
          dense f32 spikes through the pallas-csr family.
      ``e2e_event/<family>/packed/s<pct>``   same stack with packed
          emission and packed-csr consumers; ``routes=`` asserts every
          consume resolved to the packed family (no silent densify).
      ``e2e_event/<family>/packed_margin/s<pct>``  paired ratio vs the
          self-measured clone noise band (the hybrid suite's protocol).
      ``e2e_event/<family>/bytes/s<pct>``    the modeled bytes-moved
          ledger: event-payload HBM traffic (emission writes + spike-tile
          reads) per payload, reduction, and the payload-invariant
          weight/output traffic alongside. Committed as BENCH_PR7.json.
    """
    rows = []
    platform = jax.default_backend()
    tpu = platform == "tpu"
    csr = "pallas-csr" if tpu else "pallas-csr-interpret"
    pcsr = "packed-csr" if tpu else "packed-csr-interpret"

    def f32_scope():
        import contextlib
        ctx = contextlib.ExitStack()
        for op in ("spike_matmul", "econv"):
            ctx.enter_context(dispatch.use_backend(csr, op=op))
        return ctx

    def packed_scope():
        import contextlib
        ctx = contextlib.ExitStack()
        for op in ("spike_matmul", "econv"):
            ctx.enter_context(dispatch.use_backend(pcsr, op=op))
        return ctx

    for family, spec in FAMILIES.items():
        stages = [(n, kind, shape,
                   jax.random.normal(jax.random.PRNGKey(i + 1),
                                     wshape, jnp.float32) * 0.05)
                  for i, (n, kind, shape, wshape) in enumerate(spec)]
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            drives = [
                _stage_drive(jax.random.fold_in(key, i), kind, shape,
                             sparsity)
                for i, (_, kind, shape, _w) in enumerate(stages)]
            # parity guard: the packed route must match the f32 oracle
            # before its timings mean anything, and every consume must
            # ATTRIBUTE to the packed family (never a silent densify)
            with f32_scope():
                ref = _forward(drives, stages, True)
            with dispatch.watch_resolutions() as recs, packed_scope():
                outs = _forward_packed(drives, stages)
            for a, b in zip(ref, outs):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-4)
            picked = [r["attribution"].split("<-")[0] for r in recs
                      if r["op"] in ("spike_matmul", "econv")]
            assert picked and all(p == pcsr for p in picked), \
                f"packed consume leaked off the packed family: {picked}"
            routes = ":".join(sorted(set(picked)))

            # per-layer paired timing (the hybrid suite's protocol):
            # modes interleaved per layer, per-(layer, mode) minimums
            # summed; f32b/packedb re-run the same pins — their sums
            # against the originals are the measured noise floor.
            modes = ("f32", "packed", "f32b", "packedb")
            sums = {m: 0.0 for m in modes}
            for stage, d in zip(stages, drives):
                def one(m, st=stage, dd=d):
                    if m.startswith("f32"):
                        with f32_scope():
                            return _forward([dd], [st], True)
                    with packed_scope():
                        return _forward_packed([dd], [st])
                layer_best, _ = time_interleaved(
                    {m: (lambda m=m: one(m)) for m in modes}, iters=ITERS)
                for m in modes:
                    sums[m] += layer_best[m]
            ratio = sums["packed"] / sums["f32"]
            band = max(abs(sums["f32b"] / sums["f32"] - 1.0),
                       abs(sums["packedb"] / sums["packed"] - 1.0))

            spike, weight_b, out_b = _stack_bytes(stages, drives)
            mb = 1.0 / 2**20
            pct = int(sparsity * 100)
            common = f"platform={platform};layers={len(stages)}"
            rows.append(csv_row(f"e2e_event/{family}/f32csr/s{pct}",
                                sums["f32"] * 1e6,
                                f"{common};backend={csr}"))
            rows.append(csv_row(f"e2e_event/{family}/packed/s{pct}",
                                sums["packed"] * 1e6,
                                f"{common};backend={pcsr};routes={routes}"))
            rows.append(csv_row(
                f"e2e_event/{family}/packed_margin/s{pct}", 0.0,
                f"packed_vs_f32={ratio:.3f};noise_band={band:.3f};"
                f"not_slower={not_slower(ratio, band)};{common}"))
            rows.append(csv_row(
                f"e2e_event/{family}/bytes/s{pct}", 0.0,
                f"spike_mb_f32={spike['f32'] * mb:.3f};"
                f"spike_mb_packed={spike['packed'] * mb:.3f};"
                f"bytes_reduction={spike['f32'] / spike['packed']:.1f};"
                f"weight_mb={weight_b * mb:.3f};out_mb={out_b * mb:.3f};"
                f"total_mb_f32={(spike['f32'] + weight_b + out_b) * mb:.3f};"
                f"total_mb_packed="
                f"{(spike['packed'] + weight_b + out_b) * mb:.3f};"
                f"total_reduction="
                f"{(spike['f32'] + weight_b + out_b) / (spike['packed'] + weight_b + out_b):.2f};"
                f"{common}"))
    return rows


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--packed", action="store_true",
                    help="packed-payload rows (e2e packed pipeline + "
                         "single-op packed sparsity sweep) instead of the "
                         "carried-vs-rederive suite")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="(with --packed) write BENCH_PR7-schema JSON: "
                         "packed-route resolution + the bytes-moved rows")
    args = ap.parse_args()
    if not args.packed:
        print("\n".join(run()))
        return
    from .sparsity_sweep import run_packed as run_packed_ops
    rows = run_packed_ops() + run_packed()
    print("\n".join(rows))
    if args.json:
        pcsr = ("packed-csr" if jax.default_backend() == "tpu"
                else "packed-csr-interpret")
        with dispatch.use_backend(pcsr, op="spike_matmul"), \
                dispatch.use_backend(pcsr, op="apec_matmul"), \
                dispatch.use_backend(pcsr, op="econv"):
            resolved = dispatch.resolved_backends()
        with open(args.json, "w") as f:
            json.dump({"sweeps": [{
                "requested": pcsr,
                "resolved": resolved,
                "rows": rows,
            }]}, f, indent=2)


if __name__ == "__main__":
    main()
