"""Whole-network forward: carried occupancy (EventTensor) vs re-derive.

The PR 3/4 sweeps timed single ops; this suite times the thing the
full-event pipeline actually changes — a whole multi-layer forward where
every spiking layer's metadata either (a) is re-derived by each consumer
from the dense activation it was just handed (`rederive`: the pre-PR 5
model behavior) or (b) flows from the producer as an `EventTensor`
(`carried`: the fused LIF emits the map, convs propagate it through
im2col on tile granularity, matmuls consume it directly).

Layer stacks mirror the two model families' event-hot shapes (the paper's
SCNN convs and the SpikingFormer SPS + FFN); each layer's drive is
clustered-event spikes pinned at the sweep sparsity (the
`sparsity_sweep.clustered_spikes` generator — LIF with v_th=1 fires a
{0,1}*v_th drive back out exactly, so per-layer sparsity is controlled at
the PR 3 points instead of drifting with untrained weights). Both
variants run the same kernels (`pallas-csr` family) on identical spike
values — the measured delta is purely the metadata plumbing: the
consumer-side dense `tile_occupancy` passes (kh*kw-fold on im2col
patches) the carried route deletes, minus the producer-side emission it
adds.

Rows: ``e2e_event/<family>/<carried|rederive>/s<pct>`` with the network
total, per-layer pre-pass share columns (``prepass_share_<layer>``: the
fraction of the re-derive total each layer's standalone pre-pass eats,
measured on that layer's actual consumer operand), and a
``e2e_event/<family>/speedup/s<pct>`` row (rederive/carried). Committed
as BENCH_PR5.json by CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import EventTensor
from repro.core.lif import LIFConfig
from repro.core.spikes import build_csr
from repro.kernels import dispatch, ops
from repro.models.layers import lif_fire_events
from .common import csv_row, time_fn
from .sparsity_sweep import SPARSITIES, clustered_spikes

LIF = LIFConfig()        # v_th=1.0: a {0,1} drive fires itself back out

# (name, kind, drive shape (T, B, ...), weight shape). Conv layers are the
# event-hot part of both families: their re-derive pre-pass reads the
# kh*kw-times-larger im2col patch tensor (K = 9*C at 3x3).
FAMILIES = {
    "cnn": (           # VGG event-hot tail (8x8x128 convs) + EAFC-style
                       # fused fc head, T=2
        ("conv1", "conv", (2, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("conv2", "conv", (2, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("conv3", "conv", (2, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("fc_head", "matmul", (2, 2, 64, 512), (512, 128)),
    ),
    "spikingformer": (                        # SPS tail + encoder FFN, T=4
        ("sps_conv", "conv", (4, 2, 8, 8, 128), (3, 3, 128, 128)),
        ("fc1", "matmul", (4, 2, 64, 512), (512, 128)),
        ("fc2", "matmul", (4, 2, 64, 512), (512, 128)),
    ),
}
ITERS = 24   # CPU wall-clock needs more samples than the op sweeps


def _time_min(fn, *args, iters=ITERS, warmup=2):
    """Best-of-N wall seconds (stable for the small pre-pass probes)."""
    import time
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_pair(fn_a, fn_b, *args, iters=ITERS, warmup=2):
    """Paired measurement for two routes whose difference (a few ms of
    metadata work) is an order of magnitude below their totals: samples
    are INTERLEAVED (so load drift biases both routes the same way) with
    the order ALTERNATED per iteration (cancels the measured ~4%
    first-in-pair cache advantage), and each route reports its MINIMUM —
    this host's cgroup scheduling inserts multi-ms stalls that corrupt
    means and medians, while the per-route minimum is the reproducible
    unthrottled cost. Returns (min_a, min_b, min_b/min_a)."""
    import time

    def one(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        return time.perf_counter() - t0

    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ts_a, ts_b = [], []
    for i in range(iters):
        if i % 2 == 0:
            ts_a.append(one(fn_a))
            ts_b.append(one(fn_b))
        else:
            ts_b.append(one(fn_b))
            ts_a.append(one(fn_a))
    return min(ts_a), min(ts_b), min(ts_b) / min(ts_a)


def _stage_drive(key, kind, shape, sparsity):
    t = shape[0]
    k = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    pattern = clustered_spikes(key, rows, k, sparsity, block_m=128,
                               block_k=min(128, k))
    return (pattern * LIF.v_th).reshape(shape)


def _consume(kind, s, w):
    """The layer's event op on spikes-or-EventTensor (csr family pinned
    by the caller): conv folds (T, B) into the batch like models/cnn."""
    if kind == "conv":
        from repro.core.econv import econv
        flat = s.reshape((-1,) + s.shape[2:])
        return econv(flat, w)
    return dispatch.spike_matmul(s, w)


# Jitted producers (one compile per drive shape): the fire stage is the
# same compiled scan in both variants — `carried` additionally emits the
# map inside the same jit, `rederive` leaves the consumer to re-derive it
# eagerly from the dense spikes (the serve-path calling convention, where
# concrete maps buy the trimmed eager CSR grid).
@jax.jit
def _produce_carried(drive):
    return lif_fire_events(drive, LIF)


@jax.jit
def _produce_dense(drive):
    return dispatch.lif_scan(drive)


def _forward(drives, stages, carried: bool):
    outs = []
    for (name, kind, _, w), drive in zip(stages, drives):
        s = _produce_carried(drive) if carried else _produce_dense(drive)
        outs.append(_consume(kind, s, w))
    return outs


def _layer_prepass_seconds(kind, drive, w):
    """What the re-derive route pays per call for THIS layer: the dense
    `tile_occupancy` read of the consumer operand (im2col patches for
    convs) plus the eager CSR compaction."""
    s = _produce_dense(drive)
    if kind == "conv":
        flat = s.reshape((-1,) + s.shape[2:])
        kh, kw = w.shape[:2]
        operand = jax.lax.conv_general_dilated_patches(
            flat, (kh, kw), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        operand = operand.reshape(-1, operand.shape[-1])
    else:
        operand = s.reshape(-1, s.shape[-1])

    def prepass(x):
        return build_csr(ops.padded_occupancy(x), 128, 128)

    return _time_min(prepass, operand)


def run() -> list[str]:
    rows = []
    platform = jax.default_backend()
    csr = "pallas-csr" if platform == "tpu" else "pallas-csr-interpret"
    for family, spec in FAMILIES.items():
        stages = [(n, kind, shape,
                   jax.random.normal(jax.random.PRNGKey(i + 1),
                                     wshape, jnp.float32) * 0.05)
                  for i, (n, kind, shape, wshape) in enumerate(spec)]
        for sparsity in SPARSITIES:
            key = jax.random.PRNGKey(int(sparsity * 1000))
            drives = [
                _stage_drive(jax.random.fold_in(key, i), kind, shape,
                             sparsity)
                for i, (_, kind, shape, _w) in enumerate(stages)]
            with dispatch.use_backend(csr, op="spike_matmul"), \
                    dispatch.use_backend(csr, op="econv"):
                # value parity guard: same spikes, same kernels — the two
                # routes must agree before their timings mean anything
                for oc, od in zip(_forward(drives, stages, True),
                                  _forward(drives, stages, False)):
                    np.testing.assert_allclose(np.asarray(oc),
                                               np.asarray(od), atol=1e-4)
                # Per-layer paired timing, summed to the network total:
                # each layer's two routes are measured interleaved under
                # identical cache/scheduler conditions (a monolithic
                # whole-pipeline call lets allocator/cache interactions
                # between unrelated layers leak into the few-ms metadata
                # delta being measured).
                t_carried = t_rederive = 0.0
                fields = []
                for stage, d in zip(stages, drives):
                    a, b, _ = _time_pair(
                        lambda dd, st=stage: _forward([dd], [st], True),
                        lambda dd, st=stage: _forward([dd], [st], False), d)
                    t_carried += a * 1e6
                    t_rederive += b * 1e6
                    name, kind, _, w = stage
                    pre = _layer_prepass_seconds(kind, d, w) * 1e6
                    fields.append((name, a * 1e6, b * 1e6, pre))
                shares = ";".join(
                    f"prepass_share_{name}="
                    f"{pre / max(t_rederive, 1e-9):.3f}"
                    for name, _, _, pre in fields)
                layer_cols = ";".join(
                    f"us_{name}={ca:.0f}/{re:.0f}"
                    for name, ca, re, _ in fields)
            pct = int(sparsity * 100)
            common = f"platform={platform};backend={csr};layers={len(stages)}"
            rows.append(csv_row(f"e2e_event/{family}/carried/s{pct}",
                                t_carried, f"{common};occupancy=carried"))
            rows.append(csv_row(f"e2e_event/{family}/rederive/s{pct}",
                                t_rederive,
                                f"{common};occupancy=rederived;{shares};"
                                f"{layer_cols}"))
            rows.append(csv_row(
                f"e2e_event/{family}/speedup/s{pct}", 0.0,
                f"carried_speedup="
                f"{t_rederive / max(t_carried, 1e-9):.3f};{common}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
